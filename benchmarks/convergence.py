"""Paper Fig. 4: convergence — first-round accuracy should *increase* with
non-IID severity (the confidence/skew relationship §6.7)."""
from __future__ import annotations

import json
from pathlib import Path

import jax

from benchmarks import common
from repro.core import federation

ART = Path(__file__).resolve().parent / "artifacts"


def run(dataset: str = "synthmnist", seed: int = 0,
        scale: common.Scale | None = None, data_dir: str | None = None,
        encoding: str = "bool") -> dict:
    scale = scale or common.Scale(rounds=3)
    # the pool is experiment-independent: ingest once, partition per exp
    dcfg = common.load_pool(dataset, scale, seed, data_dir=data_dir,
                            encoding=encoding)
    if dcfg.writers is not None:
        raise ValueError(
            f"{dataset!r} partitions writer-naturally — the convergence "
            f"sweep varies the Dirichlet experiment axis, which does "
            f"not apply; use an IDX flavour")
    tm_cfg = common.bench_tm_config(dataset, dcfg, scale)
    first_round = {}
    curves = {}
    for exp in (1, 2, 3, 4, 5):
        data = common.partition_pool(dcfg, exp, scale, seed)
        fed_cfg = federation.FedConfig(n_clients=scale.n_clients,
                                       rounds=scale.rounds,
                                       local_epochs=scale.local_epochs)
        _, hist = federation.run(data, tm_cfg, fed_cfg,
                                 jax.random.PRNGKey(seed + exp))
        accs = [round(float(h.mean_accuracy), 4) for h in hist]
        first_round[exp] = accs[0]
        curves[exp] = accs
        print(f"convergence exp{exp}: {accs}", flush=True)
    out = {"dataset": dataset, "first_round_acc": first_round,
           "curves": curves,
           "claim_exp5_first_round_is_max":
               first_round[5] == max(first_round.values())}
    ART.mkdir(exist_ok=True)
    (ART / "convergence.json").write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    run()
