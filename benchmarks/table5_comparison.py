"""Paper Table 5: TPFL vs FedAvg / FedProx / IFCA / FLIS-DC / FLIS-HC /
FedTM under the fully non-IID setup (experiment 5), accuracy +
per-model upload cost.

All seven method rows run through the federated runtime engine — one
``Strategy`` each, under the same scheduler — so every communication
column is metered byte-exact from the wire codec's encoded buffers
(``len(buffer)``, not arithmetic).  FLIS runs its dynamic per-round
clustering as the engine's server-side ``assign`` hook (DC and HC
flavours, capped at ``flis_max_slots`` server rows); FedTM is the
one-slot full-weight TM strategy on the same ``tm.py`` parameters as
TPFL.  The straight-line loops in ``core/baselines.py`` are no longer
run here — they are the bit-parity references the conformance suite
pins these engine rows against.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from benchmarks import common
from repro.core import baselines, federation
from repro.fl.runtime import Engine, FedTMStrategy, RuntimeConfig
from repro.fl.runtime.strategy import build_baseline_strategy

ART = Path(__file__).resolve().parent / "artifacts"

ENGINE_BASELINES = ("fedavg", "fedprox", "ifca", "flis_dc", "flis_hc")


def _run_engine(strat, data, scale, key, backend: str) -> tuple:
    engine = Engine(strat, data, RuntimeConfig(rounds=scale.rounds,
                                               backend=backend))
    _, reports = engine.run(key)
    accs = [float(r.mean_accuracy) for r in reports]
    up = sum(r.upload_bytes for r in reports) / 1e6
    down = sum(r.download_bytes_per_client for r in reports) / 1e6
    return accs, up, down


def run(dataset: str = "synthmnist", scale: common.Scale | None = None,
        seed: int = 0, backend: str = "inprocess",
        data_dir: str | None = None, encoding: str = "bool") -> list[dict]:
    """``backend="shardmap"``: every row's sync rounds run shard-mapped
    over a ``clients`` mesh (bit-identical numbers — the conformance
    contract).  ``data_dir`` routes the dataset through the ingest
    cache — real files when present, the offline mirror otherwise."""
    scale = scale or common.Scale()
    data, dcfg = common.make_fed_dataset(dataset, 5, scale, seed,
                                         data_dir=data_dir,
                                         encoding=encoding)
    tm_cfg = common.bench_tm_config(dataset, dcfg, scale)
    rows = []

    def add(name, accs, up_mb, down_mb, t0):
        per_model = up_mb / scale.n_clients / scale.rounds
        rows.append({"method": name,
                     "accuracy": round(accs[-1], 4),
                     "acc_per_round": [round(a, 4) for a in accs],
                     "upload_mb_total": round(up_mb, 5),
                     "download_mb_total": round(down_mb, 5),
                     "upload_mb_per_model_round": round(per_model, 6),
                     "wall_s": round(time.time() - t0, 1)})
        print(f"table5 {name}: acc={rows[-1]['accuracy']} "
              f"up/model/round={per_model*1000:.3f}KB", flush=True)

    # TPFL through the runtime (sync, full participation, float32 wire)
    t0 = time.time()
    fed_cfg = federation.FedConfig(n_clients=scale.n_clients,
                                   rounds=scale.rounds,
                                   local_epochs=scale.local_epochs)
    _, hist = federation.run(data, tm_cfg, fed_cfg, jax.random.PRNGKey(1),
                             runtime_cfg=RuntimeConfig(backend=backend))
    up, down = federation.total_comm_mb(hist)
    add("tpfl", [float(h.mean_accuracy) for h in hist], up, down, t0)

    # hyperparameters come from the same BaselineConfig as the reference
    # loops the conformance suite pins, so Table 5 stays apples-to-apples
    bcfg = baselines.BaselineConfig(
        n_clients=scale.n_clients, rounds=scale.rounds,
        local_epochs=scale.local_epochs, ifca_k=min(10, dcfg.n_classes))

    # engine-run baselines (byte-exact metering, same scheduler) —
    # including FLIS, whose dynamic clustering is the assign hook
    for name in ENGINE_BASELINES:
        t0 = time.time()
        strat = build_baseline_strategy(
            name, n_features=dcfg.n_features, n_classes=dcfg.n_classes,
            n_hidden=bcfg.n_hidden, local_epochs=bcfg.local_epochs,
            batch=bcfg.batch, lr=bcfg.lr, prox_mu=bcfg.prox_mu,
            ifca_k=bcfg.ifca_k, max_slots=bcfg.flis_max_slots,
            probe_size=bcfg.flis_probe,
            flis_threshold=bcfg.flis_threshold)
        accs, up, down = _run_engine(strat, data, scale,
                                     jax.random.PRNGKey(2), backend)
        add(name, accs, up, down, t0)

    # FedTM: full-weight TM averaging on the engine, same TM as TPFL
    t0 = time.time()
    accs, up, down = _run_engine(
        FedTMStrategy(tm_cfg, local_epochs=scale.local_epochs), data,
        scale, jax.random.PRNGKey(3), backend)
    add("fedtm", accs, up, down, t0)

    ART.mkdir(exist_ok=True)
    (ART / "table5_comparison.json").write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    run()
