"""Paper Table 4 / Fig. 4 / Fig. 5: TPFL accuracy + communication under the
5 experimental setups, per dataset — run through the federated runtime.

Validated claims (trends; absolute MNIST numbers are gated on real data —
DESIGN.md §2): accuracy rises with non-IID severity, upload cost is flat
(one weight vector per client·round), download cost grows with the number
of populated clusters.  Communication columns are metered byte-exact from
the wire codec's actual encoded buffers (``float32`` reproduces the
paper's §6.7 arithmetic; ``int8``/``int4`` show the quantized-uplink
variants); paper-scale columns use the exact formulas.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from benchmarks import common
from repro.core import federation
from repro.fl.runtime import CodecConfig, RuntimeConfig

ART = Path(__file__).resolve().parent / "artifacts"


def run(datasets=("synthmnist", "synthfashion"),
        experiments=(1, 3, 5), scale: common.Scale | None = None,
        seed: int = 0, codecs=("float32", "int8"),
        backend: str = "inprocess", data_dir: str | None = None,
        encoding: str = "bool") -> list[dict]:
    """``backend="shardmap"`` runs every cell's sync round shard-mapped
    over a ``clients`` mesh of all visible devices — same numbers
    (conformance-pinned bit-exact), mesh execution path.  ``data_dir``
    routes the datasets through the ingest cache (real IDX/LEAF files
    when present, the offline mirror otherwise): with real MNIST /
    FashionMNIST dropped in, these cells are the paper's absolute
    Table-4 numbers."""
    scale = scale or common.Scale()
    rows = []
    for name in datasets:
        # the pool is experiment-independent: ingest once per dataset
        dcfg = common.load_pool(name, scale, seed, data_dir=data_dir,
                                encoding=encoding)
        # writer-natural pools have one split — the experiment axis
        # (fraction of simulated non-IID clients) does not apply
        exps = experiments if dcfg.writers is None else ("natural",)
        for exp in exps:
            data = common.partition_pool(
                dcfg, exp if exp != "natural" else 1, scale, seed)
            tm_cfg = common.bench_tm_config(name, dcfg, scale)
            fed_cfg = federation.FedConfig(
                n_clients=scale.n_clients, rounds=scale.rounds,
                local_epochs=scale.local_epochs)
            for codec in codecs:
                rt_cfg = RuntimeConfig(codec=CodecConfig(codec),
                                       backend=backend)
                t0 = time.time()
                _, hist = federation.run(data, tm_cfg, fed_cfg,
                                         jax.random.PRNGKey(seed + 7),
                                         runtime_cfg=rt_cfg)
                up, down = federation.total_comm_mb(hist)
                rows.append({
                    "dataset": name, "experiment": exp, "codec": codec,
                    "backend": backend,
                    "accuracy": round(float(hist[-1].mean_accuracy), 4),
                    "acc_per_round": [round(float(h.mean_accuracy), 4)
                                      for h in hist],
                    "upload_mb": round(up, 5),
                    "download_mb": round(down, 5),
                    "clusters_final": int((hist[-1].cluster_counts
                                           > 0).sum()),
                    "paper_scale": common.paper_scale_comm_mb(
                        name, dcfg.n_classes),
                    "wall_s": round(time.time() - t0, 1),
                })
                print(f"table4 {name} "
                      f"{exp if exp == 'natural' else f'exp{exp}'} "
                      f"[{codec}]: "
                      f"acc={rows[-1]['accuracy']} "
                      f"up={rows[-1]['upload_mb']}MB "
                      f"down={rows[-1]['download_mb']}MB "
                      f"({rows[-1]['wall_s']}s)", flush=True)
    ART.mkdir(exist_ok=True)
    (ART / "table4_tpfl.json").write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    run()
