"""Shared benchmark scale settings.

Paper scale (100 clients × 30000 samples × 10 epochs × 10 rounds) is CPU-
prohibitive; benchmarks run a proportionally reduced federation (same
code paths, same formulas) and report both the measured numbers and the
paper-scale extrapolation of the *exact* communication formulas.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import tm
from repro.data.ingest import natural, registry as datasets


@dataclasses.dataclass(frozen=True)
class Scale:
    n_clients: int = 20
    n_train: int = 80
    n_test: int = 40
    n_conf: int = 40
    rounds: int = 5
    local_epochs: int = 3
    side: int = 12               # 12×12 synthetic images
    pool: int = 6000


PAPER_TM = {
    # dataset → (clauses, s, T) per paper Table 2
    "synthmnist": (300, 10.0, 1000),
    "synthfashion": (500, 10.0, 1000),
    "synthfemnist": (500, 10.0, 1000),
}

BENCH_TM = {
    # reduced clause counts at bench scale (same ratios)
    "synthmnist": (48, 5.0, 40),
    "synthfashion": (64, 5.0, 40),
    "synthfemnist": (64, 5.0, 40),
}

# real flavours share the TM hyperparameters of their synthetic mirror
_TM_KEY = {"mnist": "synthmnist", "fashionmnist": "synthfashion",
           "femnist": "synthfemnist"}


def load_pool(name: str, scale: Scale, seed: int = 0,
              data_dir: str | None = None, encoding: str = "bool"):
    """The encoded global Pool — depends only on (name, data_dir,
    encoding, seed, scale geometry), so benchmarks hoist it out of
    their per-experiment loops."""
    return datasets.load(name, data_dir=data_dir, encoding=encoding,
                         n_samples=scale.pool, side=scale.side, seed=seed)


def partition_pool(pool, experiment: int, scale: Scale, seed: int = 0):
    """Pool → ClientData at bench scale — the shared ingest dispatch
    (natural writer split for writer-tagged pools, Dirichlet
    otherwise), keyed the way every benchmark seeds it."""
    return natural.partition_pool(
        pool, n_clients=scale.n_clients, n_train=scale.n_train,
        n_test=scale.n_test, n_conf=scale.n_conf,
        key=jax.random.PRNGKey(seed + 1), experiment=experiment)


def make_fed_dataset(name: str, experiment: int, scale: Scale,
                     seed: int = 0, data_dir: str | None = None,
                     encoding: str = "bool"):
    """(ClientData, Pool) for any registry flavour — one-shot
    convenience over :func:`load_pool` + :func:`partition_pool`.  The
    returned Pool carries ``n_classes`` / ``n_features`` for model
    sizing."""
    pool = load_pool(name, scale, seed, data_dir, encoding)
    return partition_pool(pool, experiment, scale, seed), pool


def bench_tm_config(name: str, pool, scale: Scale) -> tm.TMConfig:
    m, s, T = BENCH_TM[_TM_KEY.get(name, name)]
    return tm.TMConfig(n_classes=pool.n_classes, n_clauses=m,
                       n_features=pool.n_features, n_states=63, s=s, T=T)


def paper_scale_comm_mb(name: str, n_classes: int) -> dict:
    """Exact paper-scale communication formulas (Table 4/5 columns)."""
    m, _, _ = PAPER_TM[_TM_KEY.get(name, name)]
    clients, rounds, bpw = 100, 10, 4
    tpfl_up = clients * rounds * (m * bpw + 4) / 1e6
    tpfl_down_max = n_classes * rounds * m * bpw / 1e6
    fedtm_up = clients * rounds * n_classes * m * bpw / 1e6
    return {"tpfl_upload_mb": round(tpfl_up, 3),
            "tpfl_download_mb_max": round(tpfl_down_max, 3),
            "fedtm_upload_mb": round(fedtm_up, 3),
            "tpfl_per_model_upload_mb": round(tpfl_up / clients, 4)}
