"""Shared benchmark scale settings.

Paper scale (100 clients × 30000 samples × 10 epochs × 10 rounds) is CPU-
prohibitive; benchmarks run a proportionally reduced federation (same
code paths, same formulas) and report both the measured numbers and the
paper-scale extrapolation of the *exact* communication formulas.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core import tm
from repro.data import partition, synthetic


@dataclasses.dataclass(frozen=True)
class Scale:
    n_clients: int = 20
    n_train: int = 80
    n_test: int = 40
    n_conf: int = 40
    rounds: int = 5
    local_epochs: int = 3
    side: int = 12               # 12×12 synthetic images
    pool: int = 6000


PAPER_TM = {
    # dataset → (clauses, s, T) per paper Table 2
    "synthmnist": (300, 10.0, 1000),
    "synthfashion": (500, 10.0, 1000),
    "synthfemnist": (500, 10.0, 1000),
}

BENCH_TM = {
    # reduced clause counts at bench scale (same ratios)
    "synthmnist": (48, 5.0, 40),
    "synthfashion": (64, 5.0, 40),
    "synthfemnist": (64, 5.0, 40),
}


def make_fed_dataset(name: str, experiment: int, scale: Scale,
                     seed: int = 0):
    x, y, dcfg = synthetic.make_dataset(name, scale.pool,
                                        jax.random.PRNGKey(seed),
                                        side=scale.side)
    data = partition.partition(
        x, y, dcfg.n_classes, n_clients=scale.n_clients,
        experiment=experiment, key=jax.random.PRNGKey(seed + 1),
        n_train=scale.n_train, n_test=scale.n_test, n_conf=scale.n_conf)
    return data, dcfg


def bench_tm_config(name: str, dcfg, scale: Scale) -> tm.TMConfig:
    m, s, T = BENCH_TM[name]
    return tm.TMConfig(n_classes=dcfg.n_classes, n_clauses=m,
                       n_features=dcfg.n_features, n_states=63, s=s, T=T)


def paper_scale_comm_mb(name: str, n_classes: int) -> dict:
    """Exact paper-scale communication formulas (Table 4/5 columns)."""
    m, _, _ = PAPER_TM[name]
    clients, rounds, bpw = 100, 10, 4
    tpfl_up = clients * rounds * (m * bpw + 4) / 1e6
    tpfl_down_max = n_classes * rounds * m * bpw / 1e6
    fedtm_up = clients * rounds * n_classes * m * bpw / 1e6
    return {"tpfl_upload_mb": round(tpfl_up, 3),
            "tpfl_download_mb_max": round(tpfl_down_max, 3),
            "fedtm_upload_mb": round(fedtm_up, 3),
            "tpfl_per_model_upload_mb": round(tpfl_up / clients, 4)}
