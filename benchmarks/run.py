"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract,
then the per-table JSON artifacts land in benchmarks/artifacts/.

  PYTHONPATH=src python -m benchmarks.run            # all benches
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced scale
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (ablation_multiclass, common, convergence,  # noqa: E402
                        kernel_bench, roofline, table4_tpfl,
                        table5_comparison)

ART = Path(__file__).resolve().parent / "artifacts"


def emit_bench(dataset: str, scale, backend: str,
               data_dir: str | None = None,
               encoding: str = "bool", rounds_timed: int = 5,
               warmup_rounds: int = 1) -> dict:
    """Per-strategy sync-round wall time → BENCH_round_latency.json.

    ``warmup_rounds`` warm-up rounds (compile + jit-cache fill) then
    the **median of ≥5 timed rounds** per strategy — each round
    bracketed by ``time.perf_counter`` with an explicit
    ``jax.block_until_ready`` fence on the round's output state, so a
    timing covers the device work, not just Python dispatch.  Each
    engine runs with a telemetry :class:`~repro.fl.obs.RunRecorder`
    (in-memory, no run dir), so the artifact also records the
    **per-phase wall-time breakdown** (median per phase over the timed
    rounds) — where round time actually goes, per strategy.

    Strategies come from the CLI's one name→Strategy factory
    (``fed_train._build_strategy`` over ``fed_train.STRATEGY_CHOICES``),
    so the bench can't drift from what ``fed_train`` runs.  The two TM
    strategies (tpfl, fedtm) are additionally timed per ``tm_backend``
    (the reference jnp path and the fused Pallas kernel path — same
    round outputs bit-for-bit, conformance-pinned), so the artifact
    carries the kernel-vs-ref perf trajectory.  CI's conformance-mesh-8
    job runs this with ``--mesh`` on the 8-device clients mesh and
    uploads the JSON as an artifact, so the perf trajectory of the
    shard-mapped round has real data points.

    Artifact schema: ``rounds_timed`` / ``warmup_rounds`` (ints),
    ``round_wall_s`` ({strategy: {tm_backend: median seconds}}),
    ``phase_wall_s`` ({strategy: {tm_backend: {phase: median
    seconds}}}).  MLP strategies have a ``ref`` entry only (the TM
    backend is a no-op for them)."""
    import statistics
    import time as _time

    import jax

    from repro.core import federation
    from repro.fl.obs import RunRecorder
    from repro.fl.runtime import Engine, RuntimeConfig
    from repro.launch import fed_train

    data, pool = common.make_fed_dataset(dataset, 5, scale, 0,
                                         data_dir=data_dir,
                                         encoding=encoding)
    tm_cfg = common.bench_tm_config(dataset, pool, scale)
    n_rounds = warmup_rounds + rounds_timed
    fed_cfg = federation.FedConfig(n_clients=scale.n_clients,
                                   rounds=n_rounds,
                                   local_epochs=scale.local_epochs)
    tm_strategies = ("tpfl", "fedtm")
    out = {"dataset": dataset, "backend": backend,
           "n_devices": len(jax.devices()),
           "n_clients": scale.n_clients,
           "rounds_timed": rounds_timed,
           "warmup_rounds": warmup_rounds,
           "round_wall_s": {}, "phase_wall_s": {}}
    for name in fed_train.STRATEGY_CHOICES:
        backends = ("ref", "pallas") if name in tm_strategies else ("ref",)
        out["round_wall_s"][name] = {}
        out["phase_wall_s"][name] = {}
        for tb in backends:
            strat = fed_train._build_strategy(name, tm_cfg, fed_cfg, pool)
            rec = RunRecorder()      # in-memory: phase spans, no run dir
            engine = Engine(strat, data,
                            RuntimeConfig(rounds=n_rounds, backend=backend,
                                          tm_backend=tb),
                            telemetry=rec)
            key = jax.random.PRNGKey(0)
            k_init, k_rounds = jax.random.split(key)
            state = engine.init(k_init)
            wall = []
            for r in range(n_rounds):
                t0 = _time.perf_counter()
                state, rep = engine.run_round(
                    state, jax.random.fold_in(k_rounds, r))
                jax.block_until_ready(state)
                dt = _time.perf_counter() - t0
                rec.on_round(rep)    # pops this round's phase spans
                if r >= warmup_rounds:
                    wall.append(dt)
            out["round_wall_s"][name][tb] = round(statistics.median(wall),
                                                  4)
            timed = rec.history[warmup_rounds:]
            phases: dict[str, list[float]] = {}
            for evt in timed:
                for ph, s in (evt["phases"] or {}).items():
                    phases.setdefault(ph, []).append(s)
            out["phase_wall_s"][name][tb] = {
                ph: round(statistics.median(v), 4)
                for ph, v in sorted(phases.items())}
            print(f"bench_round_latency,"
                  f"{out['round_wall_s'][name][tb]*1e6:.0f},"
                  f"strategy={name}/{tb}", flush=True)
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_round_latency.json").write_text(json.dumps(out, indent=2))
    return out


def emit_client_scale(ns=(1_000, 100_000, 1_000_000), k_active: int = 64,
                      rounds_timed: int = 2, warmup_rounds: int = 1,
                      data_dir: str | None = None) -> dict:
    """Round wall time + host-I/O bytes vs population size N →
    BENCH_client_scale.json — the O(K) working-set trajectory.

    Each point runs the mmap-store engine (``client_store="mmap"``,
    ``store_eval="sampled"``) over a streamed LEAF population of N
    simulated clients with K active per round: per-round wall time is
    ``perf_counter`` around ``run_round`` with a ``block_until_ready``
    fence (median of the timed rounds, after warm-up), and the host-I/O
    gauges come straight off the round report (``store_read_bytes`` /
    ``store_written_bytes`` — actual bytes the store read back and
    spilled).  The point of the trajectory: wall time and I/O are flat
    in N (they scale with K), while ``resident_rows`` shows how few of
    the N rows ever materialize.

    Artifact schema: ``k_active``, ``rounds_timed``, ``warmup_rounds``,
    and ``scales`` — one row per N with ``n_clients``, ``k_active``,
    ``round_wall_s``, ``store_read_bytes``, ``store_written_bytes``,
    ``store_row_bytes``, ``resident_rows``."""
    import statistics
    import tempfile
    import time as _time

    import jax

    from repro.core import tm
    from repro.data.ingest import registry as datasets
    from repro.fl.runtime import Engine, RuntimeConfig, SchedulerConfig
    from repro.fl.runtime.strategy import TPFLStrategy
    from repro.fl.store import StreamingClientData

    root = data_dir or tempfile.mkdtemp(prefix="client_scale_data_")
    spool = datasets.load_stream("synthfemnist", root, side=8,
                                 n_samples=600, seed=0, n_writers=12)
    tm_cfg = tm.TMConfig(n_classes=spool.n_classes, n_clauses=8,
                         n_features=spool.n_features, n_states=63,
                         s=5.0, T=8)
    scales = []
    for n in ns:
        n = int(n)
        k = min(k_active, n)
        sdata = StreamingClientData(spool, n_clients=n, n_train=16,
                                    n_test=8, n_conf=8,
                                    key=jax.random.PRNGKey(1))
        engine = Engine(
            TPFLStrategy(tm_cfg, local_epochs=1), sdata,
            RuntimeConfig(
                rounds=warmup_rounds + rounds_timed,
                scheduler=SchedulerConfig(participation=k / n),
                client_store="mmap",
                store_dir=tempfile.mkdtemp(prefix=f"client_store_{n}_"),
                store_eval="sampled"))
        k_init, k_rounds = jax.random.split(jax.random.PRNGKey(0))
        state = engine.init(k_init)
        wall, rd, wr = [], [], []
        for r in range(warmup_rounds + rounds_timed):
            t0 = _time.perf_counter()
            state, rep = engine.run_round(
                state, jax.random.fold_in(k_rounds, r))
            jax.block_until_ready(state)
            dt = _time.perf_counter() - t0
            if r >= warmup_rounds:
                wall.append(dt)
                rd.append(rep.store_read_bytes)
                wr.append(rep.store_written_bytes)
        scales.append({
            "n_clients": n, "k_active": engine.scheduler.k,
            "round_wall_s": round(statistics.median(wall), 4),
            "store_read_bytes": int(statistics.median(rd)),
            "store_written_bytes": int(statistics.median(wr)),
            "store_row_bytes": engine.store.row_nbytes,
            "resident_rows": engine.store.written_count()})
        print(f"bench_client_scale,"
              f"{scales[-1]['round_wall_s']*1e6:.0f},"
              f"n={n}/k={engine.scheduler.k}", flush=True)
    out = {"dataset": "synthfemnist", "k_active": k_active,
           "rounds_timed": rounds_timed, "warmup_rounds": warmup_rounds,
           "scales": scales}
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_client_scale.json").write_text(json.dumps(out, indent=2))
    return out


def emit_serve_bench(dataset: str, scale, data_dir: str | None = None,
                     encoding: str = "bool",
                     batch_sizes=(1, 8, 32), requests_timed: int = 10,
                     warmup_requests: int = 3,
                     train_rounds: int = 2) -> dict:
    """Serving-plane latency → BENCH_serve_latency.json — the repo's
    second perf trajectory file.

    Trains a small TPFL population for ``train_rounds`` rounds,
    publishes the checkpoint into a fresh
    :class:`~repro.fl.serve.ModelRegistry`, then serves mixed-cluster
    batches through a :class:`~repro.fl.serve.ServingPlane` per TM
    backend (``ref`` and ``pallas`` — bit-identical predictions,
    conformance-pinned) across a batch-size sweep.  Per (backend,
    batch) cell: ``warmup_requests`` warm-up batches (compile) then
    ``requests_timed`` batches bracketed by ``perf_counter`` — the
    plane's prediction is materialized to host, so a timing covers the
    device work — reported as p50/p99 batch latency and sustained
    requests/sec.

    Artifact schema: ``batch_sizes`` (list), ``latency_s``
    ({backend: {batch: {p50, p99}}}), ``requests_per_s``
    ({backend: {batch: float}})."""
    import statistics
    import tempfile
    import time as _time

    import jax
    import numpy as np

    from repro.core import federation
    from repro.fl.runtime import Engine, RuntimeConfig, checkpointing
    from repro.fl.serve import ModelRegistry, ServingPlane
    from repro.launch import fed_train

    data, pool = common.make_fed_dataset(dataset, 5, scale, 0,
                                         data_dir=data_dir,
                                         encoding=encoding)
    tm_cfg = common.bench_tm_config(dataset, pool, scale)
    fed_cfg = federation.FedConfig(n_clients=scale.n_clients,
                                   rounds=train_rounds,
                                   local_epochs=scale.local_epochs)
    strat = fed_train._build_strategy("tpfl", tm_cfg, fed_cfg, pool)
    root = Path(tempfile.mkdtemp(prefix="serve_bench_"))
    engine = Engine(strat, data,
                    RuntimeConfig(rounds=train_rounds,
                                  checkpoint_dir=str(root / "ckpt"),
                                  checkpoint_every=train_rounds))
    engine.run(jax.random.PRNGKey(0))
    registry = ModelRegistry(root / "registry")
    registry.publish(checkpointing.latest(root / "ckpt"))

    n, n_test = scale.n_clients, scale.n_test
    x_test = np.asarray(data.x_test)
    out = {"dataset": dataset, "n_clients": n,
           "requests_timed": requests_timed,
           "warmup_requests": warmup_requests,
           "batch_sizes": list(batch_sizes),
           "latency_s": {}, "requests_per_s": {}}
    for tb in ("ref", "pallas"):
        serve_engine = Engine(strat, data, RuntimeConfig(tm_backend=tb))
        like = serve_engine.init(
            jax.random.split(jax.random.PRNGKey(0))[0])
        plane = ServingPlane(serve_engine.strategy, registry, like)
        plane.refresh()
        out["latency_s"][tb] = {}
        out["requests_per_s"][tb] = {}
        for bs in batch_sizes:
            lat = []
            for r in range(warmup_requests + requests_timed):
                ids = (np.arange(bs) * 7 + r) % n
                x = x_test[ids, (r + np.arange(bs)) % n_test]
                t0 = _time.perf_counter()
                plane.predict(ids, x)   # materializes to host (fenced)
                if r >= warmup_requests:
                    lat.append(_time.perf_counter() - t0)
            lat.sort()
            p50 = statistics.median(lat)
            p99 = lat[min(len(lat) - 1, round(0.99 * (len(lat) - 1)))]
            rps = bs * len(lat) / sum(lat)
            out["latency_s"][tb][str(bs)] = {"p50": round(p50, 6),
                                             "p99": round(p99, 6)}
            out["requests_per_s"][tb][str(bs)] = round(rps, 1)
            print(f"bench_serve_latency,{p50*1e6:.0f},"
                  f"backend={tb}/batch={bs}/rps={rps:.0f}", flush=True)
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_serve_latency.json").write_text(json.dumps(out, indent=2))
    return out


def emit_wire_bench(rounds: int = 3, clients: int = 6,
                    socket_workers: int = 2) -> dict:
    """Wire-cost trajectory → BENCH_wire_bytes.json.

    Two sweeps over one small synthmnist federation:

    1. **bytes/round** per strategy × codec × compression-v2 on/off —
       the engine's codec-metered upload / download totals of the last
       round (steady state: round 0 can be cheaper while reference rows
       warm up).  v2 means error-feedback residuals on the lossy dense
       codecs and varint+RLE index coding on the sparse-delta path
       (``docs/transport.md``); float32 has no v2 variant (bit-exact,
       nothing to feed back).
    2. **socket round latency vs in-process** — the same tpfl/float32
       scenario through the in-process engine and through the real
       multi-process socket transport (``socket_workers`` worker
       subprocesses on the length-prefixed local-TCP wire), median of
       the telemetry tracer's per-round ``round`` spans (worker launch
       and jax warm-up excluded from per-round medians by taking the
       median, which discards the compile-heavy first round).

    Artifact schema: ``wire_bytes`` ({strategy: {codec_label: {v1|v2:
    {upload_bytes, download_broadcast, download_per_client}}}}),
    ``socket_latency_s`` ({inprocess, socket, workers})."""
    import statistics

    import jax

    from repro.fl.obs import RunRecorder
    from repro.fl.runtime import CodecConfig, Engine, RuntimeConfig
    from repro.fl.transport import TransportEngine
    from repro.launch import fed_train

    scen_kw = dict(dataset="synthmnist", clients=clients, clauses=16,
                   seed=0, rounds=rounds, local_epochs=1)
    _, data, _, _, _ = fed_train.build_scenario(**scen_kw)
    key = jax.random.PRNGKey(0)

    codec_grid = {
        "float32": {"v1": CodecConfig("float32")},
        "int8": {"v1": CodecConfig("int8"),
                 "v2": CodecConfig("int8", error_feedback=True)},
        "int4": {"v1": CodecConfig("int4"),
                 "v2": CodecConfig("int4", error_feedback=True)},
        "int8_sparse": {"v1": CodecConfig("int8", sparse=True),
                        "v2": CodecConfig("int8", sparse=True,
                                          error_feedback=True,
                                          index_coding="vrle")},
    }
    out = {"dataset": "synthmnist", "n_clients": clients,
           "rounds": rounds, "wire_bytes": {}, "socket_latency_s": {}}
    for strat_name in ("tpfl", "fedavg", "flis_dc"):
        out["wire_bytes"][strat_name] = {}
        for label, variants in codec_grid.items():
            out["wire_bytes"][strat_name][label] = {}
            for variant, ccfg in variants.items():
                strat = fed_train.build_scenario(
                    **{**scen_kw, "strategy": strat_name})[4]
                eng = Engine(strat, data,
                             RuntimeConfig(rounds=rounds, codec=ccfg))
                _, reps = eng.run(key)
                last = reps[-1]
                out["wire_bytes"][strat_name][label][variant] = {
                    "upload_bytes": last.upload_bytes,
                    "download_broadcast": last.download_bytes_broadcast,
                    "download_per_client": last.download_bytes_per_client,
                }
                print(f"bench_wire_bytes,{last.upload_bytes},"
                      f"strategy={strat_name}/codec={label}/{variant}",
                      flush=True)

    def _round_median(run_fn):
        rec = RunRecorder()          # in-memory: per-round phase spans
        run_fn(rec)
        spans = [ev["phases"]["round"] for ev in rec.history
                 if ev.get("phases") and "round" in ev["phases"]]
        return round(statistics.median(spans), 4)

    _, data2, _, _, strat = fed_train.build_scenario(**scen_kw)
    out["socket_latency_s"]["inprocess"] = _round_median(
        lambda rec: Engine(strat, data2, RuntimeConfig(rounds=rounds),
                           telemetry=rec).run(key))
    out["socket_latency_s"]["workers"] = socket_workers
    out["socket_latency_s"]["socket"] = _round_median(
        lambda rec: TransportEngine(
            strat, data2,
            RuntimeConfig(rounds=rounds, transport="socket",
                          workers=socket_workers),
            telemetry=rec, spec={"scenario": scen_kw}).run(key))
    print(f"bench_wire_latency,"
          f"{out['socket_latency_s']['socket']*1e6:.0f},"
          f"socket_vs_inprocess="
          f"{out['socket_latency_s']['socket']:.3f}s/"
          f"{out['socket_latency_s']['inprocess']:.3f}s", flush=True)
    ART.mkdir(exist_ok=True)
    (ART / "BENCH_wire_bytes.json").write_text(json.dumps(out, indent=2))
    return out


def main() -> None:
    from repro.data.ingest import registry as datasets

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="run table4/table5 federations shard-mapped "
                         "over a clients mesh of all visible devices")
    ap.add_argument("--datasets", default="synthmnist,synthfashion",
                    help="comma-separated table4 dataset flavours "
                         f"(registry names: {', '.join(datasets.names())};"
                         " table5 uses the first)")
    ap.add_argument("--data-dir", default=None,
                    help="ingest cache for table4/table5 (offline mirror"
                         " / real IDX+LEAF files — docs/datasets.md); "
                         "required for the real flavours")
    ap.add_argument("--encoding", default="bool",
                    help="feature encoding spec, e.g. bool | "
                         "thermometer:4 | quantile:8")
    ap.add_argument("--emit-bench", action="store_true",
                    help="only run the round-latency bench: per "
                         "strategy (and per tm_backend — ref and "
                         "pallas — for tpfl/fedtm), 1 warm-up round "
                         "then the median of 5 perf_counter-timed, "
                         "block_until_ready-fenced sync rounds, plus "
                         "the per-phase wall-time breakdown from the "
                         "telemetry tracer — written to artifacts/"
                         "BENCH_round_latency.json (rounds_timed, "
                         "warmup_rounds, round_wall_s, phase_wall_s, "
                         "both keyed {strategy: {tm_backend: ...}}; "
                         "the conformance-mesh-8 CI artifact); also "
                         "emits the client-scale trajectory")
    ap.add_argument("--emit-client-scale", action="store_true",
                    help="only run the client-scale bench: mmap-store "
                         "engine over a streamed synthfemnist "
                         "population, K active of N total — per N, "
                         "1 warm-up round then the median of 2 "
                         "perf_counter-timed rounds plus the store's "
                         "host-I/O byte gauges — written to artifacts/"
                         "BENCH_client_scale.json (the client-scale "
                         "CI artifact)")
    ap.add_argument("--emit-serve-bench", action="store_true",
                    help="only run the serving-plane bench: train a "
                         "small TPFL population, publish its checkpoint "
                         "into a registry, then serve mixed-cluster "
                         "batches per TM backend (ref, pallas) across a "
                         "batch-size sweep — p50/p99 batch latency and "
                         "sustained requests/sec — written to artifacts/"
                         "BENCH_serve_latency.json (the serve CI "
                         "artifact)")
    ap.add_argument("--emit-wire-bench", action="store_true",
                    help="only run the wire-cost bench: bytes/round per "
                         "strategy × codec × compression-v2 on/off "
                         "(error-feedback residuals, varint+RLE sparse "
                         "indices), plus socket-transport round latency "
                         "vs in-process — written to artifacts/"
                         "BENCH_wire_bytes.json (the transport CI "
                         "artifact)")
    ap.add_argument("--client-scale-ns", default=None,
                    help="comma-separated population sizes for the "
                         "client-scale bench (default "
                         "1000,100000,1000000; --quick default "
                         "1000,10000)")
    args = ap.parse_args()
    backend = "shardmap" if args.mesh else "inprocess"
    wanted = [n.strip() for n in args.datasets.split(",") if n.strip()]
    if not wanted:
        ap.error("--datasets needs at least one registry name")
    try:
        table_datasets = tuple(datasets.get(n).name for n in wanted)
    except ValueError as e:
        ap.error(str(e))
    if args.data_dir is None:
        file_backed = [n for n in table_datasets
                       if n in datasets.REAL_DATASETS]
        if file_backed:
            ap.error(f"--data-dir is required for the real flavours: "
                     f"{', '.join(file_backed)}")

    scale = common.Scale(n_clients=10, n_train=40, n_test=20, n_conf=20,
                         rounds=2, local_epochs=1) if args.quick \
        else common.Scale()

    if args.client_scale_ns is not None:
        scale_ns = tuple(int(s) for s in args.client_scale_ns.split(","))
    else:
        scale_ns = (1_000, 10_000) if args.quick \
            else (1_000, 100_000, 1_000_000)

    if args.emit_client_scale:
        print("name,us_per_call,derived")
        emit_client_scale(ns=scale_ns)
        return

    if args.emit_wire_bench:
        print("name,us_per_call,derived")
        emit_wire_bench(rounds=2 if args.quick else 3)
        return

    if args.emit_serve_bench:
        print("name,us_per_call,derived")
        emit_serve_bench(table_datasets[0], scale,
                         data_dir=args.data_dir, encoding=args.encoding,
                         requests_timed=5 if args.quick else 10)
        return

    if args.emit_bench:
        print("name,us_per_call,derived")
        emit_bench(table_datasets[0], scale, backend,
                   data_dir=args.data_dir, encoding=args.encoding)
        emit_client_scale(ns=scale_ns)
        return

    print("name,us_per_call,derived")
    for row in kernel_bench.run():
        print(row)

    t0 = time.time()
    rows4 = table4_tpfl.run(datasets=table_datasets, scale=scale,
                            backend=backend, data_dir=args.data_dir,
                            encoding=args.encoding)
    print(f"table4_tpfl,{(time.time()-t0)*1e6/max(len(rows4),1):.0f},"
          f"rows={len(rows4)}")

    t0 = time.time()
    rows5 = table5_comparison.run(dataset=table_datasets[0], scale=scale,
                                  backend=backend, data_dir=args.data_dir,
                                  encoding=args.encoding)
    best = max(rows5, key=lambda r: r["accuracy"])
    print(f"table5_comparison,{(time.time()-t0)*1e6/max(len(rows5),1):.0f},"
          f"best={best['method']}:{best['accuracy']}")

    t0 = time.time()
    conv = convergence.run(scale=common.Scale(
        rounds=2 if args.quick else 3,
        n_clients=scale.n_clients, n_train=scale.n_train,
        n_test=scale.n_test, n_conf=scale.n_conf,
        local_epochs=scale.local_epochs))
    print(f"convergence,{(time.time()-t0)*1e6:.0f},"
          f"exp5_first_round_max={conv['claim_exp5_first_round_is_max']}")

    t0 = time.time()
    abl = ablation_multiclass.run(scale=common.Scale(
        rounds=2 if args.quick else 3,
        n_clients=scale.n_clients, n_train=scale.n_train,
        n_test=scale.n_test, n_conf=scale.n_conf,
        local_epochs=scale.local_epochs))
    print(f"ablation_multiclass,{(time.time()-t0)*1e6/3:.0f},"
          f"best_j={max(abl, key=lambda r: r['accuracy'])['top_classes']}")

    rf = roofline.run()
    print(f"roofline,0,artifacts={rf['rows']}")


if __name__ == "__main__":
    main()
