"""TM kernel micro-bench: clause-eval oracle wall time (CPU) + Pallas
kernel validation timing.  (The Pallas kernels target TPU; CPU interpret
mode is a correctness harness, so the derived column reports the kernel's
*analytic* TPU roofline time, not CPU wall time.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import tm
from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6     # µs


def run() -> list[str]:
    rows = []
    for (C, m, o, B) in [(10, 300, 784, 64), (62, 500, 784, 32)]:
        L = 2 * o
        key = jax.random.PRNGKey(0)
        include = jax.random.bernoulli(key, 0.1, (C * m, L)).astype(jnp.int8)
        lits = jax.random.bernoulli(key, 0.5, (B, L)).astype(jnp.int8)
        f = jax.jit(lambda i, l: ref.clause_outputs_ref(i, l))
        us = bench(f, include, lits)
        flops = 2.0 * B * C * m * L
        bytes_ = (include.size + lits.size + B * C * m * 4)
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6
        rows.append(f"clause_eval_C{C}_m{m}_B{B},{us:.1f},"
                    f"tpu_roofline_us={t_tpu:.2f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
