"""TM kernel micro-bench: jnp oracle vs interpret-mode Pallas kernels.

Times both the pure-jnp clause-eval oracle and the actual Pallas
kernels (interpret mode on this CPU container — the kernels target TPU,
so the derived column reports the kernel's *analytic* TPU roofline
time alongside the CPU wall time), plus the fused train-epoch kernel
against its reference-scan equivalent.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import tm
from repro.kernels import clause_eval, ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def bench(fn, *args, iters=5):
    out = fn(*args)                  # warm-up: compile + first run, once
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6     # µs


def run() -> list[str]:
    rows = []
    for (C, m, o, B) in [(10, 300, 784, 64), (62, 500, 784, 32)]:
        L = 2 * o
        key = jax.random.PRNGKey(0)
        include = jax.random.bernoulli(key, 0.1, (C * m, L)).astype(jnp.int8)
        lits = jax.random.bernoulli(key, 0.5, (B, L)).astype(jnp.int8)
        flops = 2.0 * B * C * m * L
        bytes_ = (include.size + lits.size + B * C * m * 4)
        t_tpu = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW) * 1e6

        f = jax.jit(lambda i, l: ref.clause_outputs_ref(i, l))
        us = bench(f, include, lits)
        rows.append(f"clause_eval_C{C}_m{m}_B{B},{us:.1f},"
                    f"tpu_roofline_us={t_tpu:.2f}")

        # the Pallas kernel itself, interpret mode (CPU correctness
        # harness; same analytic TPU roofline as the oracle row).  Big
        # tiles keep the interpret grid small — per-step overhead
        # dominates interpret wall time, and tile invariance is pinned
        # by tests/test_kernels.py, so the tiling is a free choice here.
        us_k = bench(lambda i, l: clause_eval.clause_outputs_pallas(
            i, l, bt=B, ct=512, lt=512), include, lits)
        rows.append(f"clause_eval_pallas_interp_C{C}_m{m}_B{B},{us_k:.1f},"
                    f"tpu_roofline_us={t_tpu:.2f}")

    # fused train-epoch kernel vs the reference per-sample scan, at the
    # quick-bench federated scale (one round's client cohort)
    N, S, C, m, o = 10, 40, 10, 48, 100
    cfg = tm.TMConfig(n_classes=C, n_clauses=m, n_features=o,
                      n_states=63, s=5.0, T=40)
    kcfg = tm.TMConfig(n_classes=C, n_clauses=m, n_features=o,
                       n_states=63, s=5.0, T=40, use_kernel=True)
    key = jax.random.PRNGKey(1)
    params = jax.vmap(lambda k: tm.init_params(cfg, k))(
        jax.random.split(key, N))
    xs = (jax.random.uniform(jax.random.fold_in(key, 1),
                             (N, S, o)) < 0.5).astype(jnp.int32)
    ys = jax.random.randint(jax.random.fold_in(key, 2), (N, S), 0, C)
    keys = jax.random.split(jax.random.fold_in(key, 3), N)
    us_fused = bench(
        lambda p, x, y, k: tm.train_batched(p, x, y, k, kcfg),
        params, xs, ys, keys, iters=3)
    us_ref = bench(
        lambda p, x, y, k: tm.train_batched(p, x, y, k, cfg),
        params, xs, ys, keys, iters=3)
    rows.append(f"train_epoch_fused_interp_N{N}_S{S}_C{C}_m{m},"
                f"{us_fused:.1f},ref_scan_us={us_ref:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
