"""§Roofline: aggregate the dry-run artifacts into the per-(arch × shape ×
mesh) roofline table (markdown + JSON).  Reads benchmarks/artifacts/
dryrun_*.json produced by repro.launch.dryrun.
"""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts"


def load() -> list[dict]:
    rows = []
    for f in sorted(ART.glob("dryrun_*.json")):
        # baseline table only: skip perf-iteration artifacts (…_<tag>.json)
        if not (f.name.endswith("_16x16.json")
                or f.name.endswith("_2x16x16.json")):
            continue
        rows.append(json.loads(f.read_text()))
    return rows


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def table(rows: list[dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "bound | model GFLOPs | useful ratio | peak GB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"{rf['bottleneck']} | "
            f"{rf.get('model_flops_global', 0)/1e9:.1f} | "
            f"{rf.get('useful_flops_ratio', 0):.3f} | "
            f"{r['memory']['peak_bytes_per_device']/1e9:.2f} |")
    return hdr + "\n".join(lines)


def run() -> dict:
    rows = load()
    n16 = sum(1 for r in rows if r["mesh"] == "16x16")
    n512 = sum(1 for r in rows if r["mesh"] == "2x16x16")
    out = {"n_single_pod": n16, "n_multi_pod": n512, "rows": len(rows)}
    print(f"roofline: {n16} single-pod + {n512} multi-pod artifacts")
    md = "## Single-pod (16×16 = 256 chips)\n\n" + table(rows, "16x16") \
        + "\n\n## Multi-pod (2×16×16 = 512 chips)\n\n" \
        + table(rows, "2x16x16") + "\n"
    (ART / "roofline_table.md").write_text(md)
    (ART / "roofline_summary.json").write_text(json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    run()
