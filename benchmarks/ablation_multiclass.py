"""Beyond-paper ablation: the paper's §7 future work implemented —
top-j multi-class weight sharing (soft multi-cluster membership) and
confidence thresholding.  Reports accuracy vs upload for j ∈ {1, 2, 3}
under fully non-IID partitioning.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax

from benchmarks import common
from repro.core import federation

ART = Path(__file__).resolve().parent / "artifacts"


def run(dataset: str = "synthmnist", scale: common.Scale | None = None,
        seed: int = 0, data_dir: str | None = None,
        encoding: str = "bool") -> list[dict]:
    scale = scale or common.Scale(rounds=3)
    data, dcfg = common.make_fed_dataset(dataset, 5, scale, seed,
                                         data_dir=data_dir,
                                         encoding=encoding)
    tm_cfg = common.bench_tm_config(dataset, dcfg, scale)
    rows = []
    for j in (1, 2, 3):
        fed = federation.FedConfig(n_clients=scale.n_clients,
                                   rounds=scale.rounds,
                                   local_epochs=scale.local_epochs,
                                   top_classes=j)
        _, hist = federation.run(data, tm_cfg, fed,
                                 jax.random.PRNGKey(seed + j))
        up, down = federation.total_comm_mb(hist)
        rows.append({
            "top_classes": j,
            "accuracy": round(float(hist[-1].mean_accuracy), 4),
            "upload_mb": round(up, 5),
            "download_mb": round(down, 5),
            "clusters_final": int((hist[-1].cluster_counts > 0).sum()),
        })
        print(f"ablation j={j}: acc={rows[-1]['accuracy']} "
              f"up={rows[-1]['upload_mb']}MB "
              f"clusters={rows[-1]['clusters_final']}", flush=True)
    ART.mkdir(exist_ok=True)
    (ART / "ablation_multiclass.json").write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    run()
