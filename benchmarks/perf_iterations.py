"""§Perf driver: run the hillclimb matrix (3 chosen pairs × knob settings)
as dryrun subprocesses (env toggles must be set before jax imports).

Pairs (chosen per the assignment criteria):
  * deepseek-v3-671b × train_4k — most collective-bound baseline
  * xlstm-350m       × train_4k — worst roofline fraction (recurrent
                                   resharding pathology)
  * jamba-1.5-large-398b × train_4k — largest model; hybrid MoE+Mamba,
                                   closest to the paper's routing story

Each experiment = (tag, env overrides, extra dryrun args).  Artifacts land
as dryrun_<arch>_<shape>_<mesh>_<tag>.json for EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "benchmarks" / "artifacts"

EXPERIMENTS: dict[str, list[tuple[str, dict, list]]] = {
    "deepseek-v3-671b": [
        ("noflash", {"REPRO_NO_FLASH_VJP": "1"}, []),
        ("moe_ep", {"REPRO_SHARD_MOE": "1"}, []),
        ("optbf16", {}, ["--opt-dtype", "bf16"]),
        ("moe_ep_optbf16", {"REPRO_SHARD_MOE": "1"},
         ["--opt-dtype", "bf16"]),
    ],
    "xlstm-350m": [
        ("r_repl", {"REPRO_XLSTM_R_REPLICATED": "1"}, []),
        ("chunkwise", {"REPRO_MLSTM_CHUNKWISE": "1"}, []),
        ("chunkwise_r_repl", {"REPRO_MLSTM_CHUNKWISE": "1",
                              "REPRO_XLSTM_R_REPLICATED": "1"}, []),
    ],
    "granite-moe-3b-a800m": [
        ("tp_nofsdp", {"REPRO_MOE_TP_NO_FSDP": "1"}, []),
        ("tp_nofsdp_optbf16", {"REPRO_MOE_TP_NO_FSDP": "1"},
         ["--opt-dtype", "bf16"]),
    ],
    "jamba-1.5-large-398b": [
        ("noflash", {"REPRO_NO_FLASH_VJP": "1"}, []),
        ("moe_ep", {"REPRO_SHARD_MOE": "1"}, []),
        ("optbf16", {}, ["--opt-dtype", "bf16"]),
    ],
}


def run_one(arch: str, tag: str, env: dict, extra: list,
            shape: str = "train_4k") -> dict | None:
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--tag", tag, *extra]
    full_env = {**os.environ, "PYTHONPATH": str(ROOT / "src"), **env}
    print(f"→ {arch} {shape} [{tag}] env={env} {extra}", flush=True)
    r = subprocess.run(cmd, env=full_env, capture_output=True, text=True,
                       cwd=ROOT)
    if r.returncode != 0:
        print(r.stdout[-2000:], r.stderr[-2000:])
        return None
    mesh_id = arch.replace(".", "_")
    f = ART / f"dryrun_{mesh_id}_{shape}_16x16_{tag}.json"
    if not f.exists():
        f = ART / f"dryrun_{arch}_{shape}_16x16_{tag}.json"
    return json.loads(f.read_text()) if f.exists() else None


def main() -> None:
    results = {}
    for arch, exps in EXPERIMENTS.items():
        for tag, env, extra in exps:
            r = run_one(arch, tag, env, extra)
            if r:
                rf = r["roofline"]
                results[f"{arch}:{tag}"] = rf
                print(f"   comp={rf['compute_s']:.3e} "
                      f"mem={rf['memory_s']:.3e} "
                      f"coll={rf['collective_s']:.3e} "
                      f"peak={r['memory']['peak_bytes_per_device']/1e9:.1f}GB",
                      flush=True)
    (ART / "perf_iterations.json").write_text(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
