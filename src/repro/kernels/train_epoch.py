"""Fused TM training-epoch kernel: one ``pallas_call`` per epoch.

The reference ``tm.train_epoch`` scans samples on the host side of the
kernel boundary: each scan step re-launches batch-1 clause evaluation
and two TA updates, so the clause banks round-trip HBM every sample.
This kernel inverts that — the whole parameter state (every client's
``ta_state`` and ``weights``) is resident in VMEM for the full epoch,
and the per-sample feedback loop runs *inside* the kernel body
(``grid=(1,)``, whole-array blocks; the no-intermediate-HBM idiom).

Layout is client-batched: a leading ``N`` axis carries all clients of a
federated round through one launch.  This is deliberately *not* a
per-client kernel under ``jax.vmap`` — vmap of a ``pallas_call`` batches
by prepending a grid axis, which serializes clients and re-slices blocks
every grid step; one launch over the stacked clients is the fast shape
on both CPU interpret mode and a TPU core.

Bit-parity with the reference scan (pinned in ``tests/test_tm.py`` and
``tests/test_fl_conformance.py``) holds because:

* randomness is pre-generated outside with the reference key discipline
  (:mod:`repro.kernels.draws`);
* class votes are per-class independent — ``votes[c]`` reads only class
  ``c``'s clauses/weights, and the negative class ``ȳ ≠ y`` — so
  processing (sample, target-role) then (sample, negative-role) as two
  loop iterations recomputes exactly the reference's pre-sample values;
* count accumulation uses f32 ``dot_general`` on 0/1 operands: integer
  values below 2²⁴ are exact in f32, so ``viol == 0.0`` and the vote
  sums match the int32 einsum bit-for-bit (same contract as
  ``clause_eval.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _epoch_kernel(ta_ref, w_ref, lits_ref, cls2_ref, uact_ref, coin_ref,
                  ta_out, w_out, *, n_states: int, T: int, n_samples: int):
    ta_all = ta_ref[...]          # (N, C, m, L) int32
    w_all = w_ref[...]            # (N, C, m)    int32
    lits = lits_ref[...]          # (N, S, L)    int32 0/1
    cls2 = cls2_ref[...]          # (N, S, 2)    int32 — [target, negative]
    uact = uact_ref[...]          # (N, S, 2, m) float32
    coin = coin_ref[...]          # (N, S, 2, m, L) int8 — bit1 inc, bit2 dec

    N, C, m, L = ta_all.shape
    rows = jnp.arange(N)
    pol = jnp.where(jnp.arange(m) % 2 == 0, 1, -1)
    pos = pol > 0
    polf = pol.astype(jnp.float32)
    tf = jnp.float32(T)

    def body(i, carry):
        ta_all, w_all = carry
        s, role = i // 2, i % 2
        is_target = role == 0

        cls = jax.lax.dynamic_slice(cls2, (0, s, role), (N, 1, 1))[:, 0, 0]
        lit = jax.lax.dynamic_slice(lits, (0, s, 0), (N, 1, L))[:, 0]
        ua = jax.lax.dynamic_slice(uact, (0, s, role, 0), (N, 1, 1, m))[:, 0, 0]
        cn = jax.lax.dynamic_slice(
            coin, (0, s, role, 0, 0), (N, 1, 1, m, L))[:, 0, 0]

        ta = ta_all[rows, cls]    # (N, m, L)
        w = w_all[rows, cls]      # (N, m)

        # clause outputs on this sample's literals (learning mode: empty
        # clauses fire) — violations counted in exact-f32 dot_general
        inc = (ta > n_states).astype(jnp.float32)
        nlit = (1 - lit).astype(jnp.float32)
        viol = jax.lax.dot_general(
            inc, nlit, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        fired = viol == 0.0       # (N, m)

        votes = jnp.sum(
            fired.astype(jnp.float32) * polf[None] * w.astype(jnp.float32),
            axis=1)
        v = jnp.clip(votes, -tf, tf)
        p_act = jnp.where(is_target, tf - v, tf + v) / (2.0 * tf)
        active = ua < p_act[:, None]                     # (N, m)

        # Type I goes to same-polarity clauses on the target, opposite on
        # the negative; Type II is the complement
        t1 = jnp.where(is_target, pos[None], ~pos[None]) & active
        t2 = jnp.where(is_target, ~pos[None], pos[None]) & active

        litb = (lit != 0)[:, None, :]                    # (N, 1, L)
        fb = fired[:, :, None]                           # (N, m, 1)
        up1 = t1[:, :, None] & fb & litb & ((cn & 1) == 1)
        down1 = t1[:, :, None] & ((fb & ~litb) | ~fb) & ((cn & 2) == 2)
        up2 = t2[:, :, None] & fb & ~litb & (ta <= n_states)
        delta = (up1.astype(jnp.int32) - down1.astype(jnp.int32)
                 + up2.astype(jnp.int32))
        ta_all = ta_all.at[rows, cls].set(
            jnp.clip(ta + delta, 1, 2 * n_states))

        winc = (t1 & fired).astype(jnp.int32)
        wdec = (t2 & fired).astype(jnp.int32)
        w_all = w_all.at[rows, cls].set(jnp.maximum(w + winc - wdec, 0))
        return ta_all, w_all

    ta_all, w_all = jax.lax.fori_loop(0, 2 * n_samples, body,
                                      (ta_all, w_all))
    ta_out[...] = ta_all
    w_out[...] = w_all


@functools.partial(jax.jit,
                   static_argnames=("n_states", "T", "interpret"))
def train_epoch_pallas(ta_state: jax.Array, weights: jax.Array,
                       lits: jax.Array, cls2: jax.Array,
                       u_act: jax.Array, coin: jax.Array,
                       *, n_states: int, T: int,
                       interpret: bool = True):
    """One TM epoch over all clients in a single kernel launch.

    Args:
      ta_state: (N, C, m, L) int32 — per-client TA banks.
      weights:  (N, C, m) int32 — per-client clause weights.
      lits:     (N, S, L) int32 0/1 — per-client literal planes.
      cls2:     (N, S, 2) int32 — per (client, sample): [target, negative].
      u_act:    (N, S, 2, m) float32 — activation uniforms per role.
      coin:     (N, S, 2, m, L) int8 — pre-compared Type-I coin flips.

    Returns ``(ta_state, weights)`` after the sample-sequential epoch,
    bit-identical to the reference ``tm.train_epoch`` per client.
    """
    n_samples = lits.shape[1]
    whole = [pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd)
             for a in (ta_state, weights, lits, cls2, u_act, coin)]
    out_specs = [pl.BlockSpec(ta_state.shape,
                              lambda i, nd=ta_state.ndim: (0,) * nd),
                 pl.BlockSpec(weights.shape,
                              lambda i, nd=weights.ndim: (0,) * nd)]
    kernel = functools.partial(_epoch_kernel, n_states=n_states, T=T,
                               n_samples=n_samples)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=whole,
        out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct(ta_state.shape, jnp.int32),
                   jax.ShapeDtypeStruct(weights.shape, jnp.int32)],
        interpret=interpret,
        name="tm_train_epoch_fused",
    )(ta_state, weights, lits, cls2, u_act, coin)
