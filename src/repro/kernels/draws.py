"""Pre-generated feedback randomness for the fused TM epoch kernel.

The reference trainer (:mod:`repro.core.tm`) draws its stochastic
choices *inside* the per-sample scan — fine for jnp, but a Pallas kernel
body cannot host the threefry hash portably (counter-based PRNG inside a
Mosaic kernel is TPU-generation-specific).  So the fused epoch kernel
consumes the whole epoch's randomness as plain arrays, generated here
with exactly the reference key discipline:

* per sample ``i``: ``k_neg, k_t, k_n = split(keys[i], 3)`` where
  ``keys = split(epoch_key, n_samples)`` — the negative class is
  ``(y + randint(k_neg, 1, C)) % C``;
* per feedback role (target ``k_t`` / negative ``k_n``):
  ``k_act, k_s1, k_s2 = split(k, 3)`` — clause-activation uniforms from
  ``k_act``, the Type-I increment/decrement coin flips from ``k_s1`` /
  ``k_s2``.

The coin flips are stored pre-compared, two bits per (clause, literal)
in one int8 plane (bit 1 = increment draw hit, bit 2 = decrement draw
hit), via the **int-domain compare trick**: jax's float32
``uniform(k, shape)`` is exactly ``(bits(k) >> 9) * 2**-23``, so

    uniform(k, shape) < p   ⟺   (bits(k) >> 9) < ceil(float32(p) · 2²³)

bit-for-bit (both sides of the float compare are exact f32 values;
:func:`int_threshold` is pinned against ``jax.random.uniform`` by
``tests/test_kernels.py``).  This skips the uint32→f32 convert and the
f32 compare for the two (m, L) planes per role — the dominant draw
volume — while staying bit-identical to the reference path.

The activation uniforms stay f32: their compare threshold ``p_act`` is
vote-dependent and computed inside the kernel.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# f32 uniforms carry exactly 23 mantissa bits: u = (bits >> 9) * 2^-23
_MANTISSA = float(1 << 23)


def int_threshold(p: float) -> int:
    """uniform(k, s) < p  ⟺  (bits(k, s) >> 9) < int_threshold(p)."""
    return math.ceil(float(np.float32(p)) * _MANTISSA)


def epoch_draws(key: jax.Array, n_samples: int, n_clauses: int,
                n_literals: int, n_classes: int,
                p_inc: float, p_dec: float):
    """One epoch's randomness, reference key discipline (see module doc).

    Returns ``(offsets, u_act, coin)``:

    * ``offsets`` (S,) int32 — negative-class offset in [1, C);
    * ``u_act``   (S, 2, m) float32 — clause-activation uniforms, role
      0 = target, 1 = negative;
    * ``coin``    (S, 2, m, L) int8 — bit 1: Type-I increment draw hit
      (``u < p_inc``), bit 2: decrement draw hit (``u < p_dec``).
    """
    m, L = n_clauses, n_literals
    t_inc = int_threshold(p_inc)
    t_dec = int_threshold(p_dec)
    keys = jax.random.split(key, n_samples)

    def per_sample(_, k):
        k_neg, k_t, k_n = jax.random.split(k, 3)

        def role(kr):
            k_act, k_s1, k_s2 = jax.random.split(kr, 3)
            ua = jax.random.uniform(k_act, (m,))
            h1 = jax.random.bits(k_s1, (m, L), jnp.uint32) >> 9
            h2 = jax.random.bits(k_s2, (m, L), jnp.uint32) >> 9
            return ua, ((h1 < t_inc).astype(jnp.int8)
                        + 2 * (h2 < t_dec).astype(jnp.int8))

        ua_t, c_t = role(k_t)
        ua_n, c_n = role(k_n)
        off = jax.random.randint(k_neg, (), 1, n_classes)
        return 0, (off.astype(jnp.int32), jnp.stack([ua_t, ua_n]),
                   jnp.stack([c_t, c_n]))

    _, (offsets, u_act, coin) = jax.lax.scan(per_sample, 0, keys)
    return offsets, u_act, coin
