"""Pallas TPU kernel for the Tsetlin-Automaton state transition.

The TA update is a memory-bound elementwise op over the `(m, 2o)` state
tile of the two classes touched per training sample (target + sampled
negative).  The kernel fuses the Type I / Type II feedback masks, the
stochastic reward/penalty draws (uniforms generated outside, passed in),
the delta and the `[1, 2N]` clamp into a single VMEM pass — one read and
one write of the state tile instead of the ~8 intermediate tensors the
unfused jnp path materializes.

Tiling: grid over `(m/mt, L/lt)`; per-step residency is one `(mt, lt)`
int32 state tile + two uniform tiles + broadcast rows/cols, well under
VMEM at the default (256, 512) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def _ta_kernel(ta_ref, lit_ref, fired_ref, t1_ref, t2_ref,
               u_inc_ref, u_dec_ref, out_ref, *,
               p_inc: float, p_dec: float, n_states: int):
    ta = ta_ref[...]
    lit = lit_ref[...] != 0            # (1, lt)  broadcast over clauses
    fired = fired_ref[...] != 0        # (mt, 1)  broadcast over literals
    t1 = t1_ref[...] != 0
    t2 = t2_ref[...] != 0

    up1 = t1 & fired & lit & (u_inc_ref[...] < p_inc)
    down1 = t1 & ((fired & (~lit)) | (~fired)) & (u_dec_ref[...] < p_dec)
    up2 = t2 & fired & (~lit) & (ta <= n_states)
    delta = up1.astype(jnp.int32) - down1.astype(jnp.int32) \
        + up2.astype(jnp.int32)
    out_ref[...] = jnp.clip(ta + delta, 1, 2 * n_states)


@functools.partial(
    jax.jit,
    static_argnames=("p_inc", "p_dec", "n_states", "mt", "lt", "interpret"))
def ta_update_pallas(ta: jnp.ndarray, lit: jnp.ndarray, fired: jnp.ndarray,
                     type1: jnp.ndarray, type2: jnp.ndarray,
                     u_inc: jnp.ndarray, u_dec: jnp.ndarray,
                     p_inc: float, p_dec: float, n_states: int,
                     mt: int = 256, lt: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """See :func:`repro.kernels.ref.ta_update_ref` for exact semantics."""
    m, L = ta.shape
    mt = min(mt, _ceil_to(m, 8))
    lt = min(lt, _ceil_to(L, 128))
    mp, Lp = _ceil_to(m, mt), _ceil_to(L, lt)

    def pad(a, rows, cols):
        return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))

    # state pads to 1 (valid) so the clamp never sees 0; masks pad to 0.
    ta_p = jnp.pad(ta, ((0, mp - m), (0, Lp - L)), constant_values=1)
    args = (
        ta_p,
        pad(lit.astype(jnp.int32), 1, Lp),
        pad(fired.astype(jnp.int32), mp, 1),
        pad(type1.astype(jnp.int32), mp, 1),
        pad(type2.astype(jnp.int32), mp, 1),
        pad(u_inc.astype(jnp.float32), mp, Lp),
        pad(u_dec.astype(jnp.float32), mp, Lp),
    )
    out = pl.pallas_call(
        functools.partial(_ta_kernel, p_inc=float(p_inc), p_dec=float(p_dec),
                          n_states=int(n_states)),
        grid=(mp // mt, Lp // lt),
        in_specs=[
            pl.BlockSpec((mt, lt), lambda i, j: (i, j)),
            pl.BlockSpec((1, lt), lambda i, j: (0, j)),
            pl.BlockSpec((mt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((mt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((mt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((mt, lt), lambda i, j: (i, j)),
            pl.BlockSpec((mt, lt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((mt, lt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, Lp), jnp.int32),
        interpret=interpret,
        name="tm_ta_update",
    )(*args)
    return out[:m, :L]
