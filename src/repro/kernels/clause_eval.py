"""Pallas TPU kernels for Tsetlin-Machine clause evaluation.

The TM hot-spot is the conjunctive clause evaluation: for every sample `b`
and clause `j`, count how many *included* literals are violated
(`included & literal==0`) — the clause fires iff the count is zero
(paper §4.1).  On TPU this is a boolean-matmul-shaped reduction that maps
straight onto the MXU: we cast the {0,1} operands to f32 and accumulate the
violation counts as an f32 dot (exact for counts < 2^24).

Two kernels:

* :func:`clause_outputs_pallas` — tiled `(B, L) × (L, CM) → (B, CM)`
  violation count with a k-loop over literal tiles, then `== 0`.
  BlockSpecs keep one `(bt, lt)` literal tile and one `(ct, lt)`
  include tile resident in VMEM per grid step; `bt, ct, lt` default to
  MXU/VPU-aligned multiples of (8, 128).

* :func:`fused_votes_pallas` — fuses clause eval with the Eq.-1 weighted
  class vote: grid is `(B tiles, classes)`; each step loads the whole
  `(m, L)` clause bank of one class into VMEM (m·L ≤ a few hundred KB for
  paper-scale machines), computes fired clauses, and reduces
  `votes = fired @ (polarity·weight)` without materializing the `(B, C, m)`
  clause tensor in HBM.

* :func:`fused_votes_batched_pallas` — the same fused vote with a leading
  client axis, one launch for a whole federated round (`grid=(1,)`,
  whole-array blocks).  The per-class reduction is a `(CM, C)` selector
  matmul so the kernel body needs no reshape; the predict-mode
  empty-clause rule is folded into the weight plane (`wpol · nonempty`,
  exact in f32).  This is what the engine's `tm_backend="pallas"`
  evaluate/confidence paths call — batching *inside* the kernel instead
  of vmapping `fused_votes_pallas` (vmap of a `pallas_call` prepends a
  grid axis, serializing clients).

On this CPU-only container the kernels run under ``interpret=True``
(exercised by the test suite against :mod:`repro.kernels.ref`); on real
TPUs the same `pallas_call`s compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


# ---------------------------------------------------------------------------
# Kernel 1: tiled violation-count matmul → clause outputs
# ---------------------------------------------------------------------------

def _clause_kernel(nlit_ref, inc_ref, out_ref):
    """out[bt, ct] += nlit[bt, lt] @ inc[ct, lt]^T  (f32 accumulation)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nlit = nlit_ref[...].astype(jnp.float32)
    inc = inc_ref[...].astype(jnp.float32)
    out_ref[...] += jax.lax.dot_general(
        nlit, inc, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("predict", "bt", "ct", "lt", "interpret"))
def clause_outputs_pallas(include: jnp.ndarray, lits: jnp.ndarray,
                          predict: bool = False, bt: int = 8, ct: int = 128,
                          lt: int = 128, interpret: bool = True) -> jnp.ndarray:
    """include: (CM, L) {0,1}; lits: (B, L) {0,1} → fired (B, CM) int32."""
    CM, L = include.shape
    B = lits.shape[0]
    Bp, CMp, Lp = _ceil_to(B, bt), _ceil_to(CM, ct), _ceil_to(L, lt)
    # pad: extra literals are zero in both operands → no violation contribution
    nlit = _pad2((1 - lits).astype(jnp.int8), Bp, Lp)
    # padded literal columns of real clauses must not count as violations:
    # (1-lits) pads to 0 there, so include padding value is irrelevant; pad 0.
    inc = _pad2(include.astype(jnp.int8), CMp, Lp)

    grid = (Bp // bt, CMp // ct, Lp // lt)
    viol = pl.pallas_call(
        _clause_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, lt), lambda b, c, k: (b, k)),
            pl.BlockSpec((ct, lt), lambda b, c, k: (c, k)),
        ],
        out_specs=pl.BlockSpec((bt, ct), lambda b, c, k: (b, c)),
        out_shape=jax.ShapeDtypeStruct((Bp, CMp), jnp.float32),
        interpret=interpret,
        name="tm_clause_eval",
    )(nlit, inc)

    fired = (viol[:B, :CM] == 0).astype(jnp.int32)
    if predict:
        fired = fired * (include.sum(-1) > 0).astype(jnp.int32)[None, :]
    return fired


# ---------------------------------------------------------------------------
# Kernel 2: fused clause eval + weighted class vote (Eq. 1)
# ---------------------------------------------------------------------------

def _votes_kernel(nlit_ref, inc_ref, wpol_ref, nonempty_ref, out_ref):
    nlit = nlit_ref[...].astype(jnp.float32)          # (bt, L)
    inc = inc_ref[0].astype(jnp.float32)              # (m, L)
    viol = jax.lax.dot_general(                        # (bt, m)
        nlit, inc, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    fired = (viol == 0.0).astype(jnp.float32)
    fired = fired * nonempty_ref[0].astype(jnp.float32)  # (bt, m)·(1, m)
    wpol = wpol_ref[0].astype(jnp.float32)            # (1, m)
    out_ref[...] = jax.lax.dot_general(                # (bt, 1)
        fired, wpol, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("predict", "bt", "interpret"))
def fused_votes_pallas(include: jnp.ndarray, lits: jnp.ndarray,
                       wpol: jnp.ndarray, predict: bool = True,
                       bt: int = 8, interpret: bool = True) -> jnp.ndarray:
    """include: (C, m, L); lits: (B, L); wpol: (C, m) → votes (B, C) int32."""
    C, m, L = include.shape
    B = lits.shape[0]
    Bp, mp, Lp = _ceil_to(B, bt), _ceil_to(m, 128), _ceil_to(L, 128)

    nlit = _pad2((1 - lits).astype(jnp.int8), Bp, Lp)
    inc = jnp.pad(include.astype(jnp.int8),
                  ((0, 0), (0, mp - m), (0, Lp - L)))
    # padded clauses have empty includes → viol 0 → would fire: kill them via
    # the nonempty mask (also implements the predict-mode empty-clause rule).
    if predict:
        ne = (include.sum(-1) > 0)
    else:
        ne = jnp.ones((C, m), dtype=bool)
    ne = jnp.pad(ne.astype(jnp.int8), ((0, 0), (0, mp - m)))[:, None, :]
    wp = jnp.pad(wpol.astype(jnp.float32), ((0, 0), (0, mp - m)))[:, None, :]

    votes = pl.pallas_call(
        _votes_kernel,
        grid=(Bp // bt, C),
        in_specs=[
            pl.BlockSpec((bt, Lp), lambda b, c: (b, 0)),
            pl.BlockSpec((1, mp, Lp), lambda b, c: (c, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda b, c: (c, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda b, c: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda b, c: (b, c)),
        out_shape=jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        interpret=interpret,
        name="tm_fused_votes",
    )(nlit, inc, wp, ne)
    return votes[:B].astype(jnp.int32)


# ---------------------------------------------------------------------------
# Kernel 3: client-batched fused votes (one launch per federated round)
# ---------------------------------------------------------------------------

def _votes_batched_kernel(nlit_ref, inc_ref, wp_ref, sel_ref, out_ref):
    nlit = nlit_ref[...].astype(jnp.float32)          # (N, B, L)
    inc = inc_ref[...].astype(jnp.float32)            # (N, CM, L)
    viol = jax.lax.dot_general(                        # (N, B, CM)
        nlit, inc, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    fired = (viol == 0.0).astype(jnp.float32)
    contrib = fired * wp_ref[...][:, None, :]          # wp: (N, CM)
    out_ref[...] = jax.lax.dot_general(                # (N, B, C)
        contrib, sel_ref[...], (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("predict", "interpret"))
def fused_votes_batched_pallas(include: jnp.ndarray, lits: jnp.ndarray,
                               wpol: jnp.ndarray, predict: bool = True,
                               interpret: bool = True) -> jnp.ndarray:
    """include: (N,C,m,L); lits: (N,B,L); wpol: (N,C,m) → votes (N,B,C) i32.

    Empty clauses are killed by zeroing their weight instead of their
    clause output — ``fired·(wpol·nonempty) == (fired·nonempty)·wpol``
    exactly (small-int products are exact in f32), which keeps the kernel
    a pair of dot_generals with no masking pass.
    """
    N, C, m, L = include.shape
    B = lits.shape[1]
    CM = C * m
    inc = include.reshape(N, CM, L).astype(jnp.int8)
    nlit = (1 - lits).astype(jnp.int8)
    wp = wpol.astype(jnp.float32)
    if predict:
        wp = wp * (include.sum(-1) > 0).astype(jnp.float32)
    wp = wp.reshape(N, CM)
    sel = jax.nn.one_hot(jnp.arange(CM) // m, C, dtype=jnp.float32)

    whole = [pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd)
             for a in (nlit, inc, wp, sel)]
    votes = pl.pallas_call(
        _votes_batched_kernel,
        grid=(1,),
        in_specs=whole,
        out_specs=pl.BlockSpec((N, B, C), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, B, C), jnp.float32),
        interpret=interpret,
        name="tm_fused_votes_batched",
    )(nlit, inc, wp, sel)
    return votes.astype(jnp.int32)
