"""Jit'd public wrappers around the TM Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are *targeted* at TPU and compiled there), False on TPU backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import clause_eval as _ce
from repro.kernels import ta_update as _ta
from repro.kernels import train_epoch as _te


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def clause_outputs(include: jnp.ndarray, lits: jnp.ndarray,
                   predict: bool = False) -> jnp.ndarray:
    """include: (C, m, L) or (CM, L); lits: (B, L) → fired int32.

    Returns (B, C, m) when given a 3-D include mask, else (B, CM).
    """
    interp = _interpret_default()
    if include.ndim == 3:
        C, m, L = include.shape
        out = _ce.clause_outputs_pallas(include.reshape(C * m, L), lits,
                                        predict=predict, interpret=interp)
        return out.reshape(lits.shape[0], C, m)
    return _ce.clause_outputs_pallas(include, lits, predict=predict,
                                     interpret=interp)


def fused_votes(include: jnp.ndarray, lits: jnp.ndarray, wpol: jnp.ndarray,
                predict: bool = True) -> jnp.ndarray:
    """(C,m,L) × (B,L) × (C,m) → unclipped Eq.-1 votes (B, C)."""
    return _ce.fused_votes_pallas(include, lits, wpol, predict=predict,
                                  interpret=_interpret_default())


def fused_votes_batched(include: jnp.ndarray, lits: jnp.ndarray,
                        wpol: jnp.ndarray, predict: bool = True
                        ) -> jnp.ndarray:
    """Client-batched Eq.-1 votes: (N,C,m,L) × (N,B,L) × (N,C,m) → (N,B,C)."""
    return _ce.fused_votes_batched_pallas(include, lits, wpol,
                                          predict=predict,
                                          interpret=_interpret_default())


def train_epoch_fused(ta: jnp.ndarray, w: jnp.ndarray, lits: jnp.ndarray,
                      cls2: jnp.ndarray, u_act: jnp.ndarray,
                      coin: jnp.ndarray, *, n_states: int, T: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused training epoch over stacked clients; see train_epoch.py."""
    return _te.train_epoch_pallas(ta, w, lits, cls2, u_act, coin,
                                  n_states=n_states, T=T,
                                  interpret=_interpret_default())


def ta_update(ta: jnp.ndarray, lit: jnp.ndarray, fired: jnp.ndarray,
              type1: jnp.ndarray, type2: jnp.ndarray,
              u_inc: jnp.ndarray, u_dec: jnp.ndarray,
              p_inc: float, p_dec: float, n_states: int) -> jnp.ndarray:
    """Fused Type I/II TA transition; see ref.ta_update_ref."""
    return _ta.ta_update_pallas(ta, lit, fired, type1, type2, u_inc, u_dec,
                                p_inc=p_inc, p_dec=p_dec, n_states=n_states,
                                interpret=_interpret_default())
