"""Pure-jnp oracles for the TM Pallas kernels.

Each function here is the semantic reference for the identically-named
kernel in :mod:`repro.kernels.clause_eval` / :mod:`repro.kernels.ta_update`.
Tests sweep shapes/dtypes and assert the kernels (run in ``interpret=True``
on this CPU container; compiled on real TPUs) match these bit-exactly.
"""
from __future__ import annotations

import jax.numpy as jnp


def clause_outputs_ref(include: jnp.ndarray, lits: jnp.ndarray,
                       predict: bool = False) -> jnp.ndarray:
    """include: (CM, L) {0,1}; lits: (B, L) {0,1} → fired (B, CM) int32.

    fired[b, j] = 1 iff every included literal of clause j is 1 in sample b.
    Empty clauses fire during learning, not during prediction.
    """
    viol = (1 - lits).astype(jnp.int32) @ include.T.astype(jnp.int32)
    fired = (viol == 0).astype(jnp.int32)
    if predict:
        fired = fired * (include.sum(-1) > 0).astype(jnp.int32)[None, :]
    return fired


def fused_votes_ref(include: jnp.ndarray, lits: jnp.ndarray,
                    wpol: jnp.ndarray, predict: bool = True) -> jnp.ndarray:
    """Fused clause-eval + weighted class vote (paper Eq. 1).

    include: (C, m, L) {0,1}; lits: (B, L) {0,1}; wpol: (C, m) int32
    (polarity·weight) → votes (B, C) int32 (unclipped).
    """
    C, m, L = include.shape
    fired = clause_outputs_ref(include.reshape(C * m, L), lits, predict)
    return jnp.einsum("bcm,cm->bc", fired.reshape(-1, C, m), wpol)


def ta_update_ref(ta: jnp.ndarray, lit: jnp.ndarray, fired: jnp.ndarray,
                  type1: jnp.ndarray, type2: jnp.ndarray,
                  u_inc: jnp.ndarray, u_dec: jnp.ndarray,
                  p_inc: float, p_dec: float, n_states: int) -> jnp.ndarray:
    """Type I / Type II TA state transition for one clause bank.

    ta: (m, L) int32 states in [1, 2N]; lit: (1, L) {0,1};
    fired/type1/type2: (m, 1) {0,1}; u_inc/u_dec: (m, L) uniforms in [0,1).

    Type I  (on type1 clauses):
      fired & lit          → +1 w.p. p_inc      (recognize)
      fired & ¬lit | ¬fired → −1 w.p. p_dec     (erase / forget)
    Type II (on type2 clauses):
      fired & ¬lit & excluded → +1 deterministically (reject false positive)
    """
    litb = lit.astype(bool)
    firedb = fired.astype(bool)
    t1 = type1.astype(bool)
    t2 = type2.astype(bool)
    up1 = t1 & firedb & litb & (u_inc < p_inc)
    down1 = t1 & ((firedb & (~litb)) | (~firedb)) & (u_dec < p_dec)
    up2 = t2 & firedb & (~litb) & (ta <= n_states)
    delta = up1.astype(jnp.int32) - down1.astype(jnp.int32) + up2.astype(jnp.int32)
    return jnp.clip(ta + delta, 1, 2 * n_states)
