"""Version-compat shims for jax mesh APIs.

The repo targets the current jax mesh API (`jax.sharding.get_abstract_mesh`,
`jax.set_mesh`); older jax (≤0.4.x) spells these differently or not at all.
All mesh queries in model/launch code go through this module so a version
bump in either direction is a one-file change.
"""
from __future__ import annotations

import jax


def get_abstract_mesh():
    """The mesh jit is currently tracing under, or None if unavailable.

    Callers treat None (and a mesh without the axis they want) as "no
    constraint" — so on jax versions with no abstract-mesh tracking the
    sharding hints simply become no-ops instead of crashing.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        try:  # jax 0.4.x kept it private
            from jax._src import mesh as _mesh_lib
            fn = _mesh_lib.get_abstract_mesh
        except (ImportError, AttributeError):
            return None
    # deliberately no try around the call: the sharding constraints this
    # gates are load-bearing (§Perf), so an API change should crash
    # loudly rather than silently disable them
    mesh = fn()
    # older jax returns an empty sentinel (no axis_names) when no mesh is set
    if not getattr(mesh, "axis_names", None):
        return None
    return mesh


def make_mesh(shape, axes):
    """``jax.make_mesh(shape, axes, axis_types=Auto)`` with fallbacks for
    jax versions predating ``AxisType`` (where every axis is Auto anyway)
    or ``jax.make_mesh`` itself."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = {}
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    fn = getattr(jax, "make_mesh", None)
    if fn is not None:
        try:
            return fn(shape, axes, **kwargs)
        except TypeError:       # older signature without axis_types
            return fn(shape, axes)
    import numpy as np
    from jax.sharding import Mesh
    n = 1
    for s in shape:
        n *= s
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def set_mesh(mesh):
    """Context manager equivalent of ``jax.set_mesh(mesh)``.

    On jax without ``set_mesh``, a concrete ``Mesh`` is itself the context
    manager that installs the thread-local physical mesh — same effect for
    the lower/compile paths used here.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    return mesh
