"""PartitionSpec assignment for parameter trees, activations and caches.

Scheme (MaxText-style 2-D FSDP×TP, extended with a pod axis):

* mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
  multi-pod.  The batch shards over ``fsdp_axes`` = ("pod","data"); tensor
  dimensions shard over ``"model"``.
* weight matrices shard **both** ways — the input/feature dim over the
  FSDP axes, the head/ff/vocab dim over "model" — so per-device parameter
  bytes scale with 1/(pods·data·model) (what lets 671B params + Adam
  state compile on 256–512 chips).
* MoE expert banks: ``("ep" sharding)`` expert axis over "model"
  (expert parallelism) when E % model == 0, else the d_expert dim over
  "model" (``"tp"``).
* scalars / norm scales / small vectors: replicated.

Rules are *name-pattern based* over the flattened param tree path, so new
modules compose without touching this file as long as they follow the
naming convention.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return "/".join(out)


def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
               moe_sharding: str = "ep") -> P:
    """Map one parameter (by tree path + shape) to a PartitionSpec.

    Leading dim is treated as the scan axis when the path sits under
    "segments".  Any axis whose size does not divide the mesh axis falls
    back to replication (e.g. granite-moe's vocab 49155).
    """
    fsdp = fsdp_axes(mesh)
    f0 = fsdp if len(fsdp) > 1 else (fsdp[0] if fsdp else None)
    fsdp_size = 1
    for a in fsdp:
        fsdp_size *= mesh.shape[a]
    m_size = mesh.shape.get("model", 1)
    stacked = "segments" in path
    lead: tuple = (None,) if stacked else ()
    ndim_eff = len(shape) - (1 if stacked else 0)
    eshape = shape[1:] if stacked else shape

    def spec(*dims):
        # divisibility guard per sharded dim
        safe = []
        for size, d in zip(eshape, dims):
            if d == "model" and size % m_size != 0:
                d = None
            if d is not None and d == f0 and size % fsdp_size != 0:
                d = None
            safe.append(d)
        return P(*lead, *safe)

    f = f0

    # ---- embeddings / head: (vocab, d) or (d, vocab) --------------------
    if path.endswith("embed"):
        return spec("model", f)            # vocab-sharded lookup table
    if path.endswith("lm_head"):
        # d replicated on purpose: FSDP-sharding the contraction dim makes
        # SPMD all-gather the (B,T,d) activations over the batch axis at
        # the unembed (§Perf: 2×12.9 GB/device/step measured); replicating
        # d costs only V·d/model_size bytes per device.
        return spec(None, "model")

    # ---- MoE expert banks (E, d, f) / (E, f, d) --------------------------
    if any(path.endswith(s) for s in ("ffn/gate", "ffn/up", "ffn/down")) \
            and ndim_eff == 3:
        if moe_sharding == "ep":
            return spec("model", f, None)  # expert-parallel
        import os
        if os.environ.get("REPRO_MOE_TP_NO_FSDP") == "1":
            # §Perf knob: FSDP-sharding d_model inside tp-MoE expert banks
            # makes every expert einsum contract over a sharded dim (an
            # all-reduce per layer); replicating d and sharding only
            # d_expert trades small param bytes for that collective.
            return spec(None, None, "model") \
                if path.endswith(("ffn/gate", "ffn/up")) \
                else spec(None, "model", None)
        return spec(None, f, "model") if path.endswith(("ffn/gate", "ffn/up")) \
            else spec(None, "model", f)
    if path.endswith("router"):
        return spec(f, None)

    # ---- attention projections -------------------------------------------
    if any(path.endswith(s) for s in
           ("wq", "wk", "wv", "wq_b", "wkv_b", "up", "gate",
            "in_proj", "x_proj", "wx", "w_gates")):
        return spec(f, "model") if ndim_eff == 2 else spec(None)
    if any(path.endswith(s) for s in
           ("wo", "down", "out_proj", "dt_proj")):
        return spec("model", f) if ndim_eff == 2 else spec(None)
    if any(path.endswith(s) for s in ("wq_a", "wkv_a")):
        return spec(f, "model")

    # ---- xLSTM recurrent (4, H, dh, dh), Mamba A_log (d_inner, N) --------
    if path.endswith("/r") and ndim_eff == 4:
        import os
        if os.environ.get("REPRO_XLSTM_R_REPLICATED") == "1":
            # §Perf knob: the sLSTM recurrence re-shards (model→batch) on
            # every time step when r is model-sharded; r is tiny (4·H·dh²)
            # so replicating it removes the per-step collective chain.
            return spec(None, None, None, None)
        return spec(None, None, "model", None)
    if path.endswith("A_log"):
        return spec("model", None)
    if path.endswith(("conv_w",)) and ndim_eff == 2:
        return spec(None, "model")
    if any(path.endswith(s) for s in ("conv_b", "dt_bias", "D")) \
            and ndim_eff == 1:
        return spec("model")

    # ---- everything else (norm scales, biases, small vecs): replicated ---
    return spec(*([None] * ndim_eff))


def param_specs(params: Any, mesh: Mesh, moe_sharding: str = "ep") -> Any:
    def one(path, leaf):
        return param_spec(_path_str(path), leaf.shape, mesh, moe_sharding)
    return jax.tree_util.tree_map_with_path(one, params)


def shardings(params: Any, mesh: Mesh, moe_sharding: str = "ep") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, moe_sharding))


# ---------------------------------------------------------------------------
# Activations / batches / caches
# ---------------------------------------------------------------------------

def _fsdp_or_none(mesh: Mesh, batch: int):
    """FSDP axes if the batch divides them, else replicate (e.g. the
    batch-1 long_500k decode)."""
    f = fsdp_axes(mesh)
    total = 1
    for a in f:
        total *= mesh.shape[a]
    if f and total and batch % total == 0:
        return f if len(f) > 1 else f[0]
    return None


def batch_spec(mesh: Mesh, batch: int) -> P:
    return P(_fsdp_or_none(mesh, batch), None)


def cache_specs(caches: Any, mesh: Mesh) -> Any:
    """Decode-cache PartitionSpecs, matched structurally per cache type.

    Batch over the FSDP axes (when divisible); the *sequence* dim of
    KV/latent caches shards over "model" (context parallelism — softmax
    over a sharded length lowers to an all-reduce of max/sum, which is
    how 32k×128 KV caches fit per-device); recurrent state features
    shard over "model" when divisible.
    """
    from repro.models.attention import KVCache, MLACache, QuantKVCache
    from repro.models.mamba import MambaCache
    from repro.models.xlstm import MLSTMCache, SLSTMCache

    msize = mesh.shape.get("model", 1)

    def div(n):
        return "model" if n % msize == 0 else None

    def handle(c):
        # leaves carry a leading stacked-layer axis from init_cache
        if isinstance(c, KVCache):
            b = _fsdp_or_none(mesh, c.k.shape[1])
            kv = P(None, b, div(c.k.shape[2]), None, None)
            return KVCache(k=kv, v=kv, pos=P(None, b))
        if isinstance(c, QuantKVCache):
            b = _fsdp_or_none(mesh, c.k_q.shape[1])
            s_ax = div(c.k_q.shape[2])
            kv = P(None, b, s_ax, None, None)
            sc = P(None, b, s_ax, None)
            return QuantKVCache(k_q=kv, v_q=kv, k_scale=sc, v_scale=sc,
                                pos=P(None, b))
        if isinstance(c, MLACache):
            b = _fsdp_or_none(mesh, c.c_kv.shape[1])
            s = div(c.c_kv.shape[2])
            return MLACache(c_kv=P(None, b, s, None),
                            k_rope=P(None, b, s, None), pos=P(None, b))
        if isinstance(c, MambaCache):
            b = _fsdp_or_none(mesh, c.h.shape[1])
            return MambaCache(h=P(None, b, div(c.h.shape[2]), None),
                              conv=P(None, b, None, div(c.conv.shape[3])),
                              pos=P(None, b))
        if isinstance(c, MLSTMCache):
            b = _fsdp_or_none(mesh, c.C.shape[1])
            dh = div(c.C.shape[3])
            return MLSTMCache(C=P(None, b, None, dh, None),
                              n=P(None, b, None, dh), m=P(None, b, None),
                              conv=P(None, b, None, div(c.conv.shape[3])),
                              pos=P(None, b))
        if isinstance(c, SLSTMCache):
            b = _fsdp_or_none(mesh, c.c.shape[1])
            dh = div(c.c.shape[3])
            return SLSTMCache(c=P(None, b, None, dh),
                              n=P(None, b, None, dh),
                              h=P(None, b, div(c.h.shape[2])),
                              m=P(None, b, None), pos=P(None, b))
        raise TypeError(type(c))

    def is_cache(x):
        return isinstance(x, (KVCache, QuantKVCache, MLACache, MambaCache,
                              MLSTMCache, SLSTMCache))

    return jax.tree.map(handle, caches, is_leaf=is_cache)
