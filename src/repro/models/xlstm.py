"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent gating), per arXiv:2405.04517.

Both are exponential-gated LSTMs with a running log-max stabilizer `m_t`.
The mLSTM carries a per-head (dh × dh) matrix memory
``C_t = f'·C_{t-1} + i'·v k^T`` (no hidden-to-gate recurrence → the time
loop could be chunk-parallelized); the sLSTM's gates see `h_{t-1}` through
per-head recurrent matrices, so it is inherently sequential.

TPU mapping: outer `lax.scan` over time chunks with `jax.checkpoint`ed
bodies (backward recomputes inside the chunk; only chunk-boundary states
are stored — the same memory discipline as the Mamba mixer), inner exact
`lax.scan` over steps.  The per-step compute is outer-product/matvec
shaped, which the VPU handles; projections are MXU matmuls.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMCache(NamedTuple):
    C: jnp.ndarray       # (B, H, dh, dh)
    n: jnp.ndarray       # (B, H, dh)
    m: jnp.ndarray       # (B, H)
    conv: jnp.ndarray    # (B, K-1, d_inner)
    pos: jnp.ndarray


_CONV_K = 4
_EXPAND = 2


def _mdims(cfg: ModelConfig):
    d_inner = _EXPAND * cfg.d_model
    dh = d_inner // cfg.n_heads
    return d_inner, dh


def mlstm_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, dh = _mdims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_inner),   # [xu ‖ gate branch]
        "conv_w": (jax.random.normal(ks[1], (_CONV_K, d_inner)) * 0.5
                   ).astype(layers.PARAM_DTYPE),
        "conv_b": jnp.zeros((d_inner,), layers.PARAM_DTYPE),
        "wq": dense_init(ks[2], d_inner, d_inner),
        "wk": dense_init(ks[3], d_inner, d_inner),
        "wv": dense_init(ks[4], d_inner, d_inner),
        "w_gates": dense_init(ks[5], d_inner, 2 * cfg.n_heads),
        "gate_b": jnp.concatenate([jnp.zeros((cfg.n_heads,)),
                                   jnp.linspace(3.0, 6.0, cfg.n_heads)]
                                  ).astype(jnp.float32),        # i, f biases
        "h_norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(ks[6], d_inner, d),
    }


def _mlstm_qkvg(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                conv_tail: jnp.ndarray | None):
    from repro.models.mamba import _conv_causal
    B, T, _ = x.shape
    d_inner, dh = _mdims(cfg)
    H = cfg.n_heads
    xu, xg = jnp.split(x @ params["in_proj"], 2, axis=-1)
    xc = _conv_causal(xu, params["conv_w"], params["conv_b"], conv_tail)
    q = (xc @ params["wq"]).reshape(B, T, H, dh)
    k = (xc @ params["wk"]).reshape(B, T, H, dh) * dh ** -0.5
    v = (xu @ params["wv"]).reshape(B, T, H, dh)
    gates = (xc @ params["w_gates"]).astype(jnp.float32) \
        + params["gate_b"]
    i_t, f_t = gates[..., :H], gates[..., H:]        # (B, T, H) pre-acts
    f_t = jax.nn.log_sigmoid(f_t)                    # log forget gate
    return q, k, v, i_t, f_t, xg, xu


def _mlstm_step(state, qkvif):
    """Stabilized mLSTM recurrence for one step (all heads)."""
    C, n, m = state
    q, k, v, i_t, f_t = qkvif                        # (B,H,dh)·3, (B,H)·2
    m_new = jnp.maximum(f_t + m, i_t)
    ip = jnp.exp(i_t - m_new)[..., None]             # (B,H,1)
    fp = jnp.exp(f_t + m - m_new)[..., None]
    C = fp[..., None] * C + ip[..., None] * jnp.einsum("bhd,bhe->bhde", v, k)
    n = fp * n + ip * k
    num = jnp.einsum("bhde,bhe->bhd", C, q.astype(jnp.float32))
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(jnp.float32)))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return (C, n, m_new), h


def _mlstm_chunkwise(q, k, v, i_t, f_t, chunk: int):
    """Chunkwise-parallel mLSTM (§Perf beyond-paper optimization).

    The sequential recurrence reads/writes the (B, H, dh, dh) matrix
    memory every step → state traffic of T·dh² per head.  The chunkwise
    form (xLSTM appendix / mlstm_kernels) computes intra-chunk terms as a
    masked (L×L) quadratic — MXU matmuls — and touches C only at chunk
    boundaries, cutting state HBM traffic by the chunk length while
    staying exactly equivalent (same stabilized math).

    q,k,v: (B,T,H,dh) f32 (k pre-scaled); i_t: (B,T,H) log-input gate;
    f_t: (B,T,H) log-forget gate.  Returns h (B,T,H,dh) f32.
    """
    B, T, H, dh = q.shape
    L = min(chunk, T)
    n_chunks = -(-T // L)
    Tp = n_chunks * L

    def pad_c(a, fill=0.0):
        pad = [(0, 0), (0, Tp - T)] + [(0, 0)] * (a.ndim - 2)
        return jnp.pad(a, pad, constant_values=fill) \
            .reshape((B, n_chunks, L) + a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = pad_c(q), pad_c(k), pad_c(v)
    # pad i with -inf so padded positions never contribute
    ic = pad_c(i_t, -1e30)
    fc = pad_c(f_t)                                   # logf; pad 0 is fine

    tri = jnp.tril(jnp.ones((L, L), bool))            # s ≤ t
    strict = jnp.tril(jnp.ones((L, L), bool), -1)     # unused pad safety

    @jax.checkpoint
    def chunk_fn(carry, xs):
        C, n, m = carry                               # (B,H,dh,dh) (B,H,dh) (B,H)
        qk, kk, vk, ik, fk = xs                       # (B,L,H,·)
        b = jnp.cumsum(fk, axis=1)                    # (B,L,H) Σ logf ≤ t
        btot = b[:, -1]                               # (B,H)

        # --- stabilizers -------------------------------------------------
        # intra exponent: b_t − b_s + a_s  (s ≤ t); inter exponent: b_t + m
        g = b[:, :, None, :] - b[:, None, :, :] \
            + ik[:, None, :, :]                       # (B,t,s,H)
        g = jnp.where(tri[None, :, :, None], g, -1e30)
        m_intra = g.max(axis=2)                       # (B,L,H)
        m_inter = b + m[:, None, :]                   # (B,L,H)
        m_comb = jnp.maximum(m_intra, m_inter)

        D = jnp.exp(g - m_comb[:, :, None, :])        # (B,t,s,H)
        s_qk = jnp.einsum("bthd,bshd->btsh", qk, kk)
        w = s_qk * D
        h_intra = jnp.einsum("btsh,bshd->bthd", w, vk)
        inter_scale = jnp.exp(m_inter - m_comb)       # (B,L,H)
        h_inter = jnp.einsum("bthe,bhde->bthd", qk, C) \
            * inter_scale[..., None]
        num = h_intra + h_inter

        n_intra = jnp.einsum("btsh,bshd->bthd", D, kk)
        n_t = n_intra + n[:, None] * inter_scale[..., None]
        den = jnp.abs(jnp.einsum("bthd,bthd->bth", n_t, qk))
        h = num / jnp.maximum(den, jnp.exp(-m_comb))[..., None]

        # --- carry update -------------------------------------------------
        m_next = jnp.maximum(btot + m,
                             (btot[:, None] - b + ik).max(axis=1))  # (B,H)
        w_s = jnp.exp(btot[:, None] - b + ik - m_next[:, None])     # (B,L,H)
        C_new = jnp.exp(btot + m - m_next)[..., None, None] * C \
            + jnp.einsum("bsh,bshd,bshe->bhde", w_s, vk, kk)
        n_new = jnp.exp(btot + m - m_next)[..., None] * n \
            + jnp.einsum("bsh,bshd->bhd", w_s, kk)
        return (C_new, n_new, m_next), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_fn, (C0, n0, m0), (qc, kc, vc, ic, fc))
    # hs: (n_chunks, B, L, H, dh) → (B, T, H, dh)
    return hs.swapaxes(0, 1).reshape(B, Tp, H, dh)[:, :T]


def mlstm_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                chunk: int = 64, impl: str | None = None) -> jnp.ndarray:
    import os
    if impl is None:
        impl = "chunkwise" \
            if os.environ.get("REPRO_MLSTM_CHUNKWISE") == "1" else "scan"
        chunk = int(os.environ.get("REPRO_MLSTM_CHUNK", chunk))
    B, T, _ = x.shape
    d_inner, dh = _mdims(cfg)
    H = cfg.n_heads
    q, k, v, i_t, f_t, xg, _ = _mlstm_qkvg(params, x, cfg, None)

    if impl == "chunkwise":
        h = _mlstm_chunkwise(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), i_t, f_t, chunk)
        h = h.reshape(B, T, H * dh)
        h = rmsnorm(h.astype(x.dtype), params["h_norm"], cfg.norm_eps)
        return (h * jax.nn.silu(xg)) @ params["out_proj"]

    Lc = min(chunk, T)
    n_chunks = -(-T // Lc)
    Tp = n_chunks * Lc

    def pad_c(a):  # (B, T, ...) → (n_chunks, B, Lc, ...)
        a = jnp.pad(a, ((0, 0), (0, Tp - T)) + ((0, 0),) * (a.ndim - 2))
        return a.reshape((B, n_chunks, Lc) + a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(pad_c, (q.astype(jnp.float32),
                                     k.astype(jnp.float32),
                                     v.astype(jnp.float32), i_t, f_t))

    @jax.checkpoint
    def chunk_fn(state, xs):
        qk, kk, vk, ik, fk = xs

        def step(s, t):
            return _mlstm_step(s, (qk[:, t], kk[:, t], vk[:, t],
                                   ik[:, t], fk[:, t]))

        state, hs = jax.lax.scan(step, state, jnp.arange(Lc))
        return state, hs                              # (Lc, B, H, dh)

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_fn, (C0, n0, m0), (qc, kc, vc, ic, fc))
    h = hs.reshape(n_chunks * Lc, B, H * dh).swapaxes(0, 1)[:, :T]
    h = rmsnorm(h.astype(x.dtype), params["h_norm"], cfg.norm_eps)
    return (h * jax.nn.silu(xg)) @ params["out_proj"]


def mlstm_init_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    d_inner, dh = _mdims(cfg)
    H = cfg.n_heads
    return MLSTMCache(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
        conv=jnp.zeros((batch, _CONV_K - 1, d_inner), layers.ACT_DTYPE),
        pos=jnp.zeros((batch,), jnp.int32))


def mlstm_decode(params: dict, x: jnp.ndarray, cache: MLSTMCache,
                 cfg: ModelConfig) -> tuple[jnp.ndarray, MLSTMCache]:
    B = x.shape[0]
    d_inner, dh = _mdims(cfg)
    H = cfg.n_heads
    xu_now = jnp.split(x @ params["in_proj"], 2, axis=-1)[0]
    q, k, v, i_t, f_t, xg, _ = _mlstm_qkvg(params, x, cfg, cache.conv)
    state = (cache.C, cache.n, cache.m)
    state, h = _mlstm_step(state, (q[:, 0].astype(jnp.float32),
                                   k[:, 0].astype(jnp.float32),
                                   v[:, 0].astype(jnp.float32),
                                   i_t[:, 0], f_t[:, 0]))
    h = h.reshape(B, 1, H * dh)
    h = rmsnorm(h.astype(x.dtype), params["h_norm"], cfg.norm_eps)
    y = (h * jax.nn.silu(xg)) @ params["out_proj"]
    conv = jnp.concatenate([cache.conv, xu_now], axis=1)[:, 1:]
    return y, MLSTMCache(C=state[0], n=state[1], m=state[2], conv=conv,
                         pos=cache.pos + 1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMCache(NamedTuple):
    c: jnp.ndarray       # (B, H, dh)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray       # (B, H)
    pos: jnp.ndarray


def slstm_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    ff = -(-int(d * 4 / 3) // 8) * 8                 # post-MLP, factor 4/3
    return {
        "wx": dense_init(ks[0], d, 4 * d),           # i, f, z, o pre-acts
        "r": (jax.random.normal(ks[1], (4, H, dh, dh))
              * dh ** -0.5).astype(layers.PARAM_DTYPE),
        "b": jnp.concatenate([jnp.zeros((d,)),
                              jnp.ones((d,)) * 2.0,  # forget-gate bias
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "h_norm": rmsnorm_init(d),
        "up": dense_init(ks[2], d, 2 * ff),          # GLU up (gate ‖ lin)
        "down": dense_init(ks[3], ff, d),
    }


def _slstm_step(params: dict, cfg: ModelConfig, state, wx_t):
    """wx_t: (B, 4d) precomputed input pre-activations for one step."""
    c, n, h, m = state
    B = c.shape[0]
    H = cfg.n_heads
    dh = cfg.d_model // H
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("ghde,bhd->gbhe", params["r"].astype(jnp.float32), hh)
    pre = wx_t.astype(jnp.float32).reshape(B, 4, H, dh).swapaxes(0, 1) \
        + params["b"].reshape(4, 1, H, dh) + rec
    i_t, f_t, z_t, o_t = pre[0], pre[1], pre[2], pre[3]
    f_log = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(f_log + m[..., None], i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_log + m[..., None] - m_new)
    c = fp * c + ip * jnp.tanh(z_t)
    n = fp * n + ip
    h_new = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1e-6)
    return (c, n, h_new.reshape(B, -1), m_new.max(-1))


def slstm_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                chunk: int = 64) -> jnp.ndarray:
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = x @ params["wx"]                            # (B, T, 4d)

    Lc = min(chunk, T)
    n_chunks = -(-T // Lc)
    Tp = n_chunks * Lc
    wx_c = jnp.pad(wx, ((0, 0), (0, Tp - T), (0, 0))) \
        .reshape(B, n_chunks, Lc, 4 * d).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_fn(state, wxk):
        def step(s, t):
            s = _slstm_step(params, cfg, s, wxk[:, t])
            return s, s[2]
        return jax.lax.scan(step, state, jnp.arange(Lc))

    c0 = jnp.zeros((B, H, dh), jnp.float32)
    state0 = (c0, c0, jnp.zeros((B, d), jnp.float32),
              jnp.full((B, H), -1e30, jnp.float32))
    _, hs = jax.lax.scan(chunk_fn, state0, wx_c)     # (n_chunks, Lc, B, d)
    h = hs.reshape(n_chunks * Lc, B, d).swapaxes(0, 1)[:, :T]
    h = rmsnorm(h.astype(x.dtype), params["h_norm"], cfg.norm_eps)
    g, u = jnp.split(h @ params["up"], 2, axis=-1)
    return (jax.nn.gelu(g) * u) @ params["down"]


def slstm_init_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return SLSTMCache(c=z, n=z, h=jnp.zeros((batch, cfg.d_model),
                                            jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32),
                      pos=jnp.zeros((batch,), jnp.int32))


def slstm_decode(params: dict, x: jnp.ndarray, cache: SLSTMCache,
                 cfg: ModelConfig) -> tuple[jnp.ndarray, SLSTMCache]:
    wx = (x @ params["wx"])[:, 0]
    state = (cache.c, cache.n, cache.h, cache.m)
    c, n, h, m = _slstm_step(params, cfg, state, wx)
    hn = rmsnorm(h[:, None].astype(x.dtype), params["h_norm"], cfg.norm_eps)
    g, u = jnp.split(hn @ params["up"], 2, axis=-1)
    y = (jax.nn.gelu(g) * u) @ params["down"]
    return y, SLSTMCache(c=c, n=n, h=h, m=m, pos=cache.pos + 1)
