"""Mamba-1 selective-SSM mixer (Jamba's recurrent layer, arXiv:2403.19887).

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel keeps the
(d_inner, d_state) state in SRAM while streaming time steps; the TPU-native
equivalent is a *chunked associative scan* — an outer `lax.scan` over time
chunks (carrying the (B, d_inner, N) state and bounding live memory) with a
`lax.associative_scan` inside each chunk (exposing parallelism to the VPU).
Each chunk body is `jax.checkpoint`ed so the backward pass recomputes the
(B, Lc, d_inner, N) intermediates instead of storing them for all T.

Decode is the exact recurrence: one state update per token, O(1) in
sequence length — this is what makes `long_500k` native for Jamba.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


class MambaCache(NamedTuple):
    h: jnp.ndarray       # (B, d_inner, N) SSM state
    conv: jnp.ndarray    # (B, d_conv-1, d_inner) causal-conv tail
    pos: jnp.ndarray     # (B,)


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return mc, d_inner, dt_rank


def mamba_init(key: jax.Array, cfg: ModelConfig) -> dict:
    mc, d_inner, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, 2 * d_inner),
        "conv_w": (jax.random.normal(ks[1], (mc.d_conv, d_inner))
                   * (1.0 / mc.d_conv) ** 0.5).astype(layers.PARAM_DTYPE),
        "conv_b": jnp.zeros((d_inner,), layers.PARAM_DTYPE),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * mc.d_state),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner),
        "dt_bias": jnp.full((d_inner,), -4.6, layers.PARAM_DTYPE),
        "A_log": jnp.log(a),                       # f32, recurrence-critical
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, cfg.d_model),
    }


def _conv_causal(xin: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv over time.  xin: (B, T, d_inner)."""
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros((xin.shape[0], K - 1, xin.shape[2]), xin.dtype)
    else:
        pad = tail.astype(xin.dtype)
    xp = jnp.concatenate([pad, xin], axis=1)       # (B, T+K-1, d)
    out = sum(xp[:, i:i + xin.shape[1]] * w[i].astype(xin.dtype)
              for i in range(K))
    return jax.nn.silu(out + b.astype(xin.dtype))


def _ssm_inputs(params: dict, xc: jnp.ndarray, cfg: ModelConfig):
    """Per-token SSM tensors.  xc: (B, L, d_inner) (post-conv)."""
    mc, _, dt_rank = _dims(cfg)
    proj = xc @ params["x_proj"]
    dt_r = proj[..., :dt_rank]
    Bs = proj[..., dt_rank:dt_rank + mc.d_state].astype(jnp.float32)
    Cs = proj[..., dt_rank + mc.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_r @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"])                  # (d_inner, N)
    decay = jnp.exp(dt[..., None] * A)             # (B, L, d_inner, N)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bs[:, :, None, :]
    return decay, dBx, Cs


def mamba_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
                chunk: int = 256) -> jnp.ndarray:
    """Training / prefill forward.  x: (B, T, d_model)."""
    B, T, _ = x.shape
    _, d_inner, _ = _dims(cfg)
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = _conv_causal(xin, params["conv_w"], params["conv_b"])

    Lc = min(chunk, T)
    n_chunks = -(-T // Lc)
    Tp = n_chunks * Lc
    xc_p = jnp.pad(xc, ((0, 0), (0, Tp - T), (0, 0)))
    xc_c = xc_p.reshape(B, n_chunks, Lc, d_inner).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def chunk_fn(h0, xck):
        decay, dBx, Cs = _ssm_inputs(params, xck, cfg)

        def comb(a, b):
            return (a[0] * b[0], a[1] * b[0] + b[1])

        cumA, hloc = jax.lax.associative_scan(comb, (decay, dBx), axis=1)
        h = hloc + cumA * h0[:, None]               # (B, Lc, d_inner, N)
        y = jnp.einsum("blds,bls->bld", h, Cs)
        y = y + params["D"] * xck.astype(jnp.float32)
        return h[:, -1], y

    h0 = jnp.zeros((B, d_inner, cfg.mamba.d_state), jnp.float32)
    _, ys = jax.lax.scan(chunk_fn, h0, xc_c)        # (n_chunks, B, Lc, d)
    y = ys.transpose(1, 0, 2, 3).reshape(B, Tp, d_inner)[:, :T]
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return y @ params["out_proj"]


def mamba_init_cache(cfg: ModelConfig, batch: int) -> MambaCache:
    mc, d_inner, _ = _dims(cfg)
    return MambaCache(
        h=jnp.zeros((batch, d_inner, mc.d_state), jnp.float32),
        conv=jnp.zeros((batch, mc.d_conv - 1, d_inner), layers.ACT_DTYPE),
        pos=jnp.zeros((batch,), jnp.int32))


def mamba_decode(params: dict, x: jnp.ndarray, cache: MambaCache,
                 cfg: ModelConfig) -> tuple[jnp.ndarray, MambaCache]:
    """One token.  x: (B, 1, d_model)."""
    B = x.shape[0]
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)              # (B, 1, d_inner)

    window = jnp.concatenate([cache.conv, xin], axis=1)  # (B, K, d_inner)
    w = params["conv_w"]
    xc = jax.nn.silu((window * w.astype(window.dtype)[None]).sum(1)
                     + params["conv_b"].astype(window.dtype))[:, None]
    decay, dBx, Cs = _ssm_inputs(params, xc, cfg)
    h = decay[:, 0] * cache.h + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cs[:, 0])
    y = y + params["D"] * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype) * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, MambaCache(h=h, conv=window[:, 1:], pos=cache.pos + 1)
