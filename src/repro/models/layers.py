"""Shared primitive layers: RMSNorm, RoPE, SwiGLU, embeddings.

Plain init/apply function pairs over nested-dict params — everything is
`jax.eval_shape`-safe so the dry-run can build abstract parameter trees
without allocating 671B-parameter models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
ACT_DTYPE = jnp.bfloat16


def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, dtype=jnp.float32)
            * scale).astype(PARAM_DTYPE)


def dense_init(key: jax.Array, d_in: int, d_out: int) -> jnp.ndarray:
    return _normal(key, (d_in, d_out), (1.0 / d_in) ** 0.5)


def rmsnorm_init(d: int) -> jnp.ndarray:
    return jnp.ones((d,), dtype=PARAM_DTYPE)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                       dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, T, H, Dh); positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                         # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff),
        "up": dense_init(k2, d_model, d_ff),
        "down": dense_init(k3, d_ff, d_model),
    }


def mlp_apply(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ params["gate"])
    return ((g * (x @ params["up"])) @ params["down"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d_model: int) -> jnp.ndarray:
    return _normal(key, (vocab, d_model), 0.02)


def embed_apply(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return table[tokens]        # activations inherit the param dtype


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray,
            transpose: bool) -> jnp.ndarray:
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    return xf @ (w.T if transpose else w)
