"""Architecture configuration schema for the model substrate.

Every assigned architecture (`src/repro/configs/<id>.py`) instantiates a
:class:`ModelConfig`.  Layer stacks are described as *segments* —
``(repeat, pattern)`` pairs where ``pattern`` is a tuple of
:class:`LayerSpec`s — so heterogeneous stacks (Jamba's 1:7 attn:Mamba
interleave, DeepSeek's 3 dense + 58 MoE layers, xLSTM's 7:1 mLSTM:sLSTM)
scan over the repeat axis with the pattern unrolled inside, keeping the
lowered HLO small for 512-device dry-runs.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "mamba", "mlstm", "slstm"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int              # per-expert FFN hidden size
    n_shared: int = 0          # always-on shared experts (DeepSeek-V3)
    router_aux_coef: float = 0.01
    sharding: Literal["ep", "tp"] = "ep"   # expert- vs tensor-parallel


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention (arXiv:2412.19437)."""
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0           # 0 → ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: tuple[tuple[int, tuple[LayerSpec, ...]], ...]
    head_dim: int = 0          # 0 → d_model // n_heads
    qk_norm: bool = False
    attn_kind: Literal["gqa", "mla"] = "gqa"
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    window: int = 0            # 0 → full causal; >0 → sliding window
    long_window: int = 8192    # window used by the long_500k serve variant
    modality: Literal["text", "audio", "vlm"] = "text"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""           # citation for the config

    @property
    def d_head(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 128 multiple so the embedding/LM head
        always shard over the model axis (§Perf: a non-divisible vocab —
        granite-moe's 49155 — otherwise falls back to a *replicated* head
        and the full (B, T, V) f32 logits get all-gathered+all-reduced:
        measured at 2×206 GB/device/step on train_4k).  Padded logit
        columns are masked to −inf in the loss/argmax."""
        return -(-self.vocab // 128) * 128

    def layer_list(self) -> list[LayerSpec]:
        out: list[LayerSpec] = []
        for repeat, pattern in self.segments:
            out.extend(list(pattern) * repeat)
        assert len(out) == self.n_layers, \
            f"{self.name}: segments give {len(out)} layers, " \
            f"config says {self.n_layers}"
        return out

    def param_count(self) -> int:
        """Exact parameter count (used for 6·N·D model FLOPs)."""
        from repro.models import transformer
        import jax
        shapes = jax.eval_shape(
            lambda: transformer.init(jax.random.PRNGKey(0), self))
        return sum(x.size for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k + shared experts only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        # subtract the inactive routed experts' weights
        n_moe_layers = sum(1 for s in self.layer_list() if s.ffn == "moe")
        per_expert = 3 * self.d_model * self.moe.d_expert
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) \
            * per_expert
        return total - inactive


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 128,
            n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Shrink any architecture to a CPU-smoke-testable variant of the same
    family (same mixer mix, same ffn kinds, ≤4 experts)."""
    layers = cfg.layer_list()
    # keep one period of the pattern, or n_layers plain layers
    pattern = tuple(layers[:n_layers])
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    d_head = d_model // n_heads
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(4, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k), d_expert=d_model // 2,
            n_shared=min(1, cfg.moe.n_shared))
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora=d_model, kv_lora=d_model // 2,
                        d_nope=d_head, d_rope=d_head // 2, d_v=d_head)
    mamba = cfg.mamba
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=len(pattern),
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        d_ff=d_model * 2 if cfg.d_ff else 0, vocab=vocab,
        segments=((1, pattern),), head_dim=d_head, mla=mla, moe=moe,
        mamba=mamba, window=min(cfg.window, 64) if cfg.window else 0,
        long_window=64)
