"""Modality-frontend STUBS (the one sanctioned carve-out, per assignment).

The audio codec (EnCodec) and vision tokenizer (VQ-GAN) are external
frontends; this repo implements the decoder backbones that consume their
token streams.  These stubs supply shape/distribution-correct stand-ins:

* ``audio_tokens``   — EnCodec-style codebook ids (musicgen-large).
* ``vq_image_tokens``— interleaved text + VQ-image spans within the fused
  vocabulary (chameleon-34b): image spans are 1024-token blocks drawn from
  the top 8192 ids (Chameleon reserves a contiguous VQ range).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def audio_tokens(key: jax.Array, cfg: ModelConfig, batch: int,
                 seq: int) -> jnp.ndarray:
    """EnCodec frame tokens (flattened codebook stream)."""
    return jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)


def vq_image_tokens(key: jax.Array, cfg: ModelConfig, batch: int,
                    seq: int, image_span: int = 1024) -> jnp.ndarray:
    """Early-fusion stream: text tokens with VQ image-token spans."""
    k_txt, k_img, k_pos = jax.random.split(key, 3)
    # reserved VQ range: top 8192 ids, or the top half for reduced vocabs
    vq_lo = max(cfg.vocab - 8192, cfg.vocab // 2)
    image_span = min(image_span, max(seq // 2, 1))
    text = jax.random.randint(k_txt, (batch, seq), 0, vq_lo, jnp.int32)
    img = jax.random.randint(k_img, (batch, seq), vq_lo, cfg.vocab,
                             jnp.int32)
    start = jax.random.randint(k_pos, (batch, 1), 0,
                               max(seq - image_span, 1), jnp.int32)
    pos = jnp.arange(seq)[None, :]
    in_span = (pos >= start) & (pos < start + image_span)
    return jnp.where(in_span, img, text)


def tokens_for(cfg: ModelConfig, key: jax.Array, batch: int,
               seq: int) -> jnp.ndarray:
    if cfg.modality == "audio":
        return audio_tokens(key, cfg, batch, seq)
    if cfg.modality == "vlm":
        return vq_image_tokens(key, cfg, batch, seq)
    return jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
