"""Attention family: GQA/MQA (qk-norm, sliding window) and DeepSeek MLA.

Training/prefill attention runs through a flash-style *blockwise* softmax
(`_blockwise_attn`): an outer `lax.map` over query blocks and an inner
`lax.scan` over KV blocks with running (max, denom, acc) statistics — the
(T, S) score matrix is never materialized, which is what lets the 32k
prefill and 4k×256 train shapes lower within per-device memory on the
production mesh.  The HLO is two nested loops, so the lowered program
stays small for the 512-device dry-run.

Decode attends a single query over a KV cache:
  * full cache     — (B, S, Hkv, Dh), append at `pos`;
  * sliding window — ring buffer of size W, position-validity masked;
  * MLA            — compressed latent cache (c_kv ‖ k_rope), the
    *absorbed* formulation (W_UK folded into the query, W_UV into the
    output) so decode FLOPs/bytes scale with kv_lora, not H·Dh.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models import layers
from repro.models.layers import apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention with a custom VJP
# ---------------------------------------------------------------------------
#
# Plain AD through the online-softmax scans would store every per-step
# (qb × kb) score block as scan residuals — i.e. silently materialize the
# full (T, S) attention matrix per head for the backward pass (§Perf
# iteration 1 measured this at hundreds of GB/device for train_4k).  The
# custom VJP recomputes score blocks from (q, k, v, out, m·l stats) during
# the backward sweep instead: FlashAttention's standard trade of FLOPs for
# memory, expressed in pure JAX (lax.scan over blocks).

import os as _os

# §Perf toggle: REPRO_NO_FLASH_VJP=1 reverts to plain AD through the
# online-softmax scans (the paper-faithful-but-naive baseline measured in
# EXPERIMENTS.md §Perf iteration 1).
_USE_FLASH_VJP = _os.environ.get("REPRO_NO_FLASH_VJP", "") != "1"


def _blockwise_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 512, kv_block: int = 512) -> jnp.ndarray:
    """q: (B,T,H,Dq); k: (B,S,Hkv,Dq); v: (B,S,Hkv,Dv) → (B,T,H,Dv)."""
    if not _USE_FLASH_VJP:
        out, _ = _flash_fwd_impl(q, k, v, bool(causal), int(window),
                                 int(q_block), int(kv_block))
        B, T, H, _ = q.shape
        return out.reshape(B, -1, H, out.shape[-1])[:, :T].astype(v.dtype)
    return _flash(q, k, v, bool(causal), int(window), int(q_block),
                  int(kv_block))


def _mask_block(q_pos, k_pos, S, causal, window):
    mask = k_pos[None, :] < S
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    return mask


def _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block):
    """Returns (out, lse) with lse = m + log l  (B, Tp, Hkv, G)."""
    B, T, H, Dq = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    qb = min(q_block, T)
    kb = min(kv_block, S)
    nq, nk = -(-T // qb), -(-S // kb)
    Tp, Sp = nq * qb, nk * kb
    scale = Dq ** -0.5

    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    # (B, nq, qb, Hkv, G, Dq) — grouped query heads share a KV head
    qg = qp.reshape(B, nq, qb, Hkv, G, Dq).astype(jnp.float32) * scale

    def q_block_fn(qi):
        qblk = qg[:, qi]                                   # (B,qb,Hkv,G,Dq)
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = jax.lax.dynamic_slice_in_dim(kp, ki * kb, kb, axis=1)
            vblk = jax.lax.dynamic_slice_in_dim(vp, ki * kb, kb, axis=1)
            k_pos = ki * kb + jnp.arange(kb)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk,
                           kblk.astype(jnp.float32))
            mask = _mask_block(q_pos, k_pos, S, causal, window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qb, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qb, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, qb, Hkv, G, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse

    out, lse = jax.lax.map(q_block_fn, jnp.arange(nq))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tp, Hkv, G, Dv)
    lse = lse.transpose(1, 0, 2, 3, 4).reshape(B, Tp, Hkv, G)
    return out, lse


from functools import partial as _partial  # noqa: E402


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, q_block, kv_block):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    B, T, H, _ = q.shape
    return out.reshape(B, -1, H, out.shape[-1])[:, :T].astype(v.dtype)


def _flash_fwd(q, k, v, causal, window, q_block, kv_block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_block, kv_block)
    B, T, H, _ = q.shape
    o = out.reshape(B, -1, H, out.shape[-1])[:, :T].astype(v.dtype)
    return o, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_block, kv_block, res, do):
    q, k, v, out, lse = res                      # out/lse padded+grouped f32
    B, T, H, Dq = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    qb = min(q_block, T)
    kb = min(kv_block, S)
    nq, nk = -(-T // qb), -(-S // kb)
    Tp, Sp = nq * qb, nk * kb
    scale = Dq ** -0.5

    qp = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0))) \
        .reshape(B, nq, qb, Hkv, G, Dq).astype(jnp.float32)
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0))) \
        .reshape(B, nk, kb, Hkv, Dq).astype(jnp.float32)
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0))) \
        .reshape(B, nk, kb, Hkv, Dv).astype(jnp.float32)
    dop = jnp.pad(do.astype(jnp.float32),
                  ((0, 0), (0, Tp - T), (0, 0), (0, 0))) \
        .reshape(B, nq, qb, Hkv, G, Dv)
    outg = out.reshape(B, nq, qb, Hkv, G, Dv)
    lseg = lse.reshape(B, nq, qb, Hkv, G)
    # D_i = Σ_d do·o  (B, nq, qb, Hkv, G)
    Dstat = (dop * outg).sum(-1)

    def kv_step(dq, kj):
        kblk, vblk = kp[:, kj], vp[:, kj]
        k_pos = kj * kb + jnp.arange(kb)

        def q_step(carry, qi):
            dq, dkj, dvj = carry
            qblk = qp[:, qi]
            doblk = dop[:, qi]
            q_pos = qi * qb + jnp.arange(qb)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk) * scale
            mask = _mask_block(q_pos, k_pos, S, causal, window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            p = jnp.exp(s - lseg[:, qi][..., None])        # (B,qb,Hkv,G,kb)
            dvj = dvj + jnp.einsum("bqhgk,bqhgd->bkhd", p, doblk)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", doblk, vblk)
            ds = p * (dp - Dstat[:, qi][..., None]) * scale
            dq_blk = jnp.einsum("bqhgk,bkhd->bqhgd", ds, kblk)
            dq = dq.at[:, qi].add(dq_blk)
            dkj = dkj + jnp.einsum("bqhgk,bqhgd->bkhd", ds, qblk)
            return (dq, dkj, dvj), None

        dkj0 = jnp.zeros((B, kb, Hkv, Dq), jnp.float32)
        dvj0 = jnp.zeros((B, kb, Hkv, Dv), jnp.float32)
        (dq, dkj, dvj), _ = jax.lax.scan(q_step, (dq, dkj0, dvj0),
                                         jnp.arange(nq))
        return dq, (dkj, dvj)

    dq0 = jnp.zeros((B, nq, qb, Hkv, G, Dq), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dq = dq.reshape(B, Tp, H, Dq)[:, :T].astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Sp, Hkv, Dq)[:, :S] \
        .astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Sp, Hkv, Dv)[:, :S] \
        .astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def _decode_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 valid: jnp.ndarray) -> jnp.ndarray:
    """Single-step attention.  q: (B,H,Dq); k,v: (B,S,Hkv,D*);
    valid: (B,S) bool → (B,H,Dv)."""
    B, H, Dq = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dq).astype(jnp.float32) * Dq ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, -1).astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jnp.ndarray        # (B, S_cache, Hkv, Dh)
    v: jnp.ndarray
    pos: jnp.ndarray      # (B,) next absolute position


class QuantKVCache(NamedTuple):
    """int8 KV cache (§Perf beyond-paper serving optimization).

    Decode is memory-bound on KV streaming for every assigned arch;
    storing K/V as int8 with one bf16 scale per (slot, head) halves the
    bytes read per step (9/16 of bf16 including scales).  Quantization is
    per-vector absmax; dequant happens on the fly in the attention read.
    """
    k_q: jnp.ndarray      # (B, S, Hkv, Dh) int8
    v_q: jnp.ndarray
    k_scale: jnp.ndarray  # (B, S, Hkv) bf16
    v_scale: jnp.ndarray
    pos: jnp.ndarray


def _quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (..., Dh) → int8 codes + per-vector scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), \
        scale.astype(jnp.bfloat16)


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def gqa_init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, dh = cfg.d_model, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh)
        p["k_norm"] = rmsnorm_init(dh)
    return p


def _qkv(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
         cfg: ModelConfig):
    B, T, _ = x.shape
    dh = cfg.d_head
    q = (x @ params["wq"]).reshape(B, T, cfg.n_heads, dh)
    k = (x @ params["wk"]).reshape(B, T, cfg.n_kv_heads, dh)
    v = (x @ params["wv"]).reshape(B, T, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, window: int = 0) -> jnp.ndarray:
    """Training / prefill forward.  x: (B, T, d)."""
    q, k, v = _qkv(params, x, positions, cfg)
    out = _blockwise_attn(q, k, v, causal=True,
                          window=window or cfg.window)
    B, T, _, _ = q.shape
    return out.reshape(B, T, -1) @ params["wo"]


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   window: int = 0,
                   quantized: bool = False) -> KVCache | QuantKVCache:
    s = min(window, max_len) if window else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.d_head)
    pos = jnp.zeros((batch,), jnp.int32)
    if quantized:
        sshape = shape[:-1]
        return QuantKVCache(
            k_q=jnp.zeros(shape, jnp.int8), v_q=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(sshape, jnp.bfloat16),
            v_scale=jnp.zeros(sshape, jnp.bfloat16), pos=pos)
    return KVCache(k=jnp.zeros(shape, layers.ACT_DTYPE),
                   v=jnp.zeros(shape, layers.ACT_DTYPE), pos=pos)


def gqa_decode(params: dict, x: jnp.ndarray,
               cache: KVCache | QuantKVCache, cfg: ModelConfig,
               window: int = 0) -> tuple[jnp.ndarray, KVCache | QuantKVCache]:
    """One decode step.  x: (B, 1, d) → (B, 1, d), updated cache."""
    B = x.shape[0]
    pos = cache.pos                                    # (B,)
    q, k, v = _qkv(params, x, pos[:, None], cfg)
    quant = isinstance(cache, QuantKVCache)
    S = (cache.k_q if quant else cache.k).shape[1]
    w = min(window, S) if window else 0
    slot = jnp.where(w > 0, pos % S, jnp.minimum(pos, S - 1))  # ring vs append

    bidx = jnp.arange(B)
    if quant:
        kq, ks = _quantize(k[:, 0])
        vq, vs = _quantize(v[:, 0])
        cache = cache._replace(
            k_q=cache.k_q.at[bidx, slot].set(kq),
            v_q=cache.v_q.at[bidx, slot].set(vq),
            k_scale=cache.k_scale.at[bidx, slot].set(ks),
            v_scale=cache.v_scale.at[bidx, slot].set(vs))
        kc = _dequantize(cache.k_q, cache.k_scale).astype(k.dtype)
        vc = _dequantize(cache.v_q, cache.v_scale).astype(v.dtype)
    else:
        kc = cache.k.at[bidx, slot].set(k[:, 0])
        vc = cache.v.at[bidx, slot].set(v[:, 0])
        cache = KVCache(kc, vc, pos)

    slots = jnp.arange(S)[None, :]
    if w:
        valid = slots < jnp.minimum(pos + 1, S)[:, None]
    else:
        valid = slots <= pos[:, None]
    out = _decode_attn(q[:, 0], kc, vc, valid)
    y = out.reshape(B, 1, -1) @ params["wo"]
    return y, cache._replace(pos=pos + 1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # (B, S, kv_lora)
    k_rope: jnp.ndarray   # (B, S, d_rope)
    pos: jnp.ndarray


def mla_init(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora),
        "q_norm": rmsnorm_init(m.q_lora),
        "wq_b": dense_init(ks[1], m.q_lora, H * (m.d_nope + m.d_rope)),
        "wkv_a": dense_init(ks[2], d, m.kv_lora + m.d_rope),
        "kv_norm": rmsnorm_init(m.kv_lora),
        "wkv_b": dense_init(ks[3], m.kv_lora, H * (m.d_nope + m.d_v)),
        "wo": dense_init(ks[4], H * m.d_v, d),
    }


def _mla_q(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
           cfg: ModelConfig):
    m = cfg.mla
    B, T, _ = x.shape
    cq = rmsnorm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = (cq @ params["wq_b"]).reshape(B, T, cfg.n_heads, m.d_nope + m.d_rope)
    q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
                   cfg: ModelConfig):
    m = cfg.mla
    kv = x @ params["wkv_a"]                       # (B, T, kv_lora + d_rope)
    c_kv = rmsnorm(kv[..., :m.kv_lora], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., m.kv_lora:][:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]   # shared single rope head
    return c_kv, k_rope


def mla_apply(params: dict, x: jnp.ndarray, positions: jnp.ndarray,
              cfg: ModelConfig, window: int = 0) -> jnp.ndarray:
    """Training / prefill forward (non-absorbed: materialize per-head K/V)."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(params, x, positions, cfg)
    c_kv, k_rope = _mla_kv_latent(params, x, positions, cfg)
    kvb = (c_kv @ params["wkv_b"]).reshape(B, T, H, m.d_nope + m.d_v)
    k_nope, v = kvb[..., :m.d_nope], kvb[..., m.d_nope:]
    # concat rope/nope parts → one standard attention with Dq=d_nope+d_rope
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, H, m.d_rope))],
        axis=-1)
    out = _blockwise_attn(q, k, v, causal=True, window=window or cfg.window)
    return out.reshape(B, T, H * m.d_v) @ params["wo"]


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   window: int = 0) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora), layers.ACT_DTYPE),
        k_rope=jnp.zeros((batch, max_len, m.d_rope), layers.ACT_DTYPE),
        pos=jnp.zeros((batch,), jnp.int32))


def mla_decode(params: dict, x: jnp.ndarray, cache: MLACache,
               cfg: ModelConfig, window: int = 0
               ) -> tuple[jnp.ndarray, MLACache]:
    """Absorbed decode: attend in the compressed latent space."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos = cache.pos
    q_nope, q_rope = _mla_q(params, x, pos[:, None], cfg)      # (B,1,H,·)
    c_kv_new, k_rope_new = _mla_kv_latent(params, x, pos[:, None], cfg)

    bidx = jnp.arange(B)
    S = cache.c_kv.shape[1]
    slot = jnp.minimum(pos, S - 1)
    c_kv = cache.c_kv.at[bidx, slot].set(c_kv_new[:, 0])
    k_rope = cache.k_rope.at[bidx, slot].set(k_rope_new[:, 0])

    wkv_b = params["wkv_b"].reshape(m.kv_lora, H, m.d_nope + m.d_v)
    w_uk, w_uv = wkv_b[..., :m.d_nope], wkv_b[..., m.d_nope:]
    # absorb W_UK into the query → score directly against the latent cache
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))               # (B,H,kv_lora)
    s = jnp.einsum("bhl,bsl->bhs", q_abs, c_kv.astype(jnp.float32))
    s += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                    k_rope.astype(jnp.float32))
    s *= (m.d_nope + m.d_rope) ** -0.5
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", p, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhl,lhd->bhd", o_lat, w_uv.astype(jnp.float32))
    y = o.reshape(B, 1, H * m.d_v).astype(x.dtype) @ params["wo"]
    return y, MLACache(c_kv, k_rope, pos + 1)
