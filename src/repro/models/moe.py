"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Dispatch strategy (default ``impl="capacity"``): tokens·top_k slots are
sorted by expert id and scattered into a fixed `(E, capacity)` buffer
(overflow drops, standard GShard/Switch semantics).  Expert FFNs then run
as *batched dense* einsums over the buffer — exact FLOPs in
`cost_analysis`, MXU-shaped matmuls on TPU, and the expert axis shards
cleanly (expert parallelism on the `model` mesh axis; the token→buffer
scatter lowers to the all-to-all the paper's aggregation-routing story
maps onto).

``impl="ragged"`` routes through `jax.lax.ragged_dot` (MegaBlocks-style
grouped matmul, no drops) — preferred on real TPUs with Mosaic support;
kept out of the dry-run because XLA:CPU's cost model bills ragged_dot as
E dense matmuls, which would corrupt the roofline's compute term.

DeepSeek-V3 details supported: shared (always-on) experts beside the
routed ones, sigmoid routing option, aux-free bias — we implement the
standard softmax router with a Switch-style load-balance aux loss
(coefficient per config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def _constrain_ep(buf: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """§Perf knob (REPRO_SHARD_MOE=1): pin the dispatch buffer to
    expert-parallel sharding so the token→expert movement lowers as one
    all-to-all instead of whatever resharding chain SPMD picks."""
    import os
    if os.environ.get("REPRO_SHARD_MOE") != "1" \
            or cfg.moe.sharding != "ep":
        return buf
    from jax.sharding import PartitionSpec as P
    from repro.sharding import compat
    mesh = compat.get_abstract_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return buf
    if cfg.moe.n_experts % mesh.shape["model"] != 0:
        return buf
    if buf.ndim == 4:   # per-row dispatch: (B, E, cap, d)
        return jax.lax.with_sharding_constraint(
            buf, P(None, "model", None, None))
    return jax.lax.with_sharding_constraint(buf, P("model", None, None))


def moe_init(key: jax.Array, cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, m.n_experts), jnp.float32)
                   * d ** -0.5).astype(jnp.float32),
        "gate": jax.random.normal(ks[1], (m.n_experts, d, m.d_expert),
                                  jnp.float32).astype(layers.PARAM_DTYPE)
        * d ** -0.5,
        "up": jax.random.normal(ks[2], (m.n_experts, d, m.d_expert),
                                jnp.float32).astype(layers.PARAM_DTYPE)
        * d ** -0.5,
        "down": jax.random.normal(ks[3], (m.n_experts, m.d_expert, d),
                                  jnp.float32).astype(layers.PARAM_DTYPE)
        * m.d_expert ** -0.5,
    }
    if m.n_shared:
        p["shared"] = layers.mlp_init(ks[4], d, m.n_shared * m.d_expert)
    return p


def _route(params: dict, xf: jnp.ndarray, cfg: ModelConfig):
    """xf: (S, d) → (topk weights (S,k), ids (S,k), aux loss)."""
    m = cfg.moe
    logits = xf.astype(jnp.float32) @ params["router"]        # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)       # renormalize
    # Switch-style load balance: E · Σ_e f_e · P_e
    me = probs.mean(0)                                         # (E,)
    ce = jnp.zeros((m.n_experts,)).at[ids.reshape(-1)].add(
        1.0 / ids.size)
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_coef
    return w, ids, aux


def _expert_ffn(params: dict, buf: jnp.ndarray) -> jnp.ndarray:
    """buf: (E, cap, d) → (E, cap, d) batched dense SwiGLU."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", buf, params["up"])
    return jnp.einsum("ecf,efd->ecd", h, params["down"])


def moe_apply(params: dict, x: jnp.ndarray, cfg: ModelConfig,
              capacity_factor: float = 1.25,
              impl: str = "capacity") -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) → (y (B, T, d), aux loss scalar).

    ``impl="capacity"`` (default) dispatches *per batch row*: each row
    sorts its own T·k slots into a (E, cap_row) buffer, so under a
    batch-sharded mesh the sort/scatter stays device-local and the only
    cross-device movement is the expert einsum's all-to-all/all-gather
    (§Perf iteration: the earlier global-sort formulation lowered to a
    distributed 8M-element sort — hundreds of GB of collective traffic
    per MoE layer).  ``impl="capacity_global"`` keeps the global-sort
    form for comparison; ``impl="ragged"`` is the MegaBlocks-style path.
    """
    m = cfg.moe
    B, T, d = x.shape
    S = B * T
    xf = x.reshape(S, d)
    w, ids, aux = _route(params, xf, cfg)

    if impl == "capacity":
        y = _dispatch_per_row(params, x, w.reshape(B, T, m.top_k),
                              ids.reshape(B, T, m.top_k), cfg,
                              capacity_factor)
        if m.n_shared:
            y = y + layers.mlp_apply(params["shared"], xf).reshape(B, T, d)
        return y.astype(x.dtype), aux

    k = m.top_k
    flat_ids = ids.reshape(-1)                                 # (S·k,)
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    tok_of_slot = order // k                                   # source token

    if impl == "ragged":
        counts = jnp.zeros((m.n_experts,), jnp.int32).at[sorted_ids].add(1)
        xs = xf[tok_of_slot]                                   # (S·k, d)
        g = jax.nn.silu(jax.lax.ragged_dot(xs, params["gate"], counts))
        h = g * jax.lax.ragged_dot(xs, params["up"], counts)
        ys = jax.lax.ragged_dot(h, params["down"], counts)     # (S·k, d)
        y = jnp.zeros((S, d), jnp.float32).at[tok_of_slot].add(
            ys.astype(jnp.float32) * w.reshape(-1)[order][:, None])
    else:
        cap = max(int(S * k * capacity_factor / m.n_experts), 1)
        cap = -(-cap // 8) * 8                                  # align
        counts = jnp.zeros((m.n_experts,), jnp.int32).at[sorted_ids].add(1)
        starts = jnp.cumsum(counts) - counts                    # exclusive
        pos_in_e = jnp.arange(S * k) - starts[sorted_ids]
        keep = pos_in_e < cap
        dest = jnp.where(keep, sorted_ids * cap + pos_in_e, m.n_experts * cap)
        buf = jnp.zeros((m.n_experts * cap, d), x.dtype)
        buf = buf.at[dest].set(xf[tok_of_slot], mode="drop")
        buf = _constrain_ep(buf.reshape(m.n_experts, cap, d), cfg)
        out_buf = _constrain_ep(_expert_ffn(params, buf), cfg)
        ys = out_buf.reshape(-1, d).at[dest].get(
            mode="fill", fill_value=0.0)                        # (S·k, d)
        y = jnp.zeros((S, d), jnp.float32).at[tok_of_slot].add(
            ys.astype(jnp.float32)
            * (w.reshape(-1)[order] * keep)[:, None])

    if m.n_shared:
        y = y + layers.mlp_apply(params["shared"], xf)
    return y.reshape(B, T, d).astype(x.dtype), aux


def _dispatch_per_row(params: dict, x: jnp.ndarray, w: jnp.ndarray,
                      ids: jnp.ndarray, cfg: ModelConfig,
                      capacity_factor: float) -> jnp.ndarray:
    """Row-local capacity dispatch.  x: (B,T,d); w/ids: (B,T,k)."""
    m = cfg.moe
    B, T, d = x.shape
    k = m.top_k
    cap = max(int(T * k * capacity_factor / m.n_experts), 1)
    cap = -(-cap // 4) * 4

    flat_ids = ids.reshape(B, T * k)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)        # (B, T·k)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    tok_of_slot = order // k
    counts = jax.nn.one_hot(sorted_ids, m.n_experts,
                            dtype=jnp.int32).cumsum(axis=1)
    # position within expert group = rank among equal ids seen so far − 1
    pos_in_e = jnp.take_along_axis(
        counts, sorted_ids[..., None], axis=-1)[..., 0] - 1    # (B, T·k)
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_ids * cap + pos_in_e,
                     m.n_experts * cap)

    xs = jnp.take_along_axis(
        x, tok_of_slot[..., None], axis=1)                     # (B,T·k,d)
    buf = jnp.zeros((B, m.n_experts * cap, d), x.dtype)
    bidx = jnp.arange(B)[:, None]
    buf = buf.at[bidx, dest].set(xs, mode="drop")
    buf = _constrain_ep(buf.reshape(B, m.n_experts, cap, d), cfg)

    g = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["gate"]))
    h = g * jnp.einsum("becd,edf->becf", buf, params["up"])
    out = jnp.einsum("becf,efd->becd", h, params["down"])
    out = out.reshape(B, m.n_experts * cap, d)

    ys = out.at[bidx, dest].get(mode="fill", fill_value=0.0)   # (B,T·k,d)
    wk = jnp.take_along_axis(w.reshape(B, T * k), order, axis=-1) * keep
    y = jnp.zeros((B, T, d), jnp.float32)
    y = y.at[bidx, tok_of_slot].add(ys.astype(jnp.float32) * wk[..., None])
    return y


def moe_apply_dense_ref(params: dict, x: jnp.ndarray, cfg: ModelConfig
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """O(E) dense oracle (every expert on every token) for unit tests."""
    m = cfg.moe
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    w, ids, aux = _route(params, xf, cfg)
    g = jax.nn.silu(jnp.einsum("sd,edf->sef", xf, params["gate"]))
    h = g * jnp.einsum("sd,edf->sef", xf, params["up"])
    ye = jnp.einsum("sef,efd->sed", h, params["down"])         # (S, E, d)
    mask = jax.nn.one_hot(ids, m.n_experts)                    # (S, k, E)
    comb = jnp.einsum("sk,ske->se", w, mask)
    y = jnp.einsum("se,sed->sd", comb, ye)
    if m.n_shared:
        y = y + layers.mlp_apply(params["shared"], xf)
    return y.reshape(B, T, d).astype(x.dtype), aux
