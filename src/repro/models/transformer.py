"""Composable decoder stack: blocks assembled from LayerSpecs, scanned
over segment repeat axes (small HLO for 512-device dry-runs), with a
unified decode-cache protocol across attention/Mamba/xLSTM mixers.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers, mamba, moe, xlstm
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import rmsnorm, rmsnorm_init

Params = Any
Cache = Any


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def block_init(key: jax.Array, cfg: ModelConfig, spec: LayerSpec) -> Params:
    k_mix, k_ffn = jax.random.split(key)
    p: dict = {"norm1": rmsnorm_init(cfg.d_model)}
    if spec.mixer == "attn":
        p["mixer"] = (attention.mla_init(k_mix, cfg)
                      if cfg.attn_kind == "mla"
                      else attention.gqa_init(k_mix, cfg))
    elif spec.mixer == "mamba":
        p["mixer"] = mamba.mamba_init(k_mix, cfg)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.mlstm_init(k_mix, cfg)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.slstm_init(k_mix, cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "dense":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = layers.mlp_init(k_ffn, cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = moe.moe_init(k_ffn, cfg)
    return p


def block_apply(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                cfg: ModelConfig, spec: LayerSpec, window: int = 0
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        apply = (attention.mla_apply if cfg.attn_kind == "mla"
                 else attention.gqa_apply)
        h = apply(p["mixer"], h, positions, cfg, window=window)
    elif spec.mixer == "mamba":
        h = mamba.mamba_apply(p["mixer"], h, cfg)
    elif spec.mixer == "mlstm":
        h = xlstm.mlstm_apply(p["mixer"], h, cfg)
    elif spec.mixer == "slstm":
        h = xlstm.slstm_apply(p["mixer"], h, cfg)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "dense":
        x = x + layers.mlp_apply(p["ffn"], rmsnorm(x, p["norm2"],
                                                   cfg.norm_eps))
    elif spec.ffn == "moe":
        y, aux = moe.moe_apply(p["ffn"], rmsnorm(x, p["norm2"],
                                                 cfg.norm_eps), cfg)
        x = x + y
    return x, aux


def block_init_cache(cfg: ModelConfig, spec: LayerSpec, batch: int,
                     max_len: int, window: int = 0,
                     quantized: bool | None = None) -> Cache:
    if quantized is None:
        import os
        quantized = os.environ.get("REPRO_QUANT_KV") == "1"
    if spec.mixer == "attn":
        if cfg.attn_kind == "mla":
            return attention.mla_init_cache(cfg, batch, max_len, window)
        return attention.gqa_init_cache(cfg, batch, max_len, window,
                                        quantized=quantized)
    if spec.mixer == "mamba":
        return mamba.mamba_init_cache(cfg, batch)
    if spec.mixer == "mlstm":
        return xlstm.mlstm_init_cache(cfg, batch)
    if spec.mixer == "slstm":
        return xlstm.slstm_init_cache(cfg, batch)
    raise ValueError(spec.mixer)


def block_decode(p: Params, x: jnp.ndarray, cache: Cache, cfg: ModelConfig,
                 spec: LayerSpec, window: int = 0
                 ) -> tuple[jnp.ndarray, Cache]:
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.attn_kind == "mla":
            h, cache = attention.mla_decode(p["mixer"], h, cache, cfg)
        else:
            h, cache = attention.gqa_decode(p["mixer"], h, cache, cfg,
                                            window=window)
    elif spec.mixer == "mamba":
        h, cache = mamba.mamba_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "mlstm":
        h, cache = xlstm.mlstm_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "slstm":
        h, cache = xlstm.slstm_decode(p["mixer"], h, cache, cfg)
    x = x + h
    if spec.ffn == "dense":
        x = x + layers.mlp_apply(p["ffn"], rmsnorm(x, p["norm2"],
                                                   cfg.norm_eps))
    elif spec.ffn == "moe":
        y, _ = moe.moe_apply(p["ffn"], rmsnorm(x, p["norm2"], cfg.norm_eps),
                             cfg)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init(key: jax.Array, cfg: ModelConfig) -> Params:
    k_emb, k_head, k_seg = jax.random.split(key, 3)
    params: dict = {
        "embed": layers.embed_init(k_emb, cfg.padded_vocab, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(k_head, cfg.d_model,
                                              cfg.padded_vocab)
    for si, (repeat, pattern) in enumerate(cfg.segments):
        k_si = jax.random.fold_in(k_seg, si)
        pat_params = []
        for pi, spec in enumerate(pattern):
            ks = jax.random.split(jax.random.fold_in(k_si, pi), repeat)
            pat_params.append(
                jax.vmap(lambda k, s=spec: block_init(k, cfg, s))(ks))
        params["segments"].append(tuple(pat_params))
    return params


def _constrain_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """Pin logits to (batch over FSDP axes) × (vocab over model).

    Without this SPMD sometimes materializes the *full-batch* logits per
    device at the unembed/loss boundary (§Perf: 2×12.9 GB/device/step
    measured on granite-moe train_4k)."""
    from repro.sharding import compat
    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ())
    if mesh is None or "model" not in names:
        return logits
    from jax.sharding import PartitionSpec as P
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    total = 1
    for a in fsdp:
        total *= mesh.shape[a]
    b = (fsdp if len(fsdp) > 1 else fsdp[0]) \
        if fsdp and logits.shape[0] % max(total, 1) == 0 else None
    v = "model" if logits.shape[-1] % mesh.shape["model"] == 0 else None
    spec = P(b, None, v) if logits.ndim == 3 else P(b, v)
    return jax.lax.with_sharding_constraint(logits, spec)


def _constrain_batch_only(x: jnp.ndarray) -> jnp.ndarray:
    """Pin (B, T, d) activations to batch-over-FSDP, d replicated — stops
    SPMD from resharding the unembed input to a d-over-data layout whose
    contraction partial-sums all-reduce the full-batch logits."""
    from repro.sharding import compat
    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ())
    if mesh is None or "model" not in names:
        return x
    from jax.sharding import PartitionSpec as P
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    total = 1
    for a in fsdp:
        total *= mesh.shape[a]
    if not fsdp or x.shape[0] % total != 0:
        return x
    b = fsdp if len(fsdp) > 1 else fsdp[0]
    return jax.lax.with_sharding_constraint(x, P(b, None, None))


def _logits(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x, transpose=True)
    else:
        logits = layers.unembed(params["lm_head"], x, transpose=False)
    logits = _constrain_logits(logits)
    if cfg.padded_vocab != cfg.vocab:
        # mask pad columns so loss/argmax never see them
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad, -1e30, logits)
    return logits


def forward(params: Params, cfg: ModelConfig, tokens: jnp.ndarray | None = None,
            embeds: jnp.ndarray | None = None,
            positions: jnp.ndarray | None = None, window: int = 0,
            remat: bool = True, return_hidden: bool = False
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Train/prefill forward → (logits (B,T,V) f32, aux loss scalar);
    ``return_hidden=True`` skips the unembed and returns the final
    hidden states instead (used by the sharded-CE loss path)."""
    if embeds is None:
        embeds = layers.embed_apply(params["embed"], tokens)
    x = embeds
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    aux = jnp.zeros((), jnp.float32)

    for seg_params, (repeat, pattern) in zip(params["segments"],
                                             cfg.segments):
        def seg_body(carry, lp, pattern=pattern):
            xc, auxc = carry
            for spec, p in zip(pattern, lp):
                xc, a = block_apply(p, xc, positions, cfg, spec,
                                    window=window)
                auxc = auxc + a
            return (xc, auxc), None

        body = jax.checkpoint(seg_body) if remat else seg_body
        (x, aux), _ = jax.lax.scan(body, (x, aux), seg_params)
    if return_hidden:
        return x, aux
    return _logits(params, x, cfg), aux


def _sharded_ce(params: Params, x: jnp.ndarray, labels: jnp.ndarray,
                cfg: ModelConfig) -> jnp.ndarray | None:
    """Manual-SPMD unembed + cross entropy via shard_map (§Perf).

    The auto-partitioned unembed/CE pair kept resharding the full-batch
    logits (2×12.9 GB/device/step on granite-moe even after constraint
    pinning).  Here each (data, model) shard computes its local
    (B_loc, T, V_loc) logits block and only (B, T)-sized pmax/psum cross
    shards ever move.  Returns None when inapplicable (no mesh / tied
    embeddings / non-dividing shapes) — caller falls back to the
    auto-sharded path.
    """
    from repro.sharding import compat
    mesh = compat.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ())
    if mesh is None or "model" not in names or cfg.tie_embeddings \
            or "lm_head" not in params:
        return None
    from jax.sharding import PartitionSpec as P
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    total = 1
    for a in fsdp:
        total *= mesh.shape[a]
    V = cfg.padded_vocab
    msize = mesh.shape["model"]
    if not fsdp or x.shape[0] % total != 0 or V % msize != 0:
        return None
    b = fsdp if len(fsdp) > 1 else fsdp[0]
    B, T, _ = x.shape

    def f(xl, nl, wl, ll):
        xl = rmsnorm(xl, nl, cfg.norm_eps).astype(jnp.float32)
        logits = xl @ wl.astype(jnp.float32)           # (B_loc, T, V_loc)
        Vl = logits.shape[-1]
        col = jax.lax.axis_index("model") * Vl + jnp.arange(Vl)
        logits = jnp.where(col >= cfg.vocab, -1e30, logits)
        m = jax.lax.pmax(jax.lax.stop_gradient(logits.max(-1)), "model")
        ssum = jax.lax.psum(jnp.exp(logits - m[..., None]).sum(-1), "model")
        lse = jnp.log(ssum) + m
        oh = ll[..., None] == col
        lt = jax.lax.psum(jnp.where(oh, logits, 0.0).sum(-1), "model")
        ce = jnp.sum(lse - lt)
        for a in (b if isinstance(b, tuple) else (b,)):
            ce = jax.lax.psum(ce, a)
        return ce

    ce_sum = jax.shard_map(
        f, mesh=mesh,
        in_specs=(P(b, None, None), P(None), P(None, "model"), P(b, None)),
        out_specs=P())(x, params["final_norm"], params["lm_head"], labels)
    return ce_sum / (B * T)


def _ce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray,
                    valid: jnp.ndarray | None = None) -> jnp.ndarray:
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    onehot = _constrain_logits(onehot)
    ce = lse - jnp.sum(onehot * logits, axis=-1)
    if valid is not None:
        valid = jnp.broadcast_to(valid, ce.shape)
        return (ce * valid).sum() / jnp.maximum(valid.sum(), 1.0)
    return ce.mean()


def mtp_loss(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
             labels: jnp.ndarray, depth: int = 1,
             weight: float = 0.3) -> jnp.ndarray:
    """Multi-token-prediction auxiliary objective (DeepSeek-V3 §2.2).

    Simplification recorded in DESIGN.md: V3 uses one extra transformer
    block per MTP depth; here the *same* trunk/head predicts the
    (1+depth)-ahead token from each position — the sequential-prediction
    training signal without a second tower.  Positions whose target falls
    off the sequence are masked out.
    """
    logits, _ = forward(params, cfg, tokens=tokens)
    shifted = jnp.roll(labels, -depth, axis=1)
    T = labels.shape[1]
    valid = (jnp.arange(T) < T - depth).astype(logits.dtype)[None, :]
    return weight * _ce_from_logits(logits, shifted, valid)


def lm_loss(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            labels: jnp.ndarray, window: int = 0
            ) -> tuple[jnp.ndarray, dict]:
    import os
    # Opt-in (§Perf iteration, REFUTED as a default): the shard_map CE pins
    # its input to P(data, None, None), and that constraint propagates back
    # into the layer-scan carry — every layer then reshards (52 GB/device
    # all-gathers).  Kept for meshes where the carry is already batch-only.
    if os.environ.get("REPRO_SHARDED_CE") == "1":
        hidden, aux = forward(params, cfg, tokens=tokens, window=window,
                              return_hidden=True)
        ce = _sharded_ce(params, hidden, labels, cfg)
        if ce is not None:
            return ce + aux, {"ce": ce, "aux": aux}
        # fall through: no mesh / inapplicable
        logits = _logits(params, hidden, cfg)
    else:
        logits, aux = forward(params, cfg, tokens=tokens, window=window)
    # Sharding-friendly CE: `take_along_axis` across a vocab-sharded logits
    # tensor makes SPMD all-gather the full (B, T, V/shard) activations
    # (§Perf: measured 2×12.9 GB/device/step on granite-moe).  The
    # one-hot contraction + logsumexp form keeps every vocab reduction
    # local with only (B, T)-sized cross-shard psums.
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    onehot = _constrain_logits(onehot)   # co-shard with logits
    label_logit = jnp.sum(onehot * logits, axis=-1)
    ce = (lse - label_logit).mean()
    return ce + aux, {"ce": ce, "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               window: int = 0, quantized: bool | None = None) -> list:
    caches = []
    for repeat, pattern in cfg.segments:
        pat = []
        for spec in pattern:
            c = block_init_cache(cfg, spec, batch, max_len, window,
                                 quantized)
            pat.append(jax.tree.map(
                lambda a: jnp.zeros((repeat,) + a.shape, a.dtype), c))
        caches.append(tuple(pat))
    return caches


def decode_step(params: Params, cfg: ModelConfig, token: jnp.ndarray,
                caches: list, window: int = 0
                ) -> tuple[jnp.ndarray, list]:
    """token: (B, 1) int32 → (logits (B, 1, V), updated caches)."""
    x = layers.embed_apply(params["embed"], token)
    new_caches = []
    for seg_params, seg_cache, (repeat, pattern) in zip(
            params["segments"], caches, cfg.segments):
        def seg_body(xc, lp_lc, pattern=pattern):
            lp, lc = lp_lc
            new_lc = []
            for spec, p, c in zip(pattern, lp, lc):
                xc, cn = block_decode(p, xc, c, cfg, spec, window=window)
                new_lc.append(cn)
            return xc, tuple(new_lc)

        x, nc = jax.lax.scan(seg_body, x, (seg_params, seg_cache))
        new_caches.append(nc)
    return _logits(params, x, cfg), new_caches
