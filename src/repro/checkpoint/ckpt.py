"""Minimal sharding-aware checkpointing (msgpack tensor store).

Saves any pytree of arrays as {flat_key: (dtype, shape, bytes)} plus the
treedef; restore reassembles and (optionally) device_puts onto provided
shardings.  Enough for single-host runs and for the federated drivers;
a production deployment would swap in a tensorstore/OCDBT backend behind
the same two functions.
"""
from __future__ import annotations

import io
import pathlib
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str | pathlib.Path, tree: Any) -> None:
    flat = _flatten(tree)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape),
            "data": v.tobytes()}
        for k, v in flat.items()
    }
    pathlib.Path(path).parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload))


def restore(path: str | pathlib.Path, like: Any,
            shardings: Any | None = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read())
    flat = {
        k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
        for k, v in payload.items()
    }
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = flat.get(key)
        if arr is None:
            raise KeyError(
                f"checkpoint {path} lacks leaf {key!r} — it was saved "
                f"by an older state layout; restart without --resume "
                f"(or delete the stale checkpoint directory)")
        if (tuple(arr.shape) != tuple(leaf.shape)
                or np.dtype(arr.dtype) != np.dtype(leaf.dtype)):
            # explicit raise, not assert: layout-drift detection (e.g. a
            # server state saved under a different slot count) must
            # survive `python -O`.  Name the leaf and both sides so the
            # error is actionable, not just loud.
            raise ValueError(
                f"checkpoint {path}: layout mismatch for leaf {key!r} — "
                f"saved {np.dtype(arr.dtype).name}{tuple(arr.shape)}, "
                f"expected {np.dtype(leaf.dtype).name}{tuple(leaf.shape)}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
