"""Runnable training driver (CPU-scale by default; mesh-ready).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 20 \
      --reduced                      # reduced variant, CPU
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
      --steps 5 --seq 256 --batch 2  # full config, tiny shapes
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch import steps as steps_mod
from repro.models import config as mcfg
from repro.data import loader
from repro.models import stubs, transformer
from repro.optim import adamw, schedules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced family variant (CPU-sized)")
    ap.add_argument("--mtp-weight", type=float, default=0.0,
                    help="DeepSeek-style multi-token-prediction aux loss")
    ap.add_argument("--warmup", type=int, default=0,
                    help="enable warmup+cosine LR schedule")
    ap.add_argument("--save", default="", help="checkpoint path to write")
    ap.add_argument("--restore", default="",
                    help="checkpoint path to resume from")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = mcfg.reduced(cfg)
    print(f"arch={cfg.name} layers={len(cfg.layer_list())} "
          f"d_model={cfg.d_model} vocab={cfg.vocab}")

    key = jax.random.PRNGKey(args.seed)
    params = transformer.init(key, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    opt = adamw.init(params, opt_cfg)
    if args.restore:
        from repro.checkpoint import ckpt
        state = ckpt.restore(args.restore, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        print(f"restored from {args.restore} (step {int(opt.step)})")

    sched = schedules.ScheduleConfig(
        peak_lr=args.lr, warmup_steps=args.warmup,
        total_steps=max(args.steps, 1)) if args.warmup else None

    def mtp_train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, parts = transformer.lm_loss(p, cfg, batch["tokens"],
                                              batch["labels"])
            if args.mtp_weight:
                loss = loss + transformer.mtp_loss(
                    p, cfg, batch["tokens"], batch["labels"],
                    weight=args.mtp_weight)
            return loss, parts
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params)
        lr = schedules.lr_at(opt_state.step + 1, sched) if sched else None
        params, opt_state = adamw.update(params, grads, opt_state, opt_cfg,
                                         lr=lr)
        return params, opt_state, {"loss": loss, **parts}

    step = jax.jit(mtp_train_step if (args.mtp_weight or sched)
                   else steps_mod.make_train_step(cfg, opt_cfg))

    batcher = loader.TokenBatcher(cfg, args.batch, args.seq,
                                  seed=args.seed)
    for i in range(args.steps):
        batch = batcher(i)
        t0 = time.time()
        params, opt, metrics = step(params, opt, batch)
        loss = float(metrics["loss"])
        print(f"step {i:4d} loss={loss:.4f} "
              f"ce={float(metrics['ce']):.4f} "
              f"aux={float(metrics['aux']):.5f} "
              f"dt={time.time()-t0:.2f}s", flush=True)

    if args.save:
        from repro.checkpoint import ckpt
        ckpt.save(args.save, {"params": params, "opt": opt})
        print(f"saved checkpoint → {args.save}")


if __name__ == "__main__":
    main()
