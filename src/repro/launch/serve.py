"""Transformer decode demo: prefill a batch of prompts, then decode with
the unified KV-cache protocol (CPU-scale by default).

This drives the *transformer* stack's cache protocol — it is not the
federated serving plane.  Personalized federated inference (client id →
cluster model, versioned registry, warm swap) lives in
``repro.launch.fed_serve`` / ``repro.fl.serve``; see ``docs/serving.md``.

  PYTHONPATH=src python -m repro.launch.serve --arch musicgen-large \
      --reduced --prompt-len 32 --decode-steps 16 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import config as mcfg
from repro.models import stubs, transformer


def prefill_into_cache(params, cfg, tokens, caches, window=0):
    """Feed prompt tokens through decode steps to fill the cache.

    (A production system prefills with the parallel forward; the decode
    path is reused here so the driver exercises the cache protocol.)"""
    last = None
    for t in range(tokens.shape[1]):
        last, caches = transformer.decode_step(
            params, cfg, tokens[:, t:t + 1], caches, window=window)
    return last, caches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--window", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = mcfg.reduced(cfg)

    key = jax.random.PRNGKey(0)
    params = transformer.init(key, cfg)
    max_len = args.prompt_len + args.decode_steps
    caches = transformer.init_cache(cfg, args.batch, max_len, args.window)

    prompt = stubs.tokens_for(cfg, jax.random.PRNGKey(1), args.batch,
                              args.prompt_len)
    t0 = time.time()
    logits, caches = prefill_into_cache(params, cfg, prompt, caches,
                                        args.window)
    print(f"prefill {args.prompt_len} tokens: {time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, c: transformer.decode_step(
        p, cfg, t, c, window=args.window))
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.decode_steps):
        logits, caches = step(params, tok, caches)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {args.decode_steps} steps in {dt:.2f}s "
          f"({args.decode_steps*args.batch/dt:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
