"""Production mesh construction (TPU v5e target).

Functions, not module-level constants — importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import; smoke tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.sharding import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) > n:       # dry-run forces 512; single-pod uses 256
        import numpy as np
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return compat.make_mesh((1, 1), ("data", "model"))


def make_clients_mesh(n_devices: int | None = None,
                      axis: str = "clients") -> Mesh:
    """1-D ``clients`` mesh for the runtime engine's shard-mapped round
    (``fed_train --mesh clients:N``): sampled clients live one block per
    shard and aggregation is a single masked collective.  ``None`` takes
    every visible device."""
    devices = jax.devices()
    n = len(devices) if n_devices is None else n_devices
    if n < 1 or n > len(devices):
        raise ValueError(
            f"requested {n_devices} mesh devices but "
            f"{len(devices)} are visible (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N for virtual ones)")
    import numpy as np
    return Mesh(np.asarray(devices[:n]), (axis,))


# TPU v5e hardware constants (per chip) — §Roofline sources.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
