"""Post-SPMD HLO analysis: collective byte counting + roofline terms.

``collective_bytes`` parses the *optimized* (partitioned) HLO text, so all
shapes are per-device; summing result-shape bytes of every cross-replica
op gives bytes-through-ICI per device, which is the quantity the roofline
collective term divides by per-link bandwidth.
"""
from __future__ import annotations

import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor in an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_OP_RE = re.compile(
    r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"\bwhile\(.*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?(?:to_apply|calls)="
                      r"%?([\w.\-]+)")


def _parse_computations(hlo_text: str):
    """name → list of body lines; also returns the ENTRY name."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        m = _COMP_RE.match(raw)
        if m and not raw.startswith(" "):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if raw.strip() == "}" and not raw.startswith("  "):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(raw.strip())
    return comps, entry


def collective_bytes(hlo_text: str, weighted: bool = True) -> dict[str, int]:
    """Per-collective-kind result bytes from optimized (post-SPMD) HLO.

    ``weighted=True`` multiplies ops inside `while` bodies by the loop's
    ``known_trip_count`` (recursively), so collectives inside scanned layer
    stacks / flash-attention loops are counted once **per iteration** —
    without this, a 72-layer scanned model reports 1 layer's collectives.
    Loops without a known trip count count once (conservative floor).
    """
    if not weighted:
        out = {k: 0 for k in COLLECTIVES}
        for line in hlo_text.splitlines():
            m = _OP_RE.match(line.strip())
            if m and "-done(" not in line:
                out[m.group(2)] += _shape_bytes(m.group(1))
        return out

    comps, entry = _parse_computations(hlo_text)
    memo: dict[str, dict[str, int]] = {}

    def visit(name: str, stack: tuple = ()) -> dict[str, int]:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return {k: 0 for k in COLLECTIVES}
        total = {k: 0 for k in COLLECTIVES}
        for line in comps[name]:
            m = _OP_RE.match(line)
            if m and "-done(" not in line:
                total[m.group(2)] += _shape_bytes(m.group(1))
            w = _WHILE_RE.search(line)
            if w:
                t = _TRIP_RE.search(line)
                trips = int(t.group(1)) if t else 1
                sub = visit(w.group(1), stack + (name,))
                for kk in total:
                    total[kk] += trips * sub[kk]
                continue
            c = _CALL_RE.search(line)
            if c:
                sub = visit(c.group(1), stack + (name,))
                for kk in total:
                    total[kk] += sub[kk]
        memo[name] = total
        return total

    if entry is None:
        return collective_bytes(hlo_text, weighted=False)
    return visit(entry)


def roofline(cost: dict[str, Any], coll: dict[str, int], *,
             peak_flops: float, hbm_bw: float, ici_bw: float,
             model_flops: float | None = None,
             chips: int = 1, arg_bytes: float = 0.0) -> dict[str, Any]:
    """Three-term roofline from per-device cost analysis + collective bytes.

    cost_analysis() of a partitioned module reports *per-device* FLOPs and
    bytes, so each term divides by a single chip's peak — equivalent to
    the global/(chips·peak) formulation.

    XLA's cost analysis counts `while` bodies ONCE, so scanned layer
    stacks under-report FLOPs/bytes.  We therefore also report analytic
    floors — ``compute_s_analytic`` = 6·N·D (or 2·N·D) / (chips·peak) and
    ``memory_s_floor`` = per-device argument bytes (params + optimizer +
    cache must be read every step) / HBM bw — and derive the bottleneck
    from the *effective* terms ``max(hlo, floor)``.  Collective bytes are
    trip-count-weighted (see collective_bytes), so they need no floor.
    """
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    cbytes = float(sum(coll.values()))
    t_compute = flops / peak_flops
    t_memory = bytes_accessed / hbm_bw
    t_coll = cbytes / ici_bw
    t_comp_analytic = (model_flops / (chips * peak_flops)
                       if model_flops else 0.0)
    t_mem_floor = arg_bytes / hbm_bw
    terms = {"compute_s": max(t_compute, t_comp_analytic),
             "memory_s": max(t_memory, t_mem_floor),
             "collective_s": t_coll,
             "compute_s_hlo": t_compute,
             "compute_s_analytic": t_comp_analytic,
             "memory_s_hlo": t_memory,
             "memory_s_floor": t_mem_floor,
             "hlo_flops_per_device": flops,
             "hlo_bytes_per_device": bytes_accessed,
             "collective_bytes_per_device": cbytes,
             "collective_breakdown": coll}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    if model_flops is not None:
        terms["model_flops_global"] = model_flops
        terms["useful_flops_ratio"] = (
            model_flops / (flops * chips) if flops else 0.0)
    return terms
