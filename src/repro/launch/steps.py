"""jit-able train / prefill / serve steps + abstract input specs.

``input_specs`` returns weak-type-correct `ShapeDtypeStruct`s (with
NamedShardings attached) for every model input, so the dry-run lowers
and compiles each (architecture × shape × mesh) combination without
allocating anything.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import rules


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def needs_window(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """long_500k on pure-attention archs runs the sliding-window serve
    variant (DESIGN.md long-context policy); 0 = native/full attention."""
    if shape.name == "long_500k":
        return cfg.long_window
    return 0


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()
                    ) -> Callable:
    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            transformer.lm_loss, has_aux=True)(
            params, cfg, batch["tokens"], batch["labels"])
        params, opt_state = adamw.update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **parts}
    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, _ = transformer.forward(params, cfg,
                                        tokens=batch["tokens"], remat=False)
        return logits[:, -1]      # next-token logits
    return prefill_step


def make_serve_step(cfg: ModelConfig, window: int = 0) -> Callable:
    def serve_step(params, token, caches):
        logits, caches = transformer.decode_step(params, cfg, token, caches,
                                                 window=window)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches
    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def abstract_params(cfg: ModelConfig, mesh: Mesh) -> Any:
    shapes = jax.eval_shape(partial(transformer.init, cfg=cfg),
                            jax.random.PRNGKey(0))
    moe_sh = cfg.moe.sharding if cfg.moe else "ep"
    specs = rules.param_specs(shapes, mesh, moe_sh)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, params_abs: Any,
                       opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()
                       ) -> Any:
    shapes = jax.eval_shape(lambda p: adamw.init(p, opt_cfg), params_abs)
    moe_sh = cfg.moe.sharding if cfg.moe else "ep"

    def like(tree):
        specs = rules.param_specs(tree, mesh, moe_sh)
        return jax.tree.map(
            lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), tree, specs)

    return adamw.AdamWState(
        step=_sds((), jnp.int32, mesh, P()),
        m=like(shapes.m), v=like(shapes.v))


def abstract_cache(cfg: ModelConfig, mesh: Mesh, batch: int, max_len: int,
                   window: int = 0) -> Any:
    shapes = jax.eval_shape(
        lambda: transformer.init_cache(cfg, batch, max_len, window))
    specs = rules.cache_specs(shapes, mesh)
    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), shapes, specs)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig()
                ) -> dict[str, Any]:
    """All abstract inputs for one (arch × shape × mesh) dry-run."""
    bsp = rules.batch_spec(mesh, shape.global_batch)
    params = abstract_params(cfg, mesh)
    if shape.kind == "train":
        tok = _sds((shape.global_batch, shape.seq_len), jnp.int32, mesh, bsp)
        return {
            "params": params,
            "opt_state": abstract_opt_state(cfg, mesh, params, opt_cfg),
            "batch": {"tokens": tok, "labels": tok},
        }
    if shape.kind == "prefill":
        tok = _sds((shape.global_batch, shape.seq_len), jnp.int32, mesh, bsp)
        return {"params": params, "batch": {"tokens": tok}}
    # decode: one new token + a seq_len cache
    window = needs_window(cfg, shape)
    tok = _sds((shape.global_batch, 1), jnp.int32, mesh, bsp)
    caches = abstract_cache(cfg, mesh, shape.global_batch, shape.seq_len,
                            window)
    return {"params": params, "token": tok, "caches": caches,
            "window": window}
