"""Distributed TPFL: one federated round as a single pjit program, plus
the CLI front-end of the federated runtime.

Clients are a stacked `TMParams` pytree sharded over the mesh's FSDP
("data"/"pod") axes — each shard trains its slice of the client
population locally; the confidence-clustered aggregation lowers to the
masked collective of `repro.fl.masked_collectives`.  A FedAvg-on-TM
round (full-state tree mean, no clustering) is provided as the
communication baseline: the collective-bytes delta between the two
lowered programs is the paper's Table-4/5 claim, measured in the HLO
(EXPERIMENTS.md §Perf).

CLI — run any federation scenario through `repro.fl.runtime`:

  PYTHONPATH=src python -m repro.launch.fed_train \\
      --participation 0.1 --dropout 0.2 --codec int8

reports per-round mean accuracy plus byte-exact upload/download totals
(metered from the actual encoded wire buffers).  Default knobs (full
participation, sync, float32) reproduce the legacy ``federation.run``
metrics exactly.  ``--strategy`` selects any Table-5 method — including
``flis_dc`` / ``flis_hc`` (dynamic server-side clustering, capped at
``--max-slots`` rows, probe set of ``--probe-size`` samples) and
``fedtm`` — see ``docs/baselines.md``.  ``--mesh clients:8`` runs the same round shard-mapped
over an 8-device ``clients`` mesh axis (bit-identical to in-process —
the conformance suite pins it; spawn virtual CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  ``--mode
async`` composes with ``--mesh``: the upload buffer is device state and
the buffered round runs shard-mapped end-to-end (``--async-buffer
host`` keeps the in-process numpy reference).  See
``docs/async-runtime.md``.

Telemetry: ``--telemetry-dir RUN_DIR`` records the run through the
observability plane (``repro.fl.obs``) — a manifest (config, seed,
mesh, git sha, jax version) plus one structured JSONL event per round
(accuracy deciles, cluster churn/occupancy, staleness histograms, wire
bytes, per-phase wall times) — rendered afterwards by ``python -m
repro.fl.obs summarize RUN_DIR``.  ``--profile-dir`` additionally
captures a ``jax.profiler`` device trace.  Instrumentation never
perturbs the round: obs-on == obs-off bit for bit, pinned by the
conformance suite.  Round output always includes the worst-decile
client accuracy (the distributional pFL metric), telemetry or not.
See ``docs/observability.md``.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import clustering, federation, tm
from repro.data.partition import ClientData


def make_tpfl_round(tm_cfg: tm.TMConfig,
                    fed_cfg: federation.FedConfig) -> Callable:
    """(client_params, cluster_weights, data, key) → (params, cw, metrics).

    Pure-array in/out (jit/pjit-able; all Python ints stay abstract)."""

    def round_fn(client_params: tm.TMParams, cluster_weights: jnp.ndarray,
                 data: ClientData, key: jax.Array):
        state = federation.TPFLState(client_params, cluster_weights)
        params, c_top, uploads = federation._phase_a(
            state, data, key, tm_cfg, fed_cfg)
        res = clustering.aggregate(
            uploads.reshape(-1, tm_cfg.n_clauses), c_top.reshape(-1),
            tm_cfg.n_classes, prev=cluster_weights)
        params = federation._phase_d(params, c_top, res.cluster_weights)
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test)
        return params, res.cluster_weights, {
            "mean_accuracy": acc.mean(),
            "assignment": res.assignment,
            "cluster_counts": res.counts,
        }

    return round_fn


def make_fedavg_tm_round(tm_cfg: tm.TMConfig,
                         fed_cfg: federation.FedConfig) -> Callable:
    """FedAvg over the *full* TM state (TA states + all class weights) —
    the no-personalization baseline whose all-reduce moves C·m·(2o+1)
    numbers per client instead of TPFL's m."""

    def round_fn(client_params: tm.TMParams, data: ClientData,
                 key: jax.Array):
        keys = jax.random.split(key, fed_cfg.n_clients)
        params = jax.vmap(lambda p, xt, yt, k: tm.train(
            p, xt, yt, k, tm_cfg, epochs=fed_cfg.local_epochs))(
            client_params, data.x_train, data.y_train, keys)
        # full-model averaging — the global all-reduce TPFL avoids
        ta_mean = jnp.round(params.ta_state.astype(jnp.float32).mean(0)
                            ).astype(jnp.int32)
        w_mean = jnp.round(params.weights.astype(jnp.float32).mean(0)
                           ).astype(jnp.int32)
        n = params.ta_state.shape[0]
        params = tm.TMParams(
            ta_state=jnp.broadcast_to(ta_mean, params.ta_state.shape),
            weights=jnp.broadcast_to(w_mean, params.weights.shape))
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test)
        return params, {"mean_accuracy": acc.mean()}

    return round_fn


def abstract_fed_inputs(tm_cfg: tm.TMConfig, fed_cfg: federation.FedConfig,
                        mesh, n_train: int = 64, n_test: int = 32,
                        n_conf: int = 32):
    """ShapeDtypeStructs for a mesh-wide federated round (dry-run)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import rules

    n = fed_cfg.n_clients
    o = tm_cfg.n_features
    b = rules._fsdp_or_none(mesh, n)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    C, m, L = tm_cfg.n_classes, tm_cfg.n_clauses, tm_cfg.n_literals
    params = tm.TMParams(
        ta_state=sds((n, C, m, L), jnp.int32, P(b, None, None, None)),
        weights=sds((n, C, m), jnp.int32, P(b, None, None)))
    cw = sds((C, m), jnp.float32, P(None, None))

    def dat(k, dt=jnp.uint8):
        return sds((n, k, o) if dt == jnp.uint8 else (n, k), dt,
                   P(b, None, None) if dt == jnp.uint8 else P(b, None))

    data = ClientData(
        x_train=dat(n_train), y_train=dat(n_train, jnp.int32),
        x_test=dat(n_test), y_test=dat(n_test, jnp.int32),
        x_conf=dat(n_conf), y_conf=dat(n_conf, jnp.int32),
        mixtures=sds((n, C), jnp.float32, P(b, None)))
    key = sds((2,), jnp.uint32, P(None))
    return params, cw, data, key


def abstract_round_inputs(tm_cfg: tm.TMConfig, fed_cfg: federation.FedConfig,
                          mesh, **data_kw):
    """ShapeDtypeStructs for the engine's shard-mapped sync round
    (:func:`repro.fl.runtime.executors.build_sharded_round`): the
    :func:`abstract_fed_inputs` set (single round key included, for the
    legacy-builder baselines) plus per-client rng keys and the arrival
    mask, and the client axis name the round collectives run over.
    The server matrix is wrapped in the v2 strategy-owned
    :class:`~repro.fl.runtime.strategy.ServerState` pytree (TPFL
    carries no aux) — what the dry-run lowers on the production mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.fl.runtime.strategy import ServerState
    from repro.sharding import rules

    params, cw, data, key = abstract_fed_inputs(tm_cfg, fed_cfg, mesh,
                                                **data_kw)
    n = fed_cfg.n_clients
    b = rules._fsdp_or_none(mesh, n)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    keys = sds((n, 2), jnp.uint32, P(b, None))
    arrive = sds((n,), jnp.bool_, P(b))
    return params, ServerState(cw), data, key, keys, arrive, b


def abstract_async_inputs(tm_cfg: tm.TMConfig, fed_cfg: federation.FedConfig,
                          mesh, capacity: int = 512, j_slots: int = 1):
    """ShapeDtypeStructs for the engine's shard-mapped *async* buffered
    update (:func:`repro.fl.runtime.executors.build_sharded_async_update`):
    one round's upload lanes (``n_clients · j_slots`` rows — pass the
    strategy's ``j_slots`` so multi-cluster sharing sizes them right)
    sharded over the mesh's FSDP axes, the fixed-capacity device-buffer
    lanes + server replicated.  What the dry-run lowers to price the
    async round's collectives."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import rules

    n = fed_cfg.n_clients * j_slots
    b = rules._fsdp_or_none(mesh, n)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    C, m = tm_cfg.n_classes, tm_cfg.n_clauses
    up = (sds((n, m), jnp.float32, P(b, None)),   # payload vectors
          sds((n,), jnp.int32, P(b)),             # slot ids
          sds((n,), jnp.int32, P(b)),             # maturity rounds
          sds((n,), jnp.float32, P(b)),           # staleness weights
          sds((n,), jnp.bool_, P(b)))             # validity
    buf = (sds((capacity, m), jnp.float32, P(None, None)),
           sds((capacity,), jnp.int32, P(None)),
           sds((capacity,), jnp.int32, P(None)),
           sds((capacity,), jnp.float32, P(None)),
           sds((capacity,), jnp.bool_, P(None)),
           sds((capacity,), jnp.int32, P(None)))
    round_idx = sds((), jnp.int32, P())
    prev = sds((C, m), jnp.float32, P(None, None))
    return buf, up, round_idx, prev, b


# ---------------------------------------------------------------------------
# CLI: scenario runner on the federated runtime
# ---------------------------------------------------------------------------

STRATEGY_CHOICES = ("tpfl", "fedavg", "fedprox", "ifca", "flis_dc",
                    "flis_hc", "fedtm")


def _build_strategy(name: str, tm_cfg: tm.TMConfig,
                    fed_cfg: federation.FedConfig, pool,
                    max_slots: int = 8, probe_size: int = 64):
    """``pool`` is anything with ``n_features`` / ``n_classes`` (an
    ingest :class:`~repro.data.ingest.registry.Pool`).  The TM-based
    strategies (TPFL, FedTM) take the TM config; the MLP baselines size
    themselves from the pool."""
    from repro.fl.runtime.strategy import (FedTMStrategy,
                                           build_baseline_strategy)
    if name == "tpfl":
        return federation._strategy(tm_cfg, fed_cfg)
    if name == "fedtm":
        return FedTMStrategy(tm_cfg, local_epochs=fed_cfg.local_epochs)
    return build_baseline_strategy(
        name, n_features=pool.n_features, n_classes=pool.n_classes,
        local_epochs=fed_cfg.local_epochs, max_slots=max_slots,
        probe_size=probe_size)


def build_scenario(*, dataset: str, data_dir: str | None = None,
                   encoding: str = "bool", clients: int = 20,
                   clauses: int = 48, seed: int = 0, experiment: int = 5,
                   writers: int | None = None, rounds: int = 5,
                   local_epochs: int = 2, strategy: str = "tpfl",
                   max_slots: int = 8, probe_size: int = 64):
    """One materialized federation scenario: (pool, partitioned client
    data, TM config, fed config, strategy).

    Shared by the train and serve drivers so a serving process
    reconstructs exactly the training run's setup from the same knobs —
    same dataset/seed → the same partition and the same per-client init
    chain, same strategy template → the same engine-state structure a
    published checkpoint must decode into."""
    from repro.data.ingest import natural, registry as datasets

    pool = datasets.load(dataset, data_dir=data_dir, encoding=encoding,
                         n_samples=6000, side=12, seed=seed,
                         n_writers=writers or max(25, clients))
    # writer-tagged pools take the natural writer-identity split
    # (the real per-writer ``sizes`` drive --sampling weighted),
    # the rest the paper's Dirichlet split
    data = natural.partition_pool(
        pool, n_clients=clients, n_train=80, n_test=40, n_conf=40,
        key=jax.random.PRNGKey(seed + 1), experiment=experiment)
    tm_cfg = tm.TMConfig(n_classes=pool.n_classes, n_clauses=clauses,
                         n_features=pool.n_features, n_states=63,
                         s=5.0, T=40)
    fed_cfg = federation.FedConfig(n_clients=clients, rounds=rounds,
                                   local_epochs=local_epochs)
    strat = _build_strategy(strategy, tm_cfg, fed_cfg, pool,
                            max_slots=max_slots, probe_size=probe_size)
    return pool, data, tm_cfg, fed_cfg, strat


def main(argv: list[str] | None = None) -> dict:
    import argparse

    from repro.data.ingest import registry as datasets
    from repro.fl.runtime import (CodecConfig, Engine, RuntimeConfig,
                                  SchedulerConfig, checkpointing)

    ap = argparse.ArgumentParser(
        description="Federated runtime scenario runner")
    ap.add_argument("--strategy", default="tpfl",
                    choices=STRATEGY_CHOICES)
    ap.add_argument("--max-slots", type=int, default=8,
                    help="FLIS: server slot rows — dynamic clusters are "
                         "recomputed each round and capped at this many")
    ap.add_argument("--probe-size", type=int, default=64,
                    help="FLIS: size of the server-side probe set drawn "
                         "from the confidence split")
    ap.add_argument("--dataset", default="synthmnist",
                    choices=datasets.names())
    ap.add_argument("--data-dir", default=None,
                    help="dataset cache (IDX/LEAF files; the offline "
                         "mirror populates it, real files are used "
                         "transparently — see docs/datasets.md).  "
                         "Required for the real flavours; synth* fall "
                         "back to in-memory generation without it")
    ap.add_argument("--encoding", default="bool", metavar="SPEC",
                    help="feature encoding: bool[:threshold] | "
                         "thermometer[:levels] | quantile[:levels]")
    ap.add_argument("--experiment", type=int, default=5,
                    help="paper setup 1..5 (fraction of non-IID clients)")
    ap.add_argument("--writers", type=int, default=None,
                    help="LEAF mirror size (writers ≥ clients; default "
                         "max(25, clients)).  Only shapes a cache being "
                         "written — existing shards win; clear the "
                         "data dir to regenerate")
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--n-clients", type=int, default=None,
                    dest="n_clients", metavar="N",
                    help="simulated-scale population: stream per-writer "
                         "LEAF shards on demand for the sampled cohort "
                         "instead of materializing the pool (clients "
                         "map cyclically onto writers beyond the writer "
                         "count).  Scales past RAM; requires --data-dir "
                         "and --client-store mmap.  Overrides --clients")
    ap.add_argument("--active", type=int, default=None, metavar="K",
                    help="sample K clients per round (sets "
                         "--participation K/N; the engine's working set "
                         "is O(K))")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--clauses", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    # scheduler knobs
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--sampling", default="uniform",
                    choices=("uniform", "weighted", "round_robin"))
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--straggler", type=float, default=0.0)
    ap.add_argument("--max-staleness", type=int, default=2)
    # wire codec
    ap.add_argument("--codec", default="float32",
                    choices=("float32", "int8", "int4"))
    ap.add_argument("--sparse", action="store_true",
                    help="sparse delta encoding of uploads")
    ap.add_argument("--error-feedback", action="store_true",
                    dest="error_feedback",
                    help="compression v2: per-client error-feedback "
                         "residual memory on the lossy int8/int4 uplink "
                         "— each frame's quantization error is added "
                         "back before the next encode, so the bias "
                         "cancels over rounds (carried in the engine "
                         "state, checkpoint-resumable)")
    ap.add_argument("--index-coding", default="u2", dest="index_coding",
                    choices=("u2", "vrle"),
                    help="compression v2: sparse-delta index stream "
                         "coding — u2 = raw uint16 indices, vrle = "
                         "varint-coded gap/run-length (smaller for "
                         "clustered or dense masks; requires --sparse)")
    # real transport (docs/transport.md)
    ap.add_argument("--transport", default="inprocess",
                    choices=("inprocess", "loopback", "socket"),
                    help="where the federated round's client half runs: "
                         "inprocess = the single-process engine, "
                         "loopback = worker peers behind in-memory "
                         "framed queues (bit-identical to inprocess on "
                         "the identity wire, conformance-pinned), "
                         "socket = real worker subprocesses over local "
                         "TCP, exchanging the encoded uplink/downlink "
                         "frames as length-prefixed messages")
    ap.add_argument("--workers", type=int, default=0, metavar="M",
                    help="transport worker peers; the client population "
                         "is partitioned into M contiguous blocks "
                         "(required ≥ 1 for --transport loopback/socket)")
    # aggregation mode
    ap.add_argument("--mode", default="sync", choices=("sync", "async"))
    ap.add_argument("--async-min-uploads", type=int, default=4)
    ap.add_argument("--buffer-capacity", type=int, default=64)
    ap.add_argument("--staleness-discount", type=float, default=0.5)
    ap.add_argument("--async-buffer", default="device",
                    choices=("device", "host"),
                    help="async upload buffer: device = one compiled "
                         "masked update per round (works with --mesh), "
                         "host = the numpy reference loop")
    # execution backend
    ap.add_argument("--backend", default=None,
                    choices=("inprocess", "shardmap"),
                    help="round executor; 'shardmap' without --mesh uses "
                         "a clients mesh of all visible devices "
                         "(equivalent to --mesh clients)")
    ap.add_argument("--mesh", default=None, metavar="clients[:N]",
                    help="run the round shard-mapped over a clients mesh "
                         "axis of N devices (default: all visible); "
                         "composes with --mode async (device buffer)")
    ap.add_argument("--collective", default="gather",
                    choices=("gather", "psum"),
                    help="mesh aggregation lowering: gather is bit-exact "
                         "with in-process, psum is C*m collective bytes")
    ap.add_argument("--tm-backend", default="ref",
                    choices=("ref", "pallas"),
                    help="TM compute path for tpfl/fedtm: ref = pure-jnp "
                         "reference, pallas = fused TM kernels (one "
                         "client-batched launch per round stage; "
                         "interpret mode on CPU, Mosaic on TPU).  "
                         "Bit-identical outputs, conformance-pinned; "
                         "no-op for the MLP baselines")
    # host-side client store (docs/client-store.md)
    ap.add_argument("--client-store", default="resident",
                    dest="client_store", choices=("resident", "mmap"),
                    help="mmap keeps client rows in a memory-mapped "
                         "host store and gathers/spills only the K "
                         "sampled rows per round — device/RAM O(K), "
                         "bit-identical to resident (conformance-"
                         "pinned)")
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="client-store root (default: fresh temp dir); "
                         "reuse it together with --ckpt-dir to resume")
    ap.add_argument("--store-eval", default="full", dest="store_eval",
                    choices=("full", "sampled"),
                    help="mmap evaluation scope: full = chunked "
                         "population eval (resident-identical reports), "
                         "sampled = the K merged clients only (the "
                         "simulated-scale setting)")
    # checkpointing
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    # telemetry (repro.fl.obs — docs/observability.md)
    ap.add_argument("--telemetry-dir", default=None, metavar="RUN_DIR",
                    help="record the run: manifest.json + per-round "
                         "events.jsonl (accuracy deciles, cluster "
                         "churn, staleness, wire bytes, phase wall "
                         "times); render with `python -m repro.fl.obs "
                         "summarize RUN_DIR`.  Never perturbs the "
                         "round (obs-on == obs-off, conformance-pinned)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="additionally capture a jax.profiler device "
                         "trace (TensorBoard-loadable) for the run")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(args.seed)
    streaming = args.n_clients is not None
    if streaming:
        if args.client_store != "mmap":
            raise SystemExit(
                "--n-clients streams the population on demand — it "
                "requires --client-store mmap (there is no materialized "
                "pool for the resident engine to hold)")
        if args.data_dir is None:
            raise SystemExit("--n-clients needs --data-dir (LEAF shards "
                             "to stream; the mirror writes them)")
        if args.strategy in ("flis_dc", "flis_hc"):
            raise SystemExit(
                "flis_* draws its server probe set from materialized "
                "client data at init — not available on a streamed "
                "population; use --clients instead of --n-clients")
        pool = datasets.load_stream(
            args.dataset, args.data_dir, encoding=args.encoding,
            n_samples=6000, side=12, seed=args.seed,
            n_writers=args.writers or 25)
        from repro.fl.store import StreamingClientData
        data = StreamingClientData(
            pool, n_clients=args.n_clients, n_train=80, n_test=40,
            n_conf=40, key=jax.random.PRNGKey(args.seed + 1))
        n_clients = args.n_clients
        tm_cfg = tm.TMConfig(
            n_classes=pool.n_classes, n_clauses=args.clauses,
            n_features=pool.n_features, n_states=63, s=5.0, T=40)
        fed_cfg = federation.FedConfig(n_clients=n_clients,
                                       rounds=args.rounds,
                                       local_epochs=args.local_epochs)
        strategy = _build_strategy(args.strategy, tm_cfg, fed_cfg, pool,
                                   max_slots=args.max_slots,
                                   probe_size=args.probe_size)
    else:
        pool, data, tm_cfg, fed_cfg, strategy = build_scenario(
            dataset=args.dataset, data_dir=args.data_dir,
            encoding=args.encoding, clients=args.clients,
            clauses=args.clauses, seed=args.seed,
            experiment=args.experiment, writers=args.writers,
            rounds=args.rounds, local_epochs=args.local_epochs,
            strategy=args.strategy, max_slots=args.max_slots,
            probe_size=args.probe_size)
        n_clients = args.clients

    participation = args.participation
    if args.active is not None:
        if not 0 < args.active <= n_clients:
            raise SystemExit(f"--active must be in [1, {n_clients}]")
        participation = args.active / n_clients

    mesh = None
    if args.mesh is None and args.backend == "shardmap":
        args.mesh = "clients"            # all visible devices
    if args.mesh is not None:
        if args.backend == "inprocess":
            raise SystemExit("--backend inprocess contradicts --mesh")
        from repro.launch.mesh import make_clients_mesh
        name, _, count = args.mesh.partition(":")
        if name != "clients":
            raise SystemExit(f"--mesh must be clients[:N], got {args.mesh!r}")
        mesh = make_clients_mesh(int(count) if count else None)

    rt_cfg = RuntimeConfig(
        rounds=args.rounds,
        scheduler=SchedulerConfig(
            participation=participation, sampling=args.sampling,
            dropout=args.dropout, straggler=args.straggler,
            max_staleness=args.max_staleness),
        codec=CodecConfig(args.codec, sparse=args.sparse,
                          error_feedback=args.error_feedback,
                          index_coding=args.index_coding),
        transport=args.transport, workers=args.workers,
        aggregation=args.mode,
        async_min_uploads=args.async_min_uploads,
        buffer_capacity=args.buffer_capacity,
        staleness_discount=args.staleness_discount,
        async_buffer=args.async_buffer,
        backend="shardmap" if mesh is not None else "inprocess",
        mesh_collective=args.collective,
        tm_backend=args.tm_backend,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
        client_store=args.client_store, store_dir=args.store_dir,
        store_eval=args.store_eval)

    telemetry = None
    if args.telemetry_dir or args.profile_dir:
        from repro.fl import obs
        telemetry = obs.RunRecorder(run_dir=args.telemetry_dir,
                                    profile_dir=args.profile_dir)
    runner = None
    if args.transport != "inprocess":
        if args.resume:
            raise SystemExit("--resume is an in-process engine feature; "
                             "transport runs restart from round 0")
        if streaming:
            raise SystemExit("--transport partitions a materialized "
                             "population over worker blocks — not "
                             "available with --n-clients streaming")
        from repro.fl.transport import TransportEngine
        spec = None
        if args.transport == "socket":
            # worker subprocesses rebuild the identical scenario from
            # these knobs (build_scenario is deterministic in them)
            spec = {"scenario": dict(
                dataset=args.dataset, data_dir=args.data_dir,
                encoding=args.encoding, clients=args.clients,
                clauses=args.clauses, seed=args.seed,
                experiment=args.experiment, writers=args.writers,
                rounds=args.rounds, local_epochs=args.local_epochs,
                strategy=args.strategy, max_slots=args.max_slots,
                probe_size=args.probe_size)}
        runner = TransportEngine(strategy, data, rt_cfg,
                                 telemetry=telemetry, spec=spec)
        engine = runner.eng
    else:
        engine = Engine(strategy, data, rt_cfg, mesh=mesh,
                        telemetry=telemetry)
    if telemetry is not None:
        telemetry.start(obs.build_manifest(
            config=rt_cfg, seed=args.seed, mesh=mesh,
            extra={"strategy": args.strategy, "dataset": args.dataset,
                   "encoding": args.encoding, "n_clients": n_clients,
                   "client_store": args.client_store,
                   "rounds": args.rounds, "argv": argv,
                   "collective_payload_bytes":
                       engine.collective_payload_bytes()}))

    state, remaining = None, None
    if args.resume and args.ckpt_dir:
        latest = checkpointing.latest(args.ckpt_dir)
        if latest is not None:
            state = checkpointing.restore(
                latest, engine.init(jax.random.PRNGKey(args.seed)))
            # complete the originally requested total, don't extend it
            remaining = max(0, args.rounds - int(state.round_idx))
            print(f"resumed from {latest} "
                  f"({remaining} of {args.rounds} rounds remaining)",
                  flush=True)
            if remaining == 0:
                print("nothing to do: run already complete", flush=True)
                return {"final_accuracy": None, "acc_per_round": [],
                        "upload_bytes": 0,
                        "download_bytes_broadcast": 0,
                        "download_bytes_per_client": 0}

    if runner is not None:
        where = (f"{args.transport} transport, {args.workers} worker "
                 f"{'peers' if args.transport == 'loopback' else 'processes'}")
    elif mesh is None:
        where = "in-process"
    else:
        where = f"shard_map over {engine.executor.n_shards}-device " \
                f"clients mesh ({args.collective})"
    if streaming:
        split = f"streamed ({len(pool.users)} writers, cyclic)"
    elif getattr(pool, "writers", None) is not None:
        split = "writer-natural"
    else:
        split = f"exp{args.experiment}"
    print(f"{args.strategy} on {args.dataset} [{args.encoding}, "
          f"{pool.n_features}f] {split}: "
          f"{n_clients} clients, K={engine.scheduler.k}/round, "
          f"store={args.client_store}, dropout={args.dropout}, "
          f"codec={args.codec}"
          f"{'+sparse' if args.sparse else ''}, mode={args.mode}, "
          f"backend={where}", flush=True)
    if args.sampling == "weighted" and engine.scheduler.p is not None:
        p = engine.scheduler.p
        print(f"weighted sampling from partition sizes: "
              f"p in [{float(p.min()):.4f}, {float(p.max()):.4f}]",
              flush=True)
    try:
        if runner is not None:
            state, reports = runner.run(key)
        else:
            state, reports = engine.run(key, state=state, rounds=remaining)
    finally:
        if telemetry is not None:
            telemetry.close()

    # worst-decile / per-decile client accuracy — the distributional
    # personalization metric (ROADMAP item 5's "honest pFL metric"),
    # derived from the report's per_client_accuracy; no engine change
    from repro.fl.obs.events import accuracy_deciles, worst_decile_mean

    up = down_bc = down_pc = st_rd = st_wr = 0
    for rep in reports:
        up += rep.upload_bytes
        down_bc += rep.download_bytes_broadcast
        down_pc += rep.download_bytes_per_client
        st_rd += rep.store_read_bytes
        st_wr += rep.store_written_bytes
        extra = ""
        if args.mode == "async":
            extra = (f" agg={rep.aggregated_uploads}"
                     f" buf={rep.buffered_uploads}"
                     f" evict={rep.evicted_uploads}")
        if runner is not None:
            extra += (f" wire_tx={rep.wire_tx_bytes}B"
                      f" wire_rx={rep.wire_rx_bytes}B")
        print(f"round {rep.round_idx:3d}: "
              f"acc={float(rep.mean_accuracy):.4f} "
              f"w10%={worst_decile_mean(rep.per_client_accuracy):.4f} "
              f"up={rep.upload_bytes}B "
              f"down_bc={rep.download_bytes_broadcast}B "
              f"down_pc={rep.download_bytes_per_client}B "
              f"active={int(jnp.sum(rep.participation.active))}"
              f"/{engine.scheduler.k}{extra}", flush=True)
    print(f"totals: upload={up}B ({up/1e6:.4f}MB) "
          f"download_broadcast={down_bc}B ({down_bc/1e6:.4f}MB) "
          f"download_per_client={down_pc}B ({down_pc/1e6:.4f}MB)",
          flush=True)
    if args.client_store == "mmap":
        print(f"client store: read={st_rd}B written={st_wr}B "
              f"({engine.store.written_count()} of {engine.n} rows "
              f"materialized, {engine.store.row_nbytes}B/row)",
              flush=True)
    deciles = accuracy_deciles(reports[-1].per_client_accuracy)
    print("final per-client accuracy deciles: "
          + " ".join(f"p{10 * i}={d:.3f}" for i, d in enumerate(deciles)),
          flush=True)
    if args.telemetry_dir:
        print(f"telemetry: {args.telemetry_dir} — render with "
              f"`python -m repro.fl.obs summarize {args.telemetry_dir}`",
              flush=True)
    return {"final_accuracy": float(reports[-1].mean_accuracy),
            "acc_per_round": [float(r.mean_accuracy) for r in reports],
            "final_accuracy_deciles": deciles,
            "final_worst_decile_mean": worst_decile_mean(
                reports[-1].per_client_accuracy),
            "upload_bytes": up, "download_bytes_broadcast": down_bc,
            "download_bytes_per_client": down_pc,
            "store_read_bytes": st_rd, "store_written_bytes": st_wr}


if __name__ == "__main__":
    main()
