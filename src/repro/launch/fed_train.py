"""Distributed TPFL: one federated round as a single pjit program.

Clients are a stacked `TMParams` pytree sharded over the mesh's FSDP
("data"/"pod") axes — each shard trains its slice of the client
population locally; the confidence-clustered aggregation lowers to the
masked collective of `repro.fl.masked_collectives`.  A FedAvg-on-TM
round (full-state tree mean, no clustering) is provided as the
communication baseline: the collective-bytes delta between the two
lowered programs is the paper's Table-4/5 claim, measured in the HLO
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import clustering, federation, tm
from repro.data.partition import ClientData


def make_tpfl_round(tm_cfg: tm.TMConfig,
                    fed_cfg: federation.FedConfig) -> Callable:
    """(client_params, cluster_weights, data, key) → (params, cw, metrics).

    Pure-array in/out (jit/pjit-able; all Python ints stay abstract)."""

    def round_fn(client_params: tm.TMParams, cluster_weights: jnp.ndarray,
                 data: ClientData, key: jax.Array):
        state = federation.TPFLState(client_params, cluster_weights)
        params, c_top, uploads = federation._phase_a(
            state, data, key, tm_cfg, fed_cfg)
        res = clustering.aggregate(
            uploads.reshape(-1, tm_cfg.n_clauses), c_top.reshape(-1),
            tm_cfg.n_classes, prev=cluster_weights)
        params = federation._phase_d(params, c_top, res.cluster_weights)
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test)
        return params, res.cluster_weights, {
            "mean_accuracy": acc.mean(),
            "assignment": res.assignment,
            "cluster_counts": res.counts,
        }

    return round_fn


def make_fedavg_tm_round(tm_cfg: tm.TMConfig,
                         fed_cfg: federation.FedConfig) -> Callable:
    """FedAvg over the *full* TM state (TA states + all class weights) —
    the no-personalization baseline whose all-reduce moves C·m·(2o+1)
    numbers per client instead of TPFL's m."""

    def round_fn(client_params: tm.TMParams, data: ClientData,
                 key: jax.Array):
        keys = jax.random.split(key, fed_cfg.n_clients)
        params = jax.vmap(lambda p, xt, yt, k: tm.train(
            p, xt, yt, k, tm_cfg, epochs=fed_cfg.local_epochs))(
            client_params, data.x_train, data.y_train, keys)
        # full-model averaging — the global all-reduce TPFL avoids
        ta_mean = jnp.round(params.ta_state.astype(jnp.float32).mean(0)
                            ).astype(jnp.int32)
        w_mean = jnp.round(params.weights.astype(jnp.float32).mean(0)
                           ).astype(jnp.int32)
        n = params.ta_state.shape[0]
        params = tm.TMParams(
            ta_state=jnp.broadcast_to(ta_mean, params.ta_state.shape),
            weights=jnp.broadcast_to(w_mean, params.weights.shape))
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test)
        return params, {"mean_accuracy": acc.mean()}

    return round_fn


def abstract_fed_inputs(tm_cfg: tm.TMConfig, fed_cfg: federation.FedConfig,
                        mesh, n_train: int = 64, n_test: int = 32,
                        n_conf: int = 32):
    """ShapeDtypeStructs for a mesh-wide federated round (dry-run)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding import rules

    n = fed_cfg.n_clients
    o = tm_cfg.n_features
    b = rules._fsdp_or_none(mesh, n)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    C, m, L = tm_cfg.n_classes, tm_cfg.n_clauses, tm_cfg.n_literals
    params = tm.TMParams(
        ta_state=sds((n, C, m, L), jnp.int32, P(b, None, None, None)),
        weights=sds((n, C, m), jnp.int32, P(b, None, None)))
    cw = sds((C, m), jnp.float32, P(None, None))

    def dat(k, dt=jnp.uint8):
        return sds((n, k, o) if dt == jnp.uint8 else (n, k), dt,
                   P(b, None, None) if dt == jnp.uint8 else P(b, None))

    data = ClientData(
        x_train=dat(n_train), y_train=dat(n_train, jnp.int32),
        x_test=dat(n_test), y_test=dat(n_test, jnp.int32),
        x_conf=dat(n_conf), y_conf=dat(n_conf, jnp.int32),
        mixtures=sds((n, C), jnp.float32, P(b, None)))
    key = sds((2,), jnp.uint32, P(None))
    return params, cw, data, key
