import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination on the production mesh, capture memory/cost analysis and
the collective schedule, and write one JSON artifact per combination.

MUST be run as its own process (the XLA_FLAGS line above executes before
any other import — jax locks the device count on first init).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.launch import hlo_analysis, steps            # noqa: E402
from repro.sharding import compat                        # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW,          # noqa: E402
                               PEAK_FLOPS_BF16,
                               make_production_mesh)

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def dryrun(arch: str, shape_name: str, multi_pod: bool = False,
           save: bool = True, extra_tag: str = "",
           opt_dtype: str = "f32") -> dict:
    import jax.numpy as jnp
    from repro.optim import adamw
    cfg = registry.get(arch)
    shape = steps.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    opt_cfg = adamw.AdamWConfig(
        state_dtype=jnp.bfloat16 if opt_dtype == "bf16" else jnp.float32)

    t0 = time.time()
    ins = steps.input_specs(cfg, shape, mesh, opt_cfg)

    with compat.set_mesh(mesh):
        if shape.kind == "train":
            step = steps.make_train_step(cfg, opt_cfg)
            lowered = jax.jit(step).lower(ins["params"], ins["opt_state"],
                                          ins["batch"])
        elif shape.kind == "prefill":
            step = steps.make_prefill_step(cfg)
            lowered = jax.jit(step).lower(ins["params"], ins["batch"])
        else:
            step = steps.make_serve_step(cfg, window=ins["window"])
            lowered = jax.jit(step).lower(ins["params"], ins["token"],
                                          ins["caches"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = hlo_analysis.collective_bytes(compiled.as_text())

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch           # one token per sequence
        model_flops = 2.0 * n_active * tokens

    rf = hlo_analysis.roofline(
        cost, coll, peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW,
        ici_bw=ICI_BW, model_flops=model_flops, chips=chips,
        arg_bytes=mem.argument_size_in_bytes)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "params": n_params,
        "active_params": n_active,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "roofline": rf,
    }
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{result['mesh']}" + \
            (f"_{extra_tag}" if extra_tag else "")
        (ARTIFACTS / f"dryrun_{tag}.json").write_text(
            json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every arch × shape on this mesh")
    ap.add_argument("--tag", default="", help="artifact suffix for perf runs")
    ap.add_argument("--opt-dtype", default="f32", choices=["f32", "bf16"])
    args = ap.parse_args()

    archs = list(registry.ARCHS) if args.arch in ("all",) or args.all \
        else [args.arch]
    shapes = list(steps.SHAPES) if args.shape in ("all",) or args.all \
        else [args.shape]

    failures = []
    for arch in archs:
        for shape in shapes:
            try:
                r = dryrun(arch, shape, multi_pod=args.multi_pod,
                           extra_tag=args.tag,
                           opt_dtype=args.opt_dtype)
                rf = r["roofline"]
                print(f"OK   {arch:24s} {shape:12s} {r['mesh']:8s} "
                      f"compile={r['compile_s']:.0f}s "
                      f"comp={rf['compute_s']:.2e}s "
                      f"mem={rf['memory_s']:.2e}s "
                      f"coll={rf['collective_s']:.2e}s "
                      f"bound={rf['bottleneck']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, repr(e)))
                print(f"FAIL {arch:24s} {shape:12s}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")


if __name__ == "__main__":
    main()
