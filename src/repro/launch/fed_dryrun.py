import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

"""Dry-run of the paper's technique ON THE MESH: one TPFL round vs one
FedAvg-on-TM round, lowered+compiled for the production mesh at paper
scale (C=10, m=300 clauses, o=784 features, 256 clients sharded over the
FSDP axes).  The TPFL program is the *runtime engine's* shard-mapped
sync round (`repro.fl.runtime.executors.build_sharded_round` — the same
program `fed_train --mesh` executes, clients over the mesh's data axes,
aggregation one masked psum of the (C, m) accumulator); FedAvg-on-TM
keeps the legacy full-state tree-mean builder as the baseline.  The
collective-bytes delta between the two programs is the paper's
communication claim measured in the partitioned HLO.

Also lowered: the engine's *async* buffered update
(`build_sharded_async_update` — device-buffer insert, maturity gate,
staleness-discounted psum mean), priced on the same mesh, so the
heavy-traffic straggler regime has its collective bytes on record too.

  PYTHONPATH=src python -m repro.launch.fed_dryrun [--multi-pod]
"""

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402
from pathlib import Path  # noqa: E402

import jax       # noqa: E402

from repro.core import federation, tm                     # noqa: E402
from repro.launch import fed_train, hlo_analysis          # noqa: E402
from repro.launch.mesh import ICI_BW, make_production_mesh  # noqa: E402
from repro.sharding import compat  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def client_scale(n_total: int = 1_000_000, k_active: int = 256) -> dict:
    """The million-client working set on the host side of the mesh round.

    The lowered shard-mapped round above prices K=256 active clients on
    the mesh; this section prices where those K rows *come from*: a
    memory-mapped :class:`~repro.fl.store.ClientStore` sized for
    N=1,000,000 simulated clients, strategy-faithful TM rows
    (``TPFLStrategy.init_cohort`` is the fault-in path, exactly as the
    mmap engine wires it), one K-active gather → mutate → spill → flush
    cycle timed end to end.  ``resident_bytes`` is the O(K) contract in
    numbers: only the sampled rows ever materialize, everything else is
    a hole in a sparse file."""
    import tempfile

    import numpy as np

    from repro.fl.runtime.strategy import TPFLStrategy
    from repro.fl.store import ClientStore

    tm_cfg = tm.TMConfig(n_classes=10, n_clauses=16, n_features=64,
                         n_states=63, s=5.0, T=16)
    strat = TPFLStrategy(tm_cfg, local_epochs=1)
    key = jax.random.PRNGKey(0)

    def init_fn(ids):
        return jax.tree.map(
            np.asarray, strat.init_cohort(key, np.asarray(ids), n_total))

    row = jax.tree.map(lambda a: a[0], init_fn(np.asarray([0])))
    store = ClientStore(tempfile.mkdtemp(prefix="dryrun_client_store_"),
                        n_total, {"cs": row}, init_fn=lambda ids:
                        {"cs": init_fn(ids)})
    ids = np.asarray(jax.random.choice(
        jax.random.PRNGKey(1), n_total, (k_active,), replace=False))
    t0 = time.time()
    bundle = store.gather(ids)                    # faults K rows in
    bundle = jax.tree.map(lambda a: (a + 1).astype(a.dtype), bundle)
    store.spill(ids, bundle)                      # round's writeback
    store.flush()
    wall = time.time() - t0
    back = store.gather(ids)                      # round-trip check
    ok = all(bool(np.array_equal(a, b)) for a, b in zip(
        jax.tree_util.tree_leaves(bundle), jax.tree_util.tree_leaves(back)))
    section = {
        "n_clients": n_total, "k_active": k_active,
        "row_bytes": store.row_nbytes,
        "resident_rows": store.written_count(),
        "resident_bytes": store.written_count() * store.row_nbytes,
        "gather_spill_s": round(wall, 3),
        "io_read_bytes": store.io_read_bytes,
        "io_written_bytes": store.io_written_bytes,
        "roundtrip_ok": ok,
    }
    print(f"client_scale: {k_active} of {n_total} rows resident "
          f"({section['resident_bytes']/1e6:.1f} MB of "
          f"{n_total*store.row_nbytes/1e9:.0f} GB virtual), "
          f"gather+spill {section['gather_spill_s']}s", flush=True)
    return section


def run(multi_pod: bool = False, n_clients: int = 256,
        clauses: int = 300, buffer_capacity: int = 512) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    tm_cfg = tm.TMConfig(n_classes=10, n_clauses=clauses, n_features=784,
                         n_states=127, s=10.0, T=1000)
    fed_cfg = federation.FedConfig(n_clients=n_clients, rounds=1,
                                   local_epochs=1)
    params, cw, data, key, keys, arrive, client_axes = \
        fed_train.abstract_round_inputs(tm_cfg, fed_cfg, mesh,
                                        n_train=64, n_test=32, n_conf=32)
    if client_axes is None:
        raise SystemExit(f"{n_clients} clients do not divide the mesh's "
                         f"FSDP axes — pick a multiple")

    from repro.fl.runtime.executors import (build_sharded_async_update,
                                            build_sharded_round)
    strategy = federation._strategy(tm_cfg, fed_cfg)
    engine_round = build_sharded_round(
        strategy, mesh, axis_name=client_axes, collective="psum",
        n_clients=n_clients)
    # the async buffered update (device-buffer insert → maturity gate →
    # staleness-discounted psum mean) — same builder fed_train
    # --mode async --mesh runs, lowered here at paper scale
    buf, up, round_idx, prev, _ = fed_train.abstract_async_inputs(
        tm_cfg, fed_cfg, mesh, capacity=buffer_capacity,
        j_slots=strategy.j_slots)
    async_update = build_sharded_async_update(
        strategy, mesh, axis_name=client_axes, collective="psum",
        min_uploads=4, n_valid=n_clients * strategy.j_slots)

    out = {"mesh": "2x16x16" if multi_pod else "16x16",
           "n_clients": n_clients, "clauses": clauses,
           "buffer_capacity": buffer_capacity}
    with compat.set_mesh(mesh):
        for name, build, args in (
            ("tpfl", engine_round, (params, cw, data, keys, arrive)),
            ("tpfl_async", async_update, (buf, up, round_idx, prev)),
            ("fedavg_tm", fed_train.make_fedavg_tm_round(tm_cfg, fed_cfg),
             (params, data, key)),
        ):
            t0 = time.time()
            compiled = jax.jit(build).lower(*args).compile()
            coll = hlo_analysis.collective_bytes(compiled.as_text())
            total = sum(coll.values())
            out[name] = {
                "collective_bytes_per_device": total,
                "collective_s": total / ICI_BW,
                "breakdown": coll,
                "compile_s": round(time.time() - t0, 1),
            }
            print(f"{name:10s}: {total/1e6:.3f} MB/device collectives "
                  f"({out[name]['compile_s']}s compile)", flush=True)

    if out["tpfl"]["collective_bytes_per_device"]:
        out["fedavg_over_tpfl"] = (
            out["fedavg_tm"]["collective_bytes_per_device"]
            / out["tpfl"]["collective_bytes_per_device"])
        print(f"FedAvg-TM moves {out['fedavg_over_tpfl']:.1f}× the "
              f"collective bytes of TPFL")
    out["client_scale"] = client_scale()
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / f"fed_dryrun_{out['mesh']}.json").write_text(
        json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--buffer-capacity", type=int, default=512)
    args = ap.parse_args()
    run(multi_pod=args.multi_pod, n_clients=args.clients,
        buffer_capacity=args.buffer_capacity)
