import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

"""Dry-run of the paper's technique ON THE MESH: one TPFL round vs one
FedAvg-on-TM round, lowered+compiled for the production mesh at paper
scale (C=10, m=300 clauses, o=784 features, 256 clients sharded over the
FSDP axes).  The TPFL program is the *runtime engine's* shard-mapped
sync round (`repro.fl.runtime.executors.build_sharded_round` — the same
program `fed_train --mesh` executes, clients over the mesh's data axes,
aggregation one masked psum of the (C, m) accumulator); FedAvg-on-TM
keeps the legacy full-state tree-mean builder as the baseline.  The
collective-bytes delta between the two programs is the paper's
communication claim measured in the partitioned HLO.

Also lowered: the engine's *async* buffered update
(`build_sharded_async_update` — device-buffer insert, maturity gate,
staleness-discounted psum mean), priced on the same mesh, so the
heavy-traffic straggler regime has its collective bytes on record too.

  PYTHONPATH=src python -m repro.launch.fed_dryrun [--multi-pod]
"""

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402
from pathlib import Path  # noqa: E402

import jax       # noqa: E402

from repro.core import federation, tm                     # noqa: E402
from repro.launch import fed_train, hlo_analysis          # noqa: E402
from repro.launch.mesh import ICI_BW, make_production_mesh  # noqa: E402
from repro.sharding import compat  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def run(multi_pod: bool = False, n_clients: int = 256,
        clauses: int = 300, buffer_capacity: int = 512) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    tm_cfg = tm.TMConfig(n_classes=10, n_clauses=clauses, n_features=784,
                         n_states=127, s=10.0, T=1000)
    fed_cfg = federation.FedConfig(n_clients=n_clients, rounds=1,
                                   local_epochs=1)
    params, cw, data, key, keys, arrive, client_axes = \
        fed_train.abstract_round_inputs(tm_cfg, fed_cfg, mesh,
                                        n_train=64, n_test=32, n_conf=32)
    if client_axes is None:
        raise SystemExit(f"{n_clients} clients do not divide the mesh's "
                         f"FSDP axes — pick a multiple")

    from repro.fl.runtime.executors import (build_sharded_async_update,
                                            build_sharded_round)
    strategy = federation._strategy(tm_cfg, fed_cfg)
    engine_round = build_sharded_round(
        strategy, mesh, axis_name=client_axes, collective="psum",
        n_clients=n_clients)
    # the async buffered update (device-buffer insert → maturity gate →
    # staleness-discounted psum mean) — same builder fed_train
    # --mode async --mesh runs, lowered here at paper scale
    buf, up, round_idx, prev, _ = fed_train.abstract_async_inputs(
        tm_cfg, fed_cfg, mesh, capacity=buffer_capacity,
        j_slots=strategy.j_slots)
    async_update = build_sharded_async_update(
        strategy, mesh, axis_name=client_axes, collective="psum",
        min_uploads=4, n_valid=n_clients * strategy.j_slots)

    out = {"mesh": "2x16x16" if multi_pod else "16x16",
           "n_clients": n_clients, "clauses": clauses,
           "buffer_capacity": buffer_capacity}
    with compat.set_mesh(mesh):
        for name, build, args in (
            ("tpfl", engine_round, (params, cw, data, keys, arrive)),
            ("tpfl_async", async_update, (buf, up, round_idx, prev)),
            ("fedavg_tm", fed_train.make_fedavg_tm_round(tm_cfg, fed_cfg),
             (params, data, key)),
        ):
            t0 = time.time()
            compiled = jax.jit(build).lower(*args).compile()
            coll = hlo_analysis.collective_bytes(compiled.as_text())
            total = sum(coll.values())
            out[name] = {
                "collective_bytes_per_device": total,
                "collective_s": total / ICI_BW,
                "breakdown": coll,
                "compile_s": round(time.time() - t0, 1),
            }
            print(f"{name:10s}: {total/1e6:.3f} MB/device collectives "
                  f"({out[name]['compile_s']}s compile)", flush=True)

    if out["tpfl"]["collective_bytes_per_device"]:
        out["fedavg_over_tpfl"] = (
            out["fedavg_tm"]["collective_bytes_per_device"]
            / out["tpfl"]["collective_bytes_per_device"])
        print(f"FedAvg-TM moves {out['fedavg_over_tpfl']:.1f}× the "
              f"collective bytes of TPFL")
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / f"fed_dryrun_{out['mesh']}.json").write_text(
        json.dumps(out, indent=2))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--buffer-capacity", type=int, default=512)
    args = ap.parse_args()
    run(multi_pod=args.multi_pod, n_clients=args.clients,
        buffer_capacity=args.buffer_capacity)
