"""Federated serving driver: personalized inference as a service.

The deployment half of TPFL — ``fed_train`` leaves a population of
personalized models behind (a round checkpoint, optionally an mmap
client store); this driver stands up the serving plane over them:

1. **Publish.**  The newest checkpoint under ``--ckpt-dir`` is placed
   into the ``--registry`` as an immutable version (sha256
   verify-then-place, atomic rename, sidecar last; the checkpoint
   directory's ``manifest.json`` rides along as provenance).
2. **Activate.**  The plane pulls the latest registry version —
   sidecar-verified, then decoded against this process's engine-state
   template, so a corrupted payload, flipped sidecar, or layout drift
   (different strategy / slot count / clause count) is refused loudly
   before a single request is answered.
3. **Serve.**  ``--requests`` batches of ``--batch`` requests each,
   round-robin over the client population so every batch mixes
   clusters; each batch is ONE ``predict_batched`` call (a single
   fused-votes kernel launch under ``--tm-backend pallas``).  Between
   batches the plane polls ``refresh()`` — a newer version published
   mid-serving warm-swaps in atomically (in-flight batches finish on
   the old version).

The scenario flags (``--dataset --clients --clauses --seed ...``) must
repeat the training run's: they rebuild the same partition, strategy
template, and per-client init chain the checkpoint was written under
(``launch.fed_train.build_scenario`` is shared by both drivers).  With
``--client-store mmap --store-dir`` pointing at the training store,
spilled rows serve each client's own personalized model and
never-sampled clients fall back to their deterministic init — exactly
what offline evaluation resolves.  ``--verify-offline`` proves it:
every client's served prediction is compared bit-for-bit against an
unbatched offline prediction from its resolved row, and the process
exits nonzero on any mismatch.

Not to be confused with ``repro.launch.serve`` (the *transformer*
decode demo driving the unified KV-cache protocol) — this is the
federated plane.  See ``docs/serving.md``.

  PYTHONPATH=src python -m repro.launch.fed_serve \\
      --ckpt-dir runs/ckpt --clients 20 --batch 32 --requests 8 \\
      --verify-offline
"""
from __future__ import annotations

import pathlib
import statistics
import time

import jax
import numpy as np


def _offline_predict(strategy, row, x) -> np.ndarray:
    """Unbatched reference prediction for ONE client's resolved row —
    the offline path served predictions must match bit-for-bit.  TM
    strategies go through :func:`repro.core.tm.predict` (which honours
    ``use_kernel``); MLP rows through an argmax over
    :func:`repro.core.mlp.apply`."""
    import jax.numpy as jnp

    from repro.core import mlp, tm

    if getattr(strategy, "tm_cfg", None) is not None:
        return np.asarray(tm.predict(row, x, strategy.tm_cfg))
    params = getattr(row, "params", row)   # FLIS wraps the MLP
    return np.asarray(jnp.argmax(mlp.apply(params, x), axis=-1))


def main(argv: list[str] | None = None) -> dict:
    import argparse

    from repro.data.ingest import registry as datasets
    from repro.fl.runtime import (CodecConfig, Engine, RuntimeConfig,
                                  checkpointing)
    from repro.fl.serve import ModelRegistry, ServeTelemetry, ServingPlane
    from repro.launch.fed_train import STRATEGY_CHOICES, build_scenario

    ap = argparse.ArgumentParser(
        description="Federated serving plane: personalized inference "
                    "from a versioned model registry")
    # scenario — must match the training run (rebuilds its layout)
    ap.add_argument("--strategy", default="tpfl",
                    choices=STRATEGY_CHOICES)
    ap.add_argument("--dataset", default="synthmnist",
                    choices=datasets.names())
    ap.add_argument("--data-dir", default=None)
    ap.add_argument("--encoding", default="bool", metavar="SPEC")
    ap.add_argument("--experiment", type=int, default=5)
    ap.add_argument("--writers", type=int, default=None)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--clauses", type=int, default=48)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--probe-size", type=int, default=64)
    # structural knobs that shape the checkpointed engine state
    ap.add_argument("--codec", default="float32",
                    choices=("float32", "int8", "int4"))
    ap.add_argument("--sparse", action="store_true")
    ap.add_argument("--buffer-capacity", type=int, default=64)
    ap.add_argument("--tm-backend", default="ref",
                    choices=("ref", "pallas"),
                    help="TM inference path: pallas serves each "
                         "mixed-cluster batch as one fused-votes "
                         "kernel launch (bit-identical to ref)")
    ap.add_argument("--client-store", default="resident",
                    dest="client_store", choices=("resident", "mmap"))
    ap.add_argument("--store-dir", default=None, metavar="DIR",
                    help="the training run's client-store root — "
                         "spilled rows serve personalized models, "
                         "unwritten rows fall back to deterministic "
                         "init (mmap only)")
    # registry / serving
    ap.add_argument("--ckpt-dir", default=None,
                    help="training checkpoint directory; its newest "
                         "round is published into the registry at "
                         "startup")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="registry root (default: <ckpt-dir>/registry)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8,
                    help="number of batches to serve")
    ap.add_argument("--verify-offline", action="store_true",
                    help="after serving, check every client's served "
                         "prediction bit-for-bit against its resolved "
                         "row's offline prediction; exit 1 on mismatch")
    ap.add_argument("--telemetry-dir", default=None, metavar="RUN_DIR",
                    help="write serve_events.jsonl (per-batch latency "
                         "and spans, swap/publish events) there")
    args = ap.parse_args(argv)

    if args.registry is None and args.ckpt_dir is None:
        raise SystemExit("need --registry and/or --ckpt-dir: nowhere "
                         "to pull a model from")
    registry_root = args.registry or str(
        pathlib.Path(args.ckpt_dir) / "registry")

    pool, data, tm_cfg, fed_cfg, strategy = build_scenario(
        dataset=args.dataset, data_dir=args.data_dir,
        encoding=args.encoding, clients=args.clients,
        clauses=args.clauses, seed=args.seed,
        experiment=args.experiment, writers=args.writers,
        local_epochs=args.local_epochs, strategy=args.strategy,
        max_slots=args.max_slots, probe_size=args.probe_size)

    rt_cfg = RuntimeConfig(
        codec=CodecConfig(args.codec, sparse=args.sparse),
        buffer_capacity=args.buffer_capacity,
        tm_backend=args.tm_backend,
        client_store=args.client_store, store_dir=args.store_dir)
    engine = Engine(strategy, data, rt_cfg)
    # the engine's key chain is k_init, k_rounds = split(PRNGKey(seed));
    # serving re-derives k_init so an mmap store's never-spilled rows
    # fault in exactly as the training run would have generated them
    k_init, _ = jax.random.split(jax.random.PRNGKey(args.seed))
    like = engine.init(k_init)

    telemetry = ServeTelemetry(args.telemetry_dir) \
        if args.telemetry_dir else None
    registry = ModelRegistry(registry_root)
    if args.ckpt_dir:
        newest = checkpointing.latest(args.ckpt_dir)
        if newest is not None:
            version = registry.publish(newest)
            if telemetry is not None:
                telemetry.publish_event(version, registry.path_for(version))
            print(f"published {newest} as registry version {version}",
                  flush=True)
    if registry.latest() is None:
        raise SystemExit(f"registry {registry_root} is empty and "
                         f"--ckpt-dir offered no checkpoint to publish")

    plane = ServingPlane(engine.strategy, registry, like,
                         store=engine.store, telemetry=telemetry)
    plane.refresh()
    n = args.clients
    n_test = int(np.asarray(data.x_test).shape[1])
    print(f"serving {args.strategy} version {plane.active_version} "
          f"[{args.tm_backend}] over {n} clients "
          f"(store={args.client_store}): {args.requests} batches of "
          f"{args.batch}", flush=True)

    x_test = np.asarray(data.x_test)
    latencies = []
    for r in range(args.requests):
        # stride-round-robin over the population: consecutive lanes hit
        # different clients, so every batch mixes clusters
        ids = (np.arange(args.batch) * 7 + r) % n
        x = x_test[ids, (r + np.arange(args.batch)) % n_test]
        t0 = time.perf_counter()
        preds = plane.predict(ids, x)
        latencies.append(time.perf_counter() - t0)
        del preds
        plane.refresh()   # a newer published version warm-swaps here

    lat = sorted(latencies)
    p50 = statistics.median(lat)
    p99 = lat[min(len(lat) - 1, int(round(0.99 * (len(lat) - 1))))]
    served = args.requests * args.batch
    total = sum(lat)
    rps = served / total if total > 0 else float("inf")
    print(f"served {served} requests in {total * 1e3:.1f}ms: "
          f"{rps:.0f} req/s, p50={p50 * 1e6:.0f}us "
          f"p99={p99 * 1e6:.0f}us per batch", flush=True)

    result = {"version": plane.active_version, "requests": served,
              "requests_per_s": rps, "p50_s": p50, "p99_s": p99}

    if args.verify_offline:
        # one covering batch: every client once, each with its own
        # test sample — served predictions must equal the offline
        # (unbatched, per-client) predictions of the resolved rows
        ids = np.arange(n)
        x = x_test[:, 0]
        got = plane.predict(ids, x)
        state = registry.pull(plane.active_version, like)
        rows, _ = plane._resolve_rows(state, ids)
        mismatch = 0
        for c in range(n):
            row = jax.tree_util.tree_map(lambda a: a[c], rows)
            want = _offline_predict(engine.strategy, row, x[c:c + 1])[0]
            if int(want) != int(got[c]):
                mismatch += 1
                print(f"client {c}: served {int(got[c])}, "
                      f"offline {int(want)}", flush=True)
        result["verified_clients"] = n
        result["mismatches"] = mismatch
        if mismatch:
            raise SystemExit(
                f"serving parity FAILED: {mismatch}/{n} clients differ "
                f"from offline predictions")
        print(f"offline parity: OK ({n} clients bit-identical)",
              flush=True)
    return result


if __name__ == "__main__":
    main()
