"""The ``Strategy`` protocol: every federated method as one interface.

A strategy reduces a federated method to four pieces the engine can
orchestrate uniformly:

* ``init(key, n_clients)``        → (stacked client state, server matrix)
* ``client_step(cs, server, d, key)`` → (new client state, :class:`Upload`)
* ``apply_broadcast(cs, slots, server)`` → new client state
* ``evaluate(cs, x, y)``          → scalar accuracy

The unifying trick is the *upload*: every method's round contribution is
expressed as ``j`` flat float32 vectors, each tagged with a server slot
id (slot = cluster).  TPFL uploads its ``top_classes`` clause-weight
vectors tagged by class; FedAvg/FedProx upload the flattened MLP tagged
slot 0; IFCA uploads the flattened MLP tagged with the loss-minimizing
cluster.  Aggregation is then always a (masked, optionally
staleness-weighted) per-slot mean — the same masked reduction
:mod:`repro.fl.masked_collectives` lowers to a single collective on a
mesh — and the engine's scheduler/codec/async machinery applies to every
method unchanged.  Slot id −1 means "nothing shared in this slot" and is
ignored by aggregation and broadcast.

``TPFLStrategy.client_step`` / ``apply_broadcast`` are *the* Alg. 1 /
Phase-D implementations — ``repro.core.federation`` vmaps them, so the
legacy driver and the runtime engine share one source of truth.

The ``server`` matrix a ``client_step`` receives is what the client
*holds*, not what the aggregator stores: under a lossy wire codec the
engine hands in the codec-roundtripped broadcast rows
(``Engine._wire_tx_server``), so strategies that warm-start from global
state (FedAvg/FedProx/IFCA) train from exactly the precision the wire
carried.  TPFL deletes ``server`` unread — personalization never
depends on pre-round global state.

Per-shard lowering contract
---------------------------
The engine's shard-mapped backend (``runtime/executors.py``) runs
``client_step`` / ``apply_broadcast`` / ``evaluate`` *inside*
``shard_map`` — one block of sampled clients per shard, ``server``
replicated.  That imposes three requirements on every strategy, pinned
per (strategy × codec × participation) cell by the conformance suite:

* pure jax, per-client: no host callbacks, no data-dependent shapes,
  no reads of any *other* client's row (cross-client math belongs to
  the aggregation collective, nowhere else);
* ``Upload.vecs`` float32 ``(j_slots, vec_dim)`` and ``Upload.slots``
  int32 ``(j_slots,)`` exactly — the wire codec and the masked
  collective type-pun on this framing;
* a strategy instance is hashable (frozen dataclass) and equality-
  stable, because the shard-mapped stage programs cache compiled
  executables keyed on it (``jax.jit`` static argument).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import mlp, tm
from repro.data.partition import ClientData


class Upload(NamedTuple):
    vecs: jnp.ndarray    # (j, d) float32 — what goes on the wire
    slots: jnp.ndarray   # (j,)   int32   — target server slot, −1 = none


@runtime_checkable
class Strategy(Protocol):
    n_slots: int          # rows in the server matrix
    vec_dim: int          # d — length of one uploaded vector
    j_slots: int          # uploads per client per round
    downloads: str        # "assigned" (own slot) | "all_slots" (e.g. IFCA)

    def init(self, key: jax.Array, n_clients: int): ...
    def client_step(self, cs, server: jnp.ndarray, d: ClientData,
                    key: jax.Array): ...
    def apply_broadcast(self, cs, slots: jnp.ndarray,
                        server: jnp.ndarray): ...
    def evaluate(self, cs, x: jnp.ndarray, y: jnp.ndarray): ...


# ---------------------------------------------------------------------------
# TPFL (paper Alg. 1 + Phase D)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPFLStrategy:
    """Confidence-clustered selective sharing on the Tsetlin Machine."""

    tm_cfg: tm.TMConfig
    local_epochs: int = 10
    top_classes: int = 1                 # j — §7 multi-cluster extension
    conf_threshold: float | None = None  # §7 confidence gate (−1 below)
    weighted_confidence: bool = False    # Alg. 1 uses unweighted margins

    downloads: str = dataclasses.field(default="assigned", init=False)

    @property
    def n_slots(self) -> int:
        return self.tm_cfg.n_classes

    @property
    def vec_dim(self) -> int:
        return self.tm_cfg.n_clauses

    @property
    def j_slots(self) -> int:
        return self.top_classes

    def init(self, key: jax.Array, n_clients: int):
        keys = jax.random.split(key, n_clients)
        params = jax.vmap(lambda k: tm.init_params(self.tm_cfg, k))(keys)
        server = jnp.zeros((self.n_slots, self.vec_dim), jnp.float32)
        return params, server

    def client_step(self, cs: tm.TMParams, server: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        """Alg. 1: local TM training, per-class confidence, selective
        upload of the ``top_classes`` most-confident weight vectors."""
        del server  # TPFL clients never read global state before training
        cfg = self.tm_cfg
        params = tm.train(cs, d.x_train, d.y_train, key, cfg,
                          epochs=self.local_epochs)
        conf = tm.confidence_scores(params, d.x_conf, cfg,
                                    weighted=self.weighted_confidence)
        vals, c_top = jax.lax.top_k(conf, self.top_classes)       # (j,)
        if self.conf_threshold is not None:
            c_top = jnp.where(vals >= self.conf_threshold, c_top, -1)
        vecs = params.weights[jnp.clip(c_top, 0)].astype(jnp.float32)
        return params, Upload(vecs, c_top.astype(jnp.int32))

    @staticmethod
    def apply_broadcast(cs: tm.TMParams, slots: jnp.ndarray,
                        server: jnp.ndarray) -> tm.TMParams:
        """Phase D: overwrite each shared class with its cluster mean.

        A staticmethod so ``federation._phase_d`` can call it without
        materializing a strategy (it needs no config)."""
        new_w = jnp.round(server[jnp.clip(slots, 0)]).astype(jnp.int32)

        def one(wc, c_nw):
            c, nwv = c_nw
            return jnp.where(c >= 0, wc.at[c].set(nwv), wc), None

        wc, _ = jax.lax.scan(one, cs.weights, (slots, new_w))
        return cs._replace(weights=wc)

    def evaluate(self, cs: tm.TMParams, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return tm.accuracy(cs, x, y, self.tm_cfg)


# ---------------------------------------------------------------------------
# MLP flatten/unflatten (FedAvg / FedProx / IFCA wire format)
# ---------------------------------------------------------------------------

def _mlp_layout(n_features: int, n_hidden: int, n_classes: int):
    return (("w1", (n_features, n_hidden)), ("b1", (n_hidden,)),
            ("w2", (n_hidden, n_classes)), ("b2", (n_classes,)))


def _flatten_mlp(params: mlp.Params, layout) -> jnp.ndarray:
    return jnp.concatenate([params[k].astype(jnp.float32).ravel()
                            for k, _ in layout])


def _unflatten_mlp(vec: jnp.ndarray, layout) -> mlp.Params:
    out, off = {}, 0
    for k, shape in layout:
        size = 1
        for s in shape:
            size *= s
        out[k] = vec[off:off + size].reshape(shape)
        off += size
    return out


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy:
    """FedAvg (and FedProx with ``prox_mu > 0``): one global slot."""

    n_features: int
    n_hidden: int
    n_classes: int
    local_epochs: int = 10
    batch: int = 32
    lr: float = 0.05
    prox_mu: float = 0.0          # > 0 → FedProx proximal objective

    n_slots: int = dataclasses.field(default=1, init=False)
    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="assigned", init=False)

    @property
    def _layout(self):
        return _mlp_layout(self.n_features, self.n_hidden, self.n_classes)

    @property
    def vec_dim(self) -> int:
        total = 0
        for _, shape in self._layout:
            size = 1
            for s in shape:
                size *= s
            total += size
        return total

    def init(self, key: jax.Array, n_clients: int):
        g = mlp.init(key, self.n_features, self.n_hidden, self.n_classes)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_clients,) + a.shape), g)
        return stacked, _flatten_mlp(g, self._layout)[None, :]

    def client_step(self, cs: mlp.Params, server: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        start = _unflatten_mlp(server[0], self._layout)
        ref = start if self.prox_mu > 0 else None
        p = mlp.local_train(start, d.x_train, d.y_train, key,
                            epochs=self.local_epochs, batch=self.batch,
                            lr=self.lr, prox_mu=self.prox_mu, prox_ref=ref)
        return p, Upload(_flatten_mlp(p, self._layout)[None, :],
                         jnp.zeros((1,), jnp.int32))

    def apply_broadcast(self, cs: mlp.Params, slots: jnp.ndarray,
                        server: jnp.ndarray) -> mlp.Params:
        new = _unflatten_mlp(server[0], self._layout)
        # slot −1 = nothing was aggregated for this client's round: keep
        # the locally trained model instead of an un-updated global
        return jax.tree.map(
            lambda n, o: jnp.where(slots[0] >= 0, n, o), new, cs)

    def evaluate(self, cs: mlp.Params, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return mlp.accuracy(cs, x, y)


@dataclasses.dataclass(frozen=True)
class IFCAStrategy:
    """IFCA: k global models; clients pick by lowest local loss."""

    n_features: int
    n_hidden: int
    n_classes: int
    k: int = 10
    local_epochs: int = 10
    batch: int = 32
    lr: float = 0.05

    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="all_slots", init=False)

    @property
    def n_slots(self) -> int:
        return self.k

    @property
    def _layout(self):
        return _mlp_layout(self.n_features, self.n_hidden, self.n_classes)

    @property
    def vec_dim(self) -> int:
        return FedAvgStrategy.vec_dim.fget(self)  # same MLP layout

    def init(self, key: jax.Array, n_clients: int):
        ks = jax.random.split(key, self.k)
        server = jnp.stack([
            _flatten_mlp(mlp.init(kk, self.n_features, self.n_hidden,
                                  self.n_classes), self._layout)
            for kk in ks])
        g = _unflatten_mlp(server[0], self._layout)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_clients,) + a.shape), g)
        return stacked, server

    def client_step(self, cs: mlp.Params, server: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        def loss_of(vec):
            return mlp.loss_fn(_unflatten_mlp(vec, self._layout),
                               d.x_train, d.y_train)

        choice = jnp.argmin(jax.vmap(loss_of)(server))
        start = _unflatten_mlp(server[choice], self._layout)
        p = mlp.local_train(start, d.x_train, d.y_train, key,
                            epochs=self.local_epochs, batch=self.batch,
                            lr=self.lr)
        return p, Upload(_flatten_mlp(p, self._layout)[None, :],
                         choice.astype(jnp.int32)[None])

    def apply_broadcast(self, cs: mlp.Params, slots: jnp.ndarray,
                        server: jnp.ndarray) -> mlp.Params:
        new = _unflatten_mlp(server[jnp.clip(slots[0], 0)], self._layout)
        return jax.tree.map(
            lambda n, o: jnp.where(slots[0] >= 0, n, o), new, cs)

    def evaluate(self, cs: mlp.Params, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return mlp.accuracy(cs, x, y)


def build_baseline_strategy(name: str, *, n_features: int, n_classes: int,
                            n_hidden: int = 128, local_epochs: int = 10,
                            batch: int = 32, lr: float = 0.05,
                            prox_mu: float = 0.1,
                            ifca_k: int | None = None):
    """The one name→Strategy factory for the DL baselines (shared by the
    CLI and the table-5 benchmark so their hyperparameters can't drift)."""
    kw = dict(n_features=n_features, n_classes=n_classes,
              n_hidden=n_hidden, local_epochs=local_epochs,
              batch=batch, lr=lr)
    if name == "fedavg":
        return FedAvgStrategy(**kw)
    if name == "fedprox":
        return FedAvgStrategy(prox_mu=prox_mu, **kw)
    if name == "ifca":
        return IFCAStrategy(k=ifca_k or min(10, n_classes), **kw)
    raise ValueError(f"unknown baseline strategy {name!r}")
