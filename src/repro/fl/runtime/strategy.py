"""The ``Strategy`` protocol v2: every federated method as one interface.

A strategy reduces a federated method to the pieces the engine can
orchestrate uniformly:

* ``init(key, n_clients, data)``  → (stacked client state, :class:`ServerState`)
* ``client_step(cs, slots, d, key)`` → (new client state, :class:`Upload`)
* ``apply_broadcast(cs, slots, slot_matrix)`` → new client state
* ``evaluate(cs, x, y)``          → scalar accuracy

plus two *optional server-side hooks* (the v2 additions):

* ``assign(server, vecs, slots, arrive) → slots`` — recompute the slot
  id of every upload **server-side, per round**, between uplink-decode
  and aggregation.  This is what lets FLIS (Morafah et al. 2023) derive
  cluster membership each round from inference similarity on a
  server-held probe set: shapes stay static (at most ``n_slots`` rows)
  while *membership* is fully dynamic.  Strategies without the hook
  keep their client-proposed slot ids (TPFL's confidence argmax, IFCA's
  loss-minimizing choice — those need client-local data, so they stay
  in ``client_step`` and flow through the same aggregation path).
* ``server_update(server, agg, counts) → server`` — fold the per-slot
  aggregate into the server state.  Replaces the engine's hard-coded
  in-place row write: strategies control empty-slot retention, server
  momentum, and any auxiliary bookkeeping (FLIS records the round's
  cluster-membership table).  :func:`default_server_update` is the
  Alg. 2 rule (slots with contributors take the aggregate, empty slots
  keep their previous row bit-for-bit) and is what the engine applies
  when a strategy defines no hook.

Server state is a strategy-owned pytree, :class:`ServerState`: the
``(n_slots, vec_dim)`` slot matrix that rides the wire, plus an ``aux``
pytree the strategy alone interprets (FLIS: the probe set and the
membership table).  It is carried in ``EngineState``, checkpointed with
it, and restored loudly on layout drift (see
``runtime/checkpointing.py``).

The unifying trick is unchanged from v1: every method's round
contribution is expressed as ``j`` flat float32 vectors, each tagged
with a server slot id (slot = cluster).  TPFL uploads its
``top_classes`` clause-weight vectors tagged by class; FedAvg/FedProx
upload the flattened MLP tagged slot 0; IFCA uploads the flattened MLP
tagged with the loss-minimizing cluster; FLIS uploads the flattened MLP
with a placeholder tag that ``assign`` replaces server-side; FedTM
uploads the full ``(C·m)`` TM weight block into one global slot.
Aggregation is then always a (masked, optionally staleness-weighted)
per-slot mean — the same masked reduction
:mod:`repro.fl.masked_collectives` lowers to a single collective on a
mesh — and the engine's scheduler/codec/async machinery applies to
every method unchanged.  Slot id −1 means "nothing shared in this
slot" and is ignored by aggregation and broadcast.

``TPFLStrategy.client_step`` / ``apply_broadcast`` are *the* Alg. 1 /
Phase-D implementations — ``repro.core.federation`` vmaps them, so the
legacy driver and the runtime engine share one source of truth.
Likewise :func:`flis_similarity` / :func:`flis_dc_labels` /
:func:`flis_hc_labels` are shared with the ``core/baselines.py``
reference loops the conformance suite pins the engine against.

The ``slots`` matrix a ``client_step`` receives is what the client
*holds*, not what the aggregator stores: under a lossy wire codec the
engine hands in the codec-roundtripped broadcast rows
(``Engine._wire_tx_server``), so strategies that warm-start from global
state (FedAvg/FedProx/IFCA) train from exactly the precision the wire
carried.  TPFL, FLIS and FedTM delete it unread — their clients train
from their own state (which already holds last round's broadcast).

Per-shard lowering contract
---------------------------
The engine's shard-mapped backend (``runtime/executors.py``) runs
``client_step`` / ``apply_broadcast`` / ``evaluate`` *inside*
``shard_map`` — one block of sampled clients per shard, the slot matrix
replicated.  ``assign`` and ``server_update`` are *replicated* server
math: the executor all_gathers the round's uploads into canonical
client order, every shard computes the identical assignment, and each
slices back its own block.  That imposes the same requirements as v1,
pinned per (strategy × codec × participation) cell by the conformance
suite:

* pure jax, per-client for the client hooks (no host callbacks, no
  data-dependent shapes, no reads of any *other* client's row);
  ``assign`` is the one place cross-client math is allowed, and it must
  be a pure function of (server state, the round's uploads, arrival);
* ``Upload.vecs`` float32 ``(j_slots, vec_dim)`` and ``Upload.slots``
  int32 ``(j_slots,)`` exactly — the wire codec and the masked
  collective type-pun on this framing;
* a strategy instance is hashable (frozen dataclass) and equality-
  stable, because the shard-mapped stage programs cache compiled
  executables keyed on it (``jax.jit`` static argument).  Anything
  array-valued therefore belongs in ``ServerState`` (traced), never in
  a strategy field.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import mlp, tm
from repro.data.partition import ClientData

DOWNLOADS = ("assigned", "all_slots")


class Upload(NamedTuple):
    vecs: jnp.ndarray    # (j, d) float32 — what goes on the wire
    slots: jnp.ndarray   # (j,)   int32   — target server slot, −1 = none


class ServerState(NamedTuple):
    """Strategy-owned server state: the wire-visible slot matrix plus an
    opaque aux pytree only the strategy interprets (probe sets,
    membership tables, momentum...).  Carried in ``EngineState`` and
    checkpointed as one pytree."""

    slots: jnp.ndarray   # (n_slots, d) float32 — rows that ride the wire
    aux: Any = ()        # strategy-private pytree (empty for most)


def ensure_server_state(server) -> ServerState:
    """Coerce a v1 ``init`` return (bare slot matrix) into v2 form."""
    if isinstance(server, ServerState):
        return server
    return ServerState(slots=jnp.asarray(server, jnp.float32))


def default_server_update(server: ServerState, agg: jnp.ndarray,
                          counts: jnp.ndarray) -> ServerState:
    """The Alg. 2 retention rule: slots that received contributors take
    the aggregate, empty slots keep their previous row bit-for-bit."""
    return server._replace(
        slots=jnp.where(counts[:, None] > 0, agg, server.slots))


def resolve_server_update(strategy):
    """The strategy's ``server_update`` hook, or the Alg. 2 default."""
    return getattr(strategy, "server_update", None) or default_server_update


@runtime_checkable
class Strategy(Protocol):
    n_slots: int          # rows in the server slot matrix
    vec_dim: int          # d — length of one uploaded vector
    j_slots: int          # uploads per client per round
    downloads: Literal["assigned", "all_slots"]   # validated at engine init

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None): ...
    def client_step(self, cs, slots: jnp.ndarray, d: ClientData,
                    key: jax.Array): ...
    def apply_broadcast(self, cs, slots: jnp.ndarray,
                        slot_matrix: jnp.ndarray): ...
    def evaluate(self, cs, x: jnp.ndarray, y: jnp.ndarray): ...
    # optional hooks (absence = v1 behaviour):
    #   assign(server: ServerState, vecs (K,j,d), slots (K,j),
    #          arrive (K,)) -> (K,j) int32
    #   server_update(server: ServerState, agg (C,d), counts (C,))
    #          -> ServerState


# ---------------------------------------------------------------------------
# TPFL (paper Alg. 1 + Phase D)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TPFLStrategy:
    """Confidence-clustered selective sharing on the Tsetlin Machine."""

    tm_cfg: tm.TMConfig
    local_epochs: int = 10
    top_classes: int = 1                 # j — §7 multi-cluster extension
    conf_threshold: float | None = None  # §7 confidence gate (−1 below)
    weighted_confidence: bool = False    # Alg. 1 uses unweighted margins

    downloads: str = dataclasses.field(default="assigned", init=False)

    @property
    def n_slots(self) -> int:
        return self.tm_cfg.n_classes

    @property
    def vec_dim(self) -> int:
        return self.tm_cfg.n_clauses

    @property
    def j_slots(self) -> int:
        return self.top_classes

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None):
        del data
        keys = jax.random.split(key, n_clients)
        params = jax.vmap(lambda k: tm.init_params(self.tm_cfg, k))(keys)
        server = jnp.zeros((self.n_slots, self.vec_dim), jnp.float32)
        return params, ServerState(server)

    # --- O(K) init hooks (client_store="mmap") ----------------------------
    # The store regenerates never-spilled rows on demand, so init must be
    # expressible per-cohort: ``init_cohort(key, ids, n) ==
    # init(key, n)[0][ids]`` bit-for-bit (same key split, indexed), and
    # ``init_server`` is the server part alone.  Only the per-client key
    # table is O(N) — 8 bytes/client, transient.

    def init_cohort(self, key: jax.Array, ids, n_clients: int):
        keys = jax.random.split(key, n_clients)[jnp.asarray(ids)]
        return jax.vmap(lambda k: tm.init_params(self.tm_cfg, k))(keys)

    def init_server(self, key: jax.Array, n_clients: int) -> ServerState:
        del key, n_clients
        return ServerState(
            jnp.zeros((self.n_slots, self.vec_dim), jnp.float32))

    def client_step(self, cs: tm.TMParams, slots: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        """Alg. 1: local TM training, per-class confidence, selective
        upload of the ``top_classes`` most-confident weight vectors."""
        del slots  # TPFL clients never read global state before training
        cfg = self.tm_cfg
        params = tm.train(cs, d.x_train, d.y_train, key, cfg,
                          epochs=self.local_epochs)
        conf = tm.confidence_scores(params, d.x_conf, cfg,
                                    weighted=self.weighted_confidence)
        vals, c_top = jax.lax.top_k(conf, self.top_classes)       # (j,)
        if self.conf_threshold is not None:
            c_top = jnp.where(vals >= self.conf_threshold, c_top, -1)
        vecs = params.weights[jnp.clip(c_top, 0)].astype(jnp.float32)
        # slot −1 means "share nothing" — its payload row must be zero,
        # not class 0's weights, or the wire meters bytes for frames the
        # server drops (conformance pins the corrected totals).
        vecs = jnp.where((c_top >= 0)[..., None], vecs, 0.0)
        return params, Upload(vecs, c_top.astype(jnp.int32))

    @staticmethod
    def apply_broadcast(cs: tm.TMParams, slots: jnp.ndarray,
                        slot_matrix: jnp.ndarray) -> tm.TMParams:
        """Phase D: overwrite each shared class with its cluster mean.

        A staticmethod so ``federation._phase_d`` can call it without
        materializing a strategy (it needs no config)."""
        new_w = jnp.round(slot_matrix[jnp.clip(slots, 0)]).astype(jnp.int32)

        def one(wc, c_nw):
            c, nwv = c_nw
            return jnp.where(c >= 0, wc.at[c].set(nwv), wc), None

        wc, _ = jax.lax.scan(one, cs.weights, (slots, new_w))
        return cs._replace(weights=wc)

    def evaluate(self, cs: tm.TMParams, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return tm.accuracy(cs, x, y, self.tm_cfg)

    def predict_batched(self, cs: tm.TMParams,
                        x: jnp.ndarray) -> jnp.ndarray:
        """Stacked per-client predictions (N, B, o) → (N, B) — the
        serving plane's batched-inference hook.  Honours
        ``tm_cfg.use_kernel``: one fused-votes launch for the whole
        mixed-cluster batch on the pallas path."""
        return tm.predict_batched(cs, x, self.tm_cfg)

    # --- fused client-batched path (tm_backend="pallas") ------------------
    # One kernel launch for the whole sampled cohort instead of a vmap of
    # per-client steps (vmap of a pallas_call serializes clients).  The
    # executors dispatch here when ``use_fused_kernels`` is set; outputs
    # are bit-identical to the vmapped ``client_step``/``evaluate``.

    @property
    def use_fused_kernels(self) -> bool:
        return self.tm_cfg.use_kernel

    def fused_client_step(self, cs: tm.TMParams, slots: jnp.ndarray,
                          d: ClientData, keys: jnp.ndarray):
        del slots
        cfg = self.tm_cfg
        params = tm.train_batched(cs, d.x_train, d.y_train, keys, cfg,
                                  epochs=self.local_epochs)
        conf = tm.confidence_scores_batched(
            params, d.x_conf, cfg, weighted=self.weighted_confidence)
        vals, c_top = jax.lax.top_k(conf, self.top_classes)     # (N, j)
        if self.conf_threshold is not None:
            c_top = jnp.where(vals >= self.conf_threshold, c_top, -1)
        rows = jnp.arange(c_top.shape[0])[:, None]
        vecs = params.weights[rows, jnp.clip(c_top, 0)].astype(jnp.float32)
        vecs = jnp.where((c_top >= 0)[..., None], vecs, 0.0)
        return params, Upload(vecs, c_top.astype(jnp.int32))

    def fused_evaluate(self, cs: tm.TMParams, x: jnp.ndarray,
                       y: jnp.ndarray) -> jnp.ndarray:
        return tm.accuracy_batched(cs, x, y, self.tm_cfg)


# ---------------------------------------------------------------------------
# MLP flatten/unflatten (FedAvg / FedProx / IFCA / FLIS wire format)
# ---------------------------------------------------------------------------

def _mlp_layout(n_features: int, n_hidden: int, n_classes: int):
    return (("w1", (n_features, n_hidden)), ("b1", (n_hidden,)),
            ("w2", (n_hidden, n_classes)), ("b2", (n_classes,)))


def _flatten_mlp(params: mlp.Params, layout) -> jnp.ndarray:
    return jnp.concatenate([params[k].astype(jnp.float32).ravel()
                            for k, _ in layout])


def _unflatten_mlp(vec: jnp.ndarray, layout) -> mlp.Params:
    out, off = {}, 0
    for k, shape in layout:
        size = 1
        for s in shape:
            size *= s
        out[k] = vec[off:off + size].reshape(shape)
        off += size
    return out


@dataclasses.dataclass(frozen=True)
class MLPStrategyBase:
    """Shared substrate of the DL strategies (FedAvg/FedProx, IFCA,
    FLIS): one MLP layout, one flatten/unflatten wire format, one
    slot-row broadcast-apply, one evaluation.  Subclasses differ only
    in *routing* — which slot an upload targets and which row a client
    applies — which is exactly the part the v2 assign/aggregate path
    makes uniform."""

    n_features: int
    n_hidden: int
    n_classes: int
    local_epochs: int = 10
    batch: int = 32
    lr: float = 0.05

    @property
    def _layout(self):
        return _mlp_layout(self.n_features, self.n_hidden, self.n_classes)

    @property
    def vec_dim(self) -> int:
        total = 0
        for _, shape in self._layout:
            size = 1
            for s in shape:
                size *= s
            total += size
        return total

    def _stack(self, template: mlp.Params, n_clients: int):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_clients,) + a.shape), template)

    def _apply_slot_row(self, cs: mlp.Params, slot: jnp.ndarray,
                        slot_matrix: jnp.ndarray) -> mlp.Params:
        """Apply the row this client was routed to; slot −1 = nothing
        was aggregated for this client's round, so it keeps the locally
        trained model instead of an un-updated global."""
        new = _unflatten_mlp(slot_matrix[jnp.clip(slot, 0)], self._layout)
        return jax.tree.map(lambda n, o: jnp.where(slot >= 0, n, o),
                            new, cs)

    def apply_broadcast(self, cs: mlp.Params, slots: jnp.ndarray,
                        slot_matrix: jnp.ndarray) -> mlp.Params:
        return self._apply_slot_row(cs, slots[0], slot_matrix)

    def evaluate(self, cs: mlp.Params, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return mlp.accuracy(cs, x, y)

    def predict_batched(self, cs: mlp.Params,
                        x: jnp.ndarray) -> jnp.ndarray:
        """Stacked per-client predictions (N, B, o) → (N, B) int32."""
        return jax.vmap(
            lambda p, xx: jnp.argmax(mlp.apply(p, xx), axis=-1)
        )(cs, x).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(MLPStrategyBase):
    """FedAvg (and FedProx with ``prox_mu > 0``): one global slot."""

    prox_mu: float = 0.0          # > 0 → FedProx proximal objective

    n_slots: int = dataclasses.field(default=1, init=False)
    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="assigned", init=False)

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None):
        del data
        g = mlp.init(key, self.n_features, self.n_hidden, self.n_classes)
        server = _flatten_mlp(g, self._layout)[None, :]
        return self._stack(g, n_clients), ServerState(server)

    def client_step(self, cs: mlp.Params, slots: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        start = _unflatten_mlp(slots[0], self._layout)
        ref = start if self.prox_mu > 0 else None
        p = mlp.local_train(start, d.x_train, d.y_train, key,
                            epochs=self.local_epochs, batch=self.batch,
                            lr=self.lr, prox_mu=self.prox_mu, prox_ref=ref)
        return p, Upload(_flatten_mlp(p, self._layout)[None, :],
                         jnp.zeros((1,), jnp.int32))


@dataclasses.dataclass(frozen=True)
class IFCAStrategy(MLPStrategyBase):
    """IFCA: k global models; clients pick by lowest local loss.

    The loss-minimizing estimate needs client-local data, so it stays
    in ``client_step`` (there is nothing server-side to recompute — the
    server trusts the proposed slot id); the upload then flows through
    the same uniform assign/aggregate/server_update pipeline as every
    other strategy."""

    k: int = 10

    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="all_slots", init=False)

    @property
    def n_slots(self) -> int:
        return self.k

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None):
        del data
        ks = jax.random.split(key, self.k)
        server = jnp.stack([
            _flatten_mlp(mlp.init(kk, self.n_features, self.n_hidden,
                                  self.n_classes), self._layout)
            for kk in ks])
        g = _unflatten_mlp(server[0], self._layout)
        return self._stack(g, n_clients), ServerState(server)

    def client_step(self, cs: mlp.Params, slots: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        def loss_of(vec):
            return mlp.loss_fn(_unflatten_mlp(vec, self._layout),
                               d.x_train, d.y_train)

        choice = jnp.argmin(jax.vmap(loss_of)(slots))
        start = _unflatten_mlp(slots[choice], self._layout)
        p = mlp.local_train(start, d.x_train, d.y_train, key,
                            epochs=self.local_epochs, batch=self.batch,
                            lr=self.lr)
        return p, Upload(_flatten_mlp(p, self._layout)[None, :],
                         choice.astype(jnp.int32)[None])


# ---------------------------------------------------------------------------
# FLIS: dynamic clusters from inference similarity on a probe set
# ---------------------------------------------------------------------------

def flis_similarity(flat_models: jnp.ndarray, probe: jnp.ndarray,
                    layout) -> jnp.ndarray:
    """Pairwise inference similarity of K uploaded models on the probe
    set: cosine similarity of the flattened softmax prediction
    profiles.  ``(K, d) × (P, F) → (K, K)``.  Shared by the engine's
    ``FLISStrategy.assign`` and the ``core/baselines.py`` reference
    loop, so the two compute bit-identical matrices."""
    def profile(vec):
        return jax.nn.softmax(mlp.apply(_unflatten_mlp(vec, layout), probe))

    preds = jax.vmap(profile)(flat_models)            # (K, P, C)
    flat = preds.reshape(flat_models.shape[0], -1)
    flat = flat / jnp.linalg.norm(flat, axis=1, keepdims=True)
    return flat @ flat.T


def flis_dc_labels(sim: jnp.ndarray, arrive: jnp.ndarray,
                   threshold: float, max_slots: int) -> jnp.ndarray:
    """FLIS-DC: connected components of the thresholded similarity
    graph, jit-ably.  Min-label propagation for (static) K steps yields
    each arrived client's component representative (its minimum member
    index); components are then densely renumbered in order of first
    appearance — exactly the labelling of the host reference
    ``baselines._similarity_clusters`` — and clipped into the
    ``max_slots`` server rows (overflow components share the last row).
    Non-arrived clients get −1.  Shapes are static; membership is
    dynamic."""
    k = sim.shape[0]
    arrive = arrive.astype(bool)
    adj = (sim >= threshold) & arrive[:, None] & arrive[None, :]
    labels = jnp.where(arrive, jnp.arange(k, dtype=jnp.int32), k)

    def step(lab, _):
        cand = jnp.where(adj, lab[None, :], k)
        return jnp.minimum(lab, cand.min(axis=1)).astype(jnp.int32), None

    labels, _ = jax.lax.scan(step, labels, None, length=k)
    is_rep = arrive & (labels == jnp.arange(k))
    rank = jnp.cumsum(is_rep.astype(jnp.int32)) - 1    # dense id at rep idx
    dense = rank[jnp.clip(labels, 0, k - 1)]
    dense = jnp.minimum(dense, max_slots - 1)
    return jnp.where(arrive, dense, -1).astype(jnp.int32)


def flis_hc_labels(sim: jnp.ndarray, arrive: jnp.ndarray,
                   threshold: float, max_slots: int) -> jnp.ndarray:
    """FLIS-HC: average-linkage agglomerative clustering of the
    similarity matrix, jit-ably.  K−1 masked merge steps: each step
    merges the pair of active clusters with the highest average
    cross-similarity, while that maximum stays ≥ ``threshold`` — or
    unconditionally while more than ``max_slots`` clusters remain (the
    server has that many rows).  Merges always fold the larger index
    into the smaller, so a cluster's root is its minimum member index
    and the dense renumbering matches the DC convention.  Arithmetic is
    step-for-step identical to the host reference
    ``baselines._average_linkage_clusters`` (same float32 adds, same
    row-major argmax tie-break), which the conformance suite pins."""
    k = sim.shape[0]
    arrive = arrive.astype(bool)
    eye = jnp.eye(k, dtype=bool)
    size = jnp.where(arrive, 1.0, 0.0).astype(jnp.float32)
    cross = jnp.where(arrive[:, None] & arrive[None, :] & ~eye,
                      sim.astype(jnp.float32), 0.0)
    labels = jnp.where(arrive, jnp.arange(k, dtype=jnp.int32), k)
    carry = (cross, size, arrive, labels, jnp.zeros((), bool))

    def step(carry, _):
        cross, size, active, labels, done = carry
        pair_ok = active[:, None] & active[None, :] & ~eye
        avg = jnp.where(
            pair_ok,
            cross / jnp.maximum(size[:, None] * size[None, :], 1.0),
            -jnp.inf)
        flat_i = jnp.argmax(avg)            # row-major first max → a < b
        a, b = flat_i // k, flat_i % k
        best = avg.reshape(-1)[flat_i]
        n_active = active.sum()
        merge = (~done) & jnp.isfinite(best) & (n_active > 1) \
            & ((n_active > max_slots) | (best >= threshold))
        row = cross[a] + cross[b]
        row = row.at[a].set(0.0).at[b].set(0.0)
        cross2 = cross.at[a, :].set(row).at[:, a].set(row)
        cross2 = cross2.at[b, :].set(0.0).at[:, b].set(0.0)
        size2 = size.at[a].add(size[b]).at[b].set(0.0)
        active2 = active.at[b].set(False)
        labels2 = jnp.where(labels == b, a, labels)
        out = (jnp.where(merge, cross2, cross),
               jnp.where(merge, size2, size),
               jnp.where(merge, active2, active),
               jnp.where(merge, labels2, labels),
               done | ~merge)
        return out, None

    if k > 1:
        carry, _ = jax.lax.scan(step, carry, None, length=k - 1)
    cross, size, active, labels, done = carry
    rank = jnp.cumsum(active.astype(jnp.int32)) - 1
    dense = rank[jnp.clip(labels, 0, k - 1)]
    return jnp.where(arrive, dense, -1).astype(jnp.int32)


class FLISAux(NamedTuple):
    """FLIS's strategy-owned server aux: the shared unlabeled probe set
    (server-side, the standard FLIS assumption) and the last round's
    cluster-membership table (contributor count per slot)."""

    probe: jnp.ndarray     # (probe_size, n_features)
    members: jnp.ndarray   # (n_slots,) float32 — last round's counts


class FLISClientState(NamedTuple):
    """FLIS per-client state: the MLP plus the cluster row the client
    last *applied* — the ride-along that lets sparse-delta uplinks
    encode against the row the client actually holds instead of the
    conservative zero reference."""

    params: mlp.Params
    prev_slot: jnp.ndarray   # () int32 — last applied cluster id, 0 at init


@dataclasses.dataclass(frozen=True)
class FLISStrategy(MLPStrategyBase):
    """FLIS (Morafah et al. 2023 flavour): cluster membership derived
    *server-side each round* from inference similarity on a probe set.

    Clients train from their own state (which holds last round's
    cluster model) and upload the flattened MLP tagged with the cluster
    row they last *applied* (``prev_slot``, 0 before the first
    broadcast) — they still do not know this round's cluster; the
    :meth:`assign` hook discards the tag and recomputes membership from
    the decoded uploads (DC = thresholded connected components, HC =
    average-linkage agglomerative), capped at ``max_slots`` server
    rows.  :meth:`server_update` applies the Alg. 2 retention and
    records the round's membership table in ``aux.members``.  The tag's
    one job is the wire codec: sparse-delta uplinks encode against the
    tracked reference of the row the client actually holds, which is a
    far nearer reference than the zero row the old placeholder tag
    forced, so deltas stay small whenever membership is sticky.

    Works under both aggregation modes: sync runs :meth:`assign` as a
    round-synchronous server stage; async runs it over the *matured
    buffer contents* at aggregation time (the engine's host buffer
    path), so membership is recomputed from whichever uploads actually
    arrived together."""

    max_slots: int = 8
    probe_size: int = 64
    threshold: float = 0.9
    linkage: str = "dc"            # dc | hc

    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="assigned", init=False)

    def __post_init__(self):
        if self.linkage not in ("dc", "hc"):
            raise ValueError(f"unknown FLIS linkage {self.linkage!r}; "
                             f"choose 'dc' or 'hc'")

    @property
    def n_slots(self) -> int:
        return self.max_slots

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None):
        if data is None:
            raise ValueError(
                "FLISStrategy.init needs the engine's ClientData: the "
                "server-side probe set is drawn from the confidence "
                "split (x_conf)")
        k_params, k_probe = jax.random.split(key)
        stacked = jax.vmap(lambda k: mlp.init(
            k, self.n_features, self.n_hidden, self.n_classes))(
            jax.random.split(k_params, n_clients))
        pool = data.x_conf.reshape(-1, self.n_features)
        if self.probe_size > pool.shape[0]:
            raise ValueError(
                f"probe_size={self.probe_size} exceeds the confidence "
                f"split's pooled sample count ({pool.shape[0]}) — the "
                f"probe set is drawn without replacement from x_conf; "
                f"lower --probe-size or enlarge the conf split")
        idx = jax.random.choice(k_probe, pool.shape[0], (self.probe_size,),
                                replace=False)
        server = jnp.zeros((self.n_slots, self.vec_dim), jnp.float32)
        aux = FLISAux(probe=pool[idx],
                      members=jnp.zeros((self.n_slots,), jnp.float32))
        cs = FLISClientState(
            stacked, jnp.zeros((n_clients,), jnp.int32))
        return cs, ServerState(server, aux)

    def client_step(self, cs: FLISClientState, slots: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        del slots  # clients train from their own (cluster-model) state
        p = mlp.local_train(cs.params, d.x_train, d.y_train, key,
                            epochs=self.local_epochs, batch=self.batch,
                            lr=self.lr)
        return (FLISClientState(p, cs.prev_slot),
                Upload(_flatten_mlp(p, self._layout)[None, :],
                       cs.prev_slot[None]))   # tag = last applied row

    def apply_broadcast(self, cs: FLISClientState, slots: jnp.ndarray,
                        slot_matrix: jnp.ndarray) -> FLISClientState:
        """Apply the routed row and remember it: ``prev_slot`` advances
        only when a row was actually applied (slot −1 keeps both the
        local model and the old tag)."""
        return FLISClientState(
            self._apply_slot_row(cs.params, slots[0], slot_matrix),
            jnp.where(slots[0] >= 0, slots[0], cs.prev_slot))

    def evaluate(self, cs: FLISClientState, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return mlp.accuracy(cs.params, x, y)

    def predict_batched(self, cs: FLISClientState,
                        x: jnp.ndarray) -> jnp.ndarray:
        return super().predict_batched(cs.params, x)

    def assign(self, server: ServerState, vecs: jnp.ndarray,
               slots: jnp.ndarray, arrive: jnp.ndarray) -> jnp.ndarray:
        """The FLIS server step: inference similarity on the probe set →
        DC/HC clustering of the arrived uploads into at most
        ``max_slots`` dynamic clusters."""
        del slots                      # placeholder tags carry no signal
        sim = flis_similarity(vecs[:, 0, :], server.aux.probe, self._layout)
        if self.linkage == "dc":
            lab = flis_dc_labels(sim, arrive, self.threshold, self.n_slots)
        else:
            lab = flis_hc_labels(sim, arrive, self.threshold, self.n_slots)
        return lab[:, None]

    def server_update(self, server: ServerState, agg: jnp.ndarray,
                      counts: jnp.ndarray) -> ServerState:
        """Alg. 2 retention on the rows, plus the round's membership
        table recorded into ``aux`` (checkpointed with the state)."""
        slots = jnp.where(counts[:, None] > 0, agg, server.slots)
        return ServerState(slots, server.aux._replace(members=counts))


# ---------------------------------------------------------------------------
# FedTM: full-weight TM averaging, one global slot, no personalization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedTMStrategy:
    """FedTM (Qi et al. 2023 flavour): the same TM as TPFL, but every
    client uploads its *full* ``(C, m)`` weight block into one global
    slot and everyone applies the rounded global mean — no confidence
    clustering, no selective upload.  The TPFL-vs-FedTM delta therefore
    isolates the paper's contribution, now under one engine, one
    scheduler, and one byte-exact wire codec."""

    tm_cfg: tm.TMConfig
    local_epochs: int = 10

    n_slots: int = dataclasses.field(default=1, init=False)
    j_slots: int = dataclasses.field(default=1, init=False)
    downloads: str = dataclasses.field(default="assigned", init=False)

    @property
    def vec_dim(self) -> int:
        return self.tm_cfg.n_classes * self.tm_cfg.n_clauses

    def init(self, key: jax.Array, n_clients: int,
             data: ClientData | None = None):
        del data
        keys = jax.random.split(key, n_clients)
        params = jax.vmap(lambda k: tm.init_params(self.tm_cfg, k))(keys)
        server = jnp.zeros((1, self.vec_dim), jnp.float32)
        return params, ServerState(server)

    # O(K) init hooks — same contract as TPFLStrategy's:
    # init_cohort(key, ids, n) == init(key, n)[0][ids] bit-for-bit
    def init_cohort(self, key: jax.Array, ids, n_clients: int):
        keys = jax.random.split(key, n_clients)[jnp.asarray(ids)]
        return jax.vmap(lambda k: tm.init_params(self.tm_cfg, k))(keys)

    def init_server(self, key: jax.Array, n_clients: int) -> ServerState:
        del key, n_clients
        return ServerState(jnp.zeros((1, self.vec_dim), jnp.float32))

    def client_step(self, cs: tm.TMParams, slots: jnp.ndarray,
                    d: ClientData, key: jax.Array):
        del slots  # clients hold last round's global weights already
        params = tm.train(cs, d.x_train, d.y_train, key, self.tm_cfg,
                          epochs=self.local_epochs)
        vec = params.weights.astype(jnp.float32).reshape(1, -1)
        return params, Upload(vec, jnp.zeros((1,), jnp.int32))

    def apply_broadcast(self, cs: tm.TMParams, slots: jnp.ndarray,
                        slot_matrix: jnp.ndarray) -> tm.TMParams:
        cfg = self.tm_cfg
        new_w = jnp.round(slot_matrix[0]).astype(jnp.int32).reshape(
            cfg.n_classes, cfg.n_clauses)
        w = jnp.where(slots[0] >= 0, new_w, cs.weights)
        return cs._replace(weights=w)

    def evaluate(self, cs: tm.TMParams, x: jnp.ndarray,
                 y: jnp.ndarray) -> jnp.ndarray:
        return tm.accuracy(cs, x, y, self.tm_cfg)

    def predict_batched(self, cs: tm.TMParams,
                        x: jnp.ndarray) -> jnp.ndarray:
        """Stacked per-client predictions (serving hook; honours
        ``tm_cfg.use_kernel``)."""
        return tm.predict_batched(cs, x, self.tm_cfg)

    # --- fused client-batched path (tm_backend="pallas") ------------------

    @property
    def use_fused_kernels(self) -> bool:
        return self.tm_cfg.use_kernel

    def fused_client_step(self, cs: tm.TMParams, slots: jnp.ndarray,
                          d: ClientData, keys: jnp.ndarray):
        del slots
        params = tm.train_batched(cs, d.x_train, d.y_train, keys,
                                  self.tm_cfg, epochs=self.local_epochs)
        n = d.y_train.shape[0]
        vecs = params.weights.astype(jnp.float32).reshape(n, 1, -1)
        return params, Upload(vecs, jnp.zeros((n, 1), jnp.int32))

    def fused_evaluate(self, cs: tm.TMParams, x: jnp.ndarray,
                       y: jnp.ndarray) -> jnp.ndarray:
        return tm.accuracy_batched(cs, x, y, self.tm_cfg)


def build_baseline_strategy(name: str, *, n_features: int, n_classes: int,
                            n_hidden: int = 128, local_epochs: int = 10,
                            batch: int = 32, lr: float = 0.05,
                            prox_mu: float = 0.1,
                            ifca_k: int | None = None,
                            max_slots: int = 8, probe_size: int = 64,
                            flis_threshold: float = 0.9):
    """The one name→Strategy factory for the non-TPFL baselines (shared
    by the CLI and the table-5 benchmark so hyperparameters can't
    drift).  FedTM is built separately (it needs the TM config)."""
    kw = dict(n_features=n_features, n_classes=n_classes,
              n_hidden=n_hidden, local_epochs=local_epochs,
              batch=batch, lr=lr)
    if name == "fedavg":
        return FedAvgStrategy(**kw)
    if name == "fedprox":
        return FedAvgStrategy(prox_mu=prox_mu, **kw)
    if name == "ifca":
        return IFCAStrategy(k=ifca_k or min(10, n_classes), **kw)
    if name in ("flis_dc", "flis_hc"):
        return FLISStrategy(linkage=name.removeprefix("flis_"),
                            max_slots=max_slots, probe_size=probe_size,
                            threshold=flis_threshold, **kw)
    raise ValueError(f"unknown baseline strategy {name!r}")
