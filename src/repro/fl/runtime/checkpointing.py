"""Round-granular checkpoint/resume for the federated engine.

Thin layer over :mod:`repro.checkpoint.ckpt`: an :class:`EngineState` is
one pytree (client population, the strategy-owned ``ServerState`` —
slot matrix plus aux such as FLIS's probe set and membership table —
the six async device-buffer lanes, round counter), so a checkpoint is a
single msgpack tensor store named by the round it starts.  Because the engine
keys round r with ``fold_in(k_rounds, r)`` on the *absolute* round
index, a resumed run is bit-identical to the uninterrupted one — and
because the buffer lanes (payloads, slot ids, maturity rounds,
staleness weights, validity, insertion order) ride in the same pytree,
that holds for *async* runs too: uploads that were in flight at the
checkpoint mature in the resumed run exactly as they would have
(pinned by the conformance suite's async mesh resume test).

    engine = Engine(strategy, data, cfg)
    like = engine.init(jax.random.PRNGKey(0))     # structure template
    state = checkpointing.restore(checkpointing.latest(d), like)
    engine.run(key, state=state)
"""
from __future__ import annotations

import json
import pathlib
import re

from repro.checkpoint import ckpt

_PAT = re.compile(r"round_(\d+)\.msgpack$")
MANIFEST_NAME = "manifest.json"
STORE_MANIFEST_NAME = "store_manifest.json"


def path_for(directory: str | pathlib.Path, round_idx: int) -> pathlib.Path:
    return pathlib.Path(directory) / f"round_{round_idx:06d}.msgpack"


def save(directory: str | pathlib.Path, state,
         manifest: dict | None = None,
         store_manifest: dict | None = None) -> pathlib.Path:
    """Persist ``state``; the filename records the next round to run.

    ``manifest`` (the telemetry run manifest — config, seed, mesh, git
    sha; see ``repro.fl.obs.manifest``) rides along as
    ``manifest.json`` in the checkpoint directory, so a checkpoint can
    always answer what produced it.  It is provenance only: ``restore``
    never reads it, and a run without telemetry writes none.

    ``store_manifest`` (the mmap engine's ``ClientStore.manifest`` —
    version, client count, per-leaf layout) rides along the same way as
    ``store_manifest.json``: an mmap checkpoint is only the replicated
    state, the population rows live in the store directory, and this
    records which store layout the checkpoint expects.  Resume is valid
    at the *latest* checkpoint only — store rows advance in place past
    older ones (see ``docs/client-store.md``)."""
    path = path_for(directory, int(state.round_idx))
    ckpt.save(path, state)
    if manifest is not None:
        from repro.fl.obs.events import to_jsonable
        (path.parent / MANIFEST_NAME).write_text(
            json.dumps(to_jsonable(manifest), indent=2, sort_keys=True)
            + "\n")
    if store_manifest is not None:
        (path.parent / STORE_MANIFEST_NAME).write_text(
            json.dumps(store_manifest, indent=2, sort_keys=True) + "\n")
    return path


def latest(directory: str | pathlib.Path) -> pathlib.Path | None:
    """Newest checkpoint in ``directory`` (highest round), or None."""
    d = pathlib.Path(directory)
    if not d.is_dir():
        return None
    best, best_r = None, -1
    for p in d.iterdir():
        m = _PAT.search(p.name)
        if m and int(m.group(1)) > best_r:
            best, best_r = p, int(m.group(1))
    return best


def restore(path: str | pathlib.Path, like):
    """Rebuild an :class:`EngineState` from ``path`` into the structure of
    ``like`` (e.g. a fresh ``engine.init(...)`` state).

    Server-state layout drift fails *loudly*: the server subtree is
    strategy-owned (slot matrix + aux pytree — probe sets, membership
    tables), so a checkpoint written under a different strategy, slot
    count, or aux layout raises with the drifted leaves named instead
    of silently reshaping or zero-filling."""
    try:
        return ckpt.restore(path, like)
    except (KeyError, ValueError) as e:
        raise ValueError(
            f"checkpoint {path} does not match the current engine state "
            f"layout: {e}.  The server state is strategy-owned "
            f"(ServerState.slots + aux) — restoring a checkpoint from a "
            f"different strategy, --max-slots, or aux layout is refused "
            f"rather than silently coerced.  Re-run with the original "
            f"strategy/config, or start fresh without --resume."
        ) from e
