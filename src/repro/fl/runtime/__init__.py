"""Federated runtime: scheduler, strategies, wire codec, round engine.

The subsystem that replaces the monolithic ``federation.run`` loop:

* :mod:`repro.fl.runtime.scheduler` — K-of-N client sampling (uniform /
  weighted / round-robin) with dropout and straggler-staleness injection.
* :mod:`repro.fl.runtime.strategy` — the ``Strategy`` protocol (v2)
  unifying sync/async TPFL and the FedAvg / FedProx / IFCA / FLIS-DC /
  FLIS-HC / FedTM baselines behind one ``client_step / aggregate /
  broadcast`` surface, with strategy-owned :class:`ServerState` and the
  optional server-side ``assign`` / ``server_update`` hooks (dynamic
  per-round cluster assignment, custom empty-slot retention).
* :mod:`repro.fl.runtime.codec` — quantized (int8/int4) + sparse-delta
  wire encoding of the uploaded vectors, with byte-exact metering
  (``len(buffer)``, not arithmetic).
* :mod:`repro.fl.runtime.engine` — the orchestrated round engine: sync
  barrier or async buffered aggregation (fixed-capacity *device* buffer,
  masked validity, staleness-discounted averaging), jit-friendly
  static-K gather/scatter of the sampled client sub-pytrees.
* :mod:`repro.fl.runtime.executors` — where a round's compute runs: the
  in-process vmap backend, or the shard-mapped ``clients``-mesh backend
  whose aggregation — sync masked mean *and* the async buffered update —
  is a single masked collective (bit-identical to in-process; pinned by
  ``tests/test_fl_conformance.py``).
* :mod:`repro.fl.runtime.checkpointing` — round-granular save/resume on
  top of ``repro.checkpoint.ckpt`` (the async buffer lanes are part of
  the state pytree, so async runs resume bit-identically too); the
  telemetry run manifest rides along as provenance.

The telemetry plane lives next door in :mod:`repro.fl.obs`: pass a
``RunRecorder`` as ``Engine(telemetry=...)`` to get phase-span wall
times and structured per-round JSONL events — instrumentation is
read-only and conformance-pinned to never perturb the round
(``docs/observability.md``).

See ``README.md`` next to this file for the backend architecture and
how to run the conformance matrix locally, and ``docs/`` at the repo
root for the subsystem architecture and the async device-buffer design.
"""
from repro.fl.runtime.codec import CodecConfig          # noqa: F401
from repro.fl.runtime.engine import (                   # noqa: F401
    BACKENDS, Engine, EngineState, RoundReport, RuntimeConfig)
from repro.fl.runtime.executors import (                # noqa: F401
    COLLECTIVES, InProcessExecutor, ShardMapExecutor,
    build_sharded_async_update, build_sharded_round)
from repro.fl.runtime.scheduler import (                # noqa: F401
    Participation, Scheduler, SchedulerConfig)
from repro.fl.runtime.strategy import (                 # noqa: F401
    DOWNLOADS, FedAvgStrategy, FedTMStrategy, FLISStrategy, IFCAStrategy,
    ServerState, Strategy, TPFLStrategy, Upload, build_baseline_strategy,
    default_server_update)
