"""Round executors: where one federated round's compute actually runs.

The engine (``runtime/engine.py``) owns the *semantics* of a round —
scheduling, the wire codec, aggregation mode, checkpointing — and
delegates the three array-heavy pieces (client training, the masked
per-slot mean, broadcast-apply + evaluation) to a ``RoundExecutor``:

* :class:`InProcessExecutor` — eager vmap over the sampled clients, the
  host einsum of ``clustering.aggregate``.  The reference backend.
* :class:`ShardMapExecutor` — the same round lowered through
  ``shard_map`` over a ``clients`` mesh axis: each shard trains its
  block of the sampled clients, and aggregation is a single masked
  collective from :mod:`repro.fl.masked_collectives` (``all_gather`` +
  canonical einsum for bit-exactness, or the C·m ``psum`` accumulator
  for communication-optimality).  For the dominant configuration (sync
  barrier, full participation, dense float32 wire) the *entire* round —
  client_step, aggregation, broadcast-apply, evaluation — is one
  compiled sharded program (:func:`build_sharded_round`, also what the
  dry-run lowers on the production mesh).

The conformance suite (``tests/test_fl_conformance.py``) pins
shard-mapped == in-process == legacy ``federation.run`` bit-for-bit for
every (strategy, codec, participation) cell; anything that changes
per-client key derivation, reduction shapes, or merge order breaks it.

Sampled-K padding: shard_map needs the leading axis divisible by the
mesh axis size, so executors pad K (and N for evaluation) up to the
next multiple with inert rows — repeated row 0 for client state/data,
``active=False`` / slot −1 for participation — and slice the padding
back off.  Padded rows are masked out of the collective *and* trimmed
from the reduction shape (``n_valid``) so the float summation order
matches the unpadded in-process einsum exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import clustering
from repro.fl import masked_collectives

COLLECTIVES = ("gather", "psum")


def applied_slots(slots, counts, arrive):
    """Which slots are actually pushed back to each client this round:
    it arrived, it shared the slot, and the slot received an aggregate
    (a never-fed slot row must not overwrite fresh local training).
    Shared by the engine's staged path and the fused sharded body — the
    bit-parity contract depends on both using exactly this formula."""
    return jnp.where(arrive[:, None] & (slots >= 0)
                     & (counts[jnp.clip(slots, 0)] > 0), slots, -1)


def _broadcast_apply_merge(strategy, new_sub, applied, server, old_sub,
                           recv):
    """vmap ``apply_broadcast`` over clients, then revert non-receivers
    to their pre-round state.  The one merge both backends (and the
    fused round) share — the bit-parity contract depends on every
    execution path using exactly this function."""
    bc_sub = jax.vmap(strategy.apply_broadcast,
                      in_axes=(0, 0, None))(new_sub, applied, server)

    def keep(new, old):
        m = recv.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    return jax.tree.map(keep, bc_sub, old_sub)


# ---------------------------------------------------------------------------
# in-process backend (the reference semantics)
# ---------------------------------------------------------------------------

class InProcessExecutor:
    """Eager vmap backend — every round is host-orchestrated jax ops."""

    def train(self, strategy, sub_cs, server, sub_data, keys):
        new_sub, upload = jax.vmap(
            strategy.client_step, in_axes=(0, None, 0, 0))(
            sub_cs, server, sub_data, keys)
        return new_sub, upload.vecs, upload.slots     # (K,j,d), (K,j)

    def masked_mean(self, strategy, dec, slots, arrive, prev):
        """The exact Alg. 2 masked mean (weights all 1), bit-identical
        to ``clustering.aggregate``."""
        masked = jnp.where(arrive[:, None], slots, -1)
        res = clustering.aggregate(
            dec.reshape(-1, strategy.vec_dim), masked.reshape(-1),
            strategy.n_slots, prev=prev)
        return res.cluster_weights, res.counts

    def apply_merge(self, strategy, new_sub, applied, rx_server, old_sub,
                    recv):
        return _broadcast_apply_merge(strategy, new_sub, applied,
                                      rx_server, old_sub, recv)

    def evaluate(self, strategy, cs, x_test, y_test):
        return jax.vmap(strategy.evaluate)(cs, x_test, y_test)

    def fused_sync_round(self, strategy, sub_cs, server, sub_data, keys,
                         arrive):
        return None                      # no fused form; use the stages


# ---------------------------------------------------------------------------
# shard_map padding helpers
# ---------------------------------------------------------------------------

def _pad_rows(a: jnp.ndarray, mult: int, fill=None) -> jnp.ndarray:
    """Pad the leading axis up to a multiple of ``mult`` — with ``fill``,
    or by repeating row 0 (inert: results for pad rows are sliced off)."""
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    if fill is None:
        tail = jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])
    else:
        tail = jnp.full((pad,) + a.shape[1:], fill, a.dtype)
    return jnp.concatenate([a, tail], axis=0)


def _pad_tree(tree, mult: int):
    return jax.tree.map(lambda a: _pad_rows(a, mult), tree)


def _unpad(tree, n: int):
    return jax.tree.map(lambda a: a[:n], tree)


# ---------------------------------------------------------------------------
# the shard-mapped sync round (one compiled program)
# ---------------------------------------------------------------------------

def _sharded_masked_mean(vals, slots, n_slots, axis, collective, n_valid,
                         prev):
    """Per-shard uploads → replicated (server, counts), one collective."""
    if collective == "gather":
        return masked_collectives.clustered_mean_gathered(
            vals, slots, n_slots, axis, prev, n_valid=n_valid)
    means, counts = masked_collectives.clustered_weighted_mean_sharded(
        vals, slots, jnp.ones_like(slots, jnp.float32), n_slots, axis)
    server = jnp.where(counts[:, None] > 0, means, prev)
    return server, counts


def _sync_round_body(strategy, axis: str, collective: str,
                     n_valid: int | None):
    """Per-shard body of one full sync round (train → masked collective
    → broadcast-apply → evaluate).  Only valid for the identity wire
    (dense float32): lossy codecs need the host codec boundary, which
    splits the round into the stage programs below."""

    def body(sub_cs, server, sub_data, keys, arrive):
        new_sub, up = jax.vmap(
            strategy.client_step, in_axes=(0, None, 0, 0))(
            sub_cs, server, sub_data, keys)
        masked = jnp.where(arrive[:, None], up.slots, -1)
        server2, counts = _sharded_masked_mean(
            up.vecs.reshape(-1, strategy.vec_dim), masked.reshape(-1),
            strategy.n_slots, axis, collective, n_valid, server)
        applied = applied_slots(up.slots, counts, arrive)
        merged = _broadcast_apply_merge(strategy, new_sub, applied,
                                        server2, sub_cs, arrive)
        acc = jax.vmap(strategy.evaluate)(
            merged, sub_data.x_test, sub_data.y_test)
        return merged, server2, counts, applied, acc, up.slots

    return body


def build_sharded_round(strategy, mesh, axis_name: str = "clients",
                        collective: str = "psum",
                        n_clients: int | None = None):
    """One full sync round as a single shard-mappable callable —
    ``(sub_cs, server, sub_data, keys, arrive) → (new_cs, server,
    counts, applied, per_client_acc, slots)`` with clients sharded over
    ``axis_name``.  This is what the dry-run lowers on the production
    mesh (clients over the ``data`` axis) to measure the masked
    collective's bytes in the partitioned HLO, and what the
    :class:`ShardMapExecutor` runs for the identity-wire fast path.
    """
    if collective not in COLLECTIVES:
        raise ValueError(f"unknown collective {collective!r}")
    n_valid = None if n_clients is None else n_clients * strategy.j_slots
    body = _sync_round_body(strategy, axis_name, collective, n_valid)
    spec = P(axis_name)
    # check_rep=False: the 0.4.x replication checker cannot infer that
    # all_gather→slice→einsum yields a replicated value (it does, by
    # construction — every shard reduces the same gathered array)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec, P(), spec, spec, spec),
        out_specs=(spec, P(), P(), spec, spec, spec), check_rep=False)


# ---------------------------------------------------------------------------
# stage programs (jitted once per (strategy, mesh) via static args)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0, 1, 2))
def _train_program(strategy, mesh, axis, sub_cs, server, sub_data, keys):
    spec = P(axis)

    def body(cs, srv, d, k):
        return jax.vmap(strategy.client_step,
                        in_axes=(0, None, 0, 0))(cs, srv, d, k)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, P(), spec, spec),
                     out_specs=(spec, spec))(sub_cs, server, sub_data, keys)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5))
def _agg_program(n_slots, dim, mesh, axis, collective, n_valid,
                 dec, slots, arrive, prev):
    spec = P(axis)

    def body(dec_, slots_, arrive_, prev_):
        masked = jnp.where(arrive_[:, None], slots_, -1)
        return _sharded_masked_mean(
            dec_.reshape(-1, dim), masked.reshape(-1), n_slots, axis,
            collective, n_valid, prev_)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, spec, spec, P()),
                     out_specs=(P(), P()),
                     check_rep=False)(dec, slots, arrive, prev)


@partial(jax.jit, static_argnums=(0, 1, 2))
def _apply_program(strategy, mesh, axis, new_sub, applied, rx_server,
                   old_sub, recv):
    spec = P(axis)

    def body(ns, ap, srv, old, rc):
        return _broadcast_apply_merge(strategy, ns, ap, srv, old, rc)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, spec, P(), spec, spec),
                     out_specs=spec)(new_sub, applied, rx_server, old_sub,
                                     recv)


@partial(jax.jit, static_argnums=(0, 1, 2))
def _eval_program(strategy, mesh, axis, cs, x_test, y_test):
    spec = P(axis)
    return shard_map(
        lambda c, x, y: jax.vmap(strategy.evaluate)(c, x, y),
        mesh=mesh, in_specs=(spec, spec, spec),
        out_specs=spec)(cs, x_test, y_test)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _fused_program(strategy, mesh, axis, collective, n_valid,
                   sub_cs, server, sub_data, keys, arrive):
    spec = P(axis)
    body = _sync_round_body(strategy, axis, collective, n_valid)
    return shard_map(body, mesh=mesh,
                     in_specs=(spec, P(), spec, spec, spec),
                     out_specs=(spec, P(), P(), spec, spec, spec),
                     check_rep=False)(
        sub_cs, server, sub_data, keys, arrive)


# ---------------------------------------------------------------------------
# shard_map backend
# ---------------------------------------------------------------------------

class ShardMapExecutor:
    """The production-mesh backend: every stage is a compiled shard_map
    program over ``axis`` (clients one-block-per-shard), cached across
    rounds/engines by jit's static-argument cache."""

    def __init__(self, mesh=None, axis: str = "clients",
                 collective: str = "gather"):
        if collective not in COLLECTIVES:
            raise ValueError(f"unknown collective {collective!r}")
        if mesh is None:
            from repro.sharding import compat
            mesh = compat.make_mesh((len(jax.devices()),), (axis,))
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis: {mesh}")
        self.mesh = mesh
        self.axis = axis
        self.collective = collective
        self.n_shards = int(mesh.shape[axis])

    def train(self, strategy, sub_cs, server, sub_data, keys):
        k = keys.shape[0]
        new_sub, upload = _train_program(
            strategy, self.mesh, self.axis,
            _pad_tree(sub_cs, self.n_shards), server,
            _pad_tree(sub_data, self.n_shards),
            _pad_rows(keys, self.n_shards))
        new_sub = _unpad(new_sub, k)
        return new_sub, upload.vecs[:k], upload.slots[:k]

    def masked_mean(self, strategy, dec, slots, arrive, prev):
        k = dec.shape[0]
        return _agg_program(
            strategy.n_slots, strategy.vec_dim, self.mesh, self.axis,
            self.collective, k * strategy.j_slots,
            _pad_rows(dec, self.n_shards),
            _pad_rows(slots, self.n_shards, fill=-1),
            _pad_rows(arrive, self.n_shards, fill=False), prev)

    def apply_merge(self, strategy, new_sub, applied, rx_server, old_sub,
                    recv):
        k = applied.shape[0]
        merged = _apply_program(
            strategy, self.mesh, self.axis,
            _pad_tree(new_sub, self.n_shards),
            _pad_rows(applied, self.n_shards, fill=-1), rx_server,
            _pad_tree(old_sub, self.n_shards),
            _pad_rows(recv, self.n_shards, fill=False))
        return _unpad(merged, k)

    def evaluate(self, strategy, cs, x_test, y_test):
        n = x_test.shape[0]
        acc = _eval_program(
            strategy, self.mesh, self.axis, _pad_tree(cs, self.n_shards),
            _pad_rows(x_test, self.n_shards),
            _pad_rows(y_test, self.n_shards))
        return acc[:n]

    def fused_sync_round(self, strategy, sub_cs, server, sub_data, keys,
                         arrive):
        """The whole round as one compiled sharded program (identity
        wire only — the engine calls this for dense float32 sync)."""
        k = keys.shape[0]
        out = _fused_program(
            strategy, self.mesh, self.axis, self.collective,
            k * strategy.j_slots,
            _pad_tree(sub_cs, self.n_shards), server,
            _pad_tree(sub_data, self.n_shards),
            _pad_rows(keys, self.n_shards),
            _pad_rows(jnp.asarray(arrive), self.n_shards, fill=False))
        merged, server2, counts, applied, acc, slots = out
        return (_unpad(merged, k), server2, counts, applied[:k], acc[:k],
                slots[:k])
