"""Round executors: where one federated round's compute actually runs.

The engine (``runtime/engine.py``) owns the *semantics* of a round —
scheduling, the wire codec, aggregation mode, checkpointing — and
delegates the three array-heavy pieces (client training, the masked
per-slot mean, broadcast-apply + evaluation) to a ``RoundExecutor``:

* :class:`InProcessExecutor` — eager vmap over the sampled clients, the
  host einsum of ``clustering.aggregate``.  The reference backend.
* :class:`ShardMapExecutor` — the same round lowered through
  ``shard_map`` over a ``clients`` mesh axis: each shard trains its
  block of the sampled clients, and aggregation is a single masked
  collective from :mod:`repro.fl.masked_collectives` (``all_gather`` +
  canonical einsum for bit-exactness, or the C·m ``psum`` accumulator
  for communication-optimality).  For the dominant configuration (sync
  barrier, full participation, dense float32 wire) the *entire* round —
  client_step, aggregation, broadcast-apply, evaluation — is one
  compiled sharded program (:func:`build_sharded_round`, also what the
  dry-run lowers on the production mesh).

Both executors also run the **async buffered update** as one compiled
program (:func:`_buffer_insert` → maturity gate →
staleness-discounted mean): the fixed-capacity upload buffer is device
state carried in ``EngineState`` (see ``docs/async-runtime.md`` for the
lane layout), the insert/evict loop is a ``lax.scan`` of masked
single-row updates, and the gate/mean are branchless ``where`` selects
— no host round-trips.  Shard-mapped, the per-shard uploads are
all_gathered into canonical client order (the buffer is global round
state, so every shard replays the identical insert), and the mean
lowers through ``masked_collectives`` — host-form einsum for
``gather`` (bit-exact), :func:`buffered_weighted_mean_sharded` for
``psum``.

The conformance suite (``tests/test_fl_conformance.py``) pins
shard-mapped == in-process == legacy ``federation.run`` bit-for-bit for
every (strategy, codec, participation) cell — and device-buffered ==
host-buffered == shard-mapped for the async mode; anything that changes
per-client key derivation, reduction shapes, insert order, or merge
order breaks it.

Sampled-K padding: shard_map needs the leading axis divisible by the
mesh axis size, so executors pad K (and N for evaluation) up to the
next multiple with inert rows — repeated row 0 for client state/data,
``active=False`` / slot −1 for participation — and slice the padding
back off.  Padded rows are masked out of the collective *and* trimmed
from the reduction shape (``n_valid``) so the float summation order
matches the unpadded in-process einsum exactly.

Sharding contract, program by program
-------------------------------------
Client-major arrays (client state, per-client data, rng keys, slot
ids, arrival masks, uploads) are sharded ``P(axis)`` — one contiguous
block per shard; the server matrix, cluster counts, the async buffer
lanes, and the round index are replicated ``P()``.

* ``_train_program``       — per-shard vmap of ``client_step``; slot
  matrix replicated in, per-shard (state, uploads) out.  No collective.
* ``_assign_program``      — the v2 server-side assignment stage: one
  tiled ``all_gather`` per upload lane into canonical client order,
  the strategy's ``assign`` hook replayed identically on every shard
  (replicated server state in), per-shard slot-id blocks out.
* ``_agg_program``         — per-shard uploads in, replicated raw
  (mean, counts) out via **one** ``all_gather`` (gather mode) or
  **one** ``psum`` of the (C, m) accumulator (psum mode); empty-slot
  retention is applied by the strategy's ``server_update``.
* ``_apply_program``       — per-shard broadcast-apply/merge; server
  replicated in.  No collective.
* ``_eval_program``        — per-shard vmap of ``evaluate``.  No
  collective.
* ``_fused_program``       — the four above fused (identity wire):
  per-shard in/out except the replicated (server, counts); the same
  single aggregation collective in the middle.
* ``_async_update_program`` / ``build_sharded_async_update`` — uploads
  per-shard in, everything else replicated both ways; one
  ``all_gather`` per upload lane (canonical insert order), plus the
  ``psum`` of :func:`buffered_weighted_mean_sharded` in psum mode.

Telemetry span boundaries (``repro.fl.obs``): the engine wraps each
executor call in a phase span and fences its outputs with
``jax.block_until_ready``, so a stage program's span bills the whole
compiled program — dispatch *and* device execution — to that phase
(``client_step`` = ``_train_program``, ``assign`` =
``_assign_program``, ``aggregate`` = ``_agg_program`` or the async
update, ``apply_merge``/``eval`` likewise, and ``fused_round`` the
whole ``_fused_program``).  Executors stay telemetry-free: nothing
observability-related crosses into compiled code, which is what keeps
obs-on == obs-off bit-exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import clustering
from repro.fl import masked_collectives
from repro.fl.runtime.strategy import resolve_server_update

COLLECTIVES = ("gather", "psum")


def applied_slots(slots, counts, arrive):
    """Which slots are actually pushed back to each client this round:
    it arrived, it shared the slot, and the slot received an aggregate
    (a never-fed slot row must not overwrite fresh local training).
    Shared by the engine's staged path and the fused sharded body — the
    bit-parity contract depends on both using exactly this formula."""
    return jnp.where(arrive[:, None] & (slots >= 0)
                     & (counts[jnp.clip(slots, 0)] > 0), slots, -1)


def _broadcast_apply_merge(strategy, new_sub, applied, server, old_sub,
                           recv):
    """vmap ``apply_broadcast`` over clients, then revert non-receivers
    to their pre-round state.  The one merge both backends (and the
    fused round) share — the bit-parity contract depends on every
    execution path using exactly this function."""
    bc_sub = jax.vmap(strategy.apply_broadcast,
                      in_axes=(0, 0, None))(new_sub, applied, server)

    def keep(new, old):
        m = recv.reshape((-1,) + (1,) * (new.ndim - 1))
        return jnp.where(m, new, old)

    return jax.tree.map(keep, bc_sub, old_sub)


def _client_step_block(strategy):
    """The block form of ``client_step`` over a stacked client cohort.

    Default: a vmap of the per-client step.  Strategies that set
    ``use_fused_kernels`` (the engine's ``tm_backend="pallas"``) supply
    ``fused_client_step`` — one client-batched kernel launch instead of
    a vmap (vmap of a ``pallas_call`` batches by prepending a grid axis,
    serializing clients).  Bit-identical outputs either way, so every
    execution path below dispatches through here.  The branch resolves
    at trace time: the strategy is a static (hashable) argument of each
    stage program."""
    if getattr(strategy, "use_fused_kernels", False):
        return strategy.fused_client_step

    def block(cs, server, d, keys):
        return jax.vmap(strategy.client_step,
                        in_axes=(0, None, 0, 0))(cs, server, d, keys)

    return block


def _evaluate_block(strategy):
    """Block form of ``evaluate`` — same dispatch as
    :func:`_client_step_block`."""
    if getattr(strategy, "use_fused_kernels", False):
        return strategy.fused_evaluate
    return lambda cs, x, y: jax.vmap(strategy.evaluate)(cs, x, y)


def evaluate_population(executor, strategy, gather_cs, gather_data,
                        n: int, chunk: int):
    """Full-population evaluation over a host-side client store, in
    fixed-size chunks — the mmap engine's ``store_eval="full"`` path.

    ``gather_cs(ids)`` / ``gather_data(ids) -> (x_test, y_test)`` pull
    each chunk's rows (store gather / streaming ingestion); only
    ``chunk`` clients are ever device-resident.  Per-client evaluation
    is an independent vmap lane on both executors (no cross-client
    reduction — the shard-mapped program pads and trims), so the
    concatenated accuracy vector is bit-identical to one monolithic
    ``executor.evaluate`` over the whole population."""
    accs = []
    for c0 in range(0, n, chunk):
        ids = np.arange(c0, min(c0 + chunk, n), dtype=np.int64)
        cs = gather_cs(ids)
        x, y = gather_data(ids)
        accs.append(np.asarray(executor.evaluate(strategy, cs, x, y)))
    return jnp.asarray(np.concatenate(accs, axis=0))


# ---------------------------------------------------------------------------
# in-process backend (the reference semantics)
# ---------------------------------------------------------------------------

class InProcessExecutor:
    """Eager vmap backend — every round is host-orchestrated jax ops."""

    def train(self, strategy, sub_cs, server, sub_data, keys):
        new_sub, upload = _client_step_block(strategy)(
            sub_cs, server, sub_data, keys)
        return new_sub, upload.vecs, upload.slots     # (K,j,d), (K,j)

    def assign(self, strategy, server, dec, slots, arrive):
        """Run the strategy's server-side assignment hook eagerly (pure
        jax on fully materialized arrays — the reference semantics the
        shard-mapped assign stage is pinned against)."""
        return strategy.assign(server, dec, slots, arrive)

    def masked_mean(self, strategy, dec, slots, arrive):
        """The exact Alg. 2 masked mean (weights all 1), bit-identical
        to ``clustering.aggregate``.  Returns the *raw* per-slot mean
        (zeros where empty) — empty-slot retention is the strategy's
        ``server_update`` decision, applied by the engine."""
        masked = jnp.where(arrive[:, None], slots, -1)
        res = clustering.aggregate(
            dec.reshape(-1, strategy.vec_dim), masked.reshape(-1),
            strategy.n_slots)
        return res.cluster_weights, res.counts

    def apply_merge(self, strategy, new_sub, applied, rx_server, old_sub,
                    recv):
        return _broadcast_apply_merge(strategy, new_sub, applied,
                                      rx_server, old_sub, recv)

    def evaluate(self, strategy, cs, x_test, y_test):
        return _evaluate_block(strategy)(cs, x_test, y_test)

    def async_update(self, strategy, buf, up, round_idx, prev,
                     min_uploads: int):
        """Insert this round's uploads into the device buffer and fold
        in the matured entries — one jitted program on the default
        device (buffer and uploads both unsharded)."""
        return _async_update_program(strategy.n_slots, min_uploads, buf,
                                     up, round_idx, prev)

    def fused_sync_round(self, strategy, sub_cs, server, sub_data, keys,
                         arrive):
        return None                      # no fused form; use the stages


# ---------------------------------------------------------------------------
# shard_map padding helpers
# ---------------------------------------------------------------------------

def _pad_rows(a: jnp.ndarray, mult: int, fill=None) -> jnp.ndarray:
    """Pad the leading axis up to a multiple of ``mult`` — with ``fill``,
    or by repeating row 0 (inert: results for pad rows are sliced off)."""
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    if fill is None:
        tail = jnp.broadcast_to(a[:1], (pad,) + a.shape[1:])
    else:
        tail = jnp.full((pad,) + a.shape[1:], fill, a.dtype)
    return jnp.concatenate([a, tail], axis=0)


def _pad_tree(tree, mult: int):
    return jax.tree.map(lambda a: _pad_rows(a, mult), tree)


def _unpad(tree, n: int):
    return jax.tree.map(lambda a: a[:n], tree)


# ---------------------------------------------------------------------------
# the shard-mapped sync round (one compiled program)
# ---------------------------------------------------------------------------

def _sharded_masked_mean(vals, slots, n_slots, axis, collective, n_valid):
    """Per-shard uploads → replicated raw (mean, counts), one
    collective.  Empty-slot retention is ``server_update``'s decision —
    this returns the bare per-slot mean (zeros where empty)."""
    if collective == "gather":
        return masked_collectives.clustered_mean_gathered(
            vals, slots, n_slots, axis, n_valid=n_valid)
    return masked_collectives.clustered_weighted_mean_sharded(
        vals, slots, jnp.ones_like(slots, jnp.float32), n_slots, axis)


def _sync_round_body(strategy, axis: str, collective: str,
                     n_valid: int | None):
    """Per-shard body of one full sync round (train → masked collective
    → server_update → broadcast-apply → evaluate).  Only valid for the
    identity wire (dense float32) and strategies without a server-side
    ``assign`` hook: lossy codecs need the host codec boundary and
    dynamic assignment is its own sharded stage, both of which split
    the round into the stage programs below.  ``server`` is the
    strategy-owned :class:`~repro.fl.runtime.strategy.ServerState`
    pytree, replicated; its ``server_update`` hook (or the Alg. 2
    default) folds the collective's result in, inside the program."""
    server_update = resolve_server_update(strategy)

    def body(sub_cs, server, sub_data, keys, arrive):
        new_sub, up = _client_step_block(strategy)(
            sub_cs, server.slots, sub_data, keys)
        masked = jnp.where(arrive[:, None], up.slots, -1)
        agg, counts = _sharded_masked_mean(
            up.vecs.reshape(-1, strategy.vec_dim), masked.reshape(-1),
            strategy.n_slots, axis, collective, n_valid)
        server2 = server_update(server, agg, counts)
        applied = applied_slots(up.slots, counts, arrive)
        merged = _broadcast_apply_merge(strategy, new_sub, applied,
                                        server2.slots, sub_cs, arrive)
        acc = _evaluate_block(strategy)(
            merged, sub_data.x_test, sub_data.y_test)
        return merged, server2, counts, applied, acc, up.slots

    return body


def build_sharded_round(strategy, mesh, axis_name: str = "clients",
                        collective: str = "psum",
                        n_clients: int | None = None):
    """One full sync round as a single shard-mappable callable —
    ``(sub_cs, server_state, sub_data, keys, arrive) → (new_cs,
    server_state, counts, applied, per_client_acc, slots)`` with clients
    sharded over ``axis_name`` and the
    :class:`~repro.fl.runtime.strategy.ServerState` pytree replicated
    both ways (the strategy's ``server_update`` runs inside the
    program).  This is what the dry-run lowers on the production mesh
    (clients over the ``data`` axis) to measure the masked collective's
    bytes in the partitioned HLO, and what the :class:`ShardMapExecutor`
    runs for the identity-wire fast path.
    """
    if collective not in COLLECTIVES:
        raise ValueError(f"unknown collective {collective!r}")
    n_valid = None if n_clients is None else n_clients * strategy.j_slots
    body = _sync_round_body(strategy, axis_name, collective, n_valid)
    spec = P(axis_name)
    # check_rep=False: the 0.4.x replication checker cannot infer that
    # all_gather→slice→einsum yields a replicated value (it does, by
    # construction — every shard reduces the same gathered array)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec, P(), spec, spec, spec),
        out_specs=(spec, P(), P(), spec, spec, spec), check_rep=False)


# ---------------------------------------------------------------------------
# stage programs (jitted once per (strategy, mesh) via static args)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=(0, 1, 2))
def _train_program(strategy, mesh, axis, sub_cs, server, sub_data, keys):
    spec = P(axis)

    def body(cs, srv, d, k):
        return _client_step_block(strategy)(cs, srv, d, k)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, P(), spec, spec),
                     out_specs=(spec, spec))(sub_cs, server, sub_data, keys)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5))
def _agg_program(n_slots, dim, mesh, axis, collective, n_valid,
                 dec, slots, arrive):
    spec = P(axis)

    def body(dec_, slots_, arrive_):
        masked = jnp.where(arrive_[:, None], slots_, -1)
        return _sharded_masked_mean(
            dec_.reshape(-1, dim), masked.reshape(-1), n_slots, axis,
            collective, n_valid)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, spec, spec),
                     out_specs=(P(), P()),
                     check_rep=False)(dec, slots, arrive)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _assign_program(strategy, mesh, axis, k, k_padded,
                    server, dec, slots, arrive):
    """The server-side assignment stage, shard-mapped: one tiled
    ``all_gather`` per upload lane reassembles the round's decoded
    uploads in canonical client order (trimmed to the true K), every
    shard computes the *identical* replicated assignment via the
    strategy's ``assign`` hook (cross-client math — similarity graphs,
    clustering — is allowed exactly here), and each shard slices back
    its own block of the new slot ids."""
    spec = P(axis)
    n_shards = int(mesh.shape[axis])
    blk = k_padded // n_shards

    def body(server_, dec_, slots_, arrive_):
        g = lambda a: jax.lax.all_gather(a, axis, tiled=True)[:k]
        new = strategy.assign(server_, g(dec_), g(slots_), g(arrive_))
        new = new.astype(jnp.int32)
        pad = k_padded - k
        if pad:
            new = jnp.concatenate(
                [new, jnp.full((pad,) + new.shape[1:], -1, jnp.int32)])
        i = jax.lax.axis_index(axis)
        return jax.lax.dynamic_slice_in_dim(new, i * blk, blk)

    return shard_map(body, mesh=mesh,
                     in_specs=(P(), spec, spec, spec),
                     out_specs=spec, check_rep=False)(
        server, dec, slots, arrive)


@partial(jax.jit, static_argnums=(0, 1, 2))
def _apply_program(strategy, mesh, axis, new_sub, applied, rx_server,
                   old_sub, recv):
    spec = P(axis)

    def body(ns, ap, srv, old, rc):
        return _broadcast_apply_merge(strategy, ns, ap, srv, old, rc)

    return shard_map(body, mesh=mesh,
                     in_specs=(spec, spec, P(), spec, spec),
                     out_specs=spec)(new_sub, applied, rx_server, old_sub,
                                     recv)


@partial(jax.jit, static_argnums=(0, 1, 2))
def _eval_program(strategy, mesh, axis, cs, x_test, y_test):
    spec = P(axis)
    return shard_map(
        _evaluate_block(strategy),
        mesh=mesh, in_specs=(spec, spec, spec),
        out_specs=spec)(cs, x_test, y_test)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _fused_program(strategy, mesh, axis, collective, n_valid,
                   sub_cs, server, sub_data, keys, arrive):
    spec = P(axis)
    body = _sync_round_body(strategy, axis, collective, n_valid)
    return shard_map(body, mesh=mesh,
                     in_specs=(spec, P(), spec, spec, spec),
                     out_specs=(spec, P(), P(), spec, spec, spec),
                     check_rep=False)(
        sub_cs, server, sub_data, keys, arrive)


# ---------------------------------------------------------------------------
# the async buffered update (device buffer, one compiled program)
# ---------------------------------------------------------------------------
#
# The buffer is six fixed-capacity lanes carried in EngineState —
# payloads (cap, d) plus slot-id / maturity-round / staleness-weight /
# validity / insertion-seq lanes (cap,).  Everything below is pure jax:
# per-shard it is *replicated* state (in_specs P()), because insertion
# is a global sequential decision every shard must agree on.

def _buffer_insert(buf, up_vecs, up_slots, up_ready, up_weight, up_valid):
    """Sequential masked insert of one round's uploads — the compiled
    form of the host insert loop, bit-identical by construction.

    Replicated per shard (no collective): a ``lax.scan`` over the U
    uploads where each step picks the first free lane
    (``argmin(valid)``), or on overflow evicts the oldest *insertion*
    (``argmin(seq over valid)``), and applies a masked single-row
    update (``up_valid=False`` rows are no-ops, so padding is inert).
    Returns ``(new_buf, evicted_count)``.
    """
    intmax = jnp.iinfo(jnp.int32).max
    vecs, slots, ready, weight, valid, seq = buf
    next_seq = jnp.where(valid.any(),
                         jnp.where(valid, seq, -1).max() + 1,
                         0).astype(jnp.int32)

    def step(carry, up):
        vecs, slots, ready, weight, valid, seq, nseq, evicted = carry
        v, s, rdy, w, ins = up
        full = valid.all()
        i_free = jnp.argmin(valid)                    # first invalid lane
        i_old = jnp.argmin(jnp.where(valid, seq, intmax))
        i = jnp.where(full, i_old, i_free)
        vecs = vecs.at[i].set(jnp.where(ins, v, vecs[i]))
        slots = slots.at[i].set(jnp.where(ins, s, slots[i]))
        ready = ready.at[i].set(jnp.where(ins, rdy, ready[i]))
        weight = weight.at[i].set(jnp.where(ins, w, weight[i]))
        seq = seq.at[i].set(jnp.where(ins, nseq, seq[i]))
        valid = valid.at[i].set(valid[i] | ins)
        evicted = evicted + (ins & full).astype(jnp.int32)
        nseq = nseq + ins.astype(jnp.int32)
        return (vecs, slots, ready, weight, valid, seq, nseq, evicted), None

    carry = (vecs, slots, ready, weight, valid, seq, next_seq,
             jnp.zeros((), jnp.int32))
    carry, _ = jax.lax.scan(
        step, carry, (up_vecs, up_slots, up_ready, up_weight, up_valid))
    vecs, slots, ready, weight, valid, seq, _, evicted = carry
    return (vecs, slots, ready, weight, valid, seq), evicted


def _async_gate_and_mean(buf, round_idx, n_slots, min_uploads, prev,
                         mean_fn):
    """Maturity gate + staleness-discounted mean, branchless.

    An entry is *mature* once ``round_idx`` reaches its ready round; it
    *contributes* if its discount weight is nonzero.  The
    ``async_min_uploads`` gate is a masked predicate: below threshold
    every slot id is masked to −1, so counts are zero, the server keeps
    ``prev`` row-for-row, and the buffer is left untouched — the same
    observable as the host engine's early return, with no host branch.
    ``mean_fn(vals, slots, weights) → (C, d)`` is the backend's
    lowering of the weighted mean (host einsum, or a mesh collective).
    Returns ``(server, counts, n_agg, n_buffered, new_buf)``.
    """
    vecs, slots, ready, weight, valid, seq = buf
    mature = valid & (ready <= round_idx)
    # zero-discount entries can never move the weighted mean — count
    # them as consumed noise, not as aggregated uploads (host parity)
    contrib = mature & (weight > 0.0)
    gate = mature.sum() >= min_uploads
    s = jnp.where(contrib & gate, slots, -1)
    w = jnp.where(contrib & gate, weight, 0.0)
    mean = mean_fn(vecs, s, w)
    counts = jax.nn.one_hot(s, n_slots, dtype=jnp.float32).sum(0)
    server = jnp.where(counts[:, None] > 0, mean, prev)
    valid = jnp.where(gate, valid & ~mature, valid)
    n_agg = jnp.where(gate, contrib.sum(), 0).astype(jnp.int32)
    new_buf = (vecs, slots, ready, weight, valid, seq)
    return server, counts, n_agg, valid.sum().astype(jnp.int32), new_buf


@partial(jax.jit, static_argnums=(0, 1))
def _async_update_program(n_slots, min_uploads, buf, up, round_idx, prev):
    """In-process async round update: insert → gate → host-form mean,
    one jitted program (no host round-trips between the stages)."""
    buf, evicted = _buffer_insert(buf, *up)
    server, counts, n_agg, n_buf, buf = _async_gate_and_mean(
        buf, round_idx, n_slots, min_uploads, prev,
        lambda v, s, w: masked_collectives.clustered_weighted_mean(
            v, s, w, n_slots))
    return server, counts, n_agg, n_buf, evicted, buf


def build_sharded_async_update(strategy, mesh, axis_name: str = "clients",
                               collective: str = "gather",
                               min_uploads: int = 4,
                               n_valid: int | None = None):
    """The async buffered update as one shard-mappable callable —
    ``(buf, (up_vecs, up_slots, up_ready, up_weight, up_valid),
    round_idx, prev) → (server, counts, n_agg, n_buf, evicted, buf)``.

    Uploads are sharded over ``axis_name`` (one block per shard, like
    the sync round); the buffer lanes, ``round_idx`` and ``prev`` are
    replicated, and so is everything returned.  Inside the body one
    tiled ``all_gather`` per upload lane reassembles the round's
    uploads in canonical client order (trimmed to ``n_valid`` to drop
    mesh padding), every shard replays the identical insert scan, and
    the mean lowers per ``collective``:

    * ``gather`` — the host-form ``clustered_weighted_mean`` on the
      replicated buffer: zero extra collectives, **bit-exact** with the
      in-process program (the conformance suite's async contract);
    * ``psum`` — :func:`masked_collectives.buffered_weighted_mean_sharded`,
      each shard reducing its block of buffer rows into the C·m psum
      accumulator (allclose, shard-order reduction).

    This is also what ``fed_dryrun`` lowers on the production mesh to
    price the async round's collectives.
    """
    if collective not in COLLECTIVES:
        raise ValueError(f"unknown collective {collective!r}")
    n_slots = strategy.n_slots
    # clients may live on one mesh axis ("clients") or a tuple of FSDP
    # axes (the dry-run's ("pod", "data")); collectives take either,
    # shard count is the product
    names = axis_name if isinstance(axis_name, tuple) else (axis_name,)
    n_shards = 1
    for a in names:
        n_shards *= int(mesh.shape[a])
    spec = P(axis_name)

    def body(buf, up, round_idx, prev):
        gathered = tuple(
            jax.lax.all_gather(a, axis_name, tiled=True)[:n_valid]
            for a in up)
        buf, evicted = _buffer_insert(buf, *gathered)
        if collective == "gather":
            def mean_fn(v, s, w):
                return masked_collectives.clustered_weighted_mean(
                    v, s, w, n_slots)
        else:
            def mean_fn(v, s, w):
                return masked_collectives.buffered_weighted_mean_sharded(
                    v, s, w, n_slots, axis_name, n_shards)[0]
        server, counts, n_agg, n_buf, buf = _async_gate_and_mean(
            buf, round_idx, n_slots, min_uploads, prev, mean_fn)
        return server, counts, n_agg, n_buf, evicted, buf

    # check_rep=False: every shard computes the same replicated insert /
    # gate from the same gathered uploads (the 0.4.x checker cannot see
    # through all_gather→scan→einsum to infer that)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(), spec, P(), P()),
                     out_specs=P(), check_rep=False)


@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5))
def _async_sharded_program(n_slots_strategy, mesh, axis, collective,
                           min_uploads, n_valid, buf, up, round_idx, prev):
    return build_sharded_async_update(
        n_slots_strategy, mesh, axis_name=axis, collective=collective,
        min_uploads=min_uploads, n_valid=n_valid)(buf, up, round_idx, prev)


# ---------------------------------------------------------------------------
# shard_map backend
# ---------------------------------------------------------------------------

class ShardMapExecutor:
    """The production-mesh backend: every stage is a compiled shard_map
    program over ``axis`` (clients one-block-per-shard), cached across
    rounds/engines by jit's static-argument cache."""

    def __init__(self, mesh=None, axis: str = "clients",
                 collective: str = "gather"):
        if collective not in COLLECTIVES:
            raise ValueError(f"unknown collective {collective!r}")
        if mesh is None:
            from repro.sharding import compat
            mesh = compat.make_mesh((len(jax.devices()),), (axis,))
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no {axis!r} axis: {mesh}")
        self.mesh = mesh
        self.axis = axis
        self.collective = collective
        self.n_shards = int(mesh.shape[axis])

    def train(self, strategy, sub_cs, server, sub_data, keys):
        k = keys.shape[0]
        new_sub, upload = _train_program(
            strategy, self.mesh, self.axis,
            _pad_tree(sub_cs, self.n_shards), server,
            _pad_tree(sub_data, self.n_shards),
            _pad_rows(keys, self.n_shards))
        new_sub = _unpad(new_sub, k)
        return new_sub, upload.vecs[:k], upload.slots[:k]

    def assign(self, strategy, server, dec, slots, arrive):
        """Shard-mapped server-side assignment: uploads sharded over
        ``axis`` (padded with inert slot-−1 / non-arrived rows), the
        server state replicated, the gathered assignment replayed
        identically on every shard — see :func:`_assign_program`."""
        k = slots.shape[0]
        k_padded = k + ((-k) % self.n_shards)
        out = _assign_program(
            strategy, self.mesh, self.axis, k, k_padded, server,
            _pad_rows(dec, self.n_shards),
            _pad_rows(slots, self.n_shards, fill=-1),
            _pad_rows(arrive, self.n_shards, fill=False))
        return out[:k]

    def masked_mean(self, strategy, dec, slots, arrive):
        k = dec.shape[0]
        return _agg_program(
            strategy.n_slots, strategy.vec_dim, self.mesh, self.axis,
            self.collective, k * strategy.j_slots,
            _pad_rows(dec, self.n_shards),
            _pad_rows(slots, self.n_shards, fill=-1),
            _pad_rows(arrive, self.n_shards, fill=False))

    def apply_merge(self, strategy, new_sub, applied, rx_server, old_sub,
                    recv):
        k = applied.shape[0]
        merged = _apply_program(
            strategy, self.mesh, self.axis,
            _pad_tree(new_sub, self.n_shards),
            _pad_rows(applied, self.n_shards, fill=-1), rx_server,
            _pad_tree(old_sub, self.n_shards),
            _pad_rows(recv, self.n_shards, fill=False))
        return _unpad(merged, k)

    def evaluate(self, strategy, cs, x_test, y_test):
        n = x_test.shape[0]
        acc = _eval_program(
            strategy, self.mesh, self.axis, _pad_tree(cs, self.n_shards),
            _pad_rows(x_test, self.n_shards),
            _pad_rows(y_test, self.n_shards))
        return acc[:n]

    def async_update(self, strategy, buf, up, round_idx, prev,
                     min_uploads: int):
        """The shard-mapped async buffered update: uploads sharded over
        ``axis`` (padded to the mesh with inert ``valid=False`` rows),
        buffer lanes / round index / server replicated, one compiled
        program per (strategy, upload-count) — see
        :func:`build_sharded_async_update`."""
        uv, us, ur, uw, ua = up
        u = uv.shape[0]
        padded = (_pad_rows(uv, self.n_shards),
                  _pad_rows(us, self.n_shards, fill=-1),
                  _pad_rows(ur, self.n_shards, fill=0),
                  _pad_rows(uw, self.n_shards, fill=0.0),
                  _pad_rows(ua, self.n_shards, fill=False))
        return _async_sharded_program(
            strategy, self.mesh, self.axis, self.collective, min_uploads,
            u, buf, padded, round_idx, prev)

    def fused_sync_round(self, strategy, sub_cs, server, sub_data, keys,
                         arrive):
        """The whole round as one compiled sharded program (identity
        wire only — the engine calls this for dense float32 sync)."""
        k = keys.shape[0]
        out = _fused_program(
            strategy, self.mesh, self.axis, self.collective,
            k * strategy.j_slots,
            _pad_tree(sub_cs, self.n_shards), server,
            _pad_tree(sub_data, self.n_shards),
            _pad_rows(keys, self.n_shards),
            _pad_rows(jnp.asarray(arrive), self.n_shards, fill=False))
        merged, server2, counts, applied, acc, slots = out
        return (_unpad(merged, k), server2, counts, applied[:k], acc[:k],
                slots[:k])
