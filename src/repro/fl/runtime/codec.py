"""Wire codec for federated uploads/broadcasts — real bytes, not formulas.

Every vector that crosses the client↔aggregator boundary is encoded to an
actual ``bytes`` buffer and decoded back before aggregation, so the
communication numbers reported by the engine are ``len(buffer)`` of what
would really be sent, and lossy codecs (int8/int4) really do perturb the
aggregate the way they would in deployment.

Formats (little-endian throughout; the codec config is shared out-of-band
by both endpoints, so frames carry no codec/type tags):

* ``float32`` dense — payload is the raw ``<f4`` vector: ``4·m`` bytes.
  This is the legacy wire format; with it the engine's metered totals
  reproduce the hand-computed §6.7 accounting exactly.
* ``int8`` dense — ``scale <f4`` + ``m`` bytes.  Symmetric quantization
  ``q = round(x / scale)``, ``scale = max|x| / 127``.
* ``int4`` dense — ``scale <f4`` + ``ceil(m/2)`` bytes; two's-complement
  nibbles packed two per byte, ``q ∈ [−7, 7]`` stored biased by +8.
* sparse delta (any dtype, ``sparse=True``) — the encoder subtracts the
  shared reference ``ref``, quantizes the *delta*, and sends only
  nonzero entries: ``flag u1`` + [``scale <f4``] + ``count <u4`` +
  ``count·(idx <u2 + value)``.  When the sparse frame would be larger
  than the dense one the encoder falls back to dense (``flag = 0``).
  The reference is whatever both endpoints share out-of-band; the
  engine tracks it *per client* (``EngineState.ref_vecs`` — the slot
  row each client last received over the broadcast, zeros if never
  synced), so delta savings stay honest under partial participation.

``encode`` → ``bytes``; ``decode`` → float32 numpy vector.  Round-trip is
bit-exact for float32 and within one quantization step otherwise (the
satellite test pins this).
"""
from __future__ import annotations

import dataclasses
import struct

import numpy as np

CODECS = ("float32", "int8", "int4")

_QMAX = {"int8": 127, "int4": 7}


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    name: str = "float32"       # float32 | int8 | int4
    sparse: bool = False        # sparse delta encoding vs shared reference

    def __post_init__(self):
        if self.name not in CODECS:
            raise ValueError(f"unknown codec {self.name!r}; "
                             f"choose from {CODECS}")


# ---------------------------------------------------------------------------
# dense payloads
# ---------------------------------------------------------------------------

def _quantize(vec: np.ndarray, qmax: int) -> tuple[np.ndarray, float]:
    peak = float(np.max(np.abs(vec))) if vec.size else 0.0
    scale = peak / qmax if peak > 0 else 1.0
    q = np.clip(np.rint(vec / scale), -qmax, qmax).astype(np.int8)
    return q, scale


def _pack_int4(q: np.ndarray) -> bytes:
    """q in [−7, 7] → biased nibbles [1, 15], two per byte."""
    b = (q.astype(np.int16) + 8).astype(np.uint8)
    if b.size % 2:
        b = np.concatenate([b, np.zeros(1, np.uint8)])
    return ((b[0::2] << 4) | b[1::2]).tobytes()


def _unpack_int4(buf: bytes, m: int) -> np.ndarray:
    b = np.frombuffer(buf, dtype=np.uint8)
    out = np.empty(b.size * 2, np.int16)
    out[0::2] = b >> 4
    out[1::2] = b & 0x0F
    return (out[:m] - 8).astype(np.float32)


def _encode_dense(vec: np.ndarray, name: str) -> bytes:
    if name == "float32":
        return vec.astype("<f4").tobytes()
    q, scale = _quantize(vec, _QMAX[name])
    head = struct.pack("<f", scale)
    if name == "int8":
        return head + q.tobytes()
    return head + _pack_int4(q)


def _decode_dense(buf: bytes, m: int, name: str) -> np.ndarray:
    if name == "float32":
        return np.frombuffer(buf, dtype="<f4", count=m).astype(np.float32)
    (scale,) = struct.unpack_from("<f", buf, 0)
    if name == "int8":
        q = np.frombuffer(buf, dtype=np.int8, count=m,
                          offset=4).astype(np.float32)
    else:
        q = _unpack_int4(buf[4:], m)
    return q * scale


def _value_bytes(name: str, count: int) -> int:
    if name == "float32":
        return 4 * count
    if name == "int8":
        return count
    return (count + 1) // 2


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------

def encode(vec: np.ndarray, cfg: CodecConfig,
           ref: np.ndarray | None = None) -> bytes:
    """Encode one float vector; ``ref`` is the shared delta reference
    (ignored unless ``cfg.sparse``)."""
    vec = np.asarray(vec, dtype=np.float32).ravel()
    if not cfg.sparse:
        return _encode_dense(vec, cfg.name)

    delta = vec if ref is None else vec - np.asarray(ref, np.float32).ravel()
    if cfg.name == "float32":
        q, scale = delta, None
        nz = np.nonzero(delta)[0]
    else:
        q, scale = _quantize(delta, _QMAX[cfg.name])
        nz = np.nonzero(q)[0]
    if nz.size > 0xFFFF or vec.size > 0xFFFF:
        nz = None                         # u2 indices can't address it
    if nz is not None:
        sparse_cost = 5 + (0 if scale is None else 4) \
            + 2 * nz.size + _value_bytes(cfg.name, nz.size)
        dense_cost = 1 + len(_encode_dense(vec, cfg.name))
        if sparse_cost < dense_cost:
            parts = [b"\x01"]
            if scale is not None:
                parts.append(struct.pack("<f", scale))
            parts.append(struct.pack("<I", nz.size))
            parts.append(nz.astype("<u2").tobytes())
            if cfg.name == "float32":
                parts.append(delta[nz].astype("<f4").tobytes())
            elif cfg.name == "int8":
                parts.append(q[nz].tobytes())
            else:
                parts.append(_pack_int4(q[nz]))
            return b"".join(parts)
    return b"\x00" + _encode_dense(vec, cfg.name)


def decode(buf: bytes, m: int, cfg: CodecConfig,
           ref: np.ndarray | None = None) -> np.ndarray:
    """Decode one frame produced by :func:`encode` back to float32 (m,)."""
    if not cfg.sparse:
        return _decode_dense(buf, m, cfg.name)

    flag, buf = buf[0], buf[1:]
    if flag == 0:
        return _decode_dense(buf, m, cfg.name)
    off = 0
    scale = None
    if cfg.name != "float32":
        (scale,) = struct.unpack_from("<f", buf, off)
        off += 4
    (count,) = struct.unpack_from("<I", buf, off)
    off += 4
    idx = np.frombuffer(buf, dtype="<u2", count=count, offset=off
                        ).astype(np.int64)
    off += 2 * count
    if cfg.name == "float32":
        vals = np.frombuffer(buf, dtype="<f4", count=count, offset=off
                             ).astype(np.float32)
    elif cfg.name == "int8":
        vals = np.frombuffer(buf, dtype=np.int8, count=count, offset=off
                             ).astype(np.float32) * scale
    else:
        vals = _unpack_int4(buf[off:], count) * scale
    delta = np.zeros(m, np.float32)
    delta[idx] = vals
    base = np.zeros(m, np.float32) if ref is None \
        else np.asarray(ref, np.float32).ravel().copy()
    return base + delta


def roundtrip_tolerance(vec: np.ndarray, cfg: CodecConfig) -> float:
    """Worst-case |decode(encode(x)) − x| for this codec on this vector
    (half a quantization step, plus float slack)."""
    if cfg.name == "float32":
        return 0.0
    peak = float(np.max(np.abs(np.asarray(vec)))) if np.size(vec) else 0.0
    return 0.5 * peak / _QMAX[cfg.name] + 1e-5
