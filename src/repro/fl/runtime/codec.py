"""Wire codec for federated uploads/broadcasts — real bytes, not formulas.

Every vector that crosses the client↔aggregator boundary is encoded to an
actual ``bytes`` buffer and decoded back before aggregation, so the
communication numbers reported by the engine are ``len(buffer)`` of what
would really be sent, and lossy codecs (int8/int4) really do perturb the
aggregate the way they would in deployment.

Formats (little-endian throughout; the codec config is shared out-of-band
by both endpoints, so frames carry no codec/type tags):

* ``float32`` dense — payload is the raw ``<f4`` vector: ``4·m`` bytes.
  This is the legacy wire format; with it the engine's metered totals
  reproduce the hand-computed §6.7 accounting exactly.
* ``int8`` dense — ``scale <f4`` + ``m`` bytes.  Symmetric quantization
  ``q = round(x / scale)``, ``scale = max|x| / 127``.
* ``int4`` dense — ``scale <f4`` + ``ceil(m/2)`` bytes; two's-complement
  nibbles packed two per byte, ``q ∈ [−7, 7]`` stored biased by +8.
* sparse delta (any dtype, ``sparse=True``) — the encoder subtracts the
  shared reference ``ref``, quantizes the *delta*, and sends only
  nonzero entries: ``flag u1`` + [``scale <f4``] + ``count <u4`` +
  ``count·(idx <u2 + value)``.  When the sparse frame would be larger
  than the dense one the encoder falls back to dense (``flag = 0``).
  The reference is whatever both endpoints share out-of-band; the
  engine tracks it *per client* (``EngineState.ref_vecs`` — the slot
  row each client last received over the broadcast, zeros if never
  synced), so delta savings stay honest under partial participation.

Compression v2 (both opt-in via :class:`CodecConfig`):

* ``index_coding="vrle"`` — the sparse index stream is entropy-coded as
  run-length pairs of LEB128 varints instead of raw ``<u2`` indices:
  ``flag = 2`` + [``scale <f4``] + ``varint count`` + ``varint n_runs``
  + ``n_runs·(varint gap, varint run_len)`` + values.  A *run* is a
  maximal block of consecutive indices; ``gap`` is the distance from
  the end of the previous run.  Varints also lift the legacy ``<u2``
  limit: v2 frames address vectors of any length.
* ``error_feedback=True`` — the caller keeps a per-(client, slot)
  residual vector and encodes ``vec + residual`` through
  :func:`ef_encode`; the quantization error of *this* frame becomes the
  next round's residual, so lossy int8/int4 error stops accumulating
  across rounds (classic EF-SGD memory, per the communication-reduction
  taxonomy).  Requires a lossy codec — float32 round-trips bit-exact
  and the residual would be identically zero.

``encode`` → ``bytes``; ``decode`` → float32 numpy vector.  Round-trip is
bit-exact for float32 and within one quantization step otherwise (the
satellite test pins this).
"""
from __future__ import annotations

import dataclasses
import struct

import numpy as np

CODECS = ("float32", "int8", "int4")
INDEX_CODINGS = ("u2", "vrle")

_QMAX = {"int8": 127, "int4": 7}


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    name: str = "float32"       # float32 | int8 | int4
    sparse: bool = False        # sparse delta encoding vs shared reference
    error_feedback: bool = False  # EF residual memory (lossy codecs only)
    index_coding: str = "u2"    # u2 | vrle (varint+RLE sparse indices)

    def __post_init__(self):
        if self.name not in CODECS:
            raise ValueError(f"unknown codec {self.name!r}; "
                             f"choose from {CODECS}")
        if self.index_coding not in INDEX_CODINGS:
            raise ValueError(f"unknown index_coding "
                             f"{self.index_coding!r}; "
                             f"choose from {INDEX_CODINGS}")
        if self.index_coding == "vrle" and not self.sparse:
            raise ValueError("index_coding='vrle' entropy-codes the "
                             "sparse index stream and requires "
                             "sparse=True (dense frames have no "
                             "index stream)")
        if self.error_feedback and self.name == "float32":
            raise ValueError("error_feedback requires a lossy codec "
                             "(int8 | int4); float32 round-trips "
                             "bit-exact, so the residual would be "
                             "identically zero")


# ---------------------------------------------------------------------------
# dense payloads
# ---------------------------------------------------------------------------

def _quantize(vec: np.ndarray, qmax: int) -> tuple[np.ndarray, float]:
    peak = float(np.max(np.abs(vec))) if vec.size else 0.0
    scale = peak / qmax if peak > 0 else 1.0
    q = np.clip(np.rint(vec / scale), -qmax, qmax).astype(np.int8)
    return q, scale


def _pack_int4(q: np.ndarray) -> bytes:
    """q in [−7, 7] → biased nibbles [1, 15], two per byte."""
    b = (q.astype(np.int16) + 8).astype(np.uint8)
    if b.size % 2:
        b = np.concatenate([b, np.zeros(1, np.uint8)])
    return ((b[0::2] << 4) | b[1::2]).tobytes()


def _unpack_int4(buf: bytes, m: int) -> np.ndarray:
    b = np.frombuffer(buf, dtype=np.uint8)
    out = np.empty(b.size * 2, np.int16)
    out[0::2] = b >> 4
    out[1::2] = b & 0x0F
    return (out[:m] - 8).astype(np.float32)


def _encode_dense(vec: np.ndarray, name: str) -> bytes:
    if name == "float32":
        return vec.astype("<f4").tobytes()
    q, scale = _quantize(vec, _QMAX[name])
    head = struct.pack("<f", scale)
    if name == "int8":
        return head + q.tobytes()
    return head + _pack_int4(q)


def _decode_dense(buf: bytes, m: int, name: str) -> np.ndarray:
    if name == "float32":
        return np.frombuffer(buf, dtype="<f4", count=m).astype(np.float32)
    (scale,) = struct.unpack_from("<f", buf, 0)
    if name == "int8":
        q = np.frombuffer(buf, dtype=np.int8, count=m,
                          offset=4).astype(np.float32)
    else:
        q = _unpack_int4(buf[4:], m)
    return q * scale


def _value_bytes(name: str, count: int) -> int:
    if name == "float32":
        return 4 * count
    if name == "int8":
        return count
    return (count + 1) // 2


# ---------------------------------------------------------------------------
# compression v2: varint + run-length index coding
# ---------------------------------------------------------------------------

def _varint(n: int) -> bytes:
    """Unsigned LEB128."""
    out = bytearray()
    while True:
        b = n & 0x7F
        n >>= 7
        if n:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _read_varint(buf: bytes, off: int) -> tuple[int, int]:
    n = shift = 0
    while True:
        if off >= len(buf):
            raise ValueError("truncated varint in sparse v2 frame")
        b = buf[off]
        off += 1
        n |= (b & 0x7F) << shift
        if not b & 0x80:
            return n, off
        shift += 7


def _index_runs(nz: np.ndarray) -> list[tuple[int, int]]:
    """Sorted indices → (gap, run_len) pairs over maximal consecutive
    runs; gap is the distance from the end of the previous run."""
    runs: list[tuple[int, int]] = []
    prev_end = 0                              # one past last emitted index
    i = 0
    while i < nz.size:
        j = i
        while j + 1 < nz.size and nz[j + 1] == nz[j] + 1:
            j += 1
        runs.append((int(nz[i]) - prev_end, j - i + 1))
        prev_end = int(nz[j]) + 1
        i = j + 1
    return runs


def _encode_vrle_indices(nz: np.ndarray) -> bytes:
    runs = _index_runs(nz)
    parts = [_varint(nz.size), _varint(len(runs))]
    for gap, run_len in runs:
        parts.append(_varint(gap))
        parts.append(_varint(run_len))
    return b"".join(parts)


def _decode_vrle_indices(buf: bytes, off: int
                         ) -> tuple[np.ndarray, int]:
    count, off = _read_varint(buf, off)
    n_runs, off = _read_varint(buf, off)
    idx = np.empty(count, np.int64)
    pos = prev_end = 0
    for _ in range(n_runs):
        gap, off = _read_varint(buf, off)
        run_len, off = _read_varint(buf, off)
        start = prev_end + gap
        idx[pos:pos + run_len] = np.arange(start, start + run_len)
        pos += run_len
        prev_end = start + run_len
    if pos != count:
        raise ValueError("sparse v2 frame: run lengths disagree with "
                         f"count ({pos} != {count})")
    return idx, off


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------

def encode(vec: np.ndarray, cfg: CodecConfig,
           ref: np.ndarray | None = None) -> bytes:
    """Encode one float vector; ``ref`` is the shared delta reference
    (ignored unless ``cfg.sparse``)."""
    vec = np.asarray(vec, dtype=np.float32).ravel()
    if not cfg.sparse:
        return _encode_dense(vec, cfg.name)

    delta = vec if ref is None else vec - np.asarray(ref, np.float32).ravel()
    if cfg.name == "float32":
        q, scale = delta, None
        nz = np.nonzero(delta)[0]
    else:
        q, scale = _quantize(delta, _QMAX[cfg.name])
        nz = np.nonzero(q)[0]
    dense_cost = 1 + len(_encode_dense(vec, cfg.name))
    head = b"" if scale is None else struct.pack("<f", scale)

    def _values() -> bytes:
        if cfg.name == "float32":
            return delta[nz].astype("<f4").tobytes()
        if cfg.name == "int8":
            return q[nz].tobytes()
        return _pack_int4(q[nz])

    if cfg.index_coding == "vrle":
        idx_stream = _encode_vrle_indices(nz)
        if 1 + len(head) + len(idx_stream) \
                + _value_bytes(cfg.name, nz.size) < dense_cost:
            return b"".join([b"\x02", head, idx_stream, _values()])
        return b"\x00" + _encode_dense(vec, cfg.name)

    if nz.size > 0xFFFF or vec.size > 0xFFFF:
        nz = None                         # u2 indices can't address it
    if nz is not None:
        sparse_cost = 5 + len(head) \
            + 2 * nz.size + _value_bytes(cfg.name, nz.size)
        if sparse_cost < dense_cost:
            return b"".join([b"\x01", head,
                             struct.pack("<I", nz.size),
                             nz.astype("<u2").tobytes(), _values()])
    return b"\x00" + _encode_dense(vec, cfg.name)


def decode(buf: bytes, m: int, cfg: CodecConfig,
           ref: np.ndarray | None = None) -> np.ndarray:
    """Decode one frame produced by :func:`encode` back to float32 (m,)."""
    if not cfg.sparse:
        return _decode_dense(buf, m, cfg.name)

    flag, buf = buf[0], buf[1:]
    if flag == 0:
        return _decode_dense(buf, m, cfg.name)
    if flag not in (1, 2):
        raise ValueError(f"unknown sparse frame flag {flag}")
    off = 0
    scale = None
    if cfg.name != "float32":
        (scale,) = struct.unpack_from("<f", buf, off)
        off += 4
    if flag == 2:
        idx, off = _decode_vrle_indices(buf, off)
        count = idx.size
    else:
        (count,) = struct.unpack_from("<I", buf, off)
        off += 4
        idx = np.frombuffer(buf, dtype="<u2", count=count, offset=off
                            ).astype(np.int64)
        off += 2 * count
    if cfg.name == "float32":
        vals = np.frombuffer(buf, dtype="<f4", count=count, offset=off
                             ).astype(np.float32)
    elif cfg.name == "int8":
        vals = np.frombuffer(buf, dtype=np.int8, count=count, offset=off
                             ).astype(np.float32) * scale
    else:
        vals = _unpack_int4(buf[off:], count) * scale
    delta = np.zeros(m, np.float32)
    delta[idx] = vals
    base = np.zeros(m, np.float32) if ref is None \
        else np.asarray(ref, np.float32).ravel().copy()
    return base + delta


def ef_encode(vec: np.ndarray, cfg: CodecConfig, residual: np.ndarray,
              ref: np.ndarray | None = None
              ) -> tuple[bytes, np.ndarray]:
    """Error-feedback encode: compress ``vec + residual`` and return the
    frame plus the *new* residual (the quantization error this frame
    leaves behind).  Both endpoints decode with the plain :func:`decode`;
    only the sender holds residual memory."""
    vec = np.asarray(vec, dtype=np.float32).ravel()
    target = vec + np.asarray(residual, np.float32).ravel()
    buf = encode(target, cfg, ref=ref)
    decoded = decode(buf, vec.size, cfg, ref=ref)
    return buf, target - decoded


def roundtrip_tolerance(vec: np.ndarray, cfg: CodecConfig) -> float:
    """Worst-case |decode(encode(x)) − x| for this codec on this vector
    (half a quantization step, plus float slack)."""
    if cfg.name == "float32":
        return 0.0
    peak = float(np.max(np.abs(np.asarray(vec)))) if np.size(vec) else 0.0
    return 0.5 * peak / _QMAX[cfg.name] + 1e-5
