"""Partial-participation scheduling: who trains, who drops, who straggles.

Per round the scheduler produces a :class:`Participation` — K sampled
client indices (K static, so the engine's gather of the client sub-pytree
stays one compiled program), a dropout-survival mask, and per-client
staleness (rounds of upload delay for stragglers).

Sampling policies:

* ``uniform``     — K-of-N without replacement.  Full participation
  (K == N) short-circuits to ``arange(N)`` so the default configuration
  reproduces the legacy full-population ordering bit-for-bit.
* ``weighted``    — without replacement, proportional to caller-supplied
  client weights.  The engine defaults these to the real per-client
  dataset sizes recorded by ``data/partition.py`` (``ClientData.sizes``),
  the FedAvg-paper convention: clients holding more data are sampled
  more often.  Weights are any array-like — a device array, or the
  host-resident ``int64`` size table a streaming population keeps
  (``repro.fl.store.StreamingClientData.sizes``, the only O(N) state
  the mmap engine holds); both normalize through the same float32
  ``w / w.sum()``, so the sampling distribution — and the sampled ids
  for a given key — are identical resident vs. streamed (pinned by the
  conformance suite).
* ``round_robin`` — deterministic sliding window ``(r·K + i) mod N``:
  the window cycles through the population, and when K divides N every
  client participates exactly once per N/K rounds (otherwise coverage
  is still cyclic but windows can wrap and revisit early clients).

Dropout removes a selected client's upload (the client crashed or lost
connectivity mid-round: its trained state and upload never reach the
aggregator, and it receives no broadcast).  Stragglers survive but their
upload arrives ``staleness ∈ [1, max_staleness]`` rounds late — the sync
engine treats a missed barrier as a drop; the async engine buffers the
upload and applies it, staleness-discounted, when it matures.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

SAMPLING = ("uniform", "weighted", "round_robin")

# fold_in tags: keep scheduler randomness on a stream disjoint from the
# per-client training keys (which consume the raw round key).
_TAG_SELECT, _TAG_DROP, _TAG_STRAGGLE = 0x5C4ED, 0xD120F, 0x57A1E


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    participation: float = 1.0   # K = max(1, round(p·N)) clients per round
    sampling: str = "uniform"    # uniform | weighted | round_robin
    dropout: float = 0.0         # P(selected client's upload is lost)
    straggler: float = 0.0       # P(surviving upload arrives late)
    max_staleness: int = 2       # stragglers delay ∈ [1, max_staleness]

    def __post_init__(self):
        if self.sampling not in SAMPLING:
            raise ValueError(f"unknown sampling {self.sampling!r}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")


class Participation(NamedTuple):
    idx: jnp.ndarray        # (K,) int32 — sampled client ids
    active: jnp.ndarray     # (K,) bool  — survived dropout
    staleness: jnp.ndarray  # (K,) int32 — 0 = on time, s ≥ 1 = straggler

    def summary(self) -> dict:
        """Host-side participation gauges for the telemetry plane
        (``repro.fl.obs``): sampled / dropped / straggler counts and
        the staleness histogram of surviving uploads (index = rounds of
        delay; index 0 = on time).  Pure derivation — reading it cannot
        perturb the round."""
        active = np.asarray(self.active)
        stale = np.asarray(self.staleness)
        surviving = stale[active]
        hist = (np.bincount(surviving) if surviving.size
                else np.zeros(1, np.int64))
        return {
            "sampled": int(active.shape[0]),
            "dropped": int((~active).sum()),
            "arrived_on_time": int((active & (stale == 0)).sum()),
            "stragglers": int((active & (stale > 0)).sum()),
            "staleness_hist": hist.tolist(),
        }


def arrival_participation(client_ids, observed_lag) -> Participation:
    """Participation as a real transport server *observed* it for one
    round: the uploads that actually crossed the wire, with their real
    arrival lags (arrival round − source round) — rather than the
    injected schedule :meth:`Scheduler.sample` drew.

    ``client_ids[i]`` is the global id behind the i-th arrival this
    round; ``observed_lag[i]`` its lag in rounds (0 = produced and
    delivered in the same round, s ≥ 1 = a straggler's upload the
    worker flushed s rounds after training).  Every listed upload did
    arrive, so ``active`` is all-True, and :meth:`Participation.summary`
    yields the observed staleness histogram the transport runner records
    in round events — same gauge schema as the scheduled view."""
    ids = np.asarray(client_ids, np.int32).ravel()
    lag = np.asarray(observed_lag, np.int32).ravel()
    if ids.shape != lag.shape:
        raise ValueError(
            f"arrival_participation: client_ids{ids.shape} and "
            f"observed_lag{lag.shape} must be the same length")
    if lag.size and int(lag.min()) < 0:
        raise ValueError(
            "arrival_participation: negative observed lag — an upload "
            "cannot arrive before the round that produced it")
    return Participation(
        idx=jnp.asarray(ids),
        active=jnp.ones((ids.size,), bool),
        staleness=jnp.asarray(lag))


class Scheduler:
    def __init__(self, cfg: SchedulerConfig, n_clients: int,
                 weights: jnp.ndarray | None = None):
        self.cfg = cfg
        self.n = n_clients
        self.k = max(1, int(round(cfg.participation * n_clients)))
        if cfg.sampling == "weighted":
            # accept host tables (np int64 / lists) as-is: the single
            # float32 cast here is the one place weights enter the
            # draw, so any integer-exact source yields the same p
            w = jnp.ones(n_clients) if weights is None \
                else jnp.asarray(weights, jnp.float32)
            if w.shape != (n_clients,):
                raise ValueError(
                    f"client weights shape {w.shape} != ({n_clients},)")
            if not bool((w >= 0).all()) or float(w.sum()) <= 0.0:
                raise ValueError("client weights must be non-negative "
                                 "with a positive sum")
            self.p = w / w.sum()
        else:
            self.p = None

    def sample(self, round_idx: int, key: jax.Array) -> Participation:
        """Draw this round's participation from the round key.

        Uses fold_in tags so the engine can hand the *same* round key to
        per-client training without the scheduler perturbing it.
        """
        cfg = self.cfg
        k_sel = jax.random.fold_in(key, _TAG_SELECT)
        if cfg.sampling == "round_robin":
            idx = (round_idx * self.k + jnp.arange(self.k)) % self.n
        elif self.k == self.n and cfg.sampling == "uniform":
            idx = jnp.arange(self.n)        # legacy full-population order
        else:
            idx = jax.random.choice(k_sel, self.n, (self.k,),
                                    replace=False, p=self.p)
        idx = idx.astype(jnp.int32)

        if cfg.dropout > 0.0:
            active = jax.random.bernoulli(
                jax.random.fold_in(key, _TAG_DROP),
                1.0 - cfg.dropout, (self.k,))
        else:
            active = jnp.ones((self.k,), bool)

        if cfg.straggler > 0.0 and cfg.max_staleness > 0:
            k_str = jax.random.fold_in(key, _TAG_STRAGGLE)
            k_who, k_lag = jax.random.split(k_str)
            late = jax.random.bernoulli(k_who, cfg.straggler, (self.k,))
            lag = jax.random.randint(k_lag, (self.k,), 1,
                                     cfg.max_staleness + 1)
            staleness = jnp.where(late, lag, 0).astype(jnp.int32)
        else:
            staleness = jnp.zeros((self.k,), jnp.int32)
        return Participation(idx, active, staleness)
