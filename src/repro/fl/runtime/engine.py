"""The orchestrated federated round engine.

Replaces the monolithic ``federation.run`` loop with a composition of the
scheduler (who participates), a :class:`~repro.fl.runtime.strategy.Strategy`
(what a round means), the wire codec (what actually crosses the network,
metered byte-exact), and round-granular checkpointing.

Round anatomy (sync mode)
-------------------------
1. ``scheduler.sample`` picks K-of-N clients (K static → the gather of the
   sampled client sub-pytree keeps the round a single compiled program),
   plus dropout and straggler draws.
2. The K clients run ``strategy.client_step`` (vmapped).  Per-client rng
   keys are ``split(round_key, N)[idx]``, so any participation pattern
   draws from the same per-client key stream as the full-population
   legacy loop — full participation reproduces it bit-for-bit.
   *Where* the step runs is the executor's business
   (:mod:`repro.fl.runtime.executors`): in-process vmap (default), or
   shard-mapped over a ``clients`` mesh axis (``backend="shardmap"``)
   with aggregation lowered to a single masked collective — one
   compiled sharded program per round on the identity wire.  The
   conformance suite pins both backends bit-identical.
3. Each surviving upload is *encoded to real bytes* by the codec (and
   decoded back before aggregation, so lossy codecs perturb the math
   exactly as they would in deployment).  A sync barrier treats uploads
   that miss the deadline (staleness > 0) like drops.
4. **Server-side assignment** (server-state API v2): if the strategy
   defines an ``assign`` hook, the slot id of every decoded upload is
   recomputed here — FLIS derives cluster membership per round from
   inference similarity on its probe set.  Metering (step 3) always
   uses the *client-proposed* tags: what crossed the wire crossed the
   wire.  Strategies without the hook keep their proposed ids.
5. Per-slot masked mean aggregation (slot −1 contributes nothing),
   folded into the strategy-owned :class:`ServerState` by its
   ``server_update`` hook — the default keeps empty slots' previous
   rows bit-for-bit, per Alg. 2.
6. Broadcast: each surviving participant applies its slot's new server
   row; dropped/straggling clients keep their pre-round state.  Download
   bytes are metered from the encoded broadcast frames.

Async buffered mode
-------------------
Uploads land in a fixed-capacity buffer with masked validity instead of a
barrier; an entry matures at round ``r + staleness``.  As soon as
``async_min_uploads`` matured entries are available the engine aggregates
them with staleness-discounted weights (``discount ** staleness``) — the
FedAsync-style weighted mean — and invalidates the consumed entries.  On
overflow the oldest entry is evicted (counted in the round report).

The buffer is *device* state: six fixed-capacity lanes carried in
:class:`EngineState` (so checkpoints capture it and async resume is
bit-identical), updated by one compiled masked program per round — the
insert/evict scan and the maturity gate live in
:mod:`repro.fl.runtime.executors`, and under ``backend="shardmap"`` the
whole update runs inside ``shard_map`` on the ``clients`` mesh axis
with the staleness-discounted mean lowered through
:mod:`repro.fl.masked_collectives`.  ``async_buffer="host"`` keeps the
original numpy insert loop as the in-process reference the conformance
suite pins the device path against, bit for bit.  See
``docs/async-runtime.md`` for the lane layout and design.

Sharding contract: the engine itself never runs inside ``shard_map`` —
it holds replicated state (``server``, the buffer lanes, round index)
plus client-major arrays (``client_state``, data, per-client keys) and
hands them to the executor, which decides whether client-major means
"vmapped on one device" or "one block per mesh shard".  Everything the
engine reads back from an executor (server, counts, report scalars) is
replicated/host-visible.
"""
from __future__ import annotations

import dataclasses
import inspect
import tempfile
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import ClientData
from repro.fl import masked_collectives
from repro.fl.obs.recorder import NULL as NULL_TELEMETRY
from repro.fl.runtime import checkpointing
from repro.fl.runtime.codec import CodecConfig, decode, ef_encode, encode
from repro.fl.runtime import executors
from repro.fl.runtime.executors import (COLLECTIVES, InProcessExecutor,
                                        ShardMapExecutor)
from repro.fl.runtime.scheduler import (Participation, Scheduler,
                                        SchedulerConfig)
from repro.fl.runtime.strategy import (DOWNLOADS, ServerState,
                                       ensure_server_state,
                                       resolve_server_update)
from repro.fl.store.client_store import ClientStore

BACKENDS = ("inprocess", "shardmap")
TM_BACKENDS = ("ref", "pallas")
CLIENT_STORES = ("resident", "mmap")
STORE_EVALS = ("full", "sampled")
TRANSPORTS = ("inprocess", "loopback", "socket")


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    rounds: int = 10
    scheduler: SchedulerConfig = SchedulerConfig()
    codec: CodecConfig = CodecConfig()
    aggregation: str = "sync"         # sync | async
    async_min_uploads: int = 4        # B — aggregate once B uploads matured
    buffer_capacity: int = 64         # fixed-capacity async upload buffer
    staleness_discount: float = 0.5   # matured weight = discount**staleness
    async_buffer: str = "device"      # device (compiled) | host (reference)
    backend: str = "inprocess"        # inprocess | shardmap
    mesh_axis: str = "clients"        # shard_map axis clients live on
    mesh_collective: str = "gather"   # gather (bit-exact) | psum (C·m bytes)
    tm_backend: str = "ref"           # ref (jnp) | pallas (fused TM kernels)
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0         # 0 = never
    # K-active working set over the host-side client store: "mmap" keeps
    # client rows (params, TA state, sparse-codec refs) in a
    # memory-mapped ClientStore and only the scheduler's K sampled rows
    # ever become device arrays — device/RAM footprint O(K), not O(N).
    client_store: str = "resident"    # resident | mmap
    store_dir: str | None = None      # mmap store root (None = fresh temp)
    store_eval: str = "full"          # full (chunked population) | sampled
    store_eval_chunk: int = 256       # clients per chunked-eval gather
    # real-transport runtime (repro.fl.transport): "inprocess" is this
    # engine's direct function-call wire; "loopback" runs the same round
    # protocol through in-memory length-prefixed frames (the reference
    # the conformance suite pins bit-identical to inprocess on the
    # identity wire); "socket" runs M real client-worker subprocesses
    # over local TCP, where staleness/dropout are observed arrivals.
    transport: str = "inprocess"      # inprocess | loopback | socket
    workers: int = 0                  # socket worker process count (>= 1)

    def __post_init__(self):
        if self.aggregation not in ("sync", "async"):
            raise ValueError(f"unknown aggregation {self.aggregation!r}")
        if self.transport not in TRANSPORTS:
            raise ValueError(
                f"unknown transport {self.transport!r}; choose from "
                f"{TRANSPORTS} (see docs/transport.md)")
        if self.transport != "inprocess" and self.workers < 1:
            raise ValueError(
                f"transport={self.transport!r} partitions the client "
                "population over worker peers — set workers >= 1 "
                f"(got workers={self.workers})")
        if self.transport == "inprocess" and self.workers != 0:
            raise ValueError(
                f"workers={self.workers} is a transport knob; "
                "transport='inprocess' runs no workers (leave workers=0)")
        if self.transport != "inprocess" and self.aggregation == "async" \
                and self.codec.sparse:
            raise ValueError(
                "sparse delta coding needs encoder and decoder to agree "
                "on the reference rows at decode time; the arrival-"
                "driven async transport decodes uploads rounds after "
                "they were encoded, so run sparse=True with "
                "aggregation='sync' or transport='inprocess'")
        if self.transport != "inprocess" and self.backend != "inprocess":
            raise ValueError(
                f"transport={self.transport!r} distributes clients over "
                "worker processes — it composes with backend='inprocess' "
                f"only, not backend={self.backend!r} (shard_map is "
                "single-process mesh parallelism)")
        if self.transport != "inprocess" and self.client_store != "resident":
            raise ValueError(
                f"transport={self.transport!r} requires "
                "client_store='resident': worker processes own their "
                "client rows, which contradicts the single-process mmap "
                "store")
        if self.codec.error_feedback and self.client_store != "resident":
            raise ValueError(
                "codec.error_feedback keeps per-(client, slot) residual "
                "memory in EngineState — available with "
                "client_store='resident' only (the mmap store does not "
                "carry the residual lane)")
        if self.client_store not in CLIENT_STORES:
            raise ValueError(f"unknown client_store {self.client_store!r}")
        if self.store_eval not in STORE_EVALS:
            raise ValueError(f"unknown store_eval {self.store_eval!r}")
        if self.store_eval_chunk < 1:
            raise ValueError("store_eval_chunk must be >= 1")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.tm_backend not in TM_BACKENDS:
            raise ValueError(f"unknown tm_backend {self.tm_backend!r}")
        if self.mesh_collective not in COLLECTIVES:
            raise ValueError(
                f"unknown mesh_collective {self.mesh_collective!r}")
        if self.async_buffer not in ("device", "host"):
            raise ValueError(f"unknown async_buffer {self.async_buffer!r}")
        if self.backend == "shardmap" and self.aggregation == "async" \
                and self.async_buffer == "host":
            raise ValueError(
                "the host-buffered async reference is in-process only — "
                "the shard-mapped backend runs async_buffer='device'")


class EngineState(NamedTuple):
    round_idx: jnp.ndarray      # () int32 — next round to run
    client_state: Any           # strategy pytree, leading axis = clients
    server: ServerState         # strategy-owned pytree: (n_slots, d)
    #                             slot matrix + opaque aux (probe sets,
    #                             membership tables, ...), checkpointed
    #                             as one subtree
    buf_vecs: jnp.ndarray       # (cap, d) float32   async upload buffer
    buf_slots: jnp.ndarray      # (cap,) int32       (−1 = empty)
    buf_ready: jnp.ndarray      # (cap,) int32       round the entry matures
    buf_weight: jnp.ndarray     # (cap,) float32     staleness discount
    buf_valid: jnp.ndarray      # (cap,) bool        masked validity
    buf_seq: jnp.ndarray        # (cap,) int32       insertion order
    # per-client broadcast references for the sparse-delta wire: the
    # server rows each client last *received* (zeros = never synced),
    # and the round it received them (−1 = never).  Deltas are encoded
    # and decoded against these — both endpoints know them, because the
    # aggregator tracks exactly what it sent whom — so metered savings
    # stay honest under partial participation.  Zero-size placeholders
    # when the codec is dense (no reference to track).
    ref_vecs: jnp.ndarray       # (n, n_slots, d) float32, or (0, 0, 0)
    ref_round: jnp.ndarray      # (n,) int32, or (0,)
    # error-feedback residual memory (codec.error_feedback): the
    # quantization error each client's last frame for each slot left
    # behind, added back before the next encode (compression v2).
    # Carried here so checkpoints capture it and lossy-EF resume is
    # bit-identical.  Zero-size placeholder when EF is off.
    ef_residual: jnp.ndarray  # (n, n_slots, d) float32, or (0, 0, 0)


class RoundReport(NamedTuple):
    round_idx: int
    mean_accuracy: jnp.ndarray
    per_client_accuracy: jnp.ndarray   # (n,)
    assignment: jnp.ndarray            # (n, j) int32, −1 = not shared
    cluster_counts: jnp.ndarray        # (n_slots,)
    participation: Participation
    upload_bytes: int                  # Σ len(frame) actually sent up
    download_bytes_broadcast: int      # one frame per populated slot
    download_bytes_per_client: int     # Σ over receiving participants
    aggregated_uploads: int            # uploads folded into the server
    buffered_uploads: int              # async: still waiting in the buffer
    evicted_uploads: int               # async: lost to buffer overflow
    store_read_bytes: int = 0          # mmap store host reads this round
    store_written_bytes: int = 0       # mmap store host writes this round
    # real-transport gauges (repro.fl.transport): total framed bytes the
    # server actually put on / took off the wire this round — envelopes
    # and headers included, unlike the codec-metered fields above.
    # Zero on the in-process engine (nothing crossed a process wire).
    wire_tx_bytes: int = 0             # server → clients, framed
    wire_rx_bytes: int = 0             # clients → server, framed
    # per-arrival observed staleness (arrival round − source round) of
    # the uploads the transport server took in this round; None on the
    # in-process engine (staleness there is an injected schedule)
    observed_staleness: Any = None


class Engine:
    """Round orchestrator for one strategy over one client population."""

    def __init__(self, strategy, data: ClientData, cfg: RuntimeConfig,
                 client_weights: jnp.ndarray | None = None, mesh=None,
                 telemetry=None):
        # tm_backend="pallas" routes TM strategies through the fused
        # Pallas kernels: TMConfig.use_kernel flips the per-op dispatch
        # in core/tm.py *and* makes the strategy advertise its fused
        # client-batched hooks to the executors (strategy.py /
        # executors._client_step_block).  Non-TM strategies (no tm_cfg)
        # are untouched — the flag is a no-op for the MLP baselines.
        if cfg.tm_backend == "pallas" and \
                getattr(strategy, "tm_cfg", None) is not None:
            strategy = dataclasses.replace(
                strategy, tm_cfg=dataclasses.replace(
                    strategy.tm_cfg, use_kernel=True))
        self.strategy = strategy
        self.data = data
        self.cfg = cfg
        # population size: a streaming pool knows its client count
        # without materializing anything; ClientData carries it as the
        # leading axis of every array
        n_clients = getattr(data, "n_clients", None)
        self.n = int(n_clients) if n_clients is not None \
            else int(data.x_train.shape[0])
        self._mmap = cfg.client_store == "mmap"
        self._streaming = hasattr(data, "gather_clients")
        self.store: ClientStore | None = None
        if self._streaming and not self._mmap:
            raise ValueError(
                "streaming client data has no materialized population "
                "for the resident engine to index — run it with "
                "RuntimeConfig(client_store='mmap')")
        # the telemetry plane (repro.fl.obs): span/fence hooks around
        # each round stage plus the per-round event sink.  Strictly
        # read-only — it consumes reports and wall clocks, and nothing
        # it computes flows back into the round, so the conformance
        # suite pins obs-on == obs-off bit for bit.  The default NULL
        # answers every hook as a no-op (no timing, no fences).
        self.obs = telemetry if telemetry is not None else NULL_TELEMETRY
        # --- server-state API v2 contract checks -------------------------
        # downloads is a validated vocabulary, not free text: a typo used
        # to silently fall through to assigned-slot broadcast/billing
        downloads = getattr(strategy, "downloads", None)
        if downloads not in DOWNLOADS:
            raise ValueError(
                f"strategy.downloads must be one of {DOWNLOADS}, got "
                f"{downloads!r} — 'assigned' broadcasts each client its "
                f"own slot row, 'all_slots' the whole matrix (IFCA)")
        self._assign = getattr(strategy, "assign", None)
        self._server_update = resolve_server_update(strategy)
        # async × dynamic assignment: strategies with server-side hooks
        # (assign / custom server_update) aggregate on the *host* buffer
        # path, where `assign` is re-run over the matured buffer
        # contents at aggregation time — the buffer holds uploads across
        # rounds, so membership is recomputed when they are folded in,
        # not when they were sent.  The hook-less device/shardmap
        # programs hard-code the Alg. 2 fold and stay as they were.
        self._async_hooks = cfg.aggregation == "async" and (
            self._assign is not None
            or getattr(strategy, "server_update", None) is not None)
        if self._async_hooks and cfg.backend == "shardmap":
            raise ValueError(
                "async + server-side assign/server_update hooks "
                "aggregate on the in-process host buffer path — run "
                "this strategy with backend='inprocess' (the shard-"
                "mapped async program hard-codes the hook-less fold)")
        if client_weights is None and cfg.scheduler.sampling == "weighted":
            # weighted sampling defaults to the real per-client dataset
            # sizes the partitioner recorded (clients with more data are
            # sampled more often, the FedAvg-paper convention)
            sizes = getattr(data, "sizes", None)
            if sizes is not None:
                client_weights = jnp.asarray(sizes, jnp.float32)
        self.scheduler = Scheduler(cfg.scheduler, self.n, client_weights)
        if cfg.backend == "shardmap":
            self.executor = ShardMapExecutor(
                mesh=mesh, axis=cfg.mesh_axis,
                collective=cfg.mesh_collective)
        else:
            self.executor = InProcessExecutor()
        # uniform full participation samples idx = arange(N): skip the
        # identity gather/scatter so the legacy-default path copies
        # nothing (the dominant configuration for every benchmark).
        # The mmap store always stages through gather/spill — its whole
        # point is that the population is never resident.
        self._identity = (self.scheduler.k == self.n
                          and cfg.scheduler.sampling == "uniform"
                          and not self._mmap)
        # discount**staleness lookup for the async device buffer,
        # precomputed with Python double-precision pow and cast once —
        # the same double→float32 each host insert performs, so the
        # compiled path can't drift an ulp from the reference
        self._discount = jnp.asarray(np.asarray(
            [cfg.staleness_discount ** s
             for s in range(cfg.scheduler.max_staleness + 1)], np.float32))
        # (server, roundtripped rows) of the latest broadcast — reused
        # by _wire_tx_server so lossy codecs roundtrip each server once
        self._tx_cache = None

    # -- lifecycle ---------------------------------------------------------

    def _full_init(self, key: jax.Array):
        # v2 strategies take the client data (FLIS draws its server-side
        # probe set from the confidence split); a leftover v1 signature
        # still works, and a bare matrix return is coerced to ServerState.
        # Dispatch on positional capacity, not raw parameter count — a
        # v1 `init(key, n_clients, **kw)` must not be handed `data`
        # positionally.
        kinds = [p.kind for p in
                 inspect.signature(self.strategy.init).parameters.values()]
        takes_data = (inspect.Parameter.VAR_POSITIONAL in kinds
                      or sum(k in (inspect.Parameter.POSITIONAL_ONLY,
                                   inspect.Parameter.POSITIONAL_OR_KEYWORD)
                             for k in kinds) >= 3)
        if takes_data:
            return self.strategy.init(key, self.n, self.data)
        return self.strategy.init(key, self.n)

    def init(self, key: jax.Array) -> EngineState:
        if self._mmap:
            return self._init_mmap(key)
        cs, server = self._full_init(key)
        server = ensure_server_state(server)
        cap, d = self.cfg.buffer_capacity, self.strategy.vec_dim
        if self.cfg.codec.sparse:
            ref_vecs = jnp.zeros((self.n, self.strategy.n_slots, d),
                                 jnp.float32)
            ref_round = jnp.full((self.n,), -1, jnp.int32)
        else:
            ref_vecs = jnp.zeros((0, 0, 0), jnp.float32)
            ref_round = jnp.zeros((0,), jnp.int32)
        if self.cfg.codec.error_feedback:
            ef = jnp.zeros((self.n, self.strategy.n_slots, d), jnp.float32)
        else:
            ef = jnp.zeros((0, 0, 0), jnp.float32)
        return EngineState(
            round_idx=jnp.zeros((), jnp.int32),
            client_state=cs, server=server,
            buf_vecs=jnp.zeros((cap, d), jnp.float32),
            buf_slots=jnp.full((cap,), -1, jnp.int32),
            buf_ready=jnp.zeros((cap,), jnp.int32),
            buf_weight=jnp.zeros((cap,), jnp.float32),
            buf_valid=jnp.zeros((cap,), bool),
            buf_seq=jnp.zeros((cap,), jnp.int32),
            ref_vecs=ref_vecs, ref_round=ref_round, ef_residual=ef)

    def _init_mmap(self, key: jax.Array) -> EngineState:
        """Open the client store and return an O(K) engine state: the
        population's rows live under ``cfg.store_dir``; the returned
        state carries zero-row placeholders for ``client_state`` and
        the sparse-codec ref lanes (they, too, live in the store).

        Strategies exposing the O(K) init hooks (``init_cohort(key,
        ids, n) == init(key, n)[0][ids]`` bit-for-bit, plus
        ``init_server``) never materialize the population at all —
        unwritten store rows are regenerated per sampled cohort.
        Hookless strategies fall back to one full ``init`` whose rows
        are served by index: O(N) host RAM once, still O(K) device per
        round."""
        strat = self.strategy
        cohort = getattr(strat, "init_cohort", None)
        init_server = getattr(strat, "init_server", None)
        if cohort is not None and init_server is not None:
            server = ensure_server_state(init_server(key, self.n))
            row = jax.tree.map(lambda a: np.asarray(a)[0],
                               cohort(key, np.asarray([0]), self.n))

            def cs_init(ids):
                return jax.tree.map(
                    np.asarray, cohort(key, np.asarray(ids), self.n))
        else:
            cs, server = self._full_init(key)
            server = ensure_server_state(server)
            rows = jax.tree.map(np.asarray, cs)
            row = jax.tree.map(lambda a: a[0], rows)

            def cs_init(ids):
                np_ids = np.asarray(ids)
                return jax.tree.map(lambda a: a[np_ids], rows)

        cap, d = self.cfg.buffer_capacity, strat.vec_dim
        sparse = self.cfg.codec.sparse
        template = {"cs": row}
        if sparse:
            # the per-client broadcast references ride in the store too:
            # a never-synced client's reference is zeros / round −1,
            # exactly the resident init
            template["ref_vecs"] = np.zeros((strat.n_slots, d), np.float32)
            template["ref_round"] = np.asarray(-1, np.int32)

        def init_fn(ids):
            np_ids = np.asarray(ids)
            out = {"cs": cs_init(np_ids)}
            if sparse:
                out["ref_vecs"] = np.zeros(
                    (np_ids.size, strat.n_slots, d), np.float32)
                out["ref_round"] = np.full((np_ids.size,), -1, np.int32)
            return out

        root = self.cfg.store_dir or tempfile.mkdtemp(
            prefix="client_store_")
        self.store = ClientStore(root, self.n, template, init_fn=init_fn)
        placeholder = jax.tree.map(
            lambda a: jnp.zeros((0,) + np.asarray(a).shape,
                                np.asarray(a).dtype), row)
        return EngineState(
            round_idx=jnp.zeros((), jnp.int32),
            client_state=placeholder, server=server,
            buf_vecs=jnp.zeros((cap, d), jnp.float32),
            buf_slots=jnp.full((cap,), -1, jnp.int32),
            buf_ready=jnp.zeros((cap,), jnp.int32),
            buf_weight=jnp.zeros((cap,), jnp.float32),
            buf_valid=jnp.zeros((cap,), bool),
            buf_seq=jnp.zeros((cap,), jnp.int32),
            ref_vecs=jnp.zeros((0, 0, 0), jnp.float32),
            ref_round=jnp.zeros((0,), jnp.int32),
            ef_residual=jnp.zeros((0, 0, 0), jnp.float32))

    def run(self, key: jax.Array, state: EngineState | None = None,
            rounds: int | None = None
            ) -> tuple[EngineState, list[RoundReport]]:
        """Run ``cfg.rounds`` rounds — or ``rounds``, e.g. the remainder
        of an interrupted run — continuing from ``state`` if given (one
        restored by :func:`checkpointing.restore`).

        The key chain (``k_init, k_rounds = split(key)``; round r uses
        ``fold_in(k_rounds, r)`` with the *absolute* round index) matches
        the legacy ``federation.run`` driver, so both fresh runs and
        checkpoint-resumed runs reproduce it exactly.
        """
        k_init, k_rounds = jax.random.split(key)
        if state is None:
            state = self.init(k_init)
        elif self._mmap:
            # resuming over an existing store: (re)open it keyed by THIS
            # run's k_init, so rows never sampled before the checkpoint
            # fault in exactly as the uninterrupted run would have
            # generated them (the `like` state a caller built for
            # checkpointing.restore may have used a different key)
            self.init(k_init)
        reports: list[RoundReport] = []
        start = int(state.round_idx)
        n_rounds = self.cfg.rounds if rounds is None else rounds
        for r in range(start, start + n_rounds):
            with self.obs.span("round"):
                state, rep = self.run_round(
                    state, jax.random.fold_in(k_rounds, r))
                self.obs.fence(state)
            self.obs.on_round(rep)
            reports.append(rep)
            every = self.cfg.checkpoint_every
            if self.cfg.checkpoint_dir and every and (r + 1) % every == 0:
                if self._mmap:
                    # the checkpoint is only the replicated state — the
                    # population rows ARE the store, flushed alongside
                    # so checkpoint + store dir resume together (valid
                    # at the latest checkpoint: store rows advance past
                    # older ones; see docs/client-store.md)
                    self.store.flush()
                checkpointing.save(
                    self.cfg.checkpoint_dir, state,
                    manifest=self.obs.manifest,
                    store_manifest=(self.store.manifest
                                    if self._mmap else None))
        return state, reports

    # -- one round ---------------------------------------------------------

    def run_round(self, state: EngineState, round_key: jax.Array
                  ) -> tuple[EngineState, RoundReport]:
        obs = self.obs            # telemetry spans/fences — no-ops when off
        r = int(state.round_idx)
        store = self.store
        if self._mmap:
            io0 = (store.io_read_bytes, store.io_written_bytes)
        with obs.span("schedule"):
            part = self.scheduler.sample(r, round_key)
            sync = self.cfg.aggregation == "sync"
            arrive = np.asarray(part.active)
            if sync:
                arrive = arrive & (np.asarray(part.staleness) == 0)

        # gather the sampled sub-pytree (static K) + per-client keys
        sub_refs = None
        with obs.span("gather"):
            keys = jax.random.split(round_key, self.n)
            if self._identity:
                sub_cs, sub_data = state.client_state, self.data
            elif self._mmap:
                # the K sampled rows come off the host store (digest-
                # verified; never-spilled rows regenerated by the
                # strategy's deterministic init) — same per-client keys
                # as the resident gather, so the round is bit-identical
                np_ids = np.asarray(part.idx)
                keys = keys[part.idx]
                bundle = store.gather(np_ids)
                sub_cs = jax.tree.map(jnp.asarray, bundle["cs"])
                if self.cfg.codec.sparse:
                    sub_refs = (jnp.asarray(bundle["ref_vecs"]),
                                jnp.asarray(bundle["ref_round"]))
                sub_data = (self.data.gather_clients(np_ids)
                            if self._streaming else
                            jax.tree.map(lambda a: a[part.idx], self.data))
            else:
                keys = keys[part.idx]
                sub_cs = jax.tree.map(lambda a: a[part.idx],
                                      state.client_state)
                sub_data = jax.tree.map(lambda a: a[part.idx], self.data)
            obs.fence(keys)

        # identity wire + sync barrier: the executor may run the whole
        # round (train → masked collective → apply → eval) as one
        # compiled sharded program; bytes are metered arithmetically
        # (float32 frames are bit-exact, len = 4 + 4·d — codec-pinned).
        # Strategies with a server-side assign hook always take the
        # staged path: assignment is its own sharded stage there.
        fused = None
        if sync and self._identity and self._wire_is_identity() \
                and self._assign is None:
            with obs.span("fused_round"):
                fused = self.executor.fused_sync_round(
                    self.strategy, sub_cs, state.server, sub_data, keys,
                    jnp.asarray(arrive))
                obs.fence(fused)
            if fused is None:
                obs.discard("fused_round")   # in-process: no fused form
        refs = (state.ref_vecs, state.ref_round)
        ef = state.ef_residual      # EF needs a lossy wire: never fused
        if fused is not None:
            merged, server, counts, applied, acc_sub, slots = fused
            with obs.span("downlink"):
                up_bytes = self._identity_upload_bytes(
                    np.asarray(slots), np.asarray(part.active))
                _, down_bc, down_pc = self._wire_downlink(
                    server.slots, counts, arrive, applied)
        else:
            # (2) local work on the K sampled clients.  Training starts
            # from the codec-roundtripped broadcast rows — what a client
            # actually holds after a lossy downlink — not the
            # aggregator's full-precision state (identity wire: same
            # thing, zero cost).
            with obs.span("broadcast_encode"):
                tx_server = self._wire_tx_server(state.server.slots)
                obs.fence(tx_server)
            with obs.span("client_step"):
                new_sub, vecs, slots = self.executor.train(
                    self.strategy, sub_cs, tx_server, sub_data, keys)
                obs.fence(new_sub, vecs, slots)

            # (3) the wire: encode → meter → decode (sparse deltas run
            # against each client's tracked broadcast reference).
            # Metering sees the client-proposed slot tags — the frames
            # that crossed the wire — never the post-assign ids.
            with obs.span("uplink_codec"):
                dec, up_bytes, ef = self._wire_uplink(
                    state, vecs, slots, part, sub_refs=sub_refs)
                obs.fence(dec)

            # (3b) server-side assignment (v2): recompute every upload's
            # slot id from the decoded payloads — FLIS's per-round
            # dynamic clustering; absent hook = keep proposed ids.
            # Async strategies skip this stage: their uploads cross
            # rounds in the buffer, so `assign` runs over the *matured
            # buffer contents* at aggregation time instead
            # (:meth:`_aggregate_async_host`).
            if self._assign is not None and sync:
                with obs.span("assign"):
                    slots = self.executor.assign(
                        self.strategy, state.server, dec, slots,
                        jnp.asarray(arrive))
                    obs.fence(slots)

            # (4) aggregation, folded into the strategy-owned server
            # state by its server_update hook (default: Alg. 2
            # retention — empty slots keep their previous row)
            if sync:
                with obs.span("aggregate"):
                    agg, counts = self.executor.masked_mean(
                        self.strategy, dec, slots, jnp.asarray(arrive))
                    obs.fence(agg, counts)
                with obs.span("server_update"):
                    server = self._server_update(state.server, agg, counts)
                    obs.fence(server)
            elif self.cfg.async_buffer == "host" or self._async_hooks:
                with obs.span("aggregate"):
                    server, counts, n_agg, n_buf, n_evict, buf = \
                        self._aggregate_async_host(state, dec, slots,
                                                   part, r)
                    obs.fence(server, counts)
            else:
                with obs.span("aggregate"):
                    srv_mat, counts, n_agg, n_buf, n_evict, buf = \
                        self._aggregate_async(state, dec, slots, part)
                    server = state.server._replace(slots=srv_mat)
                    obs.fence(server, counts)

            # (5) broadcast + scatter + evaluate.  A slot row is only
            # pushed to clients when it actually received an aggregate
            # this round — otherwise (async round below the B threshold,
            # or a never-fed cluster) the zero-initialized/stale server
            # row would overwrite the client's freshly trained weights.
            recv = jnp.asarray(arrive)
            with obs.span("downlink"):
                applied = executors.applied_slots(slots, counts, recv)
                rx_server, down_bc, down_pc = self._wire_downlink(
                    server.slots, counts, arrive, applied)
                obs.fence(rx_server)
            with obs.span("apply_merge"):
                merged = self.executor.apply_merge(
                    self.strategy, new_sub, applied, rx_server, sub_cs,
                    recv)
                obs.fence(merged)
            acc_sub = None
            with obs.span("ref_track"):
                if self._mmap:
                    if self.cfg.codec.sparse:
                        sub_ref_vecs = np.array(
                            np.asarray(sub_refs[0], np.float32))
                        sub_ref_rounds = np.array(np.asarray(sub_refs[1]))
                        self._advance_ref_rows(
                            sub_ref_vecs, sub_ref_rounds, arrive, applied,
                            rx_server, r, self.strategy.downloads)
                        sub_refs = (jnp.asarray(sub_ref_vecs),
                                    jnp.asarray(sub_ref_rounds))
                    refs = (state.ref_vecs, state.ref_round)  # placeholders
                else:
                    refs = self._update_refs(state, part, arrive, applied,
                                             rx_server, r)
                obs.fence(refs)

            # spill the merged working set (and its advanced broadcast
            # references) back to the host store — after this the round
            # holds no per-client device state beyond the K rows
            if self._mmap:
                with obs.span("spill"):
                    bundle = {"cs": jax.tree.map(np.asarray, merged)}
                    if self.cfg.codec.sparse:
                        bundle["ref_vecs"] = np.asarray(sub_refs[0],
                                                        np.float32)
                        bundle["ref_round"] = np.asarray(sub_refs[1],
                                                         np.int32)
                    store.spill(np_ids, bundle)

        if sync:   # barrier bookkeeping, identical for fused and staged
            n_agg = int((np.asarray(slots)[arrive] >= 0).sum())
            buf = self._buf_of(state)
            n_buf = n_evict = 0

        with obs.span("eval"):
            if self._mmap:
                new_state, acc, assignment = self._store_eval(
                    state, part.idx, merged, applied, server, buf, refs,
                    ef, sub_data)
            else:
                new_state, acc, assignment = self._scatter_eval(
                    state, part.idx, merged, applied, server, buf, refs,
                    ef, acc_sub)
            obs.fence(acc)

        if self._mmap:
            store_read = store.io_read_bytes - io0[0]
            store_written = store.io_written_bytes - io0[1]
        else:
            store_read = store_written = 0
        rep = RoundReport(
            round_idx=r, mean_accuracy=acc.mean(),
            per_client_accuracy=acc, assignment=assignment,
            cluster_counts=counts, participation=part,
            upload_bytes=up_bytes, download_bytes_broadcast=down_bc,
            download_bytes_per_client=down_pc, aggregated_uploads=n_agg,
            buffered_uploads=n_buf, evicted_uploads=n_evict,
            store_read_bytes=store_read, store_written_bytes=store_written)
        return new_state, rep

    # -- pieces ------------------------------------------------------------

    def _wire_is_identity(self) -> bool:
        """Dense float32 encode→decode is a bit-exact identity (pinned by
        the codec tests) — the round needs no host codec boundary."""
        return self.cfg.codec.name == "float32" and not self.cfg.codec.sparse

    def collective_payload_bytes(self) -> int | None:
        """Per-device payload of this engine's aggregation collective on
        the mesh — the static telemetry gauge recorded in the run
        manifest (None in-process: aggregation is a local einsum)."""
        if self.cfg.backend != "shardmap":
            return None
        return masked_collectives.collective_payload_bytes(
            self.cfg.mesh_collective,
            self.scheduler.k * self.strategy.j_slots,
            self.strategy.vec_dim, self.strategy.n_slots)

    def _identity_upload_bytes(self, np_slots, active) -> int:
        """Identity-wire metering: frame = 4-byte slot id + 4·d payload,
        one frame per shared slot of each active client.  The one
        formula both the fused path and ``_wire_uplink``'s fast path
        meter with."""
        d = self.strategy.vec_dim
        return int((np_slots[active] >= 0).sum()) * (4 + 4 * d)

    @staticmethod
    def _buf_of(state: EngineState):
        """The async buffer 6-tuple, passed through unchanged by sync."""
        return (state.buf_vecs, state.buf_slots, state.buf_ready,
                state.buf_weight, state.buf_valid, state.buf_seq)

    def _wire_uplink(self, state: EngineState, vecs, slots,
                     part: Participation, sub_refs=None):
        """Encode every surviving upload to real bytes; decode what the
        aggregator would see.  Frame = slot id (<i4) + encoded vector.
        Slot −1 ("nothing shared", e.g. below ``conf_threshold``) sends
        no frame, so selective sharing really does cut metered bytes.

        Sparse-delta mode encodes against the *per-client tracked
        reference* — the slot row this client last received over the
        broadcast (``state.ref_vecs``; zeros if it never synced), which
        the aggregator knows because it recorded what it sent.  A client
        that missed recent broadcasts therefore pays for its real,
        larger delta: the metered savings are honest under partial
        participation.

        Error-feedback codecs (compression v2) add each client's
        per-slot residual memory before encoding and keep this frame's
        quantization error as the next residual
        (:func:`repro.fl.runtime.codec.ef_encode`); the updated
        ``ef_residual`` lane is returned alongside the decoded uploads.
        Residuals advance for every *sent* frame — a straggler's frame
        that misses the sync barrier was still sent, so its residual
        moved."""
        cfg = self.cfg.codec
        np_slots = np.asarray(slots)
        active = np.asarray(part.active)
        if self._wire_is_identity():
            # bit-exact identity wire: skip the host round-trip, meter
            # arithmetically.  Keeps the default round free of
            # per-frame Python.
            return (vecs, self._identity_upload_bytes(np_slots, active),
                    state.ef_residual)
        np_vecs = np.asarray(vecs, np.float32)
        # gather the K participants' reference rows on device — never
        # pull the full (n, n_slots, d) population tensor to the host.
        # The mmap engine hands the store-gathered rows in directly
        # (its state lanes are zero-row placeholders).
        if not cfg.sparse:
            np_refs = None
        elif sub_refs is not None:
            np_refs = np.asarray(sub_refs[0], np.float32)
        else:
            np_refs = np.asarray(state.ref_vecs[jnp.asarray(part.idx)],
                                 np.float32)
        sub_ef = None
        if cfg.error_feedback:
            sub_ef = np.array(np.asarray(
                state.ef_residual[jnp.asarray(part.idx)], np.float32))
        dec = np.zeros_like(np_vecs)
        total = 0
        for c in range(np_vecs.shape[0]):
            if not active[c]:
                continue                    # lost mid-round: nothing sent
            for j in range(np_vecs.shape[1]):
                s = int(np_slots[c, j])
                if s < 0:
                    continue                # nothing shared in this slot
                ref = np_refs[c, s] if cfg.sparse else None
                if sub_ef is not None:
                    frame, sub_ef[c, s] = ef_encode(
                        np_vecs[c, j], cfg, sub_ef[c, s], ref=ref)
                else:
                    frame = encode(np_vecs[c, j], cfg, ref=ref)
                total += 4 + len(frame)
                dec[c, j] = decode(frame, np_vecs.shape[2], cfg, ref=ref)
        ef = state.ef_residual
        if sub_ef is not None:
            ef = ef.at[jnp.asarray(part.idx)].set(jnp.asarray(sub_ef))
        return jnp.asarray(dec), total, ef

    def _update_refs(self, state: EngineState, part: Participation,
                     arrive, applied, rx_server, r: int):
        """Advance the per-client broadcast references: every receiving
        participant now holds the roundtripped rows it was just sent —
        its applied slots under ``downloads="assigned"``, the whole
        server matrix under ``"all_slots"`` (mirroring exactly what
        :meth:`_wire_downlink` billed).  Non-participants, drops, and
        stragglers keep their old references — that is the point."""
        if not self.cfg.codec.sparse:
            return state.ref_vecs, state.ref_round
        # work on the K sampled rows only (idx is without-replacement,
        # so the device scatter below touches each row once); the
        # untouched population rows never cross the host boundary
        idx = jnp.asarray(part.idx)
        sub = np.array(state.ref_vecs[idx])          # K rows, writable
        sub_rounds = np.array(state.ref_round[idx])
        self._advance_ref_rows(sub, sub_rounds, arrive, applied,
                               rx_server, r, self.strategy.downloads)
        return (state.ref_vecs.at[idx].set(jnp.asarray(sub)),
                state.ref_round.at[idx].set(jnp.asarray(sub_rounds)))

    @staticmethod
    def _advance_ref_rows(sub, sub_rounds, arrive, applied, rx_server, r,
                          downloads):
        """Advance K sampled reference rows in place — the one update
        both the resident scatter (:meth:`_update_refs`) and the mmap
        spill share, so their reference streams cannot diverge."""
        np_applied = np.asarray(applied)
        rx = np.asarray(rx_server, np.float32)
        for c in range(sub.shape[0]):
            if not arrive[c]:
                continue
            if downloads == "all_slots":
                sub[c] = rx
                sub_rounds[c] = r
            else:
                got = False
                for j in range(np_applied.shape[1]):
                    s = int(np_applied[c, j])
                    if s >= 0:
                        sub[c, s] = rx[s]
                        got = True
                if got:
                    sub_rounds[c] = r
        return sub, sub_rounds

    def _roundtrip_rows(self, server):
        """Encode→decode every server row through the *dense* wire codec
        (delta coding is upload-only) — what any receiver of a broadcast
        actually holds.  Returns ``(rx_rows, frame_lengths)``; float32
        is a bit-exact identity, so it skips the host round-trip and
        meters arithmetically (frame = 4·d bytes, codec-pinned)."""
        dense = CodecConfig(self.cfg.codec.name, sparse=False)
        if dense.name == "float32":
            return server, [4 * int(server.shape[1])] * int(server.shape[0])
        np_server = np.asarray(server, np.float32)
        rx = np.zeros_like(np_server)
        frame_len = []
        for s in range(np_server.shape[0]):
            frame = encode(np_server[s], dense)
            frame_len.append(len(frame))
            rx[s] = decode(frame, np_server.shape[1], dense)
        return jnp.asarray(rx), frame_len

    def _wire_tx_server(self, server):
        """The server matrix as the *clients* hold it: every row
        roundtripped through the dense codec, because the rows a client
        trains from arrived over last round's (possibly lossy)
        broadcast.  Metering is unaffected — download bytes are billed
        by :meth:`_wire_downlink` when the rows are pushed; this only
        stops ``client_step`` reading precision the wire never carried
        (see docs/async-runtime.md, byte metering).

        ``state.server`` entering round r+1 is the very array
        :meth:`_wire_downlink` roundtripped at the end of round r, so
        the downlink's result is cached by identity and the host
        encode/decode loop runs once per server matrix, not twice."""
        if self._wire_is_identity():
            return server
        cached = self._tx_cache
        if cached is not None and cached[0] is server:
            return cached[1]
        rx, _ = self._roundtrip_rows(server)
        self._tx_cache = (server, rx)
        return rx

    def _wire_downlink(self, server, counts, arrive, applied):
        """Run the broadcast through the wire too: every slot row is
        encoded (dense — delta coding is upload-only), metered, and
        decoded, and it is the *decoded* rows clients apply — a lossy
        codec degrades the downlink exactly as it would in deployment.
        ``down_bc`` is one frame per populated slot; ``down_pc`` is the
        per-client accounting over the frames receiving participants
        actually apply (legacy §6.7 accounting)."""
        np_counts = np.asarray(counts)
        rx_arr, frame_len = self._roundtrip_rows(server)
        if not self._wire_is_identity():
            self._tx_cache = (server, rx_arr)   # next round trains from it
        down_bc = sum(frame_len[s] for s in range(len(frame_len))
                      if np_counts[s] > 0)
        if self.strategy.downloads == "all_slots":
            down_pc = int(arrive.sum()) * sum(frame_len)
        else:
            down_pc = sum(frame_len[s]
                          for s in np.asarray(applied).ravel() if s >= 0)
        return rx_arr, down_bc, down_pc

    def _aggregate_async(self, state, dec, slots, part: Participation):
        """Device-buffered aggregation (the production path): flatten
        this round's uploads into lanes — payload, slot id, maturity
        round ``r + staleness``, ``discount**staleness`` weight,
        validity — and hand them with the carried buffer to the
        executor's one compiled insert→gate→mean program.  In-process
        that is a single jitted update; shard-mapped the uploads stay
        sharded on the mesh axis and the mean is a masked collective.
        Bit-identical to :meth:`_aggregate_async_host`, pinned by the
        conformance suite."""
        k, j = slots.shape
        active = jnp.asarray(part.active)
        stale = jnp.asarray(part.staleness, jnp.int32)
        flat = lambda a: jnp.broadcast_to(a[:, None], (k, j)).reshape(-1)
        up = (dec.reshape(k * j, -1).astype(jnp.float32),
              slots.reshape(-1).astype(jnp.int32),
              state.round_idx + flat(stale),
              self._discount[flat(stale)],
              flat(active) & (slots.reshape(-1) >= 0))
        server, counts, n_agg, n_buf, n_evict, buf = \
            self.executor.async_update(
                self.strategy, self._buf_of(state), up, state.round_idx,
                state.server.slots, self.cfg.async_min_uploads)
        return (server, counts, int(n_agg), int(n_buf), int(n_evict), buf)

    def _aggregate_async_host(self, state, dec, slots, part: Participation,
                              r):
        """Host-buffered aggregation (``async_buffer="host"``, and the
        path every async strategy with server-side hooks takes): the
        original numpy insert loop, kept verbatim as the executable
        reference the device path is pinned against — insert this
        round's uploads, then fold in every matured entry once
        ``async_min_uploads`` are available.

        Strategies with an ``assign`` hook have it re-run here over the
        matured buffer contents *at aggregation time* (buffer rows as
        single-upload clients, contribution mask as arrival), so
        FLIS-style dynamic membership is recomputed from what is
        actually being folded in — not from stale send-time tags.  The
        fold then goes through the strategy's ``server_update`` (the
        Alg. 2 default reproduces the legacy in-place write bit for
        bit).  Returns a full :class:`ServerState`."""
        cfg = self.cfg
        vecs = np.asarray(state.buf_vecs).copy()
        bslots = np.asarray(state.buf_slots).copy()
        ready = np.asarray(state.buf_ready).copy()
        weight = np.asarray(state.buf_weight).copy()
        valid = np.asarray(state.buf_valid).copy()
        seq = np.asarray(state.buf_seq).copy()

        np_dec = np.asarray(dec)
        np_slots = np.asarray(slots)
        active = np.asarray(part.active)
        stale = np.asarray(part.staleness)
        evicted = 0
        next_seq = int(seq[valid].max()) + 1 if valid.any() else 0
        for c in range(np_dec.shape[0]):
            if not active[c]:
                continue
            for j in range(np_dec.shape[1]):
                if np_slots[c, j] < 0:
                    continue
                free = np.nonzero(~valid)[0]
                if free.size:
                    i = free[0]
                else:       # overflow: evict the oldest *insertion*
                    occupied = np.where(valid, seq, np.iinfo(np.int32).max)
                    i = int(np.argmin(occupied))
                    evicted += 1
                vecs[i] = np_dec[c, j]
                bslots[i] = np_slots[c, j]
                ready[i] = r + int(stale[c])
                weight[i] = cfg.staleness_discount ** int(stale[c])
                valid[i] = True
                seq[i] = next_seq
                next_seq += 1

        server, counts, n_agg, n_buf, buf = self._fold_host_buffer(
            state, vecs, bslots, ready, weight, valid, seq, r)
        return server, counts, n_agg, n_buf, evicted, buf

    def _fold_host_buffer(self, state, vecs, bslots, ready, weight, valid,
                          seq, r):
        """Fold the matured host-buffer entries into the server (the
        tail of :meth:`_aggregate_async_host`, shared with the real
        transport's arrival-driven insert path — same maturity gate,
        same assign-at-aggregation hook, same ``server_update`` fold).
        Returns ``(server, counts, n_agg, n_buf, buf)``."""
        cfg = self.cfg
        # an entry whose staleness discount rounds to zero weight can never
        # contribute to the weighted mean — treat it as consumed noise so
        # its slot isn't wrongly marked populated (and then broadcast)
        mature = valid & (ready <= r)
        contrib = mature & (weight > 0.0)
        n_mature = int(mature.sum())
        if n_mature >= cfg.async_min_uploads:
            w = jnp.asarray(np.where(contrib, weight, 0.0), jnp.float32)
            s = jnp.asarray(np.where(contrib, bslots, -1), jnp.int32)
            if self._assign is not None:
                # assignment at aggregation time: the matured buffer
                # rows are the round's "uploads" (one slot each), the
                # contribution mask the arrival vector
                new_s = self.executor.assign(
                    self.strategy, state.server,
                    jnp.asarray(vecs)[:, None, :], s[:, None],
                    jnp.asarray(contrib))
                s = jnp.where(jnp.asarray(contrib),
                              new_s[:, 0], -1).astype(jnp.int32)
            mean = masked_collectives.clustered_weighted_mean(
                jnp.asarray(vecs), s, w, self.strategy.n_slots)
            counts = jax.nn.one_hot(
                s, self.strategy.n_slots, dtype=jnp.float32).sum(0)
            server = self._server_update(state.server, mean, counts)
            valid = valid & ~mature
            n_agg = int(contrib.sum())
        else:
            server = state.server
            counts = jnp.zeros((self.strategy.n_slots,), jnp.float32)
            n_agg = 0
        buf = (jnp.asarray(vecs), jnp.asarray(bslots), jnp.asarray(ready),
               jnp.asarray(weight), jnp.asarray(valid), jnp.asarray(seq))
        return server, counts, n_agg, int(valid.sum()), buf

    def _scatter_eval(self, state: EngineState, idx, merged, applied,
                      server, buf, refs, ef, acc_sub):
        """Scatter the merged sub-pytree back into the population,
        evaluate everyone, build the next state.  ``acc_sub`` is the
        fused program's per-client accuracy (full population when the
        identity gather was in effect), saving the separate eval pass."""
        if self._identity:
            cs = merged
            assignment = applied
        else:
            cs = jax.tree.map(lambda a, s: a.at[idx].set(s),
                              state.client_state, merged)
            assignment = jnp.full((self.n, self.strategy.j_slots), -1,
                                  jnp.int32).at[idx].set(applied)

        if acc_sub is not None and self._identity:
            acc = acc_sub
        else:
            acc = self.executor.evaluate(
                self.strategy, cs, self.data.x_test, self.data.y_test)
        # commit to a single device before any reduction: a mean over a
        # mesh-sharded accuracy vector reduces in device order, which is
        # ULP-different from the in-process sequential reduction (the
        # conformance suite pins the report bit-for-bit across backends)
        acc = jnp.asarray(np.asarray(acc))
        new_state = EngineState(
            round_idx=state.round_idx + 1, client_state=cs, server=server,
            buf_vecs=buf[0], buf_slots=buf[1], buf_ready=buf[2],
            buf_weight=buf[3], buf_valid=buf[4], buf_seq=buf[5],
            ref_vecs=refs[0], ref_round=refs[1], ef_residual=ef)
        return new_state, acc, assignment

    def _store_eval(self, state: EngineState, idx, merged, applied,
                    server, buf, refs, ef, sub_data):
        """mmap counterpart of :meth:`_scatter_eval`: the population
        already lives in the store (the round spilled the merged rows
        before this), so the next state keeps its zero-row placeholders.

        ``store_eval="full"`` re-gathers the whole population in
        ``store_eval_chunk`` blocks and evaluates each — per-client
        evaluation is an independent vmap lane on both executors, so
        the chunked accuracy vector is bit-identical to the resident
        monolithic eval.  ``"sampled"`` (the simulated-scale setting)
        evaluates only the K merged rows: the report's accuracy /
        assignment then cover the cohort, not the population."""
        if self.cfg.store_eval == "sampled":
            acc = self.executor.evaluate(
                self.strategy, merged, sub_data.x_test, sub_data.y_test)
            assignment = applied
        else:
            def gather_cs(ids):
                return jax.tree.map(jnp.asarray,
                                    self.store.gather(ids)["cs"])

            def gather_xy(ids):
                if self._streaming:
                    d = self.data.gather_clients(ids)
                    return d.x_test, d.y_test
                jids = jnp.asarray(ids)
                return self.data.x_test[jids], self.data.y_test[jids]

            acc = executors.evaluate_population(
                self.executor, self.strategy, gather_cs, gather_xy,
                self.n, self.cfg.store_eval_chunk)
            assignment = jnp.full((self.n, self.strategy.j_slots), -1,
                                  jnp.int32).at[jnp.asarray(idx)].set(
                applied)
        acc = jnp.asarray(np.asarray(acc))
        new_state = EngineState(
            round_idx=state.round_idx + 1,
            client_state=state.client_state, server=server,
            buf_vecs=buf[0], buf_slots=buf[1], buf_ready=buf[2],
            buf_weight=buf[3], buf_valid=buf[4], buf_seq=buf[5],
            ref_vecs=refs[0], ref_round=refs[1], ef_residual=ef)
        return new_state, acc, assignment
