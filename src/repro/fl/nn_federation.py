"""TPFL-for-NN: the paper's confidence clustering applied to neural
clients (DESIGN.md §4 / §Arch-applicability).

Confidence = summed per-class logit margin on D_conf (the differentiable
analogue of the TM vote margin).  Aggregation per round:

* trunk (w1, b1): clustered mean — members of cluster k average among
  themselves (multi-center FL, as in Alg. 2);
* head: only the `c_max` *row* of the classifier is shared and averaged
  within the cluster (the NN analogue of uploading one class's weight
  vector).

The honest caveat from DESIGN.md holds: unlike the TM (disjoint per-class
parameter blocks), an NN trunk is shared across classes, so the upload
saving is marginal — this module exists to show the technique composes
with any per-class-output model, including the 10 assigned architectures
via their LM heads.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import confidence, mlp
from repro.data.partition import ClientData
from repro.fl import masked_collectives


@dataclasses.dataclass(frozen=True)
class NNFedConfig:
    n_clients: int = 10
    rounds: int = 5
    local_epochs: int = 2
    n_hidden: int = 64
    lr: float = 0.1
    batch: int = 16


class NNHistory(NamedTuple):
    accuracy: list
    assignments: jnp.ndarray           # (rounds, n_clients)
    upload_bytes_per_client_round: int


def run(data: ClientData, cfg: NNFedConfig, key: jax.Array, *,
        n_features: int, n_classes: int) -> NNHistory:
    k_init, k_train = jax.random.split(key)
    params = jax.vmap(
        lambda k: mlp.init(k, n_features, cfg.n_hidden, n_classes))(
        jax.random.split(k_init, cfg.n_clients))

    accs, assigns = [], []
    for r in range(cfg.rounds):
        ks = jax.random.split(jax.random.fold_in(k_train, r), cfg.n_clients)
        params = jax.vmap(lambda p, xt, yt, k: mlp.local_train(
            p, xt, yt, k, epochs=cfg.local_epochs, batch=cfg.batch,
            lr=cfg.lr))(params, data.x_train, data.y_train, ks)

        # per-client confidence on D_conf → cluster = most-confident class
        logits = jax.vmap(mlp.apply)(params, data.x_conf)
        conf = jax.vmap(confidence.logit_margin_confidence)(logits)
        assign = jnp.argmax(conf, axis=-1)             # (n_clients,)

        # trunk: clustered mean; members receive their cluster's average
        for name in ("w1", "b1"):
            means = masked_collectives.clustered_mean(params[name], assign,
                                                      n_classes)
            params[name] = means[assign].astype(params[name].dtype)
        # head: share only the c_max row/entry within the cluster
        rows = jax.vmap(lambda w, c: w[:, c])(params["w2"], assign)
        row_means = masked_collectives.clustered_mean(rows, assign,
                                                      n_classes)
        params["w2"] = jax.vmap(lambda w, c, m: w.at[:, c].set(m))(
            params["w2"], assign, row_means[assign])
        be = jax.vmap(lambda b, c: b[c])(params["b2"], assign)
        be_means = masked_collectives.clustered_mean(be, assign, n_classes)
        params["b2"] = jax.vmap(lambda b, c, m: b.at[c].set(m))(
            params["b2"], assign, be_means[assign])

        acc = jax.vmap(mlp.accuracy)(params, data.x_test,
                                     data.y_test).mean()
        accs.append(float(acc))
        assigns.append(assign)

    trunk_bytes = 4 * (n_features * cfg.n_hidden + cfg.n_hidden)
    head_row_bytes = 4 * (cfg.n_hidden + 1)
    return NNHistory(accs, jnp.stack(assigns),
                     trunk_bytes + head_row_bytes + 4)
