"""The run recorder: one object the engine talks telemetry through.

The engine holds a single ``telemetry`` object and calls four hooks —
``span(name)`` / ``fence(values)`` around each round stage,
``on_round(report)`` after each round, and reads ``manifest`` when it
checkpoints.  :data:`NULL` (telemetry off, the default) answers all of
them as no-ops, so an un-instrumented engine is byte-for-byte the
pre-telemetry engine; :class:`RunRecorder` (telemetry on) times the
spans, derives the round event, and appends it to the run directory:

    run-dir/
      manifest.json    config, seed, mesh, git sha, jax version
      events.jsonl     one structured event per round

A recorder without a run directory (``RunRecorder()``) records
in-memory only — ``benchmarks/run.py emit_bench`` uses that form to get
the per-phase breakdown without a run dir.

Neutrality: the recorder only ever consumes round *outputs* (the
report) and host wall clocks.  Nothing it computes flows back into the
engine, which is what lets the conformance suite pin obs-on == obs-off
bit for bit.
"""
from __future__ import annotations

import pathlib

import numpy as np

from repro.fl.obs import events as ev
from repro.fl.obs import manifest as mf
from repro.fl.obs.tracer import NullTracer, PhaseTracer, profile_trace


class NullTelemetry(NullTracer):
    """Telemetry disabled: every hook a no-op, shared singleton."""

    manifest = None

    def on_round(self, report) -> None:
        pass

    def close(self) -> None:
        pass


NULL = NullTelemetry()


class RunRecorder(PhaseTracer):
    """Telemetry enabled: spans + structured events (+ optional
    ``jax.profiler`` capture via :func:`start`'s ``profile_dir``)."""

    def __init__(self, run_dir: str | pathlib.Path | None = None,
                 profile_dir: str | pathlib.Path | None = None):
        super().__init__()
        self.run_dir = pathlib.Path(run_dir) if run_dir else None
        self.events_path = (self.run_dir / mf.EVENTS_NAME
                            if self.run_dir else None)
        self.profile_dir = profile_dir
        self.manifest: dict | None = None
        self.history: list[dict] = []      # jsonable events, in order
        self._prev_assignment = None
        self._profile_ctx = None

    # -- lifecycle ---------------------------------------------------------

    def start(self, manifest: dict | None = None) -> "RunRecorder":
        """Write the manifest (if a run dir is set) and start the
        profiler capture (if a profile dir is set).  Idempotent per
        recorder; call before the first round."""
        self.manifest = manifest
        if self.run_dir is not None:
            self.run_dir.mkdir(parents=True, exist_ok=True)
            if manifest is not None:
                mf.write_manifest(self.run_dir, manifest)
        if self.profile_dir is not None and self._profile_ctx is None:
            self._profile_ctx = profile_trace(self.profile_dir)
            self._profile_ctx.__enter__()
        return self

    def close(self) -> None:
        """Stop the profiler capture (events are flushed per round)."""
        if self._profile_ctx is not None:
            ctx, self._profile_ctx = self._profile_ctx, None
            ctx.__exit__(None, None, None)

    # -- per-round hook ----------------------------------------------------

    def on_round(self, report) -> dict:
        """Derive this round's event from the report + the spans
        accumulated since the last call, and append it to the log."""
        event = ev.round_event(report, spans=self.take(),
                               prev_assignment=self._prev_assignment)
        self._prev_assignment = np.array(report.assignment)
        if self.events_path is not None:
            event = ev.append_event(self.events_path, event)
        else:
            event = ev.to_jsonable(event)
        self.history.append(event)
        return event
