"""The telemetry consumer: render a run directory for humans.

    PYTHONPATH=src python -m repro.fl.obs summarize <run-dir>

Reads ``manifest.json`` + ``events.jsonl`` (written by ``fed_train
--telemetry-dir`` or any :class:`~repro.fl.obs.recorder.RunRecorder`)
and prints three views:

* the **round table** — accuracy (mean and worst-decile), wire bytes by
  direction, participation, async buffer counters, per round;
* the **phase breakdown** — median wall time per round stage and its
  share of the round, the where-does-round-time-go view every perf PR
  reports against;
* the **client-accuracy deciles** of the final round — the
  distributional (worst-k) personalization metric, not just the mean.

Pure consumer: it only reads the run directory, so it can run anywhere
the JSONL landed (CI artifacts included).
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import numpy as np

from repro.fl.obs import manifest as mf
from repro.fl.obs.events import read_events


def _fmt_bytes(n: int | None) -> str:
    if n is None:
        return "-"
    if n >= 1e6:
        return f"{n / 1e6:.2f}MB"
    if n >= 1e3:
        return f"{n / 1e3:.1f}kB"
    return f"{n}B"


def _manifest_header(manifest: dict | None) -> list[str]:
    if not manifest:
        return ["manifest: (none found)"]
    cfg = manifest.get("config") or {}
    mesh = manifest.get("mesh")
    mesh_s = ("x".join(f"{k}:{v}" for k, v in mesh.items())
              if mesh else "in-process")
    parts = [
        f"strategy={manifest.get('strategy', '?')}",
        f"dataset={manifest.get('dataset', '?')}",
        f"backend={cfg.get('backend', '?')}",
        f"aggregation={cfg.get('aggregation', '?')}",
        f"mesh={mesh_s}",
        f"seed={manifest.get('seed')}",
    ]
    prov = [
        f"jax={manifest.get('jax_version')}",
        f"devices={((manifest.get('devices') or {}).get('count'))}",
        f"git={str(manifest.get('git_sha'))[:12]}",
    ]
    return ["run: " + "  ".join(parts), "env: " + "  ".join(prov)]


def _round_table(events: list[dict]) -> list[str]:
    head = (f"{'round':>5}  {'acc':>7}  {'w10%':>7}  {'up':>9}  "
            f"{'down_bc':>9}  {'down_pc':>9}  {'arrived':>7}  "
            f"{'agg':>4}  {'buf':>4}  {'evict':>5}  {'churn':>5}")
    lines = [head, "-" * len(head)]
    for e in events:
        acc = e.get("accuracy") or {}
        by = e.get("bytes") or {}
        sch = e.get("scheduler") or {}
        asy = e.get("async") or {}
        cl = e.get("cluster") or {}
        churn = cl.get("churn_vs_prev")
        lines.append(
            f"{e.get('round', '?'):>5}  "
            f"{acc.get('mean', float('nan')):>7.4f}  "
            f"{acc.get('worst_decile_mean', float('nan')):>7.4f}  "
            f"{_fmt_bytes(by.get('upload')):>9}  "
            f"{_fmt_bytes(by.get('download_broadcast')):>9}  "
            f"{_fmt_bytes(by.get('download_per_client')):>9}  "
            f"{sch.get('arrived_on_time', '-'):>7}  "
            f"{asy.get('aggregated', '-'):>4}  "
            f"{asy.get('buffered', '-'):>4}  "
            f"{asy.get('evicted', '-'):>5}  "
            + (f"{churn:>5.2f}" if churn is not None else f"{'-':>5}"))
    return lines


def phase_medians(events: list[dict]) -> dict[str, float]:
    """Median wall seconds per phase over the rounds that recorded it."""
    acc: dict[str, list[float]] = {}
    for e in events:
        for name, dt in (e.get("phases") or {}).items():
            acc.setdefault(name, []).append(float(dt))
    return {name: float(np.median(v)) for name, v in acc.items()}


def _phase_table(events: list[dict]) -> list[str]:
    med = phase_medians(events)
    if not med:
        return ["(no phase spans recorded)"]
    total = med.get("round") or sum(
        v for k, v in med.items() if k != "round")
    lines = [f"{'phase':<18} {'median_s':>10} {'share':>7}",
             "-" * 37]
    stages = {k: v for k, v in med.items() if k != "round"}
    for name, dt in sorted(stages.items(), key=lambda kv: -kv[1]):
        share = f"{100.0 * dt / total:>6.1f}%" if total else "      -"
        lines.append(f"{name:<18} {dt:>10.4f} {share}")
    lines.append("-" * 37)
    lines.append(f"{'Σ stages':<18} {sum(stages.values()):>10.4f}")
    if "round" in med:
        lines.append(f"{'round total':<18} {med['round']:>10.4f}")
    return lines


def _decile_table(event: dict) -> list[str]:
    acc = event.get("accuracy") or {}
    deciles = acc.get("deciles")
    if not deciles:
        return ["(no decile data)"]
    labels = [f"p{10 * i}" for i in range(len(deciles))]
    return [
        "  ".join(f"{lb:>6}" for lb in labels),
        "  ".join(f"{d:>6.3f}" for d in deciles),
        f"worst-decile mean = {acc.get('worst_decile_mean'):.4f}   "
        f"population mean = {acc.get('mean'):.4f}",
    ]


def summarize(run_dir: str | pathlib.Path, out=None) -> dict:
    """Render the run; returns the parsed (manifest, events) payload so
    tests and tooling can assert on it."""
    out = out or sys.stdout
    run_dir = pathlib.Path(run_dir)
    events_path = run_dir / mf.EVENTS_NAME
    if not events_path.is_file():
        raise SystemExit(f"no {mf.EVENTS_NAME} in {run_dir} — not a "
                         f"telemetry run directory")
    manifest = mf.read_manifest(run_dir)
    events = read_events(events_path)

    w = lambda s="": print(s, file=out)
    for line in _manifest_header(manifest):
        w(line)
    w(f"rounds: {len(events)}")
    w()
    for line in _round_table(events):
        w(line)
    w()
    w("per-phase wall time (median over rounds):")
    for line in _phase_table(events):
        w("  " + line)
    if events:
        w()
        w(f"client accuracy deciles (round {events[-1].get('round')}):")
        for line in _decile_table(events[-1]):
            w("  " + line)
    return {"manifest": manifest, "events": events}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fl.obs",
        description="Federated telemetry consumers (docs/observability.md)")
    sub = ap.add_subparsers(dest="command", required=True)
    s = sub.add_parser("summarize",
                       help="render a telemetry run directory: round "
                            "table, phase breakdown, accuracy deciles")
    s.add_argument("run_dir", help="directory holding manifest.json + "
                                   "events.jsonl (fed_train "
                                   "--telemetry-dir output)")
    args = ap.parse_args(argv)
    if args.command == "summarize":
        summarize(args.run_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
