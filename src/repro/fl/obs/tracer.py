"""Phase-span tracing for the federated round.

A :class:`PhaseTracer` times named host-side spans around the round's
stages — broadcast encode, client step, uplink codec, server-side
assign, aggregation, server_update, downlink, apply/merge, eval — with
explicit ``jax.block_until_ready`` fences so a span's wall time covers
the device work it launched, not just the Python dispatch.  The engine
calls ``span(name)`` / ``fence(values)`` unconditionally; with
telemetry disabled both resolve to the :data:`NULL` no-ops below (a
shared null context manager and a pass), so the un-instrumented round
is exactly the pre-telemetry round.

The **neutrality invariant**: tracing only ever *reads* — it times,
fences, and copies scalars off device.  It never feeds a value back
into the round's math, so obs-on and obs-off runs are bit-identical
(``tests/test_fl_conformance.py`` pins this across both backends and
both aggregation modes).  Fences change *when* the host waits, never
what the arrays hold.

Optional deep capture: :func:`profile_trace` wraps a run in
``jax.profiler.start_trace`` / ``stop_trace`` so ``--profile-dir`` on
``fed_train`` drops a TensorBoard-loadable device trace next to the
telemetry run directory.
"""
from __future__ import annotations

import contextlib
import time

import jax


class _NullSpan:
    """Reusable zero-cost context manager — the disabled span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Telemetry off: every hook is a no-op (no timing, no fences)."""

    enabled = False

    def span(self, name: str):
        return _NULL_SPAN

    def fence(self, *values):
        pass

    def discard(self, name: str):
        pass

    def take(self) -> dict:
        return {}


class _Span:
    """One live span: records ``perf_counter`` deltas into the tracer."""

    __slots__ = ("_tracer", "_name", "_t0")

    def __init__(self, tracer: "PhaseTracer", name: str):
        self._tracer = tracer
        self._name = name

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._tracer._record(self._name, time.perf_counter() - self._t0)
        return False


class PhaseTracer:
    """Host-side wall-time spans, accumulated per round.

    ``span(name)`` returns a context manager; re-entering the same name
    within one round accumulates (the async host-reference loop times
    its insert per upload).  ``take()`` pops the current round's
    ``{name: seconds}`` dict — the recorder calls it once per round, so
    spans never leak across rounds.
    """

    enabled = True

    def __init__(self):
        self._spans: dict[str, float] = {}

    def span(self, name: str) -> _Span:
        return _Span(self, name)

    def _record(self, name: str, dt: float) -> None:
        self._spans[name] = self._spans.get(name, 0.0) + dt

    def fence(self, *values) -> None:
        """Block until every array in ``values`` (pytrees allowed) is
        computed, so the enclosing span bills the device work to the
        phase that launched it instead of whichever later phase first
        touches the result."""
        jax.block_until_ready([v for v in values if v is not None])

    def discard(self, name: str) -> None:
        """Drop a span that turned out to be vacuous (e.g. the engine
        probed an executor's fused form and it answered "no fused
        path") so events report only phases that really ran."""
        self._spans.pop(name, None)

    def take(self) -> dict[str, float]:
        spans, self._spans = self._spans, {}
        return spans


NULL = NullTracer()


@contextlib.contextmanager
def profile_trace(profile_dir: str | None):
    """``jax.profiler`` capture scoped to a ``with`` block — a no-op
    when ``profile_dir`` is None (the default: span timing only)."""
    if profile_dir is None:
        yield
        return
    jax.profiler.start_trace(str(profile_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()
