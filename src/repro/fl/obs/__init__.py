"""Federated telemetry plane (``repro.fl.obs``).

Three layers, none of which may perturb the round's numerics (obs-on ==
obs-off bit for bit, pinned by the conformance suite):

* **phase-span tracing** (:mod:`~repro.fl.obs.tracer`) — host wall time
  per round stage with ``jax.block_until_ready`` fences, plus optional
  ``jax.profiler`` capture;
* **structured round events** (:mod:`~repro.fl.obs.events` /
  :mod:`~repro.fl.obs.manifest` / :mod:`~repro.fl.obs.recorder`) —
  per-round JSONL (accuracy deciles, cluster churn and occupancy,
  empty-slot retention, staleness histograms, wire bytes, phase times)
  next to a run manifest (config, seed, mesh, git sha, jax version);
* **a consumer** (:mod:`~repro.fl.obs.summarize`) —
  ``python -m repro.fl.obs summarize <run-dir>``.

Deliberately import-light: the obs package duck-types on the runtime's
``RoundReport`` instead of importing it, so the runtime can depend on
obs (``Engine(telemetry=...)``) without a cycle.  See
``docs/observability.md``.
"""
from repro.fl.obs.events import (SCHEMA_VERSION, accuracy_deciles,
                                 append_event, read_events, round_event,
                                 to_jsonable, worst_decile_mean)
from repro.fl.obs.manifest import (build_manifest, git_sha, read_manifest,
                                   write_manifest)
from repro.fl.obs.recorder import NULL, NullTelemetry, RunRecorder
from repro.fl.obs.summarize import phase_medians, summarize
from repro.fl.obs.tracer import NullTracer, PhaseTracer, profile_trace

__all__ = [
    "SCHEMA_VERSION", "accuracy_deciles", "append_event", "read_events",
    "round_event", "to_jsonable", "worst_decile_mean",
    "build_manifest", "git_sha", "read_manifest", "write_manifest",
    "NULL", "NullTelemetry", "RunRecorder",
    "phase_medians", "summarize",
    "NullTracer", "PhaseTracer", "profile_trace",
]
