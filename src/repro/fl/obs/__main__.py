"""``python -m repro.fl.obs summarize <run-dir>`` — see summarize.py."""
import sys

from repro.fl.obs.summarize import main

sys.exit(main())
