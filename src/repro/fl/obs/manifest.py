"""Run manifests: the provenance record every telemetry run carries.

One ``manifest.json`` per run directory, written before the first
round: the full resolved configuration (``RuntimeConfig`` and friends,
dataclasses flattened), the seed, the mesh shape and device inventory,
the git sha the run was built from, and the jax version — everything a
reader needs to interpret (or re-run) the ``events.jsonl`` next to it.
The same dict rides along with engine checkpoints
(:func:`repro.fl.runtime.checkpointing.save` accepts it), so a resumed
run's provenance survives the interruption.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import platform
import subprocess
import sys
from typing import Any

import jax

from repro.fl.obs.events import to_jsonable

MANIFEST_NAME = "manifest.json"
EVENTS_NAME = "events.jsonl"


def git_sha(cwd: str | pathlib.Path | None = None) -> str | None:
    """Best-effort ``git rev-parse HEAD`` — None outside a checkout."""
    try:
        res = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=str(cwd) if cwd else None,
            capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = res.stdout.strip()
    return sha if res.returncode == 0 and sha else None


def _flatten_config(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {f.name: _flatten_config(getattr(obj, f.name))
                for f in dataclasses.fields(obj)}
    return obj


def build_manifest(config: Any = None, seed: int | None = None,
                   mesh=None, extra: dict | None = None) -> dict:
    """Assemble the provenance dict.

    ``config`` is any dataclass (nested dataclasses are flattened —
    ``RuntimeConfig`` carries its scheduler and codec along); ``mesh``
    a jax Mesh or None (in-process); ``extra`` free-form caller fields
    (CLI argv, dataset name, strategy...)."""
    devices = jax.devices()
    manifest = {
        "config": _flatten_config(config),
        "seed": seed,
        "mesh": ({str(k): int(v) for k, v in dict(mesh.shape).items()}
                 if mesh is not None else None),
        "devices": {
            "count": len(devices),
            "platform": devices[0].platform if devices else None,
        },
        "git_sha": git_sha(pathlib.Path(__file__).resolve().parents[4]),
        "jax_version": jax.__version__,
        "python_version": sys.version.split()[0],
        "host_platform": platform.platform(),
    }
    if extra:
        manifest.update(extra)
    return to_jsonable(manifest)


def write_manifest(run_dir: str | pathlib.Path,
                   manifest: dict) -> pathlib.Path:
    run_dir = pathlib.Path(run_dir)
    run_dir.mkdir(parents=True, exist_ok=True)
    path = run_dir / MANIFEST_NAME
    path.write_text(json.dumps(to_jsonable(manifest), indent=2,
                               sort_keys=True) + "\n")
    return path


def read_manifest(run_dir: str | pathlib.Path) -> dict | None:
    path = pathlib.Path(run_dir) / MANIFEST_NAME
    if not path.is_file():
        return None
    return json.loads(path.read_text())
