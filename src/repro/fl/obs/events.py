"""Structured round events: every ``RoundReport`` plus derived gauges,
one JSON object per line.

The event schema (see ``docs/observability.md`` for the field-by-field
contract) is built *from* the report — the obs layer never reaches into
the engine's math, it only derives host-side gauges from what the round
already returned:

* ``accuracy``      — mean, per-decile quantiles of the per-client
  accuracy vector, and the worst-decile mean (the honest pFL metric:
  how the bottom 10 % of clients fare, not just the average).
* ``cluster``       — per-slot contributor counts, slot occupancy and
  per-slot accuracy distribution derived from the confidence-argmax
  assignment (the paper's per-class-confidence dynamic, observed), the
  empty-slot retention rate (fraction of slots Alg. 2 left untouched),
  and assignment churn vs. the previous round (the cluster-identity
  dynamic IFCA-style methods hinge on).
* ``scheduler``     — sampled / dropped / straggler counts and the
  staleness histogram (``Participation.summary()``).
* ``bytes``         — codec-metered wire traffic by direction.
* ``async``         — aggregated / still-buffered / evicted uploads.
* ``store``         — host-I/O bytes read/written by the mmap client
  store this round (0 on the resident engine).
* ``transport``     — framed bytes the real transport (loopback /
  socket, ``repro.fl.transport``) put on and took off the wire this
  round (headers and envelopes included, unlike the codec-metered
  ``bytes`` section), plus the observed-arrival staleness summary of
  the uploads that actually landed (async transport; ``None`` on the
  in-process engine, where staleness is an injected schedule).
* ``phases``        — the round's phase-span wall times (tracer),
  including the ``wire_tx`` / ``wire_rx`` transport spans.

Serialization is numpy-safe by construction: :func:`to_jsonable`
coerces numpy/jax scalars and arrays (int64 included — ``json`` alone
raises on ``np.int64``) before anything touches the wire, and
:func:`read_events` round-trips the file back to plain Python values.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

import numpy as np

SCHEMA_VERSION = 1

# decile grid: 0 % (worst client) through 100 % (best), step 10
_DECILES = np.linspace(0.0, 1.0, 11)


def to_jsonable(value: Any) -> Any:
    """Recursively coerce a value into plain JSON types.

    Handles numpy/jax scalars (``np.int64``, ``np.float32``, bools) and
    arrays (→ nested lists), paths, and NaN/inf floats (→ None, since
    JSON has no spelling for them and downstream consumers shouldn't
    have to guess a dialect)."""
    if isinstance(value, dict):
        return {str(k): to_jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [to_jsonable(v) for v in value]
    if isinstance(value, pathlib.Path):
        return str(value)
    if isinstance(value, (bool, np.bool_)):
        return bool(value)
    if isinstance(value, (int, np.integer)):
        return int(value)
    if isinstance(value, (float, np.floating)):
        f = float(value)
        return f if np.isfinite(f) else None
    if hasattr(value, "__array__"):          # numpy / jax arrays
        arr = np.asarray(value)
        if arr.ndim == 0:
            return to_jsonable(arr.item())
        return [to_jsonable(v) for v in arr.tolist()]
    return value


def accuracy_deciles(per_client_accuracy) -> list[float]:
    """The 11 decile quantiles (0 %=worst client … 100 %=best) of the
    per-client accuracy vector — the distributional report ROADMAP
    item 5 calls the honest pFL metric."""
    acc = np.asarray(per_client_accuracy, np.float64).ravel()
    return [float(q) for q in np.quantile(acc, _DECILES)]


def worst_decile_mean(per_client_accuracy) -> float:
    """Mean accuracy of the worst 10 % of clients (at least one)."""
    acc = np.sort(np.asarray(per_client_accuracy, np.float64).ravel())
    k = max(1, int(np.ceil(acc.size / 10)))
    return float(acc[:k].mean())


def _cluster_gauges(report, prev_assignment) -> dict:
    counts = np.asarray(report.cluster_counts, np.float64)
    assignment = np.asarray(report.assignment)
    acc = np.asarray(report.per_client_accuracy, np.float64)
    n_slots = counts.shape[0]
    # slot occupancy + per-slot accuracy from the (n, j) assignment:
    # a client "occupies" every slot it shares into (−1 = none)
    occupancy = np.zeros(n_slots, np.int64)
    slot_acc_sum = np.zeros(n_slots, np.float64)
    for j in range(assignment.shape[1] if assignment.ndim == 2 else 0):
        col = assignment[:, j]
        shared = col >= 0
        np.add.at(occupancy, col[shared], 1)
        np.add.at(slot_acc_sum, col[shared], acc[shared])
    slot_accuracy = [
        float(slot_acc_sum[s] / occupancy[s]) if occupancy[s] else None
        for s in range(n_slots)]
    churn = None
    if prev_assignment is not None:
        prev = np.asarray(prev_assignment)
        if prev.shape == assignment.shape:
            churn = float((prev != assignment).any(axis=-1).mean())
    return {
        "counts": counts.tolist(),
        "populated_slots": int((counts > 0).sum()),
        "empty_slot_retention_rate": float((counts == 0).mean()),
        "occupancy": occupancy.tolist(),
        "slot_accuracy": slot_accuracy,
        "churn_vs_prev": churn,
    }


def round_event(report, spans: dict | None = None,
                prev_assignment=None) -> dict:
    """Build one structured event from a ``RoundReport`` (duck-typed —
    the obs layer has no import edge into the runtime).  Pure
    derivation: nothing here feeds back into the round."""
    part = report.participation
    ev = {
        "schema": SCHEMA_VERSION,
        "round": int(report.round_idx),
        "accuracy": {
            "mean": float(report.mean_accuracy),
            "deciles": accuracy_deciles(report.per_client_accuracy),
            "worst_decile_mean": worst_decile_mean(
                report.per_client_accuracy),
        },
        "cluster": _cluster_gauges(report, prev_assignment),
        "scheduler": (part.summary() if hasattr(part, "summary")
                      else None),
        "bytes": {
            "upload": int(report.upload_bytes),
            "download_broadcast": int(report.download_bytes_broadcast),
            "download_per_client": int(report.download_bytes_per_client),
        },
        "async": {
            "aggregated": int(report.aggregated_uploads),
            "buffered": int(report.buffered_uploads),
            "evicted": int(report.evicted_uploads),
        },
        # host-I/O gauges of the mmap client store (0 when resident —
        # getattr keeps older/minimal report shapes valid)
        "store": {
            "read_bytes": int(getattr(report, "store_read_bytes", 0)),
            "written_bytes": int(getattr(report, "store_written_bytes", 0)),
        },
        "transport": _transport_gauges(report),
        "phases": dict(spans) if spans else None,
    }
    return ev


def _transport_gauges(report) -> dict | None:
    """Per-direction framed-byte gauges + observed-arrival staleness of
    the real transport; ``None`` when nothing crossed a process wire
    (the in-process engine)."""
    tx = int(getattr(report, "wire_tx_bytes", 0))
    rx = int(getattr(report, "wire_rx_bytes", 0))
    observed = getattr(report, "observed_staleness", None)
    if tx == 0 and rx == 0 and observed is None:
        return None
    gauges = {"wire_tx_bytes": tx, "wire_rx_bytes": rx}
    if observed is not None:
        # the runner hands either the raw arrival-lag array or the
        # already-derived Participation.summary() dict
        if isinstance(observed, dict):
            gauges["observed"] = observed
        else:
            lags = np.asarray(observed, np.int64).ravel()
            hist = (np.bincount(lags) if lags.size
                    else np.zeros(1, np.int64))
            gauges["observed"] = {
                "arrived": int(lags.size),
                "arrived_on_time": int((lags == 0).sum()),
                "stragglers": int((lags > 0).sum()),
                "staleness_hist": hist.tolist(),
            }
    return gauges


def append_event(path: str | pathlib.Path, event: dict) -> dict:
    """Append one event as a JSONL line (numpy-safe) and return the
    jsonable form that was written."""
    jsonable = to_jsonable(event)
    line = json.dumps(jsonable, sort_keys=True)
    with open(path, "a") as f:
        f.write(line + "\n")
    return jsonable


def read_events(path: str | pathlib.Path) -> list[dict]:
    """Load a run's ``events.jsonl`` back into a list of dicts."""
    events = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events
