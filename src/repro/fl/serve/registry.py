"""Versioned model registry — the artifact store the serving plane
pulls from.

A registry directory holds immutable versions, one per training round
checkpoint, under the same filename scheme
:mod:`repro.fl.runtime.checkpointing` writes::

    round_000002.msgpack (+ .sha256)    # version 2: the engine state
    round_000002.manifest.json          # optional provenance ride-along
    round_000004.msgpack (+ .sha256)    # version 4 supersedes it
    ...

Integrity follows :mod:`repro.data.ingest.fetch`'s verify-then-place
discipline, tightened for serving:

* **publish** stages the checkpoint bytes to a ``.part`` temp in the
  registry, hashes them, renames atomically into place, and writes the
  ``.sha256`` sidecar last — a crashed publish leaves a ``.part`` ruin,
  never a half-valid version.  Re-publishing an existing version is a
  no-op when the bytes match and a loud :class:`RegistryError` when
  they don't (versions are immutable).
* **pull** *requires* the sidecar (``idx.verify_bytes`` alone would
  silently pass on a missing sidecar — a serving registry treats that
  as corruption, not as best-effort), re-hashes the payload against it,
  and only then decodes through
  :func:`repro.fl.runtime.checkpointing.restore`, which rejects layout
  drift naming the offending leaf and both dtype/shape pairs.

Nothing here ever mutates a placed version, so a
:class:`~repro.fl.serve.plane.ServingPlane` holding version *r* resident
keeps serving it bit-for-bit while version *r+k* is being published
next to it — the atomic warm swap is just "pull the newer file, then
swap one reference".
"""
from __future__ import annotations

import hashlib
import pathlib
import re
import shutil

from repro.data.ingest import idx
from repro.fl.runtime import checkpointing

_PAT = re.compile(r"round_(\d+)\.msgpack$")


class RegistryError(RuntimeError):
    """Publish/pull failure — nothing was placed or served."""


def _version_name(version: int) -> str:
    return f"round_{int(version):06d}.msgpack"


class ModelRegistry:
    """Immutable versioned checkpoint store under ``root``."""

    def __init__(self, root: str | pathlib.Path):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    # -- enumeration -----------------------------------------------------

    def versions(self) -> list[int]:
        """All published versions (training round indices), ascending."""
        out = []
        for p in self.root.iterdir():
            m = _PAT.search(p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> int | None:
        vs = self.versions()
        return vs[-1] if vs else None

    def path_for(self, version: int) -> pathlib.Path:
        return self.root / _version_name(version)

    def manifest_path_for(self, version: int) -> pathlib.Path:
        return self.root / f"round_{int(version):06d}.manifest.json"

    # -- publish ---------------------------------------------------------

    def publish(self, src: str | pathlib.Path) -> int:
        """Place checkpoint file ``src`` into the registry as the
        version its filename names; returns that version.

        Verify-then-place: copy to a ``.part`` temp inside the registry
        (same filesystem, so the final ``rename`` is atomic), sidecar
        written only after the payload is in place.  Idempotent for
        identical bytes; immutable otherwise.  A ``manifest.json``
        sitting next to ``src`` (the checkpoint directory's telemetry
        ride-along) is carried across as the version's provenance."""
        src = pathlib.Path(src)
        m = _PAT.search(src.name)
        if m is None:
            raise RegistryError(
                f"{src} is not a round checkpoint (expected "
                f"round_NNNNNN.msgpack) — the registry versions by "
                f"training round")
        if not src.is_file():
            raise RegistryError(f"{src} does not exist — nothing published")
        version = int(m.group(1))
        dest = self.path_for(version)
        digest = hashlib.sha256(src.read_bytes()).hexdigest()
        if dest.exists():
            placed = hashlib.sha256(dest.read_bytes()).hexdigest()
            if placed != digest:
                raise RegistryError(
                    f"version {version} already published in {self.root} "
                    f"with different bytes (placed sha256 "
                    f"{placed[:12]}…, incoming {digest[:12]}…) — "
                    f"versions are immutable; a changed round "
                    f"{version} checkpoint means the training run "
                    f"diverged, publish under a fresh registry")
            return version
        tmp = dest.with_name(dest.name + ".part")
        shutil.copyfile(src, tmp)
        if hashlib.sha256(tmp.read_bytes()).hexdigest() != digest:
            tmp.unlink()
            raise RegistryError(
                f"{src}: bytes changed while staging into {self.root} — "
                f"nothing published")
        tmp.rename(dest)
        idx.write_checksum(dest)
        src_manifest = src.parent / checkpointing.MANIFEST_NAME
        if src_manifest.is_file():
            shutil.copyfile(src_manifest, self.manifest_path_for(version))
        return version

    # -- pull ------------------------------------------------------------

    def pull(self, version: int, like):
        """Verified state for ``version``, decoded into the structure of
        ``like`` (a fresh ``engine.init(...)`` state).

        Fails loudly on every tamper mode the serving tests pin:
        missing version, missing sidecar, flipped sidecar or payload
        byte (:class:`~repro.data.ingest.idx.ChecksumError`), and
        layout drift (``ValueError`` naming the drifted leaf)."""
        path = self.path_for(version)
        if not path.is_file():
            raise RegistryError(
                f"version {version} is not in the registry {self.root} "
                f"(have {self.versions()})")
        side = idx.checksum_path(path)
        if not side.is_file():
            raise RegistryError(
                f"{path} has no .sha256 sidecar — the registry never "
                f"places a version without one, so this file did not go "
                f"through publish(); refusing to serve it")
        idx.verify_bytes(path, path.read_bytes())
        return checkpointing.restore(path, like)
