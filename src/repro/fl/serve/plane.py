"""The serving plane: client id → personalized model → prediction, in
mixed-cluster batches.

A :class:`ServingPlane` holds exactly one *active* model version — an
immutable :class:`ActiveModel` snapshot of (version, engine state)
pulled from the :class:`~repro.fl.serve.registry.ModelRegistry` — and
answers batched requests over heterogeneous clients:

**Resolution.**  Each requested client id resolves to the row that
client would be evaluated with offline (the serving-parity pin):

* resident checkpoints carry the full population in
  ``state.client_state`` — each row already *is* the cluster-resolved
  personalized model, because training folded the assigned slot row in
  at every ``apply_broadcast``;
* with an mmap :class:`~repro.fl.store.client_store.ClientStore`
  attached, spilled rows are gathered (digest-verified) as the
  personalized model, and never-sampled clients fall back to the
  store's deterministic per-client init — byte-for-byte what the
  engine's own population eval resolves for them.  The per-batch
  personalized/fallback split is reported through telemetry.

**Inference.**  The whole batch — R requests against up to R distinct
models — runs as ONE call into ``strategy.predict_batched`` (each
request its own lane), which on the ``tm_backend="pallas"`` path is a
single ``fused_votes_batched`` kernel launch for the entire
mixed-cluster batch.  Duplicate client ids share one resolved row.

**Warm swap.**  ``refresh()`` pulls a newer registry version (fully
verifying it) and then swaps the active snapshot with one reference
assignment.  ``predict`` reads that snapshot exactly once, at entry —
a version landing mid-request cannot mix into it: the in-flight batch
is served entirely by the old version, the next batch entirely by the
new (the serve tests race this on purpose via ``resolve_hook``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import numpy as np

from repro.fl.serve.registry import ModelRegistry, RegistryError
from repro.fl.serve.telemetry import NULL_SERVE


class ActiveModel(NamedTuple):
    """One immutable serving snapshot: a version and its verified state."""

    version: int
    state: Any          # EngineState pulled from the registry


class ServingPlane:
    """Personalized inference over one trained population.

    ``like`` is a fresh ``engine.init(key)`` state — the structure
    template every registry pull decodes into (layout drift between a
    published checkpoint and the serving configuration is refused, not
    coerced).  ``store`` attaches the training run's mmap
    ``ClientStore`` (keyed the same ``k_init``); without it the active
    checkpoint must carry a resident population.  ``resolve_hook``, if
    given, runs inside ``predict`` right after the active snapshot is
    taken — a test seam for racing warm swaps against in-flight
    requests."""

    def __init__(self, strategy, registry: ModelRegistry, like, *,
                 store=None, telemetry=None,
                 resolve_hook: Callable[["ServingPlane"], None] | None
                 = None):
        self.strategy = strategy
        self.registry = registry
        self.store = store
        self.obs = telemetry if telemetry is not None else NULL_SERVE
        self._like = like
        self._resolve_hook = resolve_hook
        self._active: ActiveModel | None = None
        self.last_served_version: int | None = None

    # -- versions --------------------------------------------------------

    @property
    def active_version(self) -> int | None:
        a = self._active
        return a.version if a is not None else None

    def refresh(self) -> bool:
        """Activate the newest registry version if it supersedes the
        active one.  Pull-verify first, swap last (one reference
        assignment), so a request observing the plane mid-refresh sees
        either the old snapshot or the new one, never a blend.  Returns
        True iff a swap happened."""
        newest = self.registry.latest()
        cur = self._active
        if newest is None or (cur is not None and newest <= cur.version):
            return False
        state = self.registry.pull(newest, self._like)
        self._active = ActiveModel(newest, state)
        self.obs.swap_event(cur.version if cur is not None else None,
                            newest)
        return True

    # -- inference -------------------------------------------------------

    def _resolve_rows(self, state, uniq: np.ndarray):
        """Stacked per-client rows for the unique requested ids, plus
        the personalized mask (False = deterministic-init fallback)."""
        if self.store is not None:
            rows = self.store.gather(uniq)["cs"]
            return rows, self.store.written_mask(uniq)
        cs = state.client_state
        n = jax.tree_util.tree_leaves(cs)[0].shape[0]
        if n == 0:
            raise RegistryError(
                "the active checkpoint carries no resident population "
                "(it was written by the mmap engine) — attach the "
                "training run's ClientStore to serve personalized rows")
        if uniq.size and int(uniq.max()) >= n:
            raise RegistryError(
                f"client id {int(uniq.max())} is outside the trained "
                f"population [0, {n})")
        idx = np.asarray(uniq)
        rows = jax.tree_util.tree_map(lambda a: a[idx], cs)
        return rows, np.ones((uniq.size,), bool)

    def predict(self, client_ids, x) -> np.ndarray:
        """Predictions for ``x[i]`` under ``client_ids[i]``'s model.

        ``client_ids`` is (R,) int, ``x`` is (R, n_features); returns
        (R,) int32.  The active snapshot is read once, at entry — the
        whole batch is served by that version no matter what lands in
        the registry meanwhile."""
        active = self._active
        if active is None:
            raise RegistryError(
                "the serving plane has no active model — publish a "
                "checkpoint and call refresh() first")
        if self._resolve_hook is not None:
            self._resolve_hook(self)
        ids = np.asarray(client_ids, np.int64).reshape(-1)
        x = np.asarray(x)
        if x.shape[0] != ids.size:
            raise ValueError(
                f"batch mismatch: {ids.size} client ids, {x.shape[0]} "
                f"feature rows")
        with self.obs.span("serve/resolve"):
            uniq, inv = np.unique(ids, return_inverse=True)
            rows_u, written = self._resolve_rows(active.state, uniq)
            # lane per request: duplicates share the resolved row
            rows = jax.tree_util.tree_map(lambda a: a[inv], rows_u)
        with self.obs.span("serve/predict"):
            preds = self.strategy.predict_batched(rows, x[:, None, :])
            self.obs.fence(preds)
        preds = np.asarray(preds)[:, 0].astype(np.int32)
        personalized = int(np.asarray(written)[inv].sum())
        self.last_served_version = active.version
        self.obs.batch_event(version=active.version, batch=int(ids.size),
                             unique_clients=int(uniq.size),
                             personalized=personalized,
                             fallback=int(ids.size) - personalized)
        return preds
