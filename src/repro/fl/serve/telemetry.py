"""Serve-side observability: request spans and registry events as JSONL.

Same read-only contract as the training obs plane
(:mod:`repro.fl.obs`): a :class:`ServeTelemetry` is a
:class:`~repro.fl.obs.tracer.PhaseTracer` (the plane wraps resolve /
gather / predict in ``span(...)`` with ``fence`` on the device output)
plus an event sink appending one JSON object per line to
``serve_events.jsonl`` in the run directory:

* ``{"event": "batch", ...}``   — one per served request batch: size,
  active version, wall latency, personalized-vs-fallback row counts,
  and the batch's phase spans.
* ``{"event": "swap", ...}``    — one per atomic warm swap (old and new
  versions; old is None for the first activation).
* ``{"event": "publish", ...}`` — one per checkpoint published into the
  registry by the driver.

Nothing the telemetry computes flows back into resolution or
inference — serving with :data:`NULL_SERVE` (the default) is
bit-identical to serving instrumented, exactly the training plane's
neutrality invariant.
"""
from __future__ import annotations

import pathlib

from repro.fl.obs import events
from repro.fl.obs.tracer import NullTracer, PhaseTracer

EVENTS_NAME = "serve_events.jsonl"


class NullServeTelemetry(NullTracer):
    """Serving uninstrumented: every hook is a no-op."""

    def batch_event(self, **fields) -> None:
        pass

    def swap_event(self, old: int | None, new: int) -> None:
        pass

    def publish_event(self, version: int, path) -> None:
        pass


NULL_SERVE = NullServeTelemetry()


class ServeTelemetry(PhaseTracer):
    """Span timing + JSONL event sink for one serving run."""

    def __init__(self, run_dir: str | pathlib.Path):
        super().__init__()
        self.run_dir = pathlib.Path(run_dir)
        self.run_dir.mkdir(parents=True, exist_ok=True)
        self.events_path = self.run_dir / EVENTS_NAME

    def _emit(self, event: dict) -> dict:
        return events.append_event(self.events_path, event)

    def batch_event(self, **fields) -> dict:
        """One served batch; pops the batch's accumulated spans and
        reports their sum as the batch's wall latency."""
        phases = self.take()
        return self._emit({"event": "batch", "phases": phases,
                           "latency_s": sum(phases.values()), **fields})

    def swap_event(self, old: int | None, new: int) -> dict:
        return self._emit({"event": "swap", "from_version": old,
                           "to_version": new})

    def publish_event(self, version: int, path) -> dict:
        return self._emit({"event": "publish", "version": version,
                           "path": str(path)})
