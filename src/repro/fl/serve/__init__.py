"""Federated serving plane: personalized inference as a service.

Training ends with a *population* of personalized models — TPFL's whole
point is that each client leaves with cluster-specific TM weights — and
this package is the subsystem that serves them: a versioned
:class:`~repro.fl.serve.registry.ModelRegistry` of checkpoint artifacts
(sha256 verify-then-place, atomic publish, loud rejection of corrupted
or layout-drifted files) under a
:class:`~repro.fl.serve.plane.ServingPlane` that resolves client id →
personalized row (mmap :class:`~repro.fl.store.client_store.ClientStore`
when present, cluster-slot checkpoint rows otherwise) and answers
batched inference requests over heterogeneous clients — one compiled
batched-votes launch per mixed-cluster batch on the
``tm_backend="pallas"`` path.  ``repro.launch.fed_serve`` is the
runnable driver; ``docs/serving.md`` documents the protocol.
"""
from repro.fl.serve.registry import ModelRegistry, RegistryError
from repro.fl.serve.plane import ActiveModel, ServingPlane
from repro.fl.serve.telemetry import NULL_SERVE, ServeTelemetry

__all__ = ["ActiveModel", "ModelRegistry", "NULL_SERVE", "RegistryError",
           "ServeTelemetry", "ServingPlane"]
