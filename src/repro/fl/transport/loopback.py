"""LoopbackTransport: the in-memory reference transport.

Every message still becomes real framed bytes — ``send`` packs the
frame, hands the *bytes* to the worker's decode path, and the worker's
responses queue as framed bytes for ``recv`` — so the whole wire stack
(framing, message pack/unpack, codec frames) is exercised exactly as
the socket transport exercises it, minus the kernel socket.  That is
what lets the conformance suite pin a loopback run bit-identical to the
in-process engine on the identity wire: same math, same bytes, no
process boundary to make timing nondeterministic.

Fault injection: a :class:`~repro.fl.transport.faults.FaultPlan`
``disconnect`` entry makes the n-th ``recv`` from a rank raise
:class:`~repro.fl.transport.framing.DisconnectError` once, with the
queued frame left intact for the retry — deterministic food for the
server's retry/backoff loop.
"""
from __future__ import annotations

import collections

from repro.fl.transport import framing
from repro.fl.transport.faults import FaultPlan


class LoopbackTransport:
    """In-memory transport over a list of in-process ClientWorkers."""

    def __init__(self, workers, faults: FaultPlan | None = None):
        self.workers = {w.rank: w for w in workers}
        self.ranks = sorted(self.workers)
        self.faults = faults or FaultPlan()
        self._inbox = {r: collections.deque() for r in self.ranks}
        self._recv_count = {r: 0 for r in self.ranks}

    def send(self, rank: int, kind: int, payload: bytes) -> int:
        """Frame the message, run it through the worker, queue the
        worker's framed responses.  Returns framed bytes sent."""
        frame = framing.pack_frame(kind, payload)
        in_kind, in_payload, consumed = framing.decode_frame(frame)
        if consumed != len(frame):
            raise framing.WireError(
                f"loopback frame has {len(frame) - consumed} stray bytes")
        for out_kind, out_payload in self.workers[rank].handle(
                in_kind, in_payload):
            self._inbox[rank].append(
                framing.pack_frame(out_kind, out_payload))
        return len(frame)

    def recv(self, rank: int, timeout: float | None = None
             ) -> tuple[int, bytes, int]:
        """Pop the next queued frame → (kind, payload, framed_bytes).
        ``timeout`` is accepted for interface parity and ignored — the
        loopback queue is synchronous."""
        nth = self._recv_count[rank]
        self._recv_count[rank] += 1
        if self.faults.disconnects_at(rank, nth):
            raise framing.DisconnectError(
                f"injected disconnect: recv #{nth} from worker {rank}")
        if not self._inbox[rank]:
            raise framing.WireError(
                f"protocol error: no frame pending from worker {rank}")
        frame = self._inbox[rank].popleft()
        kind, payload, _ = framing.decode_frame(frame)
        return kind, payload, len(frame)

    def reconnect(self, rank: int) -> None:
        """Nothing to re-establish in memory; the retry just re-reads."""

    def close(self) -> None:
        pass
