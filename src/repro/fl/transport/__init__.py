"""Real-transport federated runtime (docs/transport.md).

The federated round over an actual wire: a server process
(:class:`TransportEngine`) exchanging length-prefixed frames with M
client-worker peers, each owning a contiguous block of the population.
:class:`LoopbackTransport` runs the workers in-process over in-memory
queues — the reference the conformance suite pins bit-identical to the
in-process engine on the identity wire — and :class:`SocketTransport`
runs them as real subprocesses over local TCP, where staleness and
dropout are what actually happened on the wire, not an injected
schedule.
"""
from repro.fl.transport.faults import FaultPlan, RetryPolicy
from repro.fl.transport.framing import (MAX_FRAME, BadMagicError,
                                        DisconnectError, FrameTooLargeError,
                                        TruncatedFrameError, WireError,
                                        decode_frame, pack_frame, read_frame)
from repro.fl.transport.loopback import LoopbackTransport
from repro.fl.transport.messages import MsgKind
from repro.fl.transport.runner import TransportEngine
from repro.fl.transport.socket_transport import SocketTransport
from repro.fl.transport.worker import ClientWorker, block_range

__all__ = [
    "FaultPlan", "RetryPolicy",
    "WireError", "BadMagicError", "FrameTooLargeError",
    "TruncatedFrameError", "DisconnectError",
    "MAX_FRAME", "pack_frame", "read_frame", "decode_frame",
    "MsgKind", "LoopbackTransport", "SocketTransport",
    "ClientWorker", "block_range", "TransportEngine",
]
