"""SocketTransport: real multi-process federated runs over local TCP.

The server listens on an ephemeral ``127.0.0.1`` port and launches M
worker subprocesses (``python -m repro.fl.transport.worker``), each of
which rebuilds its identical slice of the scenario from a JSON spec,
connects back, and introduces itself with a HELLO frame.  From then on
every round's WORK/UPLOAD/DOWNLINK/EVAL exchange crosses a real kernel
socket as length-prefixed frames — dropout is a missing upload entry,
staleness is a frame that arrives rounds after it was produced, and the
wire gauges count bytes that actually moved between processes.

Failure behaviour is loud: a worker that dies during launch surfaces
its exit code; a ``recv`` past the policy timeout raises
``TimeoutError`` for the runner's retry loop; a peer closing mid-frame
raises the typed framing errors.
"""
from __future__ import annotations

import json
import os
import pathlib
import socket
import subprocess
import sys
import tempfile
import time

from repro.fl.transport import framing
from repro.fl.transport.messages import Hello, MsgKind


def _recv_exact(conn: socket.socket):
    def inner(n: int) -> bytes:
        chunks, remaining = [], n
        while remaining:
            try:
                chunk = conn.recv(remaining)
            except socket.timeout:
                raise TimeoutError(
                    f"socket recv timed out with {remaining} of {n} B "
                    "outstanding") from None
            if not chunk:
                break
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)
    return inner


class SocketTransport:
    """Server-side endpoint: one TCP connection per worker rank."""

    def __init__(self, conns: dict, procs: list, spec_path: str):
        self.conns = conns
        self.ranks = sorted(conns)
        self.procs = procs
        self.spec_path = spec_path

    # -- launch --------------------------------------------------------------

    @classmethod
    def launch(cls, spec: dict, workers: int,
               connect_timeout: float = 600.0) -> "SocketTransport":
        """Write the spec, start M workers, collect their HELLOs.

        ``connect_timeout`` is generous by default: each worker pays
        the full jax-import + scenario-rebuild cost before it dials in.
        """
        srv = socket.create_server(("127.0.0.1", 0))
        port = srv.getsockname()[1]
        fd, spec_path = tempfile.mkstemp(prefix="fl_transport_",
                                         suffix=".json")
        with os.fdopen(fd, "w") as fh:
            json.dump(spec, fh)
        env = dict(os.environ)
        src_root = str(pathlib.Path(__file__).resolve().parents[3])
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src_root, env.get("PYTHONPATH")) if p)
        # -c instead of -m: the package __init__ imports .worker, so
        # runpy would warn about re-executing an already-imported module
        entry = "from repro.fl.transport.worker import main; main()"
        procs = [
            subprocess.Popen(
                [sys.executable, "-c", entry,
                 "--spec", spec_path, "--rank", str(rank),
                 "--port", str(port)],
                env=env)
            for rank in range(workers)]
        conns: dict[int, socket.socket] = {}
        deadline = time.monotonic() + connect_timeout
        srv.settimeout(1.0)
        try:
            while len(conns) < workers:
                for p in procs:
                    code = p.poll()
                    if code is not None and code != 0:
                        raise RuntimeError(
                            f"transport worker exited with code {code} "
                            "before connecting — see its stderr above")
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"only {len(conns)} of {workers} workers "
                        f"connected within {connect_timeout:.0f}s")
                try:
                    conn, _ = srv.accept()
                except socket.timeout:
                    continue
                conn.settimeout(connect_timeout)
                kind, payload = framing.read_frame(_recv_exact(conn))
                if kind != MsgKind.HELLO:
                    raise framing.WireError(
                        f"expected HELLO from connecting worker, got "
                        f"message kind {kind}")
                hello = Hello.unpack(payload)
                if hello.rank in conns:
                    raise framing.WireError(
                        f"duplicate HELLO for worker rank {hello.rank}")
                conns[hello.rank] = conn
        except BaseException:
            for p in procs:
                p.kill()
            for c in conns.values():
                c.close()
            srv.close()
            raise
        srv.close()
        return cls(conns, procs, spec_path)

    # -- wire ----------------------------------------------------------------

    def send(self, rank: int, kind: int, payload: bytes) -> int:
        frame = framing.pack_frame(kind, payload)
        self.conns[rank].sendall(frame)
        return len(frame)

    def recv(self, rank: int, timeout: float | None = None
             ) -> tuple[int, bytes, int]:
        conn = self.conns[rank]
        conn.settimeout(timeout)
        kind, payload = framing.read_frame(_recv_exact(conn))
        return kind, payload, framing.HEADER.size + len(payload)

    def reconnect(self, rank: int) -> None:
        """A dead TCP peer is a dead subprocess — nothing to redial;
        the retry loop will re-raise after its attempts run out."""

    def close(self) -> None:
        for conn in self.conns.values():
            try:
                conn.close()
            except OSError:
                pass
        for p in self.procs:
            try:
                p.wait(timeout=30)
            except subprocess.TimeoutExpired:
                p.kill()
        try:
            os.unlink(self.spec_path)
        except OSError:
            pass
