"""Length-prefixed message framing for the federated transport.

Every message on the wire — loopback queue or real socket — is one frame:

    magic  <u2>   0x7F4C ("FL")
    kind   <u1>   message kind (see messages.MsgKind)
    length <u4>   payload byte count
    payload       `length` bytes, opaque to this layer

Little-endian throughout, matching the wire codec.  The framing layer is
deliberately loud: a bad magic, an oversized length prefix, or a stream
that ends mid-frame each raise a *typed* error instead of yielding a
silently truncated payload — the robustness tests pin each failure mode.
"""
from __future__ import annotations

import struct

MAGIC = 0x7F4C
HEADER = struct.Struct("<HBI")          # magic, kind, payload length
MAX_FRAME = 1 << 30                     # 1 GiB: anything larger is a bug


class WireError(Exception):
    """Base class for transport wire faults."""


class BadMagicError(WireError):
    """Frame header does not start with the FL magic (corrupted length
    prefix or desynchronized stream)."""


class FrameTooLargeError(WireError):
    """Length prefix exceeds MAX_FRAME — a corrupted header, not a real
    payload."""


class TruncatedFrameError(WireError):
    """Stream ended inside a frame (header or payload cut short)."""


class DisconnectError(WireError):
    """Peer closed the connection at a frame boundary when more frames
    were expected."""


def pack_frame(kind: int, payload: bytes) -> bytes:
    """One message → header + payload bytes."""
    if len(payload) > MAX_FRAME:
        raise FrameTooLargeError(
            f"refusing to send {len(payload)} B payload "
            f"(MAX_FRAME = {MAX_FRAME} B)")
    return HEADER.pack(MAGIC, kind, len(payload)) + payload


def unpack_header(buf: bytes) -> tuple[int, int]:
    """Header bytes → (kind, payload_length); loud on every corruption."""
    if len(buf) < HEADER.size:
        raise TruncatedFrameError(
            f"stream ended inside frame header "
            f"({len(buf)} of {HEADER.size} B)")
    magic, kind, length = HEADER.unpack_from(buf)
    if magic != MAGIC:
        raise BadMagicError(
            f"bad frame magic 0x{magic:04X} (expected 0x{MAGIC:04X}); "
            "corrupted length prefix or desynchronized stream")
    if length > MAX_FRAME:
        raise FrameTooLargeError(
            f"frame length prefix {length} B exceeds "
            f"MAX_FRAME = {MAX_FRAME} B; corrupted header")
    return kind, length


def read_frame(recv_exact) -> tuple[int, bytes]:
    """Read one frame via ``recv_exact(n) -> bytes`` (may return short
    only at EOF).  Returns (kind, payload).

    Raises :class:`DisconnectError` on EOF at a frame boundary and
    :class:`TruncatedFrameError` on EOF inside a frame.
    """
    head = recv_exact(HEADER.size)
    if not head:
        raise DisconnectError("peer closed connection between frames")
    kind, length = unpack_header(head)
    payload = recv_exact(length)
    if len(payload) != length:
        raise TruncatedFrameError(
            f"stream ended inside payload "
            f"({len(payload)} of {length} B)")
    return kind, payload


def decode_frame(buf: bytes) -> tuple[int, bytes, int]:
    """Decode one frame from a byte buffer → (kind, payload, consumed).
    Loud on truncation, like the stream path."""
    kind, length = unpack_header(buf)
    end = HEADER.size + length
    if len(buf) < end:
        raise TruncatedFrameError(
            f"buffer ended inside payload "
            f"({len(buf) - HEADER.size} of {length} B)")
    return kind, buf[HEADER.size:end], end
