"""Typed message payloads for the federated transport.

One message = one frame (``framing.py``); this module owns what lives
*inside* the payload.  Everything is little-endian and explicitly
sized — the same buffer parses identically on both ends of a socket,
and a truncated payload raises loudly through :class:`Reader`.

The round protocol (server ↔ each worker, per round):

    server → worker   WORK      round, encoded server rows, the worker's
                                sampled clients (id, rng key, active,
                                scheduled staleness)
    worker → server   UPLOAD    the round's codec frames per client —
                                the *actual* uplink bytes, tagged with
                                source round for observed staleness
    server → worker   DOWNLINK  post-aggregate rows + per-client
                                arrive/applied routing
    worker → server   EVAL      the worker block's per-client accuracy

plus HELLO (worker handshake), SHUTDOWN (server → worker, run over) and
BYE (worker's acknowledgement).  The uplink codec frame itself (slot id
+ encoded vector, ``fl/runtime/codec.py``) is carried opaquely: the
engine's byte meter counts exactly those frame bytes, while the wire
gauges (``wire_tx/wire_rx``) count whole framed messages — envelopes,
headers and all.
"""
from __future__ import annotations

import dataclasses
import enum
import struct

import numpy as np

from repro.fl.transport.framing import WireError


class MsgKind(enum.IntEnum):
    HELLO = 1
    WORK = 2
    UPLOAD = 3
    DOWNLINK = 4
    EVAL = 5
    SHUTDOWN = 6
    BYE = 7


_U1 = struct.Struct("<B")
_U4 = struct.Struct("<I")
_I4 = struct.Struct("<i")
_F4 = struct.Struct("<f")


class Writer:
    """Append-only little-endian payload builder."""

    def __init__(self):
        self._parts: list[bytes] = []

    def u1(self, v: int):
        self._parts.append(_U1.pack(v))

    def u4(self, v: int):
        self._parts.append(_U4.pack(v))

    def i4(self, v: int):
        self._parts.append(_I4.pack(v))

    def f4(self, v: float):
        self._parts.append(_F4.pack(v))

    def blob(self, b: bytes):
        """Length-prefixed byte string (u4 length + raw bytes)."""
        self._parts.append(_U4.pack(len(b)))
        self._parts.append(bytes(b))

    def getvalue(self) -> bytes:
        return b"".join(self._parts)


class Reader:
    """Sequential little-endian payload parser; loud on truncation."""

    def __init__(self, buf: bytes):
        self.buf = buf
        self.off = 0

    def _take(self, n: int) -> bytes:
        end = self.off + n
        if end > len(self.buf):
            raise WireError(
                f"message payload truncated: wanted {n} B at offset "
                f"{self.off}, have {len(self.buf)} B total")
        out = self.buf[self.off:end]
        self.off = end
        return out

    def u1(self) -> int:
        return _U1.unpack(self._take(1))[0]

    def u4(self) -> int:
        return _U4.unpack(self._take(4))[0]

    def i4(self) -> int:
        return _I4.unpack(self._take(4))[0]

    def f4(self) -> float:
        return _F4.unpack(self._take(4))[0]

    def blob(self) -> bytes:
        return self._take(self.u4())

    def done(self):
        if self.off != len(self.buf):
            raise WireError(
                f"message payload has {len(self.buf) - self.off} "
                f"trailing bytes past the parsed structure")


# -- handshake ---------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hello:
    rank: int
    lo: int          # the worker's client block is [lo, hi)
    hi: int

    def pack(self) -> bytes:
        w = Writer()
        w.u4(self.rank), w.u4(self.lo), w.u4(self.hi)
        return w.getvalue()

    @classmethod
    def unpack(cls, buf: bytes) -> "Hello":
        r = Reader(buf)
        out = cls(rank=r.u4(), lo=r.u4(), hi=r.u4())
        r.done()
        return out


# -- server → worker: the round's work order ---------------------------------

@dataclasses.dataclass(frozen=True)
class WorkClient:
    gidx: int        # global client id
    key: tuple       # raw PRNGKey words (uint32, uint32)
    active: bool     # survived the dropout draw
    staleness: int   # scheduled upload delay in rounds


@dataclasses.dataclass(frozen=True)
class Work:
    round_idx: int
    dim: int                      # server row width d
    rows: tuple                   # n_slots dense codec frames (bytes)
    clients: tuple                # WorkClient — this worker's sampled ids

    def pack(self) -> bytes:
        w = Writer()
        w.u4(self.round_idx), w.u4(self.dim), w.u4(len(self.rows))
        for row in self.rows:
            w.blob(row)
        w.u4(len(self.clients))
        for c in self.clients:
            w.u4(c.gidx)
            w.u4(int(c.key[0])), w.u4(int(c.key[1]))
            w.u1(1 if c.active else 0)
            w.u4(c.staleness)
        return w.getvalue()

    @classmethod
    def unpack(cls, buf: bytes) -> "Work":
        r = Reader(buf)
        round_idx, dim, n_rows = r.u4(), r.u4(), r.u4()
        rows = tuple(r.blob() for _ in range(n_rows))
        clients = tuple(
            WorkClient(gidx=r.u4(), key=(r.u4(), r.u4()),
                       active=bool(r.u1()), staleness=r.u4())
            for _ in range(r.u4()))
        r.done()
        return cls(round_idx, dim, rows, clients)


# -- worker → server: real uplink frames -------------------------------------

@dataclasses.dataclass(frozen=True)
class UploadEntry:
    gidx: int
    src_round: int   # round the upload was produced (arrival − src =
    #                  observed staleness)
    staleness: int   # scheduled delay tag (sync barrier accounting)
    frames: tuple    # (j_idx, slot, frame_bytes) per shared slot; the
    #                  frame is the codec's slot-id+payload unit — the
    #                  byte-metered quantity; j_idx is envelope


@dataclasses.dataclass(frozen=True)
class Upload:
    round_idx: int   # arrival round (the WORK round being answered)
    entries: tuple

    def pack(self) -> bytes:
        w = Writer()
        w.u4(self.round_idx), w.u4(len(self.entries))
        for e in self.entries:
            w.u4(e.gidx), w.u4(e.src_round), w.u4(e.staleness)
            w.u4(len(e.frames))
            for j_idx, slot, frame in e.frames:
                w.u1(j_idx), w.i4(slot)
                w.blob(frame)
        return w.getvalue()

    @classmethod
    def unpack(cls, buf: bytes) -> "Upload":
        r = Reader(buf)
        round_idx, n = r.u4(), r.u4()
        entries = []
        for _ in range(n):
            gidx, src, stale = r.u4(), r.u4(), r.u4()
            frames = tuple((r.u1(), r.i4(), r.blob())
                           for _ in range(r.u4()))
            entries.append(UploadEntry(gidx, src, stale, frames))
        r.done()
        return cls(round_idx, tuple(entries))


# -- server → worker: broadcast + routing ------------------------------------

@dataclasses.dataclass(frozen=True)
class DownClient:
    gidx: int
    arrive: bool     # applies the broadcast (sync: made the barrier)
    applied: tuple   # j_slots slot ids (−1 = nothing applied)


@dataclasses.dataclass(frozen=True)
class Downlink:
    round_idx: int
    dim: int
    rows: tuple                   # post-aggregate rows, dense frames
    clients: tuple                # DownClient per sampled block client

    def pack(self) -> bytes:
        w = Writer()
        w.u4(self.round_idx), w.u4(self.dim), w.u4(len(self.rows))
        for row in self.rows:
            w.blob(row)
        j = len(self.clients[0].applied) if self.clients else 0
        w.u4(j), w.u4(len(self.clients))
        for c in self.clients:
            w.u4(c.gidx), w.u1(1 if c.arrive else 0)
            for s in c.applied:
                w.i4(int(s))
        return w.getvalue()

    @classmethod
    def unpack(cls, buf: bytes) -> "Downlink":
        r = Reader(buf)
        round_idx, dim, n_rows = r.u4(), r.u4(), r.u4()
        rows = tuple(r.blob() for _ in range(n_rows))
        j, n = r.u4(), r.u4()
        clients = tuple(
            DownClient(gidx=r.u4(), arrive=bool(r.u1()),
                       applied=tuple(r.i4() for _ in range(j)))
            for _ in range(n))
        r.done()
        return cls(round_idx, dim, rows, clients)


# -- worker → server: block evaluation ---------------------------------------

@dataclasses.dataclass(frozen=True)
class Eval:
    round_idx: int
    acc: np.ndarray               # (block_size,) float32

    def pack(self) -> bytes:
        w = Writer()
        w.u4(self.round_idx)
        w.blob(np.asarray(self.acc, np.float32).tobytes())
        return w.getvalue()

    @classmethod
    def unpack(cls, buf: bytes) -> "Eval":
        r = Reader(buf)
        round_idx = r.u4()
        acc = np.frombuffer(r.blob(), np.float32)
        r.done()
        return cls(round_idx, acc)
