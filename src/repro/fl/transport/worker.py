"""Client-worker runtime: one process (or in-process loopback peer)
owning a contiguous block of the client population.

The worker is a message-driven state machine — ``handle(kind, payload)
→ [(kind, payload), ...]`` — with no transport knowledge of its own:
the socket main loop (:func:`run_socket_worker`) and the in-memory
loopback both push the same framed bytes through it, which is what
makes the loopback run a faithful reference for the multi-process one.

Per round the worker:

1. ``WORK``  — decodes the broadcast server rows off the dense wire
   codec, trains its block's sampled clients
   (:class:`~repro.fl.runtime.executors.InProcessExecutor` — per-client
   vmap lanes are independent, so a block vmap equals the engine's
   full-population vmap lane for lane), encodes each surviving upload
   into the *actual* codec frames (sparse refs and error-feedback
   residuals are worker-owned state: the client side of the wire), and
   answers ``UPLOAD``.  Under async aggregation a straggling client's
   frames are held back and flushed with a later round's UPLOAD, tagged
   with their source round — observed staleness on the server is real
   arrival lag, not an injected schedule.
2. ``DOWNLINK`` — decodes the post-aggregate rows, applies them per the
   server's arrive/applied routing, advances its broadcast references,
   evaluates its whole block, and answers ``EVAL``.

Run as a subprocess for ``transport="socket"``:

    python -m repro.fl.transport.worker --spec spec.json --rank R \
        --host 127.0.0.1 --port P

The spec (written by the socket transport) rebuilds the *identical*
scenario via ``repro.launch.fed_train.build_scenario`` and the identical
initial population via ``Engine.init`` on the shared init key — worker
block state is a slice of exactly the state the server holds.
"""
from __future__ import annotations

import argparse
import json
import socket

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.runtime.codec import CodecConfig, decode, ef_encode, encode
from repro.fl.runtime.engine import Engine, RuntimeConfig
from repro.fl.runtime.executors import InProcessExecutor
from repro.fl.runtime.scheduler import SchedulerConfig
from repro.fl.transport import framing
from repro.fl.transport.faults import FaultPlan
from repro.fl.transport.messages import (Downlink, Eval, Hello, MsgKind,
                                         Upload, UploadEntry, Work)


def block_range(n: int, workers: int, rank: int) -> tuple[int, int]:
    """Contiguous client block [lo, hi) owned by ``rank`` of ``workers``."""
    return rank * n // workers, (rank + 1) * n // workers


def runtime_config_to_dict(cfg: RuntimeConfig) -> dict:
    import dataclasses
    return dataclasses.asdict(cfg)


def runtime_config_from_dict(d: dict) -> RuntimeConfig:
    d = dict(d)
    d["scheduler"] = SchedulerConfig(**d["scheduler"])
    d["codec"] = CodecConfig(**d["codec"])
    return RuntimeConfig(**d)


class ClientWorker:
    """The message-driven client-side half of the round protocol."""

    def __init__(self, rank: int, lo: int, hi: int, strategy,
                 cfg: RuntimeConfig, block_cs, block_data,
                 ref_vecs=None, ref_round=None, ef=None,
                 faults: FaultPlan | None = None):
        self.rank, self.lo, self.hi = rank, lo, hi
        self.strategy = strategy
        self.cfg = cfg
        self.executor = InProcessExecutor()
        self.block_cs = block_cs
        self.block_data = block_data
        # client-side wire state, numpy for in-place per-frame updates
        self.ref_vecs = (None if ref_vecs is None
                         else np.array(np.asarray(ref_vecs, np.float32)))
        self.ref_round = (None if ref_round is None
                          else np.array(np.asarray(ref_round, np.int32)))
        self.ef = None if ef is None \
            else np.array(np.asarray(ef, np.float32))
        self.faults = faults or FaultPlan()
        self._dense = CodecConfig(cfg.codec.name, sparse=False)
        self._sync = cfg.aggregation == "sync"
        # async: encoded uploads held until their flush round arrives
        self._held: list[tuple[int, UploadEntry]] = []  # (flush_round, e)
        self._ctx = None        # in-flight round: set by WORK, used by
        #                         DOWNLINK (train → apply is split by
        #                         the server's aggregation in between)

    # -- dispatch ------------------------------------------------------------

    def handle(self, kind: int, payload: bytes) -> list[tuple[int, bytes]]:
        if kind == MsgKind.WORK:
            return [(MsgKind.UPLOAD, self._work(Work.unpack(payload)))]
        if kind == MsgKind.DOWNLINK:
            return [(MsgKind.EVAL,
                     self._downlink(Downlink.unpack(payload)))]
        if kind == MsgKind.SHUTDOWN:
            return [(MsgKind.BYE, b"")]
        raise framing.WireError(
            f"worker {self.rank}: unexpected message kind {kind}")

    # -- round halves --------------------------------------------------------

    def _decode_rows(self, rows, dim) -> jnp.ndarray:
        out = np.zeros((len(rows), dim), np.float32)
        for s, frame in enumerate(rows):
            out[s] = decode(frame, dim, self._dense)
        return jnp.asarray(out)

    def _work(self, msg: Work) -> bytes:
        r = msg.round_idx
        tx_server = self._decode_rows(msg.rows, msg.dim)
        local = np.asarray([c.gidx - self.lo for c in msg.clients],
                           np.int32)
        keys = jnp.asarray(
            np.asarray([[c.key[0], c.key[1]] for c in msg.clients],
                       np.uint32))
        jloc = jnp.asarray(local)
        sub_cs = jax.tree.map(lambda a: a[jloc], self.block_cs)
        sub_data = jax.tree.map(lambda a: a[jloc], self.block_data)
        new_sub, vecs, slots = self.executor.train(
            self.strategy, sub_cs, tx_server, sub_data, keys)

        codec_cfg = self.cfg.codec
        np_vecs = np.asarray(vecs, np.float32)
        np_slots = np.asarray(slots)
        entries = []
        for c, wc in enumerate(msg.clients):
            if not wc.active or self.faults.dropped(r, wc.gidx):
                continue                 # upload lost — nothing on the wire
            b = int(local[c])
            frames = []
            for j in range(np_vecs.shape[1]):
                s = int(np_slots[c, j])
                if s < 0:
                    continue             # nothing shared in this slot
                ref = (self.ref_vecs[b, s]
                       if codec_cfg.sparse else None)
                if self.ef is not None:
                    frame, self.ef[b, s] = ef_encode(
                        np_vecs[c, j], codec_cfg, self.ef[b, s], ref=ref)
                else:
                    frame = encode(np_vecs[c, j], codec_cfg, ref=ref)
                frames.append((j, s, frame))
            delay = wc.staleness + self.faults.delay_for(r, wc.gidx)
            entry = UploadEntry(gidx=wc.gidx, src_round=r,
                                staleness=delay, frames=tuple(frames))
            if self._sync or delay == 0:
                # sync: late frames were still *sent* this round — the
                # server meters them and lets the barrier discard them
                entries.append(entry)
            else:
                self._held.append((r + delay, entry))
        if not self._sync:
            flushed = [e for fr, e in self._held if fr <= r]
            self._held = [(fr, e) for fr, e in self._held if fr > r]
            entries.extend(flushed)

        self._ctx = (r, jloc, sub_cs, new_sub, msg.clients)
        return Upload(round_idx=r, entries=tuple(entries)).pack()

    def _downlink(self, msg: Downlink) -> bytes:
        if self._ctx is None or self._ctx[0] != msg.round_idx:
            raise framing.WireError(
                f"worker {self.rank}: DOWNLINK for round {msg.round_idx} "
                f"without a matching WORK in flight")
        r, jloc, sub_cs, new_sub, work_clients = self._ctx
        self._ctx = None
        by_gidx = {c.gidx: c for c in msg.clients}
        ordered = [by_gidx[w.gidx] for w in work_clients]
        arrive = np.asarray([c.arrive for c in ordered], bool)
        applied = np.asarray([c.applied for c in ordered], np.int32)
        rx_server = self._decode_rows(msg.rows, msg.dim)
        merged = self.executor.apply_merge(
            self.strategy, new_sub, jnp.asarray(applied), rx_server,
            sub_cs, jnp.asarray(arrive))
        self.block_cs = jax.tree.map(
            lambda a, s: a.at[jloc].set(s), self.block_cs, merged)
        if self.cfg.codec.sparse:
            local = np.asarray(jloc)
            sub = self.ref_vecs[local].copy()
            sub_rounds = self.ref_round[local].copy()
            Engine._advance_ref_rows(sub, sub_rounds, arrive, applied,
                                     np.asarray(rx_server), r,
                                     self.strategy.downloads)
            self.ref_vecs[local] = sub
            self.ref_round[local] = sub_rounds
        acc = self.executor.evaluate(
            self.strategy, self.block_cs,
            self.block_data.x_test, self.block_data.y_test)
        return Eval(round_idx=r, acc=np.asarray(acc, np.float32)).pack()


# -- socket main loop --------------------------------------------------------

def _recv_exact(conn: socket.socket):
    def inner(n: int) -> bytes:
        chunks = []
        remaining = n
        while remaining:
            chunk = conn.recv(remaining)
            if not chunk:
                break                    # EOF — framing decides how loud
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)
    return inner


def run_socket_worker(worker: ClientWorker, host: str, port: int):
    """Connect to the transport server and serve rounds until SHUTDOWN."""
    with socket.create_connection((host, port)) as conn:
        conn.sendall(framing.pack_frame(
            MsgKind.HELLO,
            Hello(worker.rank, worker.lo, worker.hi).pack()))
        recv = _recv_exact(conn)
        while True:
            kind, payload = framing.read_frame(recv)
            for out_kind, out_payload in worker.handle(kind, payload):
                conn.sendall(framing.pack_frame(out_kind, out_payload))
            if kind == MsgKind.SHUTDOWN:
                return


def worker_from_spec(spec: dict, rank: int) -> ClientWorker:
    """Rebuild the worker's slice of the federated scenario from the
    socket transport's spec: same scenario builder, same init key →
    the block state is bit-identical to the server's rows."""
    from repro.launch.fed_train import build_scenario
    cfg = runtime_config_from_dict(spec["runtime"])
    _, data, _, _, strategy = build_scenario(**spec["scenario"])
    engine = Engine(strategy, data, cfg)
    key = jnp.asarray(np.asarray(spec["key"], np.uint32))
    k_init, _ = jax.random.split(key)
    state = engine.init(k_init)
    lo, hi = block_range(engine.n, cfg.workers, rank)
    sl = slice(lo, hi)
    block_cs = jax.tree.map(lambda a: a[sl], state.client_state)
    block_data = jax.tree.map(lambda a: a[sl], data)
    ref_vecs = state.ref_vecs[sl] if cfg.codec.sparse else None
    ref_round = state.ref_round[sl] if cfg.codec.sparse else None
    ef = state.ef_residual[sl] if cfg.codec.error_feedback else None
    faults = FaultPlan(**spec.get("faults", {}))
    return ClientWorker(rank, lo, hi, engine.strategy, cfg, block_cs,
                        block_data, ref_vecs=ref_vecs,
                        ref_round=ref_round, ef=ef, faults=faults)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Federated transport client worker (one block of "
                    "the client population, spoken to over the "
                    "length-prefixed wire)")
    ap.add_argument("--spec", required=True,
                    help="JSON scenario/runtime spec written by the "
                         "socket transport")
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    args = ap.parse_args(argv)
    with open(args.spec) as fh:
        spec = json.load(fh)
    worker = worker_from_spec(spec, args.rank)
    run_socket_worker(worker, args.host, args.port)


if __name__ == "__main__":
    main()
