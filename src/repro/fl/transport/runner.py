"""TransportEngine: the server side of the real-transport runtime.

Runs the same staged federated round as
:class:`repro.fl.runtime.engine.Engine` — schedule → broadcast →
client step → uplink codec → (assign) → aggregate → server_update →
downlink → eval — but with every client-side stage executed by worker
peers behind a wire: the broadcast rows go out as encoded frames inside
WORK messages, the uplink comes back as the workers' actual codec
frames inside UPLOAD messages, and the block evaluations return as EVAL
messages.  The server keeps the server-owned halves (scheduler,
assignment, aggregation, server state, sparse-ref tracking for decode)
and reuses the engine's own helpers for them, so the two
implementations cannot drift.

Conformance contract: with ``transport="loopback"`` and the identity
wire (dense float32), a run is **bit-identical** to the in-process
engine — same reports (every pre-transport field), same codec-metered
byte totals, same final state — pinned by ``tests/test_transport.py``.
The wire gauges (``wire_tx_bytes`` / ``wire_rx_bytes``) are additional:
they count framed bytes that actually crossed the transport, which the
in-process engine by definition has none of.

Async mode is *arrival-driven*: workers hold straggling uploads and
flush them in later rounds tagged with their source round; the server
buffers whatever actually arrives, weighted by the **observed** lag
(``discount ** (arrival − source)``), and records the observed
staleness summary in each round's report/event.  Arrival order (worker
rank-major) replaces the engine's cohort insertion order, so async
transport runs are semantically equivalent but not bit-pinned.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.runtime import executors
from repro.fl.runtime.codec import CodecConfig, decode, encode
from repro.fl.runtime.engine import Engine, EngineState, RoundReport
from repro.fl.runtime.scheduler import arrival_participation
from repro.fl.transport import framing
from repro.fl.transport.faults import FaultPlan, RetryPolicy
from repro.fl.transport.loopback import LoopbackTransport
from repro.fl.transport.messages import (DownClient, Downlink, Eval,
                                         MsgKind, Upload, Work, WorkClient)
from repro.fl.transport.socket_transport import SocketTransport
from repro.fl.transport.worker import ClientWorker, block_range


class TransportEngine:
    """Round orchestrator over a real transport (loopback or socket)."""

    def __init__(self, strategy, data, cfg, telemetry=None,
                 faults: FaultPlan | None = None,
                 retry: RetryPolicy | None = None,
                 spec: dict | None = None):
        if cfg.transport not in ("loopback", "socket"):
            raise ValueError(
                f"TransportEngine runs transport='loopback' | 'socket'; "
                f"transport={cfg.transport!r} is the in-process Engine")
        if cfg.transport == "socket" and spec is None:
            raise ValueError(
                "transport='socket' needs a worker spec dict (scenario "
                "kwargs for repro.launch.fed_train.build_scenario) so "
                "worker subprocesses can rebuild the identical scenario")
        self.eng = Engine(strategy, data, cfg, telemetry=telemetry)
        self.cfg = cfg
        self.obs = self.eng.obs
        self.faults = faults or FaultPlan()
        self.retry = retry or RetryPolicy()
        self.spec = spec
        self._dense = CodecConfig(cfg.codec.name, sparse=False)

    # -- lifecycle -----------------------------------------------------------

    def run(self, key: jax.Array, rounds: int | None = None
            ) -> tuple[EngineState | None, list[RoundReport]]:
        """Run the configured rounds over the transport.

        Returns ``(final_state, reports)``.  Loopback assembles the
        final :class:`EngineState` from the server lanes plus the
        workers' block state (the conformance pin needs it); a socket
        run returns ``state=None`` — the population lives in worker
        processes that have already exited.
        """
        eng = self.eng
        k_init, k_rounds = jax.random.split(key)
        state = eng.init(k_init)
        transport, workers = self._open(state, key)
        try:
            reports: list[RoundReport] = []
            n_rounds = self.cfg.rounds if rounds is None else rounds
            for r in range(n_rounds):
                with self.obs.span("round"):
                    state, rep = self._round(
                        transport, state, jax.random.fold_in(k_rounds, r),
                        r)
                    self.obs.fence(state)
                self.obs.on_round(rep)
                reports.append(rep)
            self._shutdown(transport)
            if workers is not None:
                state = self._assemble_state(state, workers)
            else:
                state = None
        finally:
            transport.close()
        return state, reports

    def _open(self, state: EngineState, key: jax.Array):
        cfg, eng = self.cfg, self.eng
        if cfg.transport == "socket":
            spec = dict(self.spec)
            spec["runtime"] = self._runtime_dict()
            spec["key"] = [int(w) for w in np.asarray(key, np.uint32)]
            if self.faults.delay or self.faults.drop:
                spec["faults"] = {"delay": list(self.faults.delay),
                                  "drop": list(self.faults.drop)}
            return SocketTransport.launch(spec, cfg.workers,
                                          connect_timeout=
                                          self.retry.timeout * 10), None
        workers = []
        for rank in range(cfg.workers):
            lo, hi = block_range(eng.n, cfg.workers, rank)
            sl = slice(lo, hi)
            workers.append(ClientWorker(
                rank, lo, hi, eng.strategy, cfg,
                block_cs=jax.tree.map(lambda a: a[sl], state.client_state),
                block_data=jax.tree.map(lambda a: a[sl], eng.data),
                ref_vecs=(state.ref_vecs[sl] if cfg.codec.sparse else None),
                ref_round=(state.ref_round[sl] if cfg.codec.sparse
                           else None),
                ef=(state.ef_residual[sl] if cfg.codec.error_feedback
                    else None),
                faults=self.faults))
        return LoopbackTransport(workers, faults=self.faults), workers

    def _runtime_dict(self) -> dict:
        from repro.fl.transport.worker import runtime_config_to_dict
        return runtime_config_to_dict(self.cfg)

    def _shutdown(self, transport) -> None:
        for rank in transport.ranks:
            transport.send(rank, MsgKind.SHUTDOWN, b"")
        for rank in transport.ranks:
            kind, _, _ = self._recv(transport, rank, MsgKind.BYE)

    def _assemble_state(self, state: EngineState, workers) -> EngineState:
        """Loopback final state: server lanes from the server, client
        rows (and error-feedback residuals — client-side wire state)
        re-assembled from the worker blocks in rank order."""
        cs = jax.tree.map(lambda *blocks: jnp.concatenate(blocks, axis=0),
                          *[w.block_cs for w in workers])
        ef = state.ef_residual
        if self.cfg.codec.error_feedback:
            ef = jnp.concatenate(
                [jnp.asarray(w.ef) for w in workers], axis=0)
        return state._replace(client_state=cs, ef_residual=ef)

    # -- wire helpers --------------------------------------------------------

    def _recv(self, transport, rank: int, want: int):
        """One expected message, under the retry policy: disconnects and
        timeouts back off exponentially and retry; attempts exhausted →
        the last error propagates."""
        last = None
        for attempt in range(self.retry.attempts):
            if attempt:
                time.sleep(self.retry.backoff * 2 ** (attempt - 1))
                transport.reconnect(rank)
            try:
                kind, payload, nbytes = transport.recv(
                    rank, timeout=self.retry.timeout)
            except (framing.DisconnectError, TimeoutError) as e:
                last = e
                continue
            if kind != want:
                raise framing.WireError(
                    f"expected message kind {want} from worker {rank}, "
                    f"got {kind}")
            return kind, payload, nbytes
        raise last

    def _row_frames(self, server) -> list[bytes]:
        """The server matrix as dense codec frames — what WORK and
        DOWNLINK actually carry.  Deterministic encode: the bytes equal
        the engine's roundtrip encode of the same matrix."""
        np_server = np.asarray(server, np.float32)
        if self.eng._wire_is_identity():
            return [np_server[s].tobytes()
                    for s in range(np_server.shape[0])]
        return [encode(np_server[s], self._dense)
                for s in range(np_server.shape[0])]

    # -- one round -----------------------------------------------------------

    def _round(self, transport, state: EngineState, round_key, r: int
               ) -> tuple[EngineState, RoundReport]:
        eng, cfg, obs = self.eng, self.cfg, self.obs
        strategy = eng.strategy
        sync = cfg.aggregation == "sync"
        wire_tx = wire_rx = 0

        with obs.span("schedule"):
            part = eng.scheduler.sample(r, round_key)
            np_idx = np.asarray(part.idx)
            active = np.asarray(part.active)
            sched_stale = np.asarray(part.staleness)

        # the engine's exact per-client key stream: split over the full
        # population, then slice the cohort
        keys = np.asarray(jax.random.split(round_key, eng.n))[np_idx]

        with obs.span("broadcast_encode"):
            rows = self._row_frames(state.server.slots)
            d = strategy.vec_dim

        # cohort → worker blocks (position k in cohort order per rank)
        n_workers = len(transport.ranks)
        rank_of = np.empty((eng.n,), np.int32)
        for rank in transport.ranks:
            lo, hi = block_range(eng.n, n_workers, rank)
            rank_of[lo:hi] = rank
        by_rank: dict[int, list[int]] = {rank: [] for rank in
                                         transport.ranks}
        for k, g in enumerate(np_idx):
            by_rank[int(rank_of[g])].append(k)

        with obs.span("wire_tx"):
            for rank in transport.ranks:
                clients = tuple(
                    WorkClient(gidx=int(np_idx[k]),
                               key=(int(keys[k, 0]), int(keys[k, 1])),
                               active=bool(active[k]),
                               staleness=int(sched_stale[k]))
                    for k in by_rank[rank])
                wire_tx += transport.send(
                    rank, MsgKind.WORK,
                    Work(round_idx=r, dim=d, rows=tuple(rows),
                         clients=clients).pack())

        # collect the round's real uplink frames
        K, j = eng.scheduler.k, strategy.j_slots
        dec = np.zeros((K, j, d), np.float32)
        slots = np.full((K, j), -1, np.int32)
        received = np.zeros((K,), bool)
        recv_stale = np.zeros((K,), np.int32)
        arrivals: list[tuple[int, int, np.ndarray, int]] = []
        pos_of = {int(g): k for k, g in enumerate(np_idx)}
        sparse = cfg.codec.sparse
        refs_np = np.asarray(state.ref_vecs) if sparse else None
        up_bytes = 0
        with obs.span("wire_rx"):
            uploads = []
            for rank in transport.ranks:
                _, payload, nbytes = self._recv(transport, rank,
                                                MsgKind.UPLOAD)
                wire_rx += nbytes
                uploads.append(Upload.unpack(payload))
        with obs.span("uplink_codec"):
            for up in uploads:
                for e in up.entries:
                    for j_idx, s, frame in e.frames:
                        up_bytes += 4 + len(frame)
                        ref = refs_np[e.gidx, s] if sparse else None
                        vec = decode(frame, d, cfg.codec, ref=ref)
                        if sync:
                            k = pos_of[e.gidx]
                            dec[k, j_idx] = vec
                            slots[k, j_idx] = s
                        else:
                            arrivals.append(
                                (e.gidx, s, vec, r - e.src_round))
                            if e.src_round == r:
                                # on-time sender in this round's cohort:
                                # the server knows its proposed tags, so
                                # applied_slots can route rows back to it
                                slots[pos_of[e.gidx], j_idx] = s
                    if sync:
                        k = pos_of[e.gidx]
                        received[k] = True
                        recv_stale[k] = e.staleness

        observed_summary = None
        if sync:
            # the sync barrier: an upload counts only if it arrived in
            # its own round (without faults this equals the scheduled
            # active & staleness==0 mask, which is the conformance pin)
            arrive = received & (recv_stale == 0)
            dec_j = jnp.asarray(dec)
            slots_j = jnp.asarray(slots)
            if eng._assign is not None:
                with obs.span("assign"):
                    slots_j = eng.executor.assign(
                        strategy, state.server, dec_j, slots_j,
                        jnp.asarray(arrive))
                    obs.fence(slots_j)
            with obs.span("aggregate"):
                agg, counts = eng.executor.masked_mean(
                    strategy, dec_j, slots_j, jnp.asarray(arrive))
                obs.fence(agg, counts)
            with obs.span("server_update"):
                server = eng._server_update(state.server, agg, counts)
                obs.fence(server)
            n_agg = int((np.asarray(slots_j)[arrive] >= 0).sum())
            buf = eng._buf_of(state)
            n_buf = n_evict = 0
            recv_mask = arrive
        else:
            with obs.span("aggregate"):
                server, counts, n_agg, n_buf, n_evict, buf = \
                    self._buffer_arrivals(state, arrivals, r)
                obs.fence(server, counts)
            slots_j = jnp.asarray(slots)
            # every active client trained and applies the broadcast,
            # matching the engine's async recv = active
            recv_mask = active
            lags = [lag for _, _, _, lag in arrivals]
            observed_summary = arrival_participation(
                [g for g, _, _, _ in arrivals], lags).summary()

        recv = jnp.asarray(recv_mask)
        with obs.span("downlink"):
            applied = executors.applied_slots(slots_j, counts, recv)
            rx_server, down_bc, down_pc = eng._wire_downlink(
                server.slots, counts, recv_mask, applied)
            obs.fence(rx_server)
            down_rows = self._row_frames(server.slots)
        with obs.span("ref_track"):
            refs = eng._update_refs(state, part, recv_mask, applied,
                                    rx_server, r)
            obs.fence(refs)

        np_applied = np.asarray(applied)
        with obs.span("wire_tx"):
            for rank in transport.ranks:
                clients = tuple(
                    DownClient(gidx=int(np_idx[k]),
                               arrive=bool(recv_mask[k]),
                               applied=tuple(int(s)
                                             for s in np_applied[k]))
                    for k in by_rank[rank])
                wire_tx += transport.send(
                    rank, MsgKind.DOWNLINK,
                    Downlink(round_idx=r, dim=d, rows=tuple(down_rows),
                             clients=clients).pack())

        with obs.span("eval"):
            accs = []
            with obs.span("wire_rx"):
                for rank in transport.ranks:
                    _, payload, nbytes = self._recv(transport, rank,
                                                    MsgKind.EVAL)
                    wire_rx += nbytes
                    accs.append(np.asarray(Eval.unpack(payload).acc))
            acc = jnp.asarray(np.concatenate(accs))
            obs.fence(acc)

        if eng._identity:
            assignment = applied
        else:
            assignment = jnp.full((eng.n, strategy.j_slots), -1,
                                  jnp.int32).at[jnp.asarray(np_idx)].set(
                applied)

        new_state = EngineState(
            round_idx=state.round_idx + 1,
            client_state=state.client_state,   # worker-owned; see run()
            server=server,
            buf_vecs=buf[0], buf_slots=buf[1], buf_ready=buf[2],
            buf_weight=buf[3], buf_valid=buf[4], buf_seq=buf[5],
            ref_vecs=refs[0], ref_round=refs[1],
            ef_residual=state.ef_residual)
        rep = RoundReport(
            round_idx=r, mean_accuracy=acc.mean(),
            per_client_accuracy=acc, assignment=assignment,
            cluster_counts=counts, participation=part,
            upload_bytes=up_bytes, download_bytes_broadcast=down_bc,
            download_bytes_per_client=down_pc, aggregated_uploads=n_agg,
            buffered_uploads=n_buf, evicted_uploads=n_evict,
            wire_tx_bytes=wire_tx, wire_rx_bytes=wire_rx,
            observed_staleness=observed_summary)
        return new_state, rep

    def _buffer_arrivals(self, state: EngineState, arrivals, r: int):
        """Arrival-driven async aggregation: insert whatever actually
        landed this round into the host buffer — mature immediately,
        weighted by the *observed* lag — then run the engine's shared
        fold (maturity gate, assign-at-aggregation, server_update)."""
        cfg = self.eng.cfg
        vecs = np.asarray(state.buf_vecs).copy()
        bslots = np.asarray(state.buf_slots).copy()
        ready = np.asarray(state.buf_ready).copy()
        weight = np.asarray(state.buf_weight).copy()
        valid = np.asarray(state.buf_valid).copy()
        seq = np.asarray(state.buf_seq).copy()
        evicted = 0
        next_seq = int(seq[valid].max()) + 1 if valid.any() else 0
        for _, slot, vec, lag in arrivals:
            free = np.nonzero(~valid)[0]
            if free.size:
                i = free[0]
            else:            # overflow: evict the oldest insertion
                occupied = np.where(valid, seq, np.iinfo(np.int32).max)
                i = int(np.argmin(occupied))
                evicted += 1
            vecs[i] = vec
            bslots[i] = slot
            ready[i] = r                       # it arrived: mature now
            weight[i] = cfg.staleness_discount ** int(lag)
            valid[i] = True
            seq[i] = next_seq
            next_seq += 1
        server, counts, n_agg, n_buf, buf = self.eng._fold_host_buffer(
            state, vecs, bslots, ready, weight, valid, seq, r)
        return server, counts, n_agg, n_buf, evicted, buf
