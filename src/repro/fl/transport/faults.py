"""Deterministic fault injection + retry policy for the transport.

Real networks delay, drop and disconnect; the robustness tests need
those behaviours on demand and *reproducibly*.  A :class:`FaultPlan` is
a static schedule — no randomness, no wall clock — so a test can assert
exactly which upload went missing and when a retry had to fire:

* ``delay``      — ``(round, client, extra)``: the client's upload is
  held ``extra`` additional rounds before the worker sends it (async
  mode; under a sync barrier added delay means missing the barrier).
* ``drop``       — ``(round, client)``: the upload of that round is
  lost outright — the worker never sends it.
* ``disconnect`` — ``(rank, nth_recv)``: the server's n-th ``recv``
  from that worker (0-based, counted per rank over the run) raises
  :class:`~repro.fl.transport.framing.DisconnectError` once; the frame
  is delivered intact on the retry.  This exercises the server's
  per-client retry/backoff loop without a real flaky link.

:class:`RetryPolicy` bounds how the server waits: ``attempts`` tries
per expected message, ``timeout`` seconds of socket wait per try, and
an exponential ``backoff`` sleep between tries.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    delay: tuple = ()        # ((round, client, extra_rounds), ...)
    drop: tuple = ()         # ((round, client), ...)
    disconnect: tuple = ()   # ((rank, nth_recv), ...)

    def delay_for(self, round_idx: int, client: int) -> int:
        return sum(extra for r, c, extra in self.delay
                   if r == round_idx and c == client)

    def dropped(self, round_idx: int, client: int) -> bool:
        return any(r == round_idx and c == client for r, c in self.drop)

    def disconnects_at(self, rank: int, nth_recv: int) -> bool:
        return any(rk == rank and n == nth_recv
                   for rk, n in self.disconnect)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    attempts: int = 3        # tries per expected message, >= 1
    timeout: float = 60.0    # seconds of blocking wait per try (socket)
    backoff: float = 0.05    # sleep before retry k is backoff * 2**k

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError("RetryPolicy.attempts must be >= 1")
