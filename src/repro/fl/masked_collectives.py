"""TPU-native TPFL aggregation: cluster-masked reductions.

The paper's aggregator is a parameter server (Alg. 2).  On a device mesh
the same math is a *masked* reduction: every client contributes its upload
into its cluster's slot of a (C, ·) accumulator and one collective
computes all cluster means at once.  Two forms:

* :func:`clustered_mean` — host/vmap form (one-hot segment mean), used by
  the in-process federations.
* :func:`clustered_mean_sharded` — `shard_map` form over a mesh axis:
  clients live one-per-shard, the accumulator is reduced with a single
  `lax.psum`, and each shard reads back only its own cluster's row.  Its
  collective bytes (C·m) versus FedAvg-on-TM's full-state all-reduce
  (C·m·(2o+1)) is the paper's communication claim measured in the HLO.

The runtime engine's shard-mapped sync round (``backend="shardmap"``)
lowers its aggregation through the two server-matrix forms below:

* :func:`clustered_mean_gathered` — one ``all_gather`` of the per-shard
  uploads followed by the *identical* ``clustering.aggregate`` einsum on
  every shard.  Because the gathered array equals the in-process one
  value-for-value and the reduction graph is the same, this lowering is
  bit-exact with the in-process engine — it is the form the federation
  conformance suite pins.
* :func:`clustered_weighted_mean_sharded` — the communication-optimal
  form: per-shard masked partial sums, one ``psum`` of a (C, m)
  accumulator (C·m bytes per device instead of all_gather's K·m).
  Weighted, so it also covers the async engine's staleness-discounted
  means (``discount**staleness``); float reduction order differs from
  the host einsum, so it is allclose-, not bit-, equal.
* :func:`buffered_weighted_mean_sharded` — the async device-buffer
  form: the (capacity, m) upload buffer is *replicated* round state, so
  each shard takes its block of buffer rows and the mean lowers through
  :func:`clustered_weighted_mean_sharded` unchanged (same C·m psum).

Sharding contract (who holds what)
----------------------------------
The two host forms (:func:`clustered_mean`,
:func:`clustered_weighted_mean`) take fully materialized arrays — no
mesh, no collective; they are also the per-shard *reference math* the
sharded forms must agree with.  The ``*_sharded`` / ``*_gathered``
forms run **inside** ``shard_map`` over ``axis_name``: their
``local_*`` arguments are one shard's block (leading axis =
K/n_shards), ``prev`` and ``n_clusters`` are replicated, and the return
values are replicated on every shard (an all_gather or psum is the only
cross-shard edge).  :func:`buffered_weighted_mean_sharded` is the one
exception on the input side: its ``vals``/``slots``/``weights`` are the
*replicated* buffer lanes, and the function slices the shard-local
block itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import clustering


def collective_payload_bytes(collective: str, n_uploads: int, dim: int,
                             n_clusters: int) -> int:
    """Per-device payload bytes the aggregation collective moves — the
    telemetry plane's static gauge for what a round's reduction costs
    on the mesh (``repro.fl.obs`` records it in the run manifest; the
    partitioned-HLO measurement in ``fed_dryrun`` is the ground truth
    this predicts).

    * ``gather`` — one tiled ``all_gather`` of every upload: the full
      (n_uploads, dim) float32 matrix lands on each device.
    * ``psum``   — one all-reduce of the (n_clusters, dim) accumulator
      plus its (n_clusters,) weight totals: independent of how many
      clients upload.

    Pure host arithmetic — never called from compiled code, so it
    cannot perturb the round."""
    if collective == "gather":
        return 4 * n_uploads * dim
    if collective == "psum":
        return 4 * n_clusters * (dim + 1)
    raise ValueError(f"unknown collective {collective!r}")


def clustered_mean(vals: jnp.ndarray, assignment: jnp.ndarray,
                   n_clusters: int) -> jnp.ndarray:
    """vals: (n, ...) → (n_clusters, ...) per-cluster means (0 if empty)."""
    onehot = jax.nn.one_hot(assignment, n_clusters, dtype=jnp.float32)
    sums = jnp.einsum("n...,nk->k...", vals.astype(jnp.float32), onehot)
    counts = onehot.sum(0)
    return sums / jnp.maximum(counts.reshape((-1,) + (1,) * (vals.ndim - 1)),
                              1.0)


def clustered_weighted_mean(vals: jnp.ndarray, assignment: jnp.ndarray,
                            weights: jnp.ndarray,
                            n_clusters: int) -> jnp.ndarray:
    """Per-cluster *weighted* mean — the async-runtime form.

    vals: (n, ...), assignment: (n,) (−1 = masked out), weights: (n,)
    staleness discounts (0 also masks).  Returns (n_clusters, ...) of
    Σ wᵢ·vᵢ / Σ wᵢ per cluster (0 where no weight landed).  With all
    weights 1 this reduces to :func:`clustered_mean`.
    """
    onehot = jax.nn.one_hot(assignment, n_clusters, dtype=jnp.float32)
    onehot = onehot * weights.astype(jnp.float32)[:, None]       # (n, C)
    sums = jnp.einsum("n...,nk->k...", vals.astype(jnp.float32), onehot)
    total = onehot.sum(0)
    return sums / jnp.maximum(total.reshape((-1,) + (1,) * (vals.ndim - 1)),
                              1e-9)


def clustered_mean_gathered(local_vals: jnp.ndarray,
                            local_slots: jnp.ndarray,
                            n_clusters: int, axis_name: str,
                            n_valid: int | None = None
                            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: bit-exact sharded lowering of the Alg. 2 mean.

    Each shard holds its local block of uploads ``(k_local, m)`` and slot
    ids ``(k_local,)`` (−1 = masked out).  One tiled ``all_gather``
    reassembles the global upload matrix *in client order* on every
    shard, and the reduction is then literally
    :func:`repro.core.clustering.aggregate` on the same values — so the
    result is bit-identical to the in-process engine, which is the
    conformance suite's contract.

    ``n_valid`` trims trailing padding rows (the engine pads the sampled
    K to a multiple of the mesh axis) so the reduction shape — and hence
    the float summation order — matches the unpadded in-process einsum.

    Returns ``(mean, counts)``: the *raw* (C, m) per-slot means (zeros
    where empty) and the (C,) member counts.  Empty-slot retention is
    the strategy's ``server_update`` decision (server-state API v2) —
    the old merged-with-``prev`` return moved there.
    """
    vals = jax.lax.all_gather(local_vals, axis_name, tiled=True)
    slots = jax.lax.all_gather(local_slots, axis_name, tiled=True)
    if n_valid is not None:
        vals = vals[:n_valid]
        slots = slots[:n_valid]
    res = clustering.aggregate(vals, slots, n_clusters)
    return res.cluster_weights, res.counts


def clustered_weighted_mean_sharded(local_vals: jnp.ndarray,
                                    local_slots: jnp.ndarray,
                                    local_weights: jnp.ndarray,
                                    n_clusters: int, axis_name: str
                                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: weighted per-slot mean via one masked ``psum``.

    The sharded form of :func:`clustered_weighted_mean` — each shard
    folds its local uploads into a (C, m) accumulator weighted by
    ``local_weights`` (staleness discounts; 0 masks, as does slot −1),
    and a single psum of accumulator + weight totals yields every slot
    mean at once.  C·m collective bytes per device — the
    communication-optimal lowering (vs all_gather's K·m), at the cost of
    a shard-order float reduction that is allclose- rather than
    bit-equal to the host form.

    Returns ``(means, total_weight)``, means 0 where no weight landed.
    """
    onehot = jax.nn.one_hot(local_slots, n_clusters, dtype=jnp.float32)
    onehot = onehot * local_weights.astype(jnp.float32)[:, None]  # (k, C)
    part = jnp.einsum("nm,nk->km", local_vals.astype(jnp.float32), onehot)
    sums = jax.lax.psum(part, axis_name)               # (C, m)
    total = jax.lax.psum(onehot.sum(0), axis_name)     # (C,)
    means = sums / jnp.maximum(total[:, None], 1e-9)
    return means, total


def buffered_weighted_mean_sharded(vals: jnp.ndarray, slots: jnp.ndarray,
                                   weights: jnp.ndarray, n_clusters: int,
                                   axis_name: str, n_shards: int
                                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: the async buffer's staleness-discounted mean.

    ``vals`` (capacity, m) / ``slots`` / ``weights`` are the device
    buffer's lanes, **replicated** on every shard (the buffer is global
    round state, not per-client).  Each shard slices its contiguous
    block of ``ceil(capacity / n_shards)`` rows (tail-padded with slot
    −1 / weight 0, which the mask ignores) and the reduction is then
    exactly :func:`clustered_weighted_mean_sharded` — one psum of the
    (C, m) accumulator, C·m collective bytes per device regardless of
    buffer capacity.  Shard-order reduction ⇒ allclose-, not bit-,
    equal to the host :func:`clustered_weighted_mean`.

    Returns ``(means, total_weight)``, both replicated.
    """
    cap = vals.shape[0]
    blk = -(-cap // n_shards)
    pad = blk * n_shards - cap
    if pad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad,) + vals.shape[1:], vals.dtype)])
        slots = jnp.concatenate([slots, jnp.full((pad,), -1, slots.dtype)])
        weights = jnp.concatenate(
            [weights, jnp.zeros((pad,), weights.dtype)])
    i = jax.lax.axis_index(axis_name)
    v = jax.lax.dynamic_slice_in_dim(vals, i * blk, blk)
    s = jax.lax.dynamic_slice_in_dim(slots, i * blk, blk)
    w = jax.lax.dynamic_slice_in_dim(weights, i * blk, blk)
    return clustered_weighted_mean_sharded(v, s, w, n_clusters, axis_name)


def clustered_mean_sharded(local_val: jnp.ndarray, my_cluster: jnp.ndarray,
                           n_clusters: int, axis_name: str) -> jnp.ndarray:
    """Inside shard_map: each shard holds one client's upload (m,) and its
    cluster id; returns this client's new cluster-averaged vector.

    One psum of a (C, m) accumulator — the masked all-reduce that replaces
    the paper's server round-trip.
    """
    onehot = jax.nn.one_hot(my_cluster, n_clusters, dtype=jnp.float32)
    contrib = onehot[:, None] * local_val.astype(jnp.float32)[None, :]
    sums = jax.lax.psum(contrib, axis_name)            # (C, m)
    counts = jax.lax.psum(onehot, axis_name)           # (C,)
    means = sums / jnp.maximum(counts[:, None], 1.0)
    return means[my_cluster]
