"""TPU-native TPFL aggregation: cluster-masked reductions.

The paper's aggregator is a parameter server (Alg. 2).  On a device mesh
the same math is a *masked* reduction: every client contributes its upload
into its cluster's slot of a (C, ·) accumulator and one collective
computes all cluster means at once.  Two forms:

* :func:`clustered_mean` — host/vmap form (one-hot segment mean), used by
  the in-process federations.
* :func:`clustered_mean_sharded` — `shard_map` form over a mesh axis:
  clients live one-per-shard, the accumulator is reduced with a single
  `lax.psum`, and each shard reads back only its own cluster's row.  This
  is what `fed_train_step` lowers in the dry-run; its collective bytes
  (C·m) versus FedAvg-on-TM's full-state all-reduce (C·m·(2o+1)) is the
  paper's communication claim measured in the HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clustered_mean(vals: jnp.ndarray, assignment: jnp.ndarray,
                   n_clusters: int) -> jnp.ndarray:
    """vals: (n, ...) → (n_clusters, ...) per-cluster means (0 if empty)."""
    onehot = jax.nn.one_hot(assignment, n_clusters, dtype=jnp.float32)
    sums = jnp.einsum("n...,nk->k...", vals.astype(jnp.float32), onehot)
    counts = onehot.sum(0)
    return sums / jnp.maximum(counts.reshape((-1,) + (1,) * (vals.ndim - 1)),
                              1.0)


def clustered_weighted_mean(vals: jnp.ndarray, assignment: jnp.ndarray,
                            weights: jnp.ndarray,
                            n_clusters: int) -> jnp.ndarray:
    """Per-cluster *weighted* mean — the async-runtime form.

    vals: (n, ...), assignment: (n,) (−1 = masked out), weights: (n,)
    staleness discounts (0 also masks).  Returns (n_clusters, ...) of
    Σ wᵢ·vᵢ / Σ wᵢ per cluster (0 where no weight landed).  With all
    weights 1 this reduces to :func:`clustered_mean`.
    """
    onehot = jax.nn.one_hot(assignment, n_clusters, dtype=jnp.float32)
    onehot = onehot * weights.astype(jnp.float32)[:, None]       # (n, C)
    sums = jnp.einsum("n...,nk->k...", vals.astype(jnp.float32), onehot)
    total = onehot.sum(0)
    return sums / jnp.maximum(total.reshape((-1,) + (1,) * (vals.ndim - 1)),
                              1e-9)


def clustered_mean_sharded(local_val: jnp.ndarray, my_cluster: jnp.ndarray,
                           n_clusters: int, axis_name: str) -> jnp.ndarray:
    """Inside shard_map: each shard holds one client's upload (m,) and its
    cluster id; returns this client's new cluster-averaged vector.

    One psum of a (C, m) accumulator — the masked all-reduce that replaces
    the paper's server round-trip.
    """
    onehot = jax.nn.one_hot(my_cluster, n_clusters, dtype=jnp.float32)
    contrib = onehot[:, None] * local_val.astype(jnp.float32)[None, :]
    sums = jax.lax.psum(contrib, axis_name)            # (C, m)
    counts = jax.lax.psum(onehot, axis_name)           # (C,)
    means = sums / jnp.maximum(counts[:, None], 1.0)
    return means[my_cluster]
