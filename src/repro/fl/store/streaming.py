"""Streaming per-client data over LEAF shards — ingestion's side of the
K-active working set.

``registry.load`` materializes the whole encoded pool before
partitioning, so the client population is capped by pool RAM.
:class:`StreamingClientData` instead holds only the *writer table*
(names + per-writer sample counts from the shard index) and produces
rectangular :class:`~repro.data.partition.ClientData` blocks **on
demand** for the ids the scheduler actually sampled —
``gather_clients(ids)`` parses only the shards those clients' writers
live in (:func:`repro.data.ingest.leaf.read_writers`), never the pool.

Parity contract (pinned by ``tests/test_ingest.py``): for
``n_clients ≤ n_writers`` the gathered rows are **bit-for-bit** the
rows :func:`repro.data.ingest.natural.partition_writers` would have
produced from the materialized pool — same contiguous writer grouping
(``np.array_split``), same per-client budget key chain
(``fold_in(fold_in(key, 0xFE31), i)``), same eval-first subsample /
wraparound padding, and an encoding applied per gathered row (the
elementwise bool / thermometer transforms commute with row selection;
``quantile`` needs the pool and is rejected at
``registry.load_stream``).  Beyond the writer count — the simulated
million-client regime — clients map cyclically onto writers
(client ``i`` → writer ``i % W``), which has no materialized
counterpart by construction.

``sizes`` is the full host-resident per-client size table (int64, 8
bytes/client — the only O(N) state) that drives the scheduler's
``weighted`` sampling without any ``ClientData`` materialization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.ingest import leaf, natural
from repro.data.partition import ClientData


class StreamingClientData:
    """Writer-table view of a LEAF dataset; per-cohort gather on demand.

    ``pool`` is a :class:`repro.data.ingest.registry.StreamPool`.  The
    constructor touches no shard payloads — only the index-derived
    writer table.
    """

    def __init__(self, pool, *, n_clients: int, n_train: int, n_test: int,
                 n_conf: int, key: jax.Array):
        self.pool = pool
        self.n_clients = int(n_clients)
        self.n_train, self.n_test, self.n_conf = n_train, n_test, n_conf
        self._key = key
        w_sizes = np.asarray(pool.writer_sizes, np.int64)
        self._n_writers = w_sizes.size
        if self._n_writers == 0:
            raise ValueError(f"stream pool {pool.name!r} has no writers")
        # cum[w] = global row offset of writer w in the (virtual) pool —
        # read_shards concatenates writers in index order, so writer w's
        # rows are exactly [cum[w], cum[w+1])
        self._cum = np.concatenate([[0], np.cumsum(w_sizes)])
        if self.n_clients <= self._n_writers:
            # the materialized partitioner's contiguous writer blocks
            groups = np.array_split(np.arange(self._n_writers),
                                    self.n_clients)
            self._g_start = np.asarray([g[0] for g in groups], np.int64)
            self._g_stop = np.asarray([g[-1] + 1 for g in groups], np.int64)
            sizes = self._cum[self._g_stop] - self._cum[self._g_start]
        else:
            # simulated-scale regime: cyclic writer reuse, no
            # materialized counterpart (partition_writers raises here)
            self._g_start = self._g_stop = None
            sizes = w_sizes[np.arange(self.n_clients) % self._n_writers]
        self.sizes = sizes.astype(np.int64)

    def _writers_of(self, i: int) -> range:
        if self._g_start is not None:
            return range(int(self._g_start[i]), int(self._g_stop[i]))
        w = i % self._n_writers
        return range(w, w + 1)

    def _row_span(self, i: int) -> tuple[int, int]:
        ws = self._writers_of(i)
        return int(self._cum[ws.start]), int(self._cum[ws.stop])

    def gather_clients(self, ids) -> ClientData:
        """Rectangular :class:`ClientData` for ``ids`` — the cohort
        block the engine trains on; only these clients' shards are
        parsed."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_clients):
            raise ValueError(
                f"client ids out of range [0, {self.n_clients})")
        wids = sorted({w for i in ids for w in self._writers_of(int(i))})
        data = leaf.read_writers(self.pool.root, wids,
                                 verify=self.pool.verify)
        eval_need = self.n_test + self.n_conf
        xs, ys, sizes, mixtures = [], [], [], []
        for i in ids:
            i = int(i)
            start, stop = self._row_span(i)
            rows = np.arange(start, stop, dtype=np.int64)
            y_all = np.concatenate(
                [data[w][1] for w in self._writers_of(i)])
            counts = np.bincount(y_all, minlength=self.pool.n_classes)
            mixtures.append(counts / counts.sum())
            sizes.append(len(rows))
            # the exact partition_writers budget draw — same key chain,
            # same permutation, same eval-first split, same wraparound
            order = rows[np.asarray(jax.random.permutation(
                jax.random.fold_in(
                    jax.random.fold_in(self._key, natural._TAG_BUDGET), i),
                len(rows)))]
            if len(order) > eval_need:
                eval_pool, train_pool = order[:eval_need], order[eval_need:]
            elif len(order) > 1:
                eval_pool, train_pool = order[:-1], order[-1:]
            else:
                eval_pool = train_pool = order
            picked = np.concatenate([
                train_pool[np.arange(self.n_train) % len(train_pool)],
                eval_pool[np.arange(self.n_test) % len(eval_pool)],
                eval_pool[(self.n_test + np.arange(self.n_conf))
                          % len(eval_pool)]])
            # global row → (writer, local row) through the offset table
            w_of = np.searchsorted(self._cum, picked, side="right") - 1
            local = picked - self._cum[w_of]
            xs.append(np.stack([data[int(w)][0][int(li)]
                                for w, li in zip(w_of, local)]))
            ys.append(np.asarray([data[int(w)][1][int(li)]
                                  for w, li in zip(w_of, local)],
                                 np.int32))
        unit = jnp.asarray(np.stack(xs), jnp.float32)     # (k, B, F) raw
        bits = self.pool.encoder(
            unit.reshape(-1, unit.shape[-1])).reshape(
            unit.shape[0], unit.shape[1], -1)
        ys = jnp.asarray(np.stack(ys), jnp.int32)
        nt, ne = self.n_train, self.n_test
        return ClientData(
            x_train=bits[:, :nt], y_train=ys[:, :nt],
            x_test=bits[:, nt:nt + ne], y_test=ys[:, nt:nt + ne],
            x_conf=bits[:, nt + ne:], y_conf=ys[:, nt + ne:],
            mixtures=jnp.asarray(np.stack(mixtures), jnp.float32),
            sizes=jnp.asarray(np.asarray(sizes), jnp.int32),
        )
