"""Host-side client store: O(K) working set over an O(N) population.

:class:`ClientStore` — memory-mapped per-client rows (params, TA
state, sparse-codec refs) with sha256 verify-then-place integrity;
:class:`StreamingClientData` — on-demand per-writer LEAF ingestion for
the sampled cohort.  Together they are what ``RuntimeConfig(
client_store="mmap")`` puts under the engine; see
``docs/client-store.md``.
"""
from repro.fl.store.client_store import ClientStore
from repro.fl.store.streaming import StreamingClientData

__all__ = ["ClientStore", "StreamingClientData"]
