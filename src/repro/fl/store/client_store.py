"""Memory-mapped per-client row store — the host side of the K-active
working set.

``EngineState`` materializes every client's params / TA state /
``ref_vecs`` as one stacked device pytree, which caps the population at
RAM scale.  :class:`ClientStore` moves those rows to disk: one sparse
memory-mapped file per pytree leaf, keyed by client id, so the engine
only ever holds the scheduler's K sampled rows resident
(``gather(ids)`` before the round, ``spill(ids, rows)`` after the
broadcast merge) and device/RAM footprint is O(K), not O(N).

Layout under ``root``::

    manifest.json (+ .sha256)   # version, n_clients, per-leaf dtype/shape
    leaf_00.bin, leaf_01.bin …  # (n_clients, *leaf_shape) sparse files
    written.bin                 # (n_clients,) u8 — 1 once a row was spilled
    digests.bin                 # (n_clients, 32) u8 — per-row sha256

Integrity follows the IDX cache's verify-then-place discipline
(:mod:`repro.data.ingest.idx`): the manifest carries a ``.sha256``
sidecar checked before it is parsed, and every *row* carries a sha256
digest over its bytes (concatenated across all leaves in flattened
template order) written at spill time and re-checked at gather time —
a flipped byte in any leaf file surfaces as a loud
:class:`~repro.data.ingest.idx.ChecksumError`, never as silently wrong
client state.

The leaf files are created sparse (``truncate`` to full size, no
payload write), so a store sized for a million virtual clients costs
actual disk only for the rows ever spilled — O(K·rounds), not O(N).
Rows never sampled are never touched: their file regions stay holes,
byte-identical across the store's whole life (property-tested).

Rows that were never spilled are *virtual*: ``gather`` regenerates them
through the caller-supplied ``init_fn(ids)`` (the strategy's
deterministic per-client init), so a fresh store behaves exactly like a
freshly initialized resident population — the base case of the
engine's bit-for-bit mmap == resident conformance pin.

``gather`` is read-only and thread-safe (concurrent gathers return
identical rows); ``io_read_bytes`` / ``io_written_bytes`` meter actual
host I/O for the telemetry plane and the client-scale bench.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import threading
from typing import Any, Callable

import jax
import numpy as np

from repro.data.ingest import idx

STORE_VERSION = 1
MANIFEST_NAME = "manifest.json"
WRITTEN_NAME = "written.bin"
DIGESTS_NAME = "digests.bin"
_DIGEST_BYTES = 32


def _ensure_file(path: pathlib.Path, nbytes: int) -> None:
    """Create ``path`` as a sparse file of ``nbytes`` (no payload write),
    or validate an existing one — a size drift means the store was
    created under a different template and must fail loudly."""
    if path.exists():
        got = path.stat().st_size
        if got != nbytes:
            raise ValueError(
                f"store file {path} is {got} bytes, expected {nbytes} — "
                f"the store on disk was created under a different "
                f"template or client count; use a fresh directory")
        return
    with open(path, "wb") as f:
        if nbytes:
            f.truncate(nbytes)


def _leaf_specs(leaves: list[np.ndarray]) -> list[dict]:
    return [{"slug": f"leaf_{i:02d}", "dtype": str(a.dtype),
             "shape": [int(s) for s in a.shape]}
            for i, a in enumerate(leaves)]


class ClientStore:
    """Host-side store of per-client pytree rows, open-or-create.

    ``template`` is ONE client's row (a pytree with no leading client
    axis) — it fixes the per-leaf dtype/shape layout recorded in the
    manifest.  ``init_fn(ids) -> stacked rows`` regenerates rows never
    spilled (deterministic per-client init); without it, gathering an
    unwritten row raises.
    """

    def __init__(self, root: str | pathlib.Path, n_clients: int,
                 template: Any,
                 init_fn: Callable[[np.ndarray], Any] | None = None,
                 verify: bool = True):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n = int(n_clients)
        self.init_fn = init_fn
        self.verify = verify
        leaves, self._treedef = jax.tree_util.tree_flatten(template)
        self._leaves = [np.asarray(a) for a in leaves]
        if not self._leaves:
            raise ValueError("client-store template has no array leaves")
        self._specs = _leaf_specs(self._leaves)
        self.row_nbytes = int(sum(a.nbytes for a in self._leaves))

        man_path = self.root / MANIFEST_NAME
        if man_path.exists():
            raw = man_path.read_bytes()
            if verify:
                idx.verify_bytes(man_path, raw)   # sidecar first, then parse
            man = json.loads(raw)
            if (man.get("version") != STORE_VERSION
                    or man.get("n_clients") != self.n
                    or man.get("leaves") != self._specs):
                raise ValueError(
                    f"store manifest {man_path} does not match the "
                    f"caller's template (n_clients={self.n}, leaves="
                    f"{self._specs}) — the store on disk belongs to a "
                    f"different engine configuration; use a fresh "
                    f"directory")
        else:
            man = {"version": STORE_VERSION, "n_clients": self.n,
                   "row_nbytes": self.row_nbytes, "leaves": self._specs}
            man_path.write_text(json.dumps(man, indent=2, sort_keys=True))
            idx.write_checksum(man_path)
        self.manifest = man

        self._maps = []
        for spec, leaf in zip(self._specs, self._leaves):
            path = self.root / (spec["slug"] + ".bin")
            _ensure_file(path, self.n * leaf.nbytes)
            self._maps.append(np.memmap(path, dtype=leaf.dtype, mode="r+",
                                        shape=(self.n,) + leaf.shape))
        _ensure_file(self.root / WRITTEN_NAME, self.n)
        _ensure_file(self.root / DIGESTS_NAME, self.n * _DIGEST_BYTES)
        self._written = np.memmap(self.root / WRITTEN_NAME, dtype=np.uint8,
                                  mode="r+", shape=(self.n,))
        self._digests = np.memmap(self.root / DIGESTS_NAME, dtype=np.uint8,
                                  mode="r+",
                                  shape=(self.n, _DIGEST_BYTES))
        self.io_read_bytes = 0
        self.io_written_bytes = 0
        self._io_lock = threading.Lock()

    # -- helpers ---------------------------------------------------------

    def _check_ids(self, ids) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64).reshape(-1)
        if ids.size and (ids.min() < 0 or ids.max() >= self.n):
            raise ValueError(
                f"client ids out of range [0, {self.n}): "
                f"[{ids.min()}, {ids.max()}]")
        return ids

    @staticmethod
    def _row_digest(row: list[np.ndarray]) -> np.ndarray:
        h = hashlib.sha256()
        for a in row:
            h.update(np.ascontiguousarray(a).tobytes())
        return np.frombuffer(h.digest(), dtype=np.uint8)

    def written_count(self) -> int:
        return int(np.asarray(self._written, dtype=np.int64).sum())

    def written_mask(self, ids) -> np.ndarray:
        """Bool mask over ``ids``: True where a row was ever spilled
        (i.e. ``gather`` returns *personalized* state, not an
        ``init_fn`` regeneration).  The serving plane uses this to
        report personalized-vs-fallback counts per batch."""
        ids = self._check_ids(ids)
        return np.asarray(self._written[ids]).astype(bool)

    # -- the two verbs ---------------------------------------------------

    def gather(self, ids) -> Any:
        """Stacked rows for ``ids``: spilled rows are read back and
        digest-verified; never-spilled rows come from ``init_fn``."""
        ids = self._check_ids(ids)
        written = np.asarray(self._written[ids]).astype(bool)
        out = [np.empty((ids.size,) + a.shape, a.dtype)
               for a in self._leaves]
        miss = ids[~written]
        if miss.size:
            if self.init_fn is None:
                raise ValueError(
                    f"clients {miss[:8].tolist()}… were never spilled "
                    f"and the store has no init_fn to regenerate them")
            init_rows = jax.tree_util.tree_leaves(self.init_fn(miss))
            if len(init_rows) != len(self._leaves):
                raise ValueError(
                    f"init_fn returned {len(init_rows)} leaves, the "
                    f"store template has {len(self._leaves)}")
            where = np.nonzero(~written)[0]
            for dst, src, leaf in zip(out, init_rows, self._leaves):
                src = np.asarray(src)
                if src.shape != (miss.size,) + leaf.shape \
                        or src.dtype != leaf.dtype:
                    raise ValueError(
                        f"init_fn leaf {src.dtype}{src.shape} does not "
                        f"match template {leaf.dtype}"
                        f"{(miss.size,) + leaf.shape}")
                dst[where] = src
        read = 0
        for j in np.nonzero(written)[0]:
            i = int(ids[j])
            row = [np.asarray(mm[i]) for mm in self._maps]
            if self.verify:
                got = self._row_digest(row)
                want = np.asarray(self._digests[i])
                if not np.array_equal(got, want):
                    raise idx.ChecksumError(
                        f"checksum mismatch for client {i} in "
                        f"{self.root}: stored row digest "
                        f"{bytes(want).hex()[:12]}…, file bytes hash to "
                        f"{bytes(got).hex()[:12]}… — the store is "
                        f"corrupt; delete it and re-run")
            for dst, a in zip(out, row):
                dst[j] = a
            read += self.row_nbytes
        with self._io_lock:
            self.io_read_bytes += read
        return jax.tree_util.tree_unflatten(self._treedef, out)

    def spill(self, ids, rows: Any) -> None:
        """Write stacked ``rows`` back under ``ids`` (verify-then-place:
        the per-row digest is recorded with the bytes, so the next
        gather re-proves integrity)."""
        ids = self._check_ids(ids)
        leaves = [np.asarray(a) for a in jax.tree_util.tree_leaves(rows)]
        if len(leaves) != len(self._leaves):
            raise ValueError(
                f"spill got {len(leaves)} leaves, the store template "
                f"has {len(self._leaves)}")
        for a, leaf in zip(leaves, self._leaves):
            if a.shape != (ids.size,) + leaf.shape or a.dtype != leaf.dtype:
                raise ValueError(
                    f"spill leaf {a.dtype}{a.shape} does not match "
                    f"template {leaf.dtype}{(ids.size,) + leaf.shape}")
        for j, i in enumerate(ids):
            i = int(i)
            row = [a[j] for a in leaves]
            for mm, a in zip(self._maps, row):
                mm[i] = a
            self._digests[i] = self._row_digest(row)
            self._written[i] = 1
        with self._io_lock:
            self.io_written_bytes += int(ids.size) * (
                self.row_nbytes + _DIGEST_BYTES + 1)

    def flush(self) -> None:
        """Push dirty pages to disk (reopen-durability; checkpoints)."""
        for mm in (*self._maps, self._written, self._digests):
            mm.flush()
