"""AdamW with optional gradient clipping — pure-pytree, sharding-agnostic
(moment states inherit the parameter PartitionSpecs via tree mapping).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def _global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(params: Any, grads: Any, state: AdamWState,
           cfg: AdamWConfig = AdamWConfig(),
           lr: Any | None = None) -> tuple[Any, AdamWState]:
    """``lr`` (scalar or traced) overrides cfg.lr — schedule hook."""
    step = state.step + 1
    if cfg.grad_clip:
        gn = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr_eff = cfg.lr if lr is None else lr

    def upd(p, g, m, v):
        gf = g.astype(cfg.state_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(cfg.state_dtype)
        return (p.astype(cfg.state_dtype) - lr_eff * delta).astype(p.dtype), \
            m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0, 0))
    new_p, new_m, new_v = jax.tree.transpose(outer, inner, out)
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
