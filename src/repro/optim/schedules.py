"""Learning-rate schedules (warmup + cosine/linear decay), pure functions
of the step counter so they jit inside the train step.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    end_lr_frac: float = 0.1
    kind: str = "cosine"          # "cosine" | "linear" | "constant"


def lr_at(step: jnp.ndarray, cfg: ScheduleConfig) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    end = cfg.peak_lr * cfg.end_lr_frac
    if cfg.kind == "cosine":
        decay = end + (cfg.peak_lr - end) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.kind == "linear":
        decay = cfg.peak_lr + (end - cfg.peak_lr) * frac
    else:
        decay = jnp.asarray(cfg.peak_lr)
    return jnp.where(step < cfg.warmup_steps, warm, decay)
