"""Yi-6B [arXiv:2403.04652] — llama-arch GQA.

32 dense layers, d_model 4096, 32 heads / 4 KV heads, d_ff 11008,
vocab 64000.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    segments=((32, (LayerSpec(mixer="attn", ffn="dense"),)),),
    long_window=8192,
    modality="text",
    source="[arXiv:2403.04652] Yi (GQA)",
)
