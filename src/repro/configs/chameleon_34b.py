"""Chameleon-34B [arXiv:2405.09818] — early-fusion mixed-modal decoder.

48 layers, d_model 8192, 64 heads / 8 KV heads, d_ff 22016, vocab 65536
(text + VQ image tokens in one fused vocabulary).  The VQ-GAN image
tokenizer is a STUB per assignment — ``repro.models.stubs.vq_image_tokens``
supplies in-vocab image-token spans; this config is the early-fusion
transformer that consumes the interleaved stream.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65536,
    segments=((48, (LayerSpec(mixer="attn", ffn="dense"),)),),
    qk_norm=True,        # Chameleon uses qk-norm for mixed-modal stability
    long_window=8192,
    modality="vlm",
    source="[arXiv:2405.09818] Chameleon (early fusion, VQ tokens)",
)
