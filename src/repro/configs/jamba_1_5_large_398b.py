"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887, 2408.12570].

72 layers, 1:7 attention:Mamba interleave (one attention layer per 8),
MoE (16 experts, top-2) on every other layer.  d_model 8192, 64 query
heads with 8 KV heads (GQA), d_ff 24576, vocab 65536.
"""
from repro.models.config import (LayerSpec, MambaConfig, MoEConfig,
                                 ModelConfig)

_M = "mamba"
_A = "attn"
# period-8 pattern: attn at position 4 (Jamba places it mid-block);
# MoE on even positions within the period (every other layer).
_PATTERN = tuple(
    LayerSpec(mixer=(_A if i == 4 else _M),
              ffn=("moe" if i % 2 == 0 else "dense"))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    segments=((9, _PATTERN),),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, n_shared=0,
                  sharding="ep"),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    window=0,            # full attention in train; hybrid → long_500k native
    long_window=8192,    # attention layers use SWA in the 500k serve variant
    modality="text",
    source="[arXiv:2403.19887] Jamba; [arXiv:2408.12570] Jamba-1.5",
)
