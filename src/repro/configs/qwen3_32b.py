"""Qwen3-32B [hf:Qwen/Qwen3-8B family card, scaled per assignment].

64 dense layers, d_model 5120, 64 heads / 8 KV heads (GQA) with qk-norm,
d_ff 25600, vocab 151936.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    segments=((64, (LayerSpec(mixer="attn", ffn="dense"),)),),
    head_dim=128,
    qk_norm=True,
    long_window=8192,    # long_500k runs the sliding-window serve variant
    modality="text",
    source="[hf:Qwen/Qwen3-8B] qk_norm GQA",
)
