"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0 family].

32 layers, d_model 1536, 24 heads / 8 KV heads, MoE with 40 experts
top-8 (per assignment; the 3.0-1b model card lists 32 — we follow the
assignment) and d_expert 512, vocab 49155.

40 experts do not divide the 16-way model axis → this config uses
tensor-parallel expert sharding (``sharding="tp"``: the d_expert
dimension shards instead of the expert axis; see sharding/rules.py).
"""
from repro.models.config import LayerSpec, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    segments=((32, (LayerSpec(mixer="attn", ffn="moe"),)),),
    moe=MoEConfig(n_experts=40, top_k=8, d_expert=512, n_shared=0,
                  sharding="tp"),
    long_window=8192,
    modality="text",
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base] scaled per assignment",
)
