"""MusicGen-Large [arXiv:2306.05284] — decoder-only over EnCodec tokens.

48 layers, d_model 2048, 32 heads (MHA: kv=32), d_ff 8192, vocab 2048
(EnCodec codebook).  The EnCodec audio frontend is a STUB per assignment —
``repro.models.stubs.audio_tokens`` supplies codec-token streams of the
right shape; this config is the language-model backbone that consumes
them.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    segments=((48, (LayerSpec(mixer="attn", ffn="dense"),)),),
    long_window=8192,
    modality="audio",
    source="[arXiv:2306.05284] MusicGen (EnCodec-token decoder)",
)
