"""xLSTM-350M [arXiv:2405.04517] — sLSTM + mLSTM blocks (attention-free).

24 layers in the paper's 7:1 mLSTM:sLSTM ratio (position 3 of each
period-8 block is sLSTM), d_model 1024, 4 heads, vocab 50304.  d_ff = 0:
the xLSTM blocks carry their own up/down projections.
"""
from repro.models.config import LayerSpec, ModelConfig

_PATTERN = tuple(
    LayerSpec(mixer=("slstm" if i == 3 else "mlstm"), ffn="none")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    segments=((3, _PATTERN),),
    long_window=0,        # recurrent state → long_500k is native
    modality="text",
    source="[arXiv:2405.04517] xLSTM (7:1 mLSTM:sLSTM)",
)
