"""Architecture registry: ``get(arch_id)`` → ModelConfig.

One module per assigned architecture under ``src/repro/configs/``; each
cites its source in ``ModelConfig.source``.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "jamba_1_5_large_398b",
    "qwen3_32b",
    "granite_20b",
    "musicgen_large",
    "yi_6b",
    "xlstm_350m",
    "deepseek_v3_671b",
    "phi3_medium_14b",
    "chameleon_34b",
    "granite_moe_3b_a800m",
)

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
# also accept the assignment's hyphenated ids (e.g. "jamba-1.5-large-398b")
_ALIAS.update({
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen3-32b": "qwen3_32b",
    "granite-20b": "granite_20b",
    "musicgen-large": "musicgen_large",
    "yi-6b": "yi_6b",
    "xlstm-350m": "xlstm_350m",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi3-medium-14b": "phi3_medium_14b",
    "chameleon-34b": "chameleon_34b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
})


def get(arch: str) -> ModelConfig:
    mod_name = _ALIAS.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get(a) for a in ARCHS}
