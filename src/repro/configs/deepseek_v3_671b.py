"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437].

61 layers: first 3 dense (d_ff 18432), remaining 58 MoE with 1 shared +
256 routed experts (top-8, d_expert 2048).  MLA attention: q_lora 1536,
kv_lora 512, 128 heads with d_nope 128 + d_rope 64, d_v 128.
d_model 7168, vocab 129280.

The assignment lists d_ff=2048 — that is the MoE expert hidden size; the
three dense layers use DeepSeek's published 18432.  MTP (multi-token
prediction) is exposed as ``mtp_depth`` in the train driver (an extra
shifted-label head), not part of the backbone config.
"""
from repro.models.config import (LayerSpec, MLAConfig, MoEConfig,
                                 ModelConfig)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,      # MLA: per-head KV reconstructed from the latent
    d_ff=18432,          # dense layers (first 3)
    vocab=129280,
    segments=(
        (3, (LayerSpec(mixer="attn", ffn="dense"),)),
        (58, (LayerSpec(mixer="attn", ffn="moe"),)),
    ),
    attn_kind="mla",
    mla=MLAConfig(q_lora=1536, kv_lora=512, d_nope=128, d_rope=64, d_v=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  sharding="ep"),
    long_window=0,       # MLA latent cache (576 B-equiv/token) → 500k native
    modality="text",
    source="[arXiv:2412.19437] DeepSeek-V3 (MLA, 1 shared + 256 routed)",
)
