"""Phi-3-Medium-14B [arXiv:2404.14219] — RoPE + SwiGLU + GQA.

40 dense layers, d_model 5120, 40 heads / 10 KV heads, d_ff 17920,
vocab 100352.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    segments=((40, (LayerSpec(mixer="attn", ffn="dense"),)),),
    long_window=8192,
    modality="text",
    source="[arXiv:2404.14219] Phi-3 (RoPE SwiGLU GQA)",
)
