"""Granite-20B (code) [arXiv:2405.04324].

52 dense llama-arch layers, d_model 6144, 48 heads with MQA (1 KV head),
d_ff 24576, vocab 49152.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    segments=((52, (LayerSpec(mixer="attn", ffn="dense"),)),),
    long_window=8192,
    modality="text",
    source="[arXiv:2405.04324] Granite Code Models (MQA)",
)
