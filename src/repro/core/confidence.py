"""Per-class confidence scores — the quantity TPFL clusters on.

Two providers with one contract `(model, D_conf) → (C,) scores`:

* TM clients (the paper): aggregate clause-vote margin on D_conf
  (Alg. 1 step 6) — re-exported from :mod:`repro.core.tm`.
* NN clients (framework generalization, DESIGN.md §4): mean per-class
  logit margin `logit_c − max_{c'≠c} logit_{c'}` over D_conf — the
  differentiable analogue of the TM vote margin.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tm import confidence_scores as tm_confidence  # noqa: F401


def logit_margin_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """logits: (B, C) → (C,) summed one-vs-rest margins (NN analogue)."""
    top = logits.max(axis=-1, keepdims=True)
    second = jnp.sort(logits, axis=-1)[:, -2][:, None]
    margin = jnp.where(logits == top, logits - second, logits - top)
    return margin.sum(axis=0)


def cluster_assignment(conf: jnp.ndarray) -> jnp.ndarray:
    """c_max = argmax_c conf[c]  (paper §4.2): cluster id == class id."""
    return jnp.argmax(conf, axis=-1)
