"""Confidence-based cluster aggregation (paper Alg. 2, Phases B+C).

The aggregator keeps at most C clusters — cluster k collects the class-k
weight vectors of every client whose maximum confidence was class k, and
averages them.  Implemented as a one-hot segment-mean so it vmaps/pjits;
on a device mesh the same computation lowers to a *masked* all-reduce
(see repro.fl.masked_collectives), which is the TPU-native form of the
paper's parameter-server aggregation.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class ClusterResult(NamedTuple):
    cluster_weights: jnp.ndarray  # (C, m) per-cluster averaged vectors
    counts: jnp.ndarray           # (C,)  |K_k| members per cluster
    assignment: jnp.ndarray       # (n_clients,) cluster id per client


def aggregate(uploads: jnp.ndarray, assignment: jnp.ndarray,
              n_clusters: int,
              prev: jnp.ndarray | None = None) -> ClusterResult:
    """uploads: (n_clients, m) — each client's W[c_max] vector.

    Empty clusters keep ``prev`` (or zero when there is no history), per
    Alg. 2: a cluster is only (re)initialized when a client contributes.
    """
    # one_hot (not eye-indexing): out-of-range ids (−1 = "not shared",
    # from the §7 threshold extension) contribute nothing
    import jax
    onehot = jax.nn.one_hot(assignment, n_clusters,
                            dtype=uploads.dtype)               # (n, C)
    sums = onehot.T @ uploads                                      # (C, m)
    counts = onehot.sum(axis=0)                                    # (C,)
    mean = sums / jnp.maximum(counts[:, None], 1)
    if prev is None:
        prev = jnp.zeros_like(mean)
    cluster_weights = jnp.where(counts[:, None] > 0, mean, prev)
    return ClusterResult(cluster_weights, counts, assignment)
