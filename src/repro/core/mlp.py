"""Minimal MLP + SGD substrate for the DL baselines (FedAvg/FedProx/IFCA/FLIS).

The paper's DL baselines use small CNN/MLP models on MNIST-family data; a
one-hidden-layer MLP reproduces their qualitative behaviour (and their
communication cost is metered from the true parameter byte count of this
model).  Pure JAX, vmappable over a client population.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, jnp.ndarray]


def init(key: jax.Array, n_features: int, n_hidden: int,
         n_classes: int) -> Params:
    k1, k2 = jax.random.split(key)
    s1 = (2.0 / n_features) ** 0.5
    s2 = (2.0 / n_hidden) ** 0.5
    return {
        "w1": jax.random.normal(k1, (n_features, n_hidden)) * s1,
        "b1": jnp.zeros((n_hidden,)),
        "w2": jax.random.normal(k2, (n_hidden, n_classes)) * s2,
        "b2": jnp.zeros((n_classes,)),
    }


def apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.relu(x.astype(jnp.float32) @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(params: Params, x: jnp.ndarray, y: jnp.ndarray,
            prox_mu: float = 0.0, prox_ref: Params | None = None
            ) -> jnp.ndarray:
    logits = apply(params, x)
    ce = -jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None],
                              axis=1).mean()
    if prox_ref is not None:
        # FedProx proximal term  (µ/2)·‖θ − θ_global‖²
        sq = sum(jnp.sum((params[k] - prox_ref[k]) ** 2) for k in params)
        ce = ce + 0.5 * prox_mu * sq
    return ce


def n_bytes(params: Params) -> int:
    return sum(int(v.size) * 4 for v in params.values())


@partial(jax.jit, static_argnames=("epochs", "batch", "prox_mu"))
def local_train(params: Params, x: jnp.ndarray, y: jnp.ndarray,
                key: jax.Array, *, epochs: int, batch: int, lr: float,
                prox_mu: float = 0.0, prox_ref: Params | None = None
                ) -> Params:
    """Sequential minibatch SGD over `epochs` passes (one client)."""
    n = x.shape[0]
    steps_per_epoch = max(n // batch, 1)

    def epoch(p, k):
        perm = jax.random.permutation(k, n)
        xb = x[perm][: steps_per_epoch * batch].reshape(
            steps_per_epoch, batch, -1)
        yb = y[perm][: steps_per_epoch * batch].reshape(
            steps_per_epoch, batch)

        def step(p, b):
            g = jax.grad(loss_fn)(p, b[0], b[1], prox_mu, prox_ref)
            return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

        p, _ = jax.lax.scan(step, p, (xb, yb))
        return p, None

    params, _ = jax.lax.scan(epoch, params, jax.random.split(key, epochs))
    return params


def accuracy(params: Params, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return (apply(params, x).argmax(-1) == y).mean()


def tree_mean(stacked: Any) -> Any:
    """Average a client-stacked pytree along axis 0 (FedAvg aggregation)."""
    return jax.tree.map(lambda a: a.mean(axis=0), stacked)
