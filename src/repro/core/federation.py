"""TPFL federation driver — Algorithms 1 & 2 of the paper, end to end.

One TPFL round (Fig. 2):
  Phase A (client, Alg. 1): local TM training on D_train, per-class
    confidence on D_conf, upload ``(c_max, W[c_max])``.
  Phase B (aggregator): route the upload to cluster k = c_max.
  Phase C (aggregator): per-cluster average of the received vectors.
  Phase D (aggregator→clients): send cluster k's averaged vector back to
    cluster k's members only; clients evaluate on D_test.

The client population is a single vmapped ``TMParams`` pytree (leading
axis = clients), so a full round is one jitted program.  Communication is
metered exactly (§6.7 accounting: upload per client = one weight vector +
class id; download per paper's Fig. 5 = one broadcast per non-empty
cluster; we also report the per-client download).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import clustering, tm
from repro.data.partition import ClientData


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 100
    rounds: int = 10
    local_epochs: int = 10
    weighted_confidence: bool = False   # Alg. 1 uses unweighted margins
    bytes_per_weight: int = 4           # int32 clause weights on the wire
    top_classes: int = 1                # j>1 = the paper's §7 future work:
                                        # share the j most-confident class
                                        # vectors → soft multi-cluster
                                        # membership (comm scales with j)
    conf_threshold: float | None = None  # §7: only share classes whose
                                        # confidence beats the threshold


class RoundMetrics(NamedTuple):
    mean_accuracy: jnp.ndarray      # paper metric: mean over all clients
    per_client_accuracy: jnp.ndarray
    assignment: jnp.ndarray         # (n_clients,) cluster ids
    cluster_counts: jnp.ndarray     # (C,)
    upload_bytes: int
    download_bytes_broadcast: int   # paper Fig.-5 accounting
    download_bytes_per_client: int


class TPFLState(NamedTuple):
    client_params: tm.TMParams      # leading axis = clients
    cluster_weights: jnp.ndarray    # (C, m) aggregator memory


def init_state(tm_cfg: tm.TMConfig, fed_cfg: FedConfig,
               key: jax.Array) -> TPFLState:
    keys = jax.random.split(key, fed_cfg.n_clients)
    params = jax.vmap(lambda k: tm.init_params(tm_cfg, k))(keys)
    cw = jnp.zeros((tm_cfg.n_classes, tm_cfg.n_clauses), jnp.float32)
    return TPFLState(params, cw)


def _strategy(tm_cfg: tm.TMConfig, fed_cfg: FedConfig):
    from repro.fl.runtime.strategy import TPFLStrategy
    return TPFLStrategy(
        tm_cfg, local_epochs=fed_cfg.local_epochs,
        top_classes=fed_cfg.top_classes,
        conf_threshold=fed_cfg.conf_threshold,
        weighted_confidence=fed_cfg.weighted_confidence)


def _phase_a(state: TPFLState, data: ClientData, key: jax.Array,
             tm_cfg: tm.TMConfig, fed_cfg: FedConfig):
    """Local training + confidence + selective upload (Alg. 1).

    ``top_classes`` j > 1 implements the paper's §7 future work: each
    client shares the weight vectors of its j most-confident classes and
    joins j clusters.  Returns c_max (n, j) and uploads (n, j, m); with
    ``conf_threshold`` set, below-threshold slots are flagged invalid
    (class id = -1) and skipped by the aggregator.

    The per-client body lives in ``runtime.strategy.TPFLStrategy`` — the
    runtime engine and this in-process driver share one implementation.
    """
    strat = _strategy(tm_cfg, fed_cfg)
    keys = jax.random.split(key, fed_cfg.n_clients)

    def client(params, d, k):
        params, up = strat.client_step(params, state.cluster_weights, d, k)
        return params, up.slots, up.vecs                    # (j,), (j, m)

    return jax.vmap(client, in_axes=(0, 0, 0))(
        state.client_params, data, keys)


def _phase_d(params: tm.TMParams, assignment: jnp.ndarray,
             cluster_weights: jnp.ndarray) -> tm.TMParams:
    """Each client overwrites its shared classes with the cluster avg.

    assignment: (n, j) class/cluster ids (−1 = not shared)."""
    from repro.fl.runtime.strategy import TPFLStrategy

    return jax.vmap(
        lambda p, a: TPFLStrategy.apply_broadcast(p, a, cluster_weights))(
        params, assignment)


def run_round(state: TPFLState, data: ClientData, key: jax.Array,
              tm_cfg: tm.TMConfig, fed_cfg: FedConfig
              ) -> tuple[TPFLState, RoundMetrics]:
    params, c_top, uploads = _phase_a(state, data, key, tm_cfg, fed_cfg)
    j = fed_cfg.top_classes
    res = clustering.aggregate(uploads.reshape(-1, tm_cfg.n_clauses),
                               c_top.reshape(-1), tm_cfg.n_classes,
                               prev=state.cluster_weights)          # B + C
    params = _phase_d(params, c_top, res.cluster_weights)            # D

    acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
        params, data.x_test, data.y_test)

    m = tm_cfg.n_clauses
    bpw = fed_cfg.bytes_per_weight
    up = fed_cfg.n_clients * j * (m * bpw + 4)       # j vectors + class ids
    nonempty = int((res.counts > 0).sum())
    down_bc = nonempty * m * bpw                     # per-cluster broadcast
    down_pc = fed_cfg.n_clients * j * m * bpw        # per-client accounting
    assignment = c_top[:, 0] if j == 1 else c_top
    metrics = RoundMetrics(acc.mean(), acc, assignment, res.counts,
                           up, down_bc, down_pc)
    return TPFLState(params, res.cluster_weights), metrics


def run(data: ClientData, tm_cfg: tm.TMConfig, fed_cfg: FedConfig,
        key: jax.Array, runtime_cfg=None
        ) -> tuple[TPFLState, list[RoundMetrics]]:
    """Run the federation through the runtime engine.

    With the default ``runtime_cfg`` (sync barrier, full participation,
    float32 codec) this reproduces the legacy in-process loop exactly —
    same per-round assignment, accuracy, and byte totals (now metered
    from real encoded buffers rather than arithmetic).  Pass a
    :class:`repro.fl.runtime.RuntimeConfig` to run the same federation
    under partial participation, dropout, stragglers, quantized codecs,
    or async buffered aggregation.
    """
    from repro.fl.runtime import Engine, RuntimeConfig

    if runtime_cfg is None:
        runtime_cfg = RuntimeConfig()
    # fed_cfg.rounds is authoritative — callers pass runtime_cfg for the
    # scenario knobs (scheduler/codec/aggregation), not the round count
    runtime_cfg = dataclasses.replace(runtime_cfg, rounds=fed_cfg.rounds)
    engine = Engine(_strategy(tm_cfg, fed_cfg), data, runtime_cfg)
    end, reports = engine.run(key)
    j = fed_cfg.top_classes
    history = [
        RoundMetrics(
            mean_accuracy=rep.mean_accuracy,
            per_client_accuracy=rep.per_client_accuracy,
            assignment=rep.assignment[:, 0] if j == 1 else rep.assignment,
            cluster_counts=rep.cluster_counts,
            upload_bytes=rep.upload_bytes,
            download_bytes_broadcast=rep.download_bytes_broadcast,
            download_bytes_per_client=rep.download_bytes_per_client)
        for rep in reports
    ]
    return TPFLState(end.client_state, end.server.slots), history


def total_comm_mb(history: list[RoundMetrics]) -> tuple[float, float]:
    """(upload MB, download MB) over the federation — paper Table 4."""
    up = sum(h.upload_bytes for h in history) / 1e6
    down = sum(h.download_bytes_broadcast for h in history) / 1e6
    return up, down
