"""The paper's five baselines (Table 3/5): the executable references.

* FedAvg  (McMahan et al. 2017)          — single global model, full averaging.
* FedProx (Li et al. 2018, µ=0.1)        — FedAvg + proximal local objective.
* IFCA    (Ghosh et al. 2020)            — k global models, loss-minimizing
                                            cluster choice, within-cluster avg.
* FLIS    (Morafah et al. 2023, flavour) — clusters recomputed each round
                                            from inference similarity on a
                                            shared probe set; DC (thresholded
                                            connected components) and HC
                                            (average-linkage agglomerative).
* FedTM   (Qi et al. 2023, flavour)      — TM with *full* (all-classes) weight
                                            averaging, no personalization.

Every Table-5 row now *runs through the federated runtime engine*
(``benchmarks/table5_comparison.py`` — one ``Strategy`` per method, one
scheduler, byte-exact wire metering).  This module is no longer the
primary path: the FLIS and FedTM loops below are the straight-line
host-side **bit-parity references** the conformance suite
(``tests/test_fl_conformance.py``) pins the engine strategies against —
same key chain as the engine (``k_init, k_rounds = split(key)``; round
r uses ``split(fold_in(k_rounds, r), n)``), same Alg. 2 aggregation
primitive (``clustering.aggregate`` on the flattened wire format), but
with no scheduler / codec / executor machinery in between, so a
divergence is attributable to the engine.  ``_similarity_clusters`` /
``_average_linkage_clusters`` are independent numpy implementations of
the clusterings the engine runs as jit-able programs
(``strategy.flis_dc_labels`` / ``flis_hc_labels``) — the suite pins the
labellings equal.

DL baselines run on the repo MLP (`core/mlp.py`); FedTM runs on the same TM
as TPFL so the TPFL-vs-FedTM delta isolates the paper's contribution
(confidence clustering + selective per-class upload).  Communication here
is metered from the true parameter byte counts (arithmetic); the engine
rows meter ``len(buffer)``-exact from the wire codec.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mlp, tm
from repro.data.partition import ClientData


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    n_clients: int = 100
    rounds: int = 10
    local_epochs: int = 10
    lr: float = 0.05
    batch: int = 32
    n_hidden: int = 128
    prox_mu: float = 0.1       # FedProx (paper §6.6: 0.1)
    ifca_k: int = 10
    flis_threshold: float = 0.9
    flis_probe: int = 64
    flis_max_slots: int = 8    # server rows: dynamic clusters are capped


class History(NamedTuple):
    accuracy: list[float]            # mean client accuracy per round
    upload_mb: float                 # totals over all rounds
    download_mb: float
    assignments: list | None = None  # per-round cluster ids (FLIS/FedTM)


def _client_keys(key: jax.Array, n: int, r: int) -> jax.Array:
    return jax.random.split(jax.random.fold_in(key, r), n)


# ---------------------------------------------------------------------------
# FedAvg / FedProx
# ---------------------------------------------------------------------------

def run_fedavg(data: ClientData, cfg: BaselineConfig, key: jax.Array,
               n_features: int, n_classes: int,
               prox: bool = False) -> History:
    k_init, k_train = jax.random.split(key)
    global_params = mlp.init(k_init, n_features, cfg.n_hidden, n_classes)
    pbytes = mlp.n_bytes(global_params)
    mu = cfg.prox_mu if prox else 0.0

    def local(p_global, xt, yt, k):
        ref = p_global if prox else None
        return mlp.local_train(p_global, xt, yt, k, epochs=cfg.local_epochs,
                               batch=cfg.batch, lr=cfg.lr,
                               prox_mu=mu, prox_ref=ref)

    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        stacked = jax.vmap(local, in_axes=(None, 0, 0, 0))(
            global_params, data.x_train, data.y_train, ks)
        global_params = mlp.tree_mean(stacked)
        acc = jax.vmap(lambda x, y: mlp.accuracy(global_params, x, y))(
            data.x_test, data.y_test).mean()
        accs.append(float(acc))
    total = cfg.rounds * cfg.n_clients * pbytes / 1e6
    return History(accs, total, total)


def run_fedprox(data: ClientData, cfg: BaselineConfig, key: jax.Array,
                n_features: int, n_classes: int) -> History:
    return run_fedavg(data, cfg, key, n_features, n_classes, prox=True)


# ---------------------------------------------------------------------------
# IFCA
# ---------------------------------------------------------------------------

def run_ifca(data: ClientData, cfg: BaselineConfig, key: jax.Array,
             n_features: int, n_classes: int) -> History:
    k_init, k_train = jax.random.split(key)
    models = jax.vmap(
        lambda k: mlp.init(k, n_features, cfg.n_hidden, n_classes))(
        jax.random.split(k_init, cfg.ifca_k))     # stacked (k, ...)
    pbytes = mlp.n_bytes(jax.tree.map(lambda a: a[0], models))

    def pick(models, xt, yt):
        # client chooses the cluster model with lowest local loss
        losses = jax.vmap(lambda p: mlp.loss_fn(p, xt, yt))(models)
        return jnp.argmin(losses)

    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        choice = jax.vmap(pick, in_axes=(None, 0, 0))(
            models, data.x_train, data.y_train)          # (n,)

        def local(models, j, xt, yt, k):
            p = jax.tree.map(lambda a: a[j], models)
            return mlp.local_train(p, xt, yt, k, epochs=cfg.local_epochs,
                                   batch=cfg.batch, lr=cfg.lr)

        trained = jax.vmap(local, in_axes=(None, 0, 0, 0, 0))(
            models, choice, data.x_train, data.y_train, ks)

        onehot = jax.nn.one_hot(choice, cfg.ifca_k)       # (n, k)
        counts = onehot.sum(0)

        def agg(new, old):
            s = jnp.einsum("n...,nk->k...", new, onehot)
            mean = s / jnp.maximum(counts, 1).reshape(
                (-1,) + (1,) * (new.ndim - 1))
            return jnp.where(
                (counts > 0).reshape((-1,) + (1,) * (new.ndim - 1)),
                mean, old)

        models = jax.tree.map(agg, trained, models)

        def client_acc(models, j, x, y):
            return mlp.accuracy(jax.tree.map(lambda a: a[j], models), x, y)

        acc = jax.vmap(client_acc, in_axes=(None, 0, 0, 0))(
            models, choice, data.x_test, data.y_test).mean()
        accs.append(float(acc))
    up = cfg.rounds * cfg.n_clients * pbytes / 1e6
    down = cfg.rounds * cfg.n_clients * cfg.ifca_k * pbytes / 1e6  # k models down
    return History(accs, up, down)


# ---------------------------------------------------------------------------
# FLIS (dynamic clustering) — the engine's bit-parity reference loop
# ---------------------------------------------------------------------------

def _similarity_clusters(sim: np.ndarray, threshold: float) -> np.ndarray:
    """FLIS-DC: connected components of the thresholded similarity
    graph, labelled in order of first appearance (= minimum member
    index).  Independent numpy implementation of the engine's jit-able
    ``strategy.flis_dc_labels`` — the conformance suite pins the two
    labellings equal."""
    n = sim.shape[0]
    labels = -np.ones(n, dtype=np.int64)
    cur = 0
    for i in range(n):
        if labels[i] >= 0:
            continue
        stack = [i]
        labels[i] = cur
        while stack:
            u = stack.pop()
            for v in range(n):
                if labels[v] < 0 and sim[u, v] >= threshold:
                    labels[v] = cur
                    stack.append(v)
        cur += 1
    return labels


def _average_linkage_clusters(sim: np.ndarray, threshold: float,
                              max_clusters: int) -> np.ndarray:
    """FLIS-HC: average-linkage agglomerative clustering.  Repeatedly
    merge the pair of clusters with the highest average cross-
    similarity while that maximum stays ≥ ``threshold`` — or
    unconditionally while more than ``max_clusters`` remain.  Merges
    fold the larger root into the smaller, so a cluster's root is its
    minimum member index and the dense renumbering matches the DC
    convention.  Arithmetic (float32 adds, row-major argmax tie-break)
    mirrors the engine's ``strategy.flis_hc_labels`` step for step —
    the conformance suite pins them equal."""
    n = sim.shape[0]
    size = np.ones(n, np.float32)
    active = np.ones(n, bool)
    cross = sim.astype(np.float32).copy()
    np.fill_diagonal(cross, 0.0)
    labels = np.arange(n)
    while True:
        pair_ok = active[:, None] & active[None, :] & ~np.eye(n, dtype=bool)
        avg = np.where(pair_ok,
                       cross / np.maximum(np.outer(size, size),
                                          np.float32(1.0)),
                       -np.inf).astype(np.float32)
        flat = int(np.argmax(avg))
        a, b = flat // n, flat % n
        best = avg.reshape(-1)[flat]
        n_active = int(active.sum())
        if not (np.isfinite(best) and n_active > 1
                and (n_active > max_clusters or best >= threshold)):
            break
        row = cross[a] + cross[b]
        row[a] = 0.0
        row[b] = 0.0
        cross[a, :] = row
        cross[:, a] = row
        cross[b, :] = 0.0
        cross[:, b] = 0.0
        size[a] += size[b]
        size[b] = 0.0
        active[b] = False
        labels[labels == b] = a
    rank = np.cumsum(active.astype(np.int64)) - 1
    return rank[labels]


def run_flis(data: ClientData, cfg: BaselineConfig, key: jax.Array,
             n_features: int, n_classes: int,
             linkage: str = "dc") -> History:
    """The straight-line FLIS loop the engine's ``FLISStrategy`` is
    pinned against: same key chain as ``Engine.run`` (``k_init,
    k_rounds = split(key)``; ``FLISStrategy.init`` splits ``k_init``
    into params/probe), same shared similarity kernel
    (``strategy.flis_similarity``), same Alg. 2 aggregation primitive
    on the flattened wire format — but host-side clustering
    (``_similarity_clusters`` / ``_average_linkage_clusters``) and no
    scheduler/codec in between."""
    from repro.core import clustering
    from repro.fl.runtime.strategy import (_flatten_mlp, _mlp_layout,
                                           _unflatten_mlp,
                                           flis_similarity)
    layout = _mlp_layout(n_features, cfg.n_hidden, n_classes)
    k_init, k_rounds = jax.random.split(key)
    k_params, k_probe = jax.random.split(k_init)
    stacked = jax.vmap(lambda k: mlp.init(k, n_features, cfg.n_hidden,
                                          n_classes))(
        jax.random.split(k_params, cfg.n_clients))
    pbytes = mlp.n_bytes(jax.tree.map(lambda a: a[0], stacked))
    # shared unlabeled probe set (server-side, standard FLIS assumption)
    pool = data.x_conf.reshape(-1, n_features)
    idx = jax.random.choice(k_probe, pool.shape[0], (cfg.flis_probe,),
                            replace=False)
    probe = pool[idx]

    accs, assignments = [], []
    for r in range(cfg.rounds):
        ks = _client_keys(k_rounds, cfg.n_clients, r)
        stacked = jax.vmap(lambda p, xt, yt, k: mlp.local_train(
            p, xt, yt, k, epochs=cfg.local_epochs, batch=cfg.batch,
            lr=cfg.lr))(stacked, data.x_train, data.y_train, ks)

        flat = jax.vmap(lambda p: _flatten_mlp(p, layout))(stacked)
        sim = np.asarray(flis_similarity(flat, probe, layout))
        if linkage == "dc":
            labels = np.minimum(_similarity_clusters(sim,
                                                     cfg.flis_threshold),
                                cfg.flis_max_slots - 1)
        else:
            labels = _average_linkage_clusters(sim, cfg.flis_threshold,
                                               cfg.flis_max_slots)

        res = clustering.aggregate(flat, jnp.asarray(labels, jnp.int32),
                                   cfg.flis_max_slots)
        new_flat = res.cluster_weights[jnp.asarray(labels)]
        stacked = jax.vmap(lambda v: _unflatten_mlp(v, layout))(new_flat)

        acc = jax.vmap(mlp.accuracy)(stacked, data.x_test,
                                     data.y_test).mean()
        accs.append(float(acc))
        assignments.append(np.asarray(labels, np.int64))
    total = cfg.rounds * cfg.n_clients * pbytes / 1e6
    return History(accs, total, total, assignments)


def run_flis_hc(data: ClientData, cfg: BaselineConfig, key: jax.Array,
                n_features: int, n_classes: int) -> History:
    return run_flis(data, cfg, key, n_features, n_classes, linkage="hc")


# ---------------------------------------------------------------------------
# FedTM (full-model TM averaging) — the engine's bit-parity reference
# ---------------------------------------------------------------------------

def run_fedtm(data: ClientData, tm_cfg: tm.TMConfig, cfg: BaselineConfig,
              key: jax.Array) -> History:
    """The straight-line FedTM loop ``FedTMStrategy`` is pinned against:
    same key chain as the engine, same flattened one-slot Alg. 2
    aggregation (integer sums are exact in float32, so the rounded
    global mean is bit-identical)."""
    from repro.core import clustering
    k_init, k_rounds = jax.random.split(key)
    params = jax.vmap(lambda k: tm.init_params(tm_cfg, k))(
        jax.random.split(k_init, cfg.n_clients))
    wbytes = tm_cfg.n_classes * tm_cfg.n_clauses * 4   # all-classes weights

    accs, assignments = [], []
    for r in range(cfg.rounds):
        ks = _client_keys(k_rounds, cfg.n_clients, r)
        params = jax.vmap(lambda p, xt, yt, k: tm.train(
            p, xt, yt, k, tm_cfg, epochs=cfg.local_epochs))(
            params, data.x_train, data.y_train, ks)
        # full (C, m) weight averaging across every client — one global
        # slot, no clustering
        flat = params.weights.astype(jnp.float32).reshape(cfg.n_clients, -1)
        res = clustering.aggregate(
            flat, jnp.zeros((cfg.n_clients,), jnp.int32), 1)
        w_global = jnp.round(res.cluster_weights[0]).astype(
            jnp.int32).reshape(tm_cfg.n_classes, tm_cfg.n_clauses)
        params = params._replace(
            weights=jnp.broadcast_to(w_global, params.weights.shape))
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test).mean()
        accs.append(float(acc))
        assignments.append(np.zeros(cfg.n_clients, np.int64))
    total = cfg.rounds * cfg.n_clients * wbytes / 1e6
    return History(accs, total, total, assignments)


BASELINES: dict[str, Callable] = {
    "fedavg": run_fedavg,
    "fedprox": run_fedprox,
    "ifca": run_ifca,
    "flis": run_flis,
    "flis_hc": run_flis_hc,
}
