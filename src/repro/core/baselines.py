"""The paper's five baselines (Table 3/5), reimplemented in JAX.

* FedAvg  (McMahan et al. 2017)          — single global model, full averaging.
* FedProx (Li et al. 2018, µ=0.1)        — FedAvg + proximal local objective.
* IFCA    (Ghosh et al. 2020)            — k global models, loss-minimizing
                                            cluster choice, within-cluster avg.
* FLIS-DC (Morafah et al. 2023, flavour) — clusters from inference similarity
                                            on a shared probe set (no fixed k).
* FedTM   (Qi et al. 2023, flavour)      — TM with *full* (all-classes) weight
                                            averaging, no personalization.

DL baselines run on the repo MLP (`core/mlp.py`); FedTM runs on the same TM
as TPFL so the TPFL-vs-FedTM delta isolates the paper's contribution
(confidence clustering + selective per-class upload).  Communication is
metered from the true parameter byte counts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mlp, tm
from repro.data.partition import ClientData


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    n_clients: int = 100
    rounds: int = 10
    local_epochs: int = 10
    lr: float = 0.05
    batch: int = 32
    n_hidden: int = 128
    prox_mu: float = 0.1       # FedProx (paper §6.6: 0.1)
    ifca_k: int = 10
    flis_threshold: float = 0.9
    flis_probe: int = 64


class History(NamedTuple):
    accuracy: list[float]            # mean client accuracy per round
    upload_mb: float                 # totals over all rounds
    download_mb: float


def _client_keys(key: jax.Array, n: int, r: int) -> jax.Array:
    return jax.random.split(jax.random.fold_in(key, r), n)


# ---------------------------------------------------------------------------
# FedAvg / FedProx
# ---------------------------------------------------------------------------

def run_fedavg(data: ClientData, cfg: BaselineConfig, key: jax.Array,
               n_features: int, n_classes: int,
               prox: bool = False) -> History:
    k_init, k_train = jax.random.split(key)
    global_params = mlp.init(k_init, n_features, cfg.n_hidden, n_classes)
    pbytes = mlp.n_bytes(global_params)
    mu = cfg.prox_mu if prox else 0.0

    def local(p_global, xt, yt, k):
        ref = p_global if prox else None
        return mlp.local_train(p_global, xt, yt, k, epochs=cfg.local_epochs,
                               batch=cfg.batch, lr=cfg.lr,
                               prox_mu=mu, prox_ref=ref)

    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        stacked = jax.vmap(local, in_axes=(None, 0, 0, 0))(
            global_params, data.x_train, data.y_train, ks)
        global_params = mlp.tree_mean(stacked)
        acc = jax.vmap(lambda x, y: mlp.accuracy(global_params, x, y))(
            data.x_test, data.y_test).mean()
        accs.append(float(acc))
    total = cfg.rounds * cfg.n_clients * pbytes / 1e6
    return History(accs, total, total)


def run_fedprox(data: ClientData, cfg: BaselineConfig, key: jax.Array,
                n_features: int, n_classes: int) -> History:
    return run_fedavg(data, cfg, key, n_features, n_classes, prox=True)


# ---------------------------------------------------------------------------
# IFCA
# ---------------------------------------------------------------------------

def run_ifca(data: ClientData, cfg: BaselineConfig, key: jax.Array,
             n_features: int, n_classes: int) -> History:
    k_init, k_train = jax.random.split(key)
    models = jax.vmap(
        lambda k: mlp.init(k, n_features, cfg.n_hidden, n_classes))(
        jax.random.split(k_init, cfg.ifca_k))     # stacked (k, ...)
    pbytes = mlp.n_bytes(jax.tree.map(lambda a: a[0], models))

    def pick(models, xt, yt):
        # client chooses the cluster model with lowest local loss
        losses = jax.vmap(lambda p: mlp.loss_fn(p, xt, yt))(models)
        return jnp.argmin(losses)

    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        choice = jax.vmap(pick, in_axes=(None, 0, 0))(
            models, data.x_train, data.y_train)          # (n,)

        def local(models, j, xt, yt, k):
            p = jax.tree.map(lambda a: a[j], models)
            return mlp.local_train(p, xt, yt, k, epochs=cfg.local_epochs,
                                   batch=cfg.batch, lr=cfg.lr)

        trained = jax.vmap(local, in_axes=(None, 0, 0, 0, 0))(
            models, choice, data.x_train, data.y_train, ks)

        onehot = jax.nn.one_hot(choice, cfg.ifca_k)       # (n, k)
        counts = onehot.sum(0)

        def agg(new, old):
            s = jnp.einsum("n...,nk->k...", new, onehot)
            mean = s / jnp.maximum(counts, 1).reshape(
                (-1,) + (1,) * (new.ndim - 1))
            return jnp.where(
                (counts > 0).reshape((-1,) + (1,) * (new.ndim - 1)),
                mean, old)

        models = jax.tree.map(agg, trained, models)

        def client_acc(models, j, x, y):
            return mlp.accuracy(jax.tree.map(lambda a: a[j], models), x, y)

        acc = jax.vmap(client_acc, in_axes=(None, 0, 0, 0))(
            models, choice, data.x_test, data.y_test).mean()
        accs.append(float(acc))
    up = cfg.rounds * cfg.n_clients * pbytes / 1e6
    down = cfg.rounds * cfg.n_clients * cfg.ifca_k * pbytes / 1e6  # k models down
    return History(accs, up, down)


# ---------------------------------------------------------------------------
# FLIS (dynamic-clustering flavour)
# ---------------------------------------------------------------------------

def _similarity_clusters(sim: np.ndarray, threshold: float) -> np.ndarray:
    """Connected components of the thresholded similarity graph."""
    n = sim.shape[0]
    labels = -np.ones(n, dtype=np.int64)
    cur = 0
    for i in range(n):
        if labels[i] >= 0:
            continue
        stack = [i]
        labels[i] = cur
        while stack:
            u = stack.pop()
            for v in range(n):
                if labels[v] < 0 and sim[u, v] >= threshold:
                    labels[v] = cur
                    stack.append(v)
        cur += 1
    return labels


def run_flis(data: ClientData, cfg: BaselineConfig, key: jax.Array,
             n_features: int, n_classes: int) -> History:
    k_init, k_probe, k_train = jax.random.split(key, 3)
    global_params = mlp.init(k_init, n_features, cfg.n_hidden, n_classes)
    pbytes = mlp.n_bytes(global_params)
    # shared unlabeled probe set (server-side, standard FLIS assumption)
    probe = data.x_conf.reshape(-1, n_features)
    idx = jax.random.choice(k_probe, probe.shape[0], (cfg.flis_probe,),
                            replace=False)
    probe = probe[idx]

    stacked = jax.vmap(lambda k: mlp.init(k, n_features, cfg.n_hidden,
                                          n_classes))(
        jax.random.split(k_init, cfg.n_clients))
    cluster_of = np.zeros(cfg.n_clients, dtype=np.int64)
    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        stacked = jax.vmap(lambda p, xt, yt, k: mlp.local_train(
            p, xt, yt, k, epochs=cfg.local_epochs, batch=cfg.batch,
            lr=cfg.lr))(stacked, data.x_train, data.y_train, ks)

        # inference similarity on the probe set
        preds = jax.vmap(lambda p: jax.nn.softmax(mlp.apply(p, probe)))(
            stacked)                                     # (n, P, C)
        flat = preds.reshape(cfg.n_clients, -1)
        flat = flat / jnp.linalg.norm(flat, axis=1, keepdims=True)
        sim = np.asarray(flat @ flat.T)
        cluster_of = _similarity_clusters(sim, cfg.flis_threshold)

        onehot = jax.nn.one_hot(jnp.asarray(cluster_of),
                                int(cluster_of.max()) + 1)
        counts = onehot.sum(0)

        def agg(a):
            s = jnp.einsum("n...,nk->k...", a, onehot)
            return s / jnp.maximum(counts, 1).reshape(
                (-1,) + (1,) * (a.ndim - 1))

        cluster_models = jax.tree.map(agg, stacked)
        stacked = jax.tree.map(
            lambda cm: cm[jnp.asarray(cluster_of)], cluster_models)

        acc = jax.vmap(mlp.accuracy)(stacked, data.x_test,
                                     data.y_test).mean()
        accs.append(float(acc))
    total = cfg.rounds * cfg.n_clients * pbytes / 1e6
    return History(accs, total, total)


# ---------------------------------------------------------------------------
# FedTM (full-model TM averaging, no personalization)
# ---------------------------------------------------------------------------

def run_fedtm(data: ClientData, tm_cfg: tm.TMConfig, cfg: BaselineConfig,
              key: jax.Array) -> History:
    k_init, k_train = jax.random.split(key)
    params = jax.vmap(lambda k: tm.init_params(tm_cfg, k))(
        jax.random.split(k_init, cfg.n_clients))
    wbytes = tm_cfg.n_classes * tm_cfg.n_clauses * 4   # all-classes weights

    accs = []
    for r in range(cfg.rounds):
        ks = _client_keys(k_train, cfg.n_clients, r)
        params = jax.vmap(lambda p, xt, yt, k: tm.train(
            p, xt, yt, k, tm_cfg, epochs=cfg.local_epochs))(
            params, data.x_train, data.y_train, ks)
        # full (C, m) weight averaging across every client — no clustering
        w_global = jnp.round(params.weights.astype(jnp.float32)
                             .mean(axis=0)).astype(jnp.int32)
        params = params._replace(
            weights=jnp.broadcast_to(w_global, params.weights.shape))
        acc = jax.vmap(lambda p, x, y: tm.accuracy(p, x, y, tm_cfg))(
            params, data.x_test, data.y_test).mean()
        accs.append(float(acc))
    total = cfg.rounds * cfg.n_clients * wbytes / 1e6
    return History(accs, total, total)


BASELINES: dict[str, Callable] = {
    "fedavg": run_fedavg,
    "fedprox": run_fedprox,
    "ifca": run_ifca,
    "flis": run_flis,
}
