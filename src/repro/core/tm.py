"""Vectorized multiclass (weighted) Tsetlin Machine in pure JAX.

This is the client model of TPFL (paper §4.1, Fig. 1, Eq. 1).

Design notes
------------
* All state lives in two integer tensors so the whole machine `vmap`s over
  a population of federated clients and `jit`s end to end:

    - ``ta_state``  (C, m, 2o) int32  — Tsetlin Automaton states in [1, 2N];
      a literal is *included* in a clause iff state > N.
    - ``weights``   (C, m)     int32  — per-clause integer vote weights
      (weighted TM; set ``weighted=False`` for the classic unit-weight TM).

* Clause polarity is positional (paper §4.1): even-indexed clauses are
  positive (vote for the class), odd-indexed are negative.

* Training follows the canonical Type I / Type II feedback of Granmo's TM,
  sample-sequential via ``lax.scan`` (the paper trains clients sample by
  sample).  All stochastic choices use explicit `jax.random` keys.

* The clause-evaluation hot loop is factored through
  :mod:`repro.kernels.ops` so the Pallas TPU kernel and the pure-jnp oracle
  are interchangeable (``use_kernel`` flag).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TMConfig:
    """Hyperparameters, named as in the paper (Table 2)."""

    n_classes: int = 10
    n_clauses: int = 300          # m, per class
    n_features: int = 784        # o (booleanized input bits)
    n_states: int = 127          # N; TA states span [1, 2N]
    s: float = 10.0              # sensitivity (specificity)
    T: int = 1000                # feedback / vote-clip threshold
    weighted: bool = True        # integer-weighted clauses (Eq. 1 weights)
    boost_true_positive: bool = False
    use_kernel: bool = False     # route clause eval through the Pallas kernel

    @property
    def n_literals(self) -> int:
        return 2 * self.n_features


class TMParams(NamedTuple):
    ta_state: jnp.ndarray  # (C, m, 2o) int32
    weights: jnp.ndarray   # (C, m) int32


def init_params(cfg: TMConfig, key: jax.Array) -> TMParams:
    """TA states start at the exclude/include boundary (N or N+1, random)."""
    shape = (cfg.n_classes, cfg.n_clauses, cfg.n_literals)
    coin = jax.random.bernoulli(key, 0.5, shape)
    ta = jnp.where(coin, cfg.n_states, cfg.n_states + 1).astype(jnp.int32)
    w = jnp.ones((cfg.n_classes, cfg.n_clauses), dtype=jnp.int32)
    return TMParams(ta_state=ta, weights=w)


def literals(x: jnp.ndarray) -> jnp.ndarray:
    """L = [x1..xo, ¬x1..¬xo]  (paper §4.1).  x is a boolean/0-1 array."""
    x = x.astype(jnp.int32)
    return jnp.concatenate([x, 1 - x], axis=-1)


def include_mask(params: TMParams, cfg: TMConfig) -> jnp.ndarray:
    return (params.ta_state > cfg.n_states).astype(jnp.int32)


def clause_polarity(cfg: TMConfig) -> jnp.ndarray:
    """+1 for even-indexed clauses, -1 for odd-indexed (paper §4.1)."""
    j = jnp.arange(cfg.n_clauses)
    return jnp.where(j % 2 == 0, 1, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _clause_outputs_jnp(include: jnp.ndarray, lits: jnp.ndarray,
                        predict: bool) -> jnp.ndarray:
    """Conjunctive clause outputs.

    include: (C, m, 2o) int32, lits: (B, 2o) int32 → (B, C, m) int32.

    A clause fires iff no included literal is 0 in the input.  Empty clauses
    (nothing included) output 1 during learning, 0 during inference — the
    standard TM convention.
    """
    C, m, L = include.shape
    inc2 = include.reshape(C * m, L)
    # violations[b, cm] = #(included literals that are 0)
    viol = (1 - lits).astype(jnp.int32) @ inc2.T.astype(jnp.int32)
    fired = (viol == 0).astype(jnp.int32).reshape(lits.shape[0], C, m)
    if predict:
        nonempty = (inc2.sum(-1) > 0).astype(jnp.int32).reshape(1, C, m)
        fired = fired * nonempty
    return fired


def clause_outputs(params: TMParams, lits: jnp.ndarray, cfg: TMConfig,
                   predict: bool = False) -> jnp.ndarray:
    include = include_mask(params, cfg)
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        return kops.clause_outputs(include, lits, predict=predict)
    return _clause_outputs_jnp(include, lits, predict)


def class_votes(params: TMParams, clauses: jnp.ndarray,
                cfg: TMConfig, clip: bool = True) -> jnp.ndarray:
    """Eq. 1: v[b, c] = Σ_j pol_j · w_j · clause_j, clipped to [-T, T]."""
    pol = clause_polarity(cfg)
    w = params.weights if cfg.weighted else jnp.ones_like(params.weights)
    v = jnp.einsum("bcm,cm->bc", clauses.astype(jnp.int32), (pol[None, :] * w))
    if clip:
        v = jnp.clip(v, -cfg.T, cfg.T)
    return v


def forward(params: TMParams, x: jnp.ndarray, cfg: TMConfig,
            predict: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, o) 0/1 → (clause outputs (B,C,m), votes (B,C))."""
    lits = literals(x)
    cl = clause_outputs(params, lits, cfg, predict=predict)
    return cl, class_votes(params, cl, cfg)


def predict(params: TMParams, x: jnp.ndarray, cfg: TMConfig) -> jnp.ndarray:
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        pol = clause_polarity(cfg)
        w = params.weights if cfg.weighted else jnp.ones_like(params.weights)
        votes = kops.fused_votes(include_mask(params, cfg), literals(x),
                                 (pol[None] * w), predict=True)
        # Eq. 1 clips votes to ±T before the argmax; under saturation the
        # clipped and raw argmax can disagree on ties, so the kernel path
        # must clip exactly like class_votes(..., clip=True) does.
        return jnp.argmax(jnp.clip(votes, -cfg.T, cfg.T), axis=-1)
    _, votes = forward(params, x, cfg, predict=True)
    return jnp.argmax(votes, axis=-1)


def accuracy(params: TMParams, x: jnp.ndarray, y: jnp.ndarray,
             cfg: TMConfig) -> jnp.ndarray:
    return (predict(params, x, cfg) == y).mean()


# ---------------------------------------------------------------------------
# Confidence (paper Alg. 1 step 6)
# ---------------------------------------------------------------------------

def confidence_scores(params: TMParams, x_conf: jnp.ndarray,
                      cfg: TMConfig, weighted: bool = False) -> jnp.ndarray:
    """conf[c] = Σ_{x∈D_conf} (Σ_j C⁺_j(x) − Σ_j C⁻_j(x)).

    Alg. 1 uses the *unweighted* clause-vote margin; set ``weighted=True``
    to use the Eq.-1 weighted margin instead (ablation knob).
    """
    lits = literals(x_conf)
    cl = clause_outputs(params, lits, cfg, predict=True)
    pol = clause_polarity(cfg)
    if weighted:
        pol = pol[None, :] * params.weights
        margin = jnp.einsum("bcm,cm->bc", cl, pol)
    else:
        margin = jnp.einsum("bcm,m->bc", cl, pol)
    return margin.sum(axis=0)  # (C,)


# ---------------------------------------------------------------------------
# Training: Type I / Type II feedback
# ---------------------------------------------------------------------------

def _feedback_one_class(ta: jnp.ndarray, w: jnp.ndarray, lits: jnp.ndarray,
                        clause_out: jnp.ndarray, votes: jnp.ndarray,
                        is_target: bool, key: jax.Array, cfg: TMConfig
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply feedback to one class's clause bank for a single sample.

    ta: (m, 2o), w: (m,), lits: (2o,), clause_out: (m,), votes: scalar.
    For the target class, positive-polarity clauses receive Type I and
    negative-polarity Type II; for the sampled negative class it is the
    mirror image.
    """
    m, L = ta.shape
    k_act, k_s1, k_s2 = jax.random.split(key, 3)

    v = jnp.clip(votes, -cfg.T, cfg.T)
    p_act = ((cfg.T - v) if is_target else (cfg.T + v)) / (2.0 * cfg.T)
    active = jax.random.bernoulli(k_act, p_act, (m,))  # clause resampling

    pol = clause_polarity(cfg)  # (m,)
    pos = pol > 0
    type1 = (pos if is_target else ~pos) & active      # (m,)
    type2 = ((~pos) if is_target else pos) & active

    # --- fused Type I / Type II TA transition -----------------------------
    # (Pallas kernel on TPU; jnp oracle otherwise — identical semantics,
    #  see repro/kernels/ref.py::ta_update_ref.)
    p_inc, p_dec = _feedback_probs(cfg)
    u_inc = jax.random.uniform(k_s1, (m, L))
    u_dec = jax.random.uniform(k_s2, (m, L))
    args = (ta, lits[None, :], clause_out[:, None],
            type1.astype(jnp.int32)[:, None], type2.astype(jnp.int32)[:, None],
            u_inc, u_dec)
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        ta = kops.ta_update(*args, p_inc=p_inc, p_dec=p_dec,
                            n_states=cfg.n_states)
    else:
        from repro.kernels import ref as kref
        ta = kref.ta_update_ref(*args, p_inc=p_inc, p_dec=p_dec,
                                n_states=cfg.n_states)

    # --- weights (integer-weighted TM) -----------------------------------
    if cfg.weighted:
        winc = (type1 & clause_out.astype(bool)).astype(jnp.int32)
        wdec = (type2 & clause_out.astype(bool)).astype(jnp.int32)
        w = jnp.maximum(w + winc - wdec, 0)
    return ta, w


def _train_one_sample(params: TMParams, x: jnp.ndarray, y: jnp.ndarray,
                      key: jax.Array, cfg: TMConfig) -> TMParams:
    lits = literals(x[None])                 # (1, 2o)
    cl = clause_outputs(params, lits, cfg)   # (1, C, m)
    votes = class_votes(params, cl, cfg)     # (1, C)
    cl, votes = cl[0], votes[0]
    lits = lits[0]

    k_neg, k_t, k_n = jax.random.split(key, 3)
    # sample a negative class uniformly from the other C-1 classes
    offset = jax.random.randint(k_neg, (), 1, cfg.n_classes)
    ybar = (y + offset) % cfg.n_classes

    def upd(cls_idx, is_target, k):
        ta_c = params.ta_state[cls_idx]
        w_c = params.weights[cls_idx]
        return _feedback_one_class(ta_c, w_c, lits, cl[cls_idx],
                                   votes[cls_idx], is_target, k, cfg)

    ta_t, w_t = upd(y, True, k_t)
    ta = params.ta_state.at[y].set(ta_t)
    w = params.weights.at[y].set(w_t)
    ta_n, w_n = _feedback_one_class(ta[ybar], w[ybar], lits, cl[ybar],
                                    votes[ybar], False, k_n, cfg)
    ta = ta.at[ybar].set(ta_n)
    w = w.at[ybar].set(w_n)
    return TMParams(ta_state=ta, weights=w)


def _feedback_probs(cfg: TMConfig) -> tuple[float, float]:
    p_inc = 1.0 if cfg.boost_true_positive else (cfg.s - 1.0) / cfg.s
    return p_inc, 1.0 / cfg.s


@partial(jax.jit, static_argnames=("cfg",))
def train_epoch(params: TMParams, xs: jnp.ndarray, ys: jnp.ndarray,
                key: jax.Array, cfg: TMConfig) -> TMParams:
    """One sample-sequential pass over (xs, ys) — the paper's local epoch.

    On the kernel path the whole epoch is a single fused ``pallas_call``
    (clause banks stay in VMEM across samples) with the randomness
    pre-generated under the reference key discipline — bit-identical to
    the scan below, pinned by ``tests/test_tm.py``.
    """
    if cfg.use_kernel and cfg.weighted:
        from repro.kernels import draws as kdraws
        from repro.kernels import ops as kops
        p_inc, p_dec = _feedback_probs(cfg)
        offs, u_act, coin = kdraws.epoch_draws(
            key, xs.shape[0], cfg.n_clauses, cfg.n_literals,
            cfg.n_classes, p_inc, p_dec)
        ys32 = ys.astype(jnp.int32)
        cls2 = jnp.stack([ys32, (ys32 + offs) % cfg.n_classes], axis=-1)
        ta, w = kops.train_epoch_fused(
            params.ta_state[None], params.weights[None],
            literals(xs)[None], cls2[None], u_act[None], coin[None],
            n_states=cfg.n_states, T=cfg.T)
        return TMParams(ta_state=ta[0], weights=w[0])

    def step(p, inp):
        x, y, k = inp
        return _train_one_sample(p, x, y, k, cfg), None

    keys = jax.random.split(key, xs.shape[0])
    params, _ = jax.lax.scan(step, params, (xs, ys, keys))
    return params


@partial(jax.jit, static_argnames=("cfg", "epochs"))
def train(params: TMParams, xs: jnp.ndarray, ys: jnp.ndarray,
          key: jax.Array, cfg: TMConfig, epochs: int = 1) -> TMParams:
    def body(p, k):
        return train_epoch(p, xs, ys, k, cfg), None
    params, _ = jax.lax.scan(body, params, jax.random.split(key, epochs))
    return params


# ---------------------------------------------------------------------------
# Client-batched entry points (federated rounds; tm_backend="pallas")
# ---------------------------------------------------------------------------
# All three take params/data with a leading client axis N.  On the
# reference path they are plain vmaps of the per-client functions; on the
# kernel path the whole round is one client-batched kernel launch, which
# is the fast shape (vmap of a pallas_call serializes clients via grid
# batching).  Outputs are bit-identical either way.

@partial(jax.jit, static_argnames=("cfg", "epochs"))
def train_batched(params: TMParams, xs: jnp.ndarray, ys: jnp.ndarray,
                  keys: jnp.ndarray, cfg: TMConfig,
                  epochs: int = 1) -> TMParams:
    """params stacked (N, ...); xs (N,S,o); ys (N,S); keys (N,2)."""
    if not (cfg.use_kernel and cfg.weighted):
        return jax.vmap(
            lambda p, x, y, k: train(p, x, y, k, cfg, epochs)
        )(params, xs, ys, keys)

    from repro.kernels import draws as kdraws
    from repro.kernels import ops as kops
    p_inc, p_dec = _feedback_probs(cfg)
    n_samples = ys.shape[1]
    lits = literals(xs)
    ys32 = ys.astype(jnp.int32)
    # (epochs, N, key): same per-client split(key, epochs) as train()
    ekeys = jnp.swapaxes(
        jax.vmap(lambda k: jax.random.split(k, epochs))(keys), 0, 1)

    def epoch_body(carry, ek):
        ta, w = carry
        offs, u_act, coin = jax.vmap(
            lambda k: kdraws.epoch_draws(k, n_samples, cfg.n_clauses,
                                         cfg.n_literals, cfg.n_classes,
                                         p_inc, p_dec))(ek)
        cls2 = jnp.stack([ys32, (ys32 + offs) % cfg.n_classes], axis=-1)
        ta, w = kops.train_epoch_fused(ta, w, lits, cls2, u_act, coin,
                                       n_states=cfg.n_states, T=cfg.T)
        return (ta, w), None

    (ta, w), _ = jax.lax.scan(epoch_body,
                              (params.ta_state, params.weights), ekeys)
    return TMParams(ta_state=ta, weights=w)


@partial(jax.jit, static_argnames=("cfg", "weighted"))
def confidence_scores_batched(params: TMParams, x_conf: jnp.ndarray,
                              cfg: TMConfig,
                              weighted: bool = False) -> jnp.ndarray:
    """Stacked confidence margins: params (N, ...), x_conf (N,B,o) → (N,C)."""
    if not cfg.use_kernel:
        return jax.vmap(
            lambda p, x: confidence_scores(p, x, cfg, weighted)
        )(params, x_conf)

    from repro.kernels import ops as kops
    include = (params.ta_state > cfg.n_states).astype(jnp.int32)
    pol = clause_polarity(cfg)
    if weighted:
        wpol = pol[None, None, :] * params.weights
    else:
        wpol = jnp.broadcast_to(pol[None, None, :], params.weights.shape)
    margin = kops.fused_votes_batched(include, literals(x_conf), wpol,
                                      predict=True)  # (N, B, C)
    return margin.sum(axis=1)


@partial(jax.jit, static_argnames=("cfg",))
def predict_batched(params: TMParams, x: jnp.ndarray,
                    cfg: TMConfig) -> jnp.ndarray:
    """Stacked predictions: params (N, ...), x (N,B,o) → (N,B) int32.

    The client-batched inference primitive: on the kernel path the
    whole heterogeneous batch — N distinct models, e.g. one per client
    of a mixed-cluster serving request — is a single
    ``fused_votes_batched`` launch, clipped to ±T before the argmax
    exactly like :func:`predict`.  The reference path is a plain vmap
    of :func:`predict`; outputs are bit-identical either way (the
    serving conformance tests pin it)."""
    if not cfg.use_kernel:
        return jax.vmap(lambda p, xx: predict(p, xx, cfg))(params, x)

    from repro.kernels import ops as kops
    include = (params.ta_state > cfg.n_states).astype(jnp.int32)
    pol = clause_polarity(cfg)
    w = params.weights if cfg.weighted else jnp.ones_like(params.weights)
    votes = kops.fused_votes_batched(include, literals(x),
                                     pol[None, None, :] * w, predict=True)
    return jnp.argmax(jnp.clip(votes, -cfg.T, cfg.T), axis=-1)


@partial(jax.jit, static_argnames=("cfg",))
def accuracy_batched(params: TMParams, x: jnp.ndarray, y: jnp.ndarray,
                     cfg: TMConfig) -> jnp.ndarray:
    """Stacked accuracy: params (N, ...), x (N,B,o), y (N,B) → (N,)."""
    if not cfg.use_kernel:
        return jax.vmap(
            lambda p, xx, yy: accuracy(p, xx, yy, cfg))(params, x, y)
    # same math as the vmapped path, via the one batched-votes kernel —
    # serving parity is by construction: eval and serve share this
    return (predict_batched(params, x, cfg) == y).mean(axis=-1)
