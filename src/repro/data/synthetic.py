"""Offline synthetic stand-ins for the paper's datasets.

The container has no network access, so MNIST / FashionMNIST / EMNIST
cannot be downloaded (repro band 2: data gate — simulated per DESIGN.md §2).
We generate *class-structured boolean image* datasets with the same shape
contract (28×28 grayscale → booleanized bits, 10 or 62 classes) so that
every TPFL/baseline experiment runs end to end and the paper's *claims*
(non-IID trends, confidence behaviour, exact communication-cost formulas)
are validated on the same code paths.

Generator model, per dataset flavour:
  * each class c gets a prototype bitmap built from k random axis-aligned
    strokes/rectangles (digit-like for "synthmnist", denser texture patches
    for "synthfashion", 62 thinner glyphs for "synthfemnist");
  * a sample of class c is the prototype with i.i.d. bit-flip noise.

The flip rate controls task difficulty; defaults give TM/MLP headroom
comparable to MNIST (mid-90s centralized accuracy at paper-scale clause
counts).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# the ingest registry is the single source of truth for dataset names;
# this module only knows how to *generate* the synthetic flavours
from repro.data.ingest.registry import SYNTH_DATASETS as DATASETS


@dataclasses.dataclass(frozen=True)
class DataConfig:
    name: str = "synthmnist"
    side: int = 28               # image side; tests shrink this for speed
    n_classes: int = 10
    flip: float = 0.08           # bit-flip noise rate
    n_strokes: int = 4           # prototype complexity

    @property
    def n_features(self) -> int:
        return self.side * self.side


def dataset_config(name: str, side: int = 28) -> DataConfig:
    if name == "synthmnist":
        return DataConfig(name=name, side=side, n_classes=10, flip=0.08,
                          n_strokes=4)
    if name == "synthfashion":
        # denser, noisier textures — harder, mirroring FMNIST < MNIST acc
        return DataConfig(name=name, side=side, n_classes=10, flip=0.12,
                          n_strokes=7)
    if name == "synthfemnist":
        # 62 classes (digits + letters), thin glyphs — hardest
        return DataConfig(name=name, side=side, n_classes=62, flip=0.10,
                          n_strokes=3)
    raise ValueError(f"unknown dataset {name!r}; choose from {DATASETS}")


def _stroke_mask(key: jax.Array, side: int, thin: bool) -> jnp.ndarray:
    """One random axis-aligned bar on a (side, side) grid."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    r0 = jax.random.randint(k1, (), 0, side)
    c0 = jax.random.randint(k2, (), 0, side)
    max_thick = 2 if thin else max(side // 7, 2)
    length = jax.random.randint(k3, (), side // 3, side)
    thick = jax.random.randint(k4, (), 1, max_thick + 1)
    horiz = jax.random.bernoulli(k1, 0.5)
    rr = jnp.arange(side)[:, None]
    cc = jnp.arange(side)[None, :]
    h = (rr >= r0) & (rr < r0 + thick) & (cc >= c0) & (cc < c0 + length)
    v = (cc >= c0) & (cc < c0 + thick) & (rr >= r0) & (rr < r0 + length)
    return jnp.where(horiz, h, v)


def class_prototypes(cfg: DataConfig, key: jax.Array) -> jnp.ndarray:
    """(n_classes, side*side) boolean prototype per class."""
    thin = cfg.name == "synthfemnist"

    def one(k):
        ks = jax.random.split(k, cfg.n_strokes)
        masks = jax.vmap(lambda kk: _stroke_mask(kk, cfg.side, thin))(ks)
        return masks.any(axis=0).reshape(-1)

    return jax.vmap(one)(jax.random.split(key, cfg.n_classes))


def sample(cfg: DataConfig, protos: jnp.ndarray, y: jnp.ndarray,
           key: jax.Array) -> jnp.ndarray:
    """Draw boolean samples for labels ``y`` by noising the prototypes."""
    noise = jax.random.bernoulli(key, cfg.flip, (y.shape[0], cfg.n_features))
    return jnp.logical_xor(protos[y], noise).astype(jnp.uint8)


def make_dataset(name: str, n_samples: int, key: jax.Array,
                 side: int = 28) -> tuple[jnp.ndarray, jnp.ndarray, DataConfig]:
    """Balanced global pool: (X (N, o) uint8 {0,1}, y (N,) int32, cfg)."""
    cfg = dataset_config(name, side=side)
    kp, ky, kx = jax.random.split(key, 3)
    protos = class_prototypes(cfg, kp)
    y = jax.random.randint(ky, (n_samples,), 0, cfg.n_classes)
    x = sample(cfg, protos, y, kx)
    return x, y.astype(jnp.int32), cfg


def booleanize(x: jnp.ndarray, threshold: float = 0.5) -> jnp.ndarray:
    """Grayscale → boolean bits (identity for already-binary data).

    Kept as the public adapter so real MNIST-family arrays drop in when a
    data directory is available (same contract as the paper's
    'independent function ... out of any dataset the user desires').
    """
    if jnp.issubdtype(x.dtype, jnp.floating):
        return (x >= threshold).astype(jnp.uint8)
    if x.dtype == jnp.uint8 and x.max() > 1:
        return (x >= int(255 * threshold)).astype(jnp.uint8)
    return x.astype(jnp.uint8)
