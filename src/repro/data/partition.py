"""Dirichlet client partitioning + the paper's 5 experimental setups.

Paper §6.3: α = 10000 → IID clients, α = 0.05 → non-IID clients.
Experiment e ∈ {1..5} makes ``(e-1)·25%`` of clients non-IID (§6.1, Fig. 3).

We follow Hsu et al. (arXiv:1909.06335), which the paper cites: each client
draws a class-mixture p_i ~ Dir(α·prior) and then samples its local dataset
label-first from the global pool.  Fixed per-client sample counts keep
everything rectangular so the federation vmaps over clients.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

IID_ALPHA = 10000.0
NONIID_ALPHA = 0.05


class ClientData(NamedTuple):
    """Rectangular per-client splits (leading axis = clients)."""

    x_train: jnp.ndarray   # (n_clients, n_train, o)
    y_train: jnp.ndarray   # (n_clients, n_train)
    x_test: jnp.ndarray    # (n_clients, n_test, o)
    y_test: jnp.ndarray
    x_conf: jnp.ndarray    # (n_clients, n_conf, o)  — D_conf (Alg. 1)
    y_conf: jnp.ndarray
    mixtures: jnp.ndarray  # (n_clients, C) the Dirichlet class mixtures
    # (n_clients,) int32 — each client's *deployment* dataset size: its
    # share of the global pool under the Dirichlet size allocation.  The
    # rectangular splits above subsample a fixed per-client budget (the
    # paper's setup), so training cost stays uniform; ``sizes`` carries
    # the size heterogeneity and drives the runtime scheduler's
    # ``weighted`` sampling (clients with more data sampled more often).
    # None for hand-built ClientData (e.g. abstract dry-run inputs).
    sizes: jnp.ndarray | None = None


def client_mixtures(n_clients: int, n_classes: int, frac_noniid: float,
                    key: jax.Array) -> jnp.ndarray:
    """First ``(1-frac)·n`` clients IID, the rest non-IID (paper Fig. 3)."""
    k_iid, k_non = jax.random.split(key)
    alpha_iid = jnp.full((n_classes,), IID_ALPHA)
    alpha_non = jnp.full((n_classes,), NONIID_ALPHA)
    p_iid = jax.random.dirichlet(k_iid, alpha_iid, (n_clients,))
    p_non = jax.random.dirichlet(k_non, alpha_non, (n_clients,))
    n_noniid = int(round(frac_noniid * n_clients))
    is_non = jnp.arange(n_clients) >= (n_clients - n_noniid)
    return jnp.where(is_non[:, None], p_non, p_iid)


def _draw_client(x: jnp.ndarray, y: jnp.ndarray, n_classes: int,
                 mixture: jnp.ndarray, n: int, key: jax.Array
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sample n (x, y) pairs label-first from the global pool.

    Uses Gumbel-top-1 over log-weights so identical labels map to a random
    pool element each draw (with replacement across draws — the pool is a
    generator-backed stand-in, so replacement does not leak test data).
    """
    k_lab, k_pick = jax.random.split(key)
    labels = jax.random.categorical(
        k_lab, jnp.log(mixture + 1e-9), shape=(n,))
    match = (y[None, :] == labels[:, None]).astype(jnp.float32)  # (n, N)
    g = jax.random.gumbel(k_pick, match.shape)
    idx = jnp.argmax(jnp.log(match + 1e-30) + g, axis=1)
    return x[idx], labels


# fold_in tag for the size allocation: a stream disjoint from the
# mixture/draw keys, so adding sizes never perturbs the drawn datasets
_TAG_SIZES = 0x517E5


def client_sizes(n_clients: int, pool: int, key: jax.Array,
                 size_alpha: float = 1.0) -> jnp.ndarray:
    """Dirichlet allocation of the global pool across clients.

    ``size_alpha`` controls heterogeneity: large → near-equal shards,
    1.0 → realistic spread (some clients hold ~10× others).  Every
    client keeps at least one sample.
    """
    props = jax.random.dirichlet(
        key, jnp.full((n_clients,), jnp.float32(size_alpha)))
    return jnp.maximum(jnp.floor(props * pool), 1).astype(jnp.int32)


def partition(x: jnp.ndarray, y: jnp.ndarray, n_classes: int, *,
              n_clients: int, experiment: int, key: jax.Array,
              n_train: int, n_test: int, n_conf: int,
              size_alpha: float = 1.0) -> ClientData:
    """Build the paper's per-client train/test/confidence splits.

    ``experiment`` ∈ {1..5}: fraction of non-IID clients = (experiment-1)/4.
    """
    if not 1 <= experiment <= 5:
        raise ValueError("experiment must be in 1..5")
    frac = (experiment - 1) / 4.0
    k_mix, k_draw = jax.random.split(key)
    mixtures = client_mixtures(n_clients, n_classes, frac, k_mix)
    sizes = client_sizes(n_clients, int(y.shape[0]),
                         jax.random.fold_in(key, _TAG_SIZES), size_alpha)

    n_total = n_train + n_test + n_conf

    def draw(mix, k):
        return _draw_client(x, y, n_classes, mix, n_total, k)

    xs, ys = jax.vmap(draw)(mixtures,
                            jax.random.split(k_draw, n_clients))
    return ClientData(
        x_train=xs[:, :n_train], y_train=ys[:, :n_train],
        x_test=xs[:, n_train:n_train + n_test],
        y_test=ys[:, n_train:n_train + n_test],
        x_conf=xs[:, n_train + n_test:], y_conf=ys[:, n_train + n_test:],
        mixtures=mixtures, sizes=sizes,
    )


# registry-facing name: the *simulated* split, vs the writer-identity
# split in ``repro.data.ingest.natural`` (both produce ClientData)
dirichlet_clients = partition
