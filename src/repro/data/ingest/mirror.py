"""Offline mirror: the synthetic generators re-pointed at real file formats.

The container has no network access, so the mirror writes *genuine*
IDX / LEAF files — built from the class-structured synthetic generators
in :mod:`repro.data.synthetic` — into the ``--data-dir`` cache the
first time a dataset is requested.  From then on every load goes
bytes → parser → encoder → partitioner, the exact pipeline real files
take, so the whole ingestion path is exercised byte-for-byte with no
download; dropping real MNIST/FashionMNIST/LEAF files into the same
cache layout makes the same commands produce the paper's absolute
numbers (the mirror never overwrites existing files).

* :func:`write_idx_mirror` — ``train-images-idx3-ubyte.gz`` +
  ``train-labels-idx1-ubyte.gz``: (N, side, side) u8 grayscale images
  (synthetic bits stored as 0/255, as a thresholded scan would be) and
  u1 labels, each with a ``.sha256`` sidecar.
* :func:`write_leaf_mirror` — ``all_data_<k>.json`` LEAF shards with
  per-writer blocks: writer sample counts drawn from a Dirichlet
  allocation (heterogeneous — some writers hold ~10× others) and a
  per-writer spiked class mixture (each hand favours its own glyphs),
  the natural non-IID structure FEMNIST is used for.

Both generators are pure functions of (flavour, side, counts, seed): a
second call with the same arguments writes byte-identical files.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.ingest import idx, leaf

IMAGES_FILE = "train-images-idx3-ubyte.gz"
LABELS_FILE = "train-labels-idx1-ubyte.gz"

# fold_in tags: one disjoint stream per mirror decision
_TAG_SIZES, _TAG_MIX, _TAG_LABELS, _TAG_PIXELS = 0x1D1, 0x1D2, 0x1D3, 0x1D4

# per-writer class-mixture concentration: spiked (each hand favours a few
# glyphs) but wider than the Dirichlet partitioner's pathological 0.05
WRITER_MIX_ALPHA = 0.3


def _synth_pool(flavour: str, n_samples: int, side: int, seed: int):
    """(N, side²) u8 bits + (N,) labels from the synthetic generator."""
    from repro.data import synthetic
    x, y, cfg = synthetic.make_dataset(flavour, n_samples,
                                       jax.random.PRNGKey(seed), side=side)
    return np.asarray(x, np.uint8), np.asarray(y, np.uint8), cfg


def write_idx_mirror(root: str | pathlib.Path, flavour: str,
                     n_samples: int, side: int, seed: int) -> None:
    """Write the IDX train pair under ``root`` from synthetic ``flavour``."""
    root = pathlib.Path(root)
    x, y, _ = _synth_pool(flavour, n_samples, side, seed)
    images = (x.reshape(n_samples, side, side) * np.uint8(255))
    idx.write(root / IMAGES_FILE, images)
    idx.write(root / LABELS_FILE, y)


def write_leaf_mirror(root: str | pathlib.Path, flavour: str,
                      n_samples: int, side: int, seed: int,
                      n_writers: int = 25) -> None:
    """Write LEAF shards under ``root``: ``n_writers`` synthetic hands
    with heterogeneous sizes and spiked per-writer class mixtures."""
    from repro.data import synthetic
    cfg = synthetic.dataset_config(flavour, side=side)
    key = jax.random.PRNGKey(seed)
    protos = synthetic.class_prototypes(cfg, jax.random.fold_in(key, 0))

    props = jax.random.dirichlet(
        jax.random.fold_in(key, _TAG_SIZES),
        jnp.ones((n_writers,), jnp.float32))
    sizes = np.maximum(
        np.floor(np.asarray(props) * n_samples), 4).astype(np.int64)
    mixtures = jax.random.dirichlet(
        jax.random.fold_in(key, _TAG_MIX),
        jnp.full((cfg.n_classes,), WRITER_MIX_ALPHA), (n_writers,))

    users, xs, ys = [], [], []
    for w in range(n_writers):
        k_lab = jax.random.fold_in(jax.random.fold_in(key, _TAG_LABELS), w)
        k_pix = jax.random.fold_in(jax.random.fold_in(key, _TAG_PIXELS), w)
        y = jax.random.categorical(
            k_lab, jnp.log(mixtures[w] + 1e-9), shape=(int(sizes[w]),))
        x = synthetic.sample(cfg, protos, y, k_pix)
        users.append(f"w{w:04d}")
        # unit-scale floats, as real LEAF FEMNIST stores pixels
        xs.append(np.asarray(x, np.float32))
        ys.append(np.asarray(y, np.int32))
    leaf.write_shards(root, users, xs, ys)
