"""Fetch-and-verify for the real MNIST-family archives.

The ingest registry reads whatever IDX files sit in the cache
(``<data_dir>/<name>/``) — the offline mirror writes synthetic stand-ins,
and real files dropped into the same layout are used transparently.
This module is the missing "drop in the real files" step for
environments *with* network: download the official archives, verify
them against pinned sha256 digests, and only then place them into the
cache (with the ``.sha256`` sidecars :mod:`repro.data.ingest.idx`
checks on every read).  A corrupted or tampered download never touches
the cache: verification happens on a temp file, placement is an atomic
rename.

No network is assumed anywhere else in the repo (CI runs fully
offline): the verify/place machinery is unit-tested against the
offline mirror's files, and :func:`fetch` accepts explicit URL
overrides — including ``file://`` URLs — so the full download path is
exercisable without a socket.

    from repro.data.ingest import fetch
    fetch.fetch("mnist", "~/tpfl-data")          # downloads + verifies
    # then exactly the same commands as the mirror path:
    #   python -m repro.launch.fed_train --dataset mnist --data-dir ~/tpfl-data

Digest provenance: the pinned sha256 values are of the gzip archives as
served by the official mirrors (ossci-datasets for MNIST, the
fashion-mnist release bucket) — the same bytes torchvision pins by md5.
If an upstream mirror ever re-compresses its archives, :func:`fetch`
fails loudly with both digests; pass ``expect=None`` explicitly to
accept an unverified file (the sidecar then records what was stored).
"""
from __future__ import annotations

import hashlib
import pathlib
import shutil
import tempfile
import urllib.request

from repro.data.ingest import idx

#: Official archive sources.  Multiple URLs per file = mirror fallback,
#: tried in order.
MNIST_BASES = (
    "https://ossci-datasets.s3.amazonaws.com/mnist/",
    "https://storage.googleapis.com/cvdf-datasets/mnist/",
)
FASHION_BASES = (
    "http://fashion-mnist.s3-website.eu-central-1.amazonaws.com/",
)

#: name → {filename: sha256-of-the-.gz-archive}
ARCHIVES: dict[str, dict[str, str]] = {
    "mnist": {
        "train-images-idx3-ubyte.gz":
            "440fcabf73cc546fa21475e81ea370265605f56be210a4024d2ca8"
            "f203523609",
        "train-labels-idx1-ubyte.gz":
            "3552534a0a558bbed6aed32b30c495cca23d567ec52cac8be1a0730"
            "e8010255c",
        "t10k-images-idx3-ubyte.gz":
            "8d422c7b0a1c1c79245a5bcf07fe86e33eeafee792b84584aec276f"
            "5a2dbc4e6",
        "t10k-labels-idx1-ubyte.gz":
            "f7ae60f92e00ec6debd23a6088c31dbd2371eca3ffa0defaefb2599"
            "24204aec6",
    },
    "fashionmnist": {
        "train-images-idx3-ubyte.gz":
            "3aede38d61863908ad78613f6a32ed271626dd12800ba2636569512"
            "369268a84",
        "train-labels-idx1-ubyte.gz":
            "a04f17134ac03560a47e3764e11b92fc97de4d1bfaf8ba1a3aa29af"
            "54cc90845",
        "t10k-images-idx3-ubyte.gz":
            "346e55b948d973a97e58d2351dde16a484bd415d4595297633bb08f"
            "03db6a073",
        "t10k-labels-idx1-ubyte.gz":
            "67da17c76eaffca5446c3361aaab5c3cd6d1c2608764d35dfb1850b"
            "086bf8dd5",
    },
}

_BASES = {"mnist": MNIST_BASES, "fashionmnist": FASHION_BASES}


class FetchError(RuntimeError):
    """Download or verification failure — nothing was placed."""


def sha256_path(path: str | pathlib.Path) -> str:
    return hashlib.sha256(pathlib.Path(path).read_bytes()).hexdigest()


def verify_file(path: str | pathlib.Path, expect: str) -> None:
    """Raise :class:`FetchError` unless ``sha256(path) == expect``."""
    got = sha256_path(path)
    if got != expect:
        raise FetchError(
            f"{path}: sha256 mismatch — expected {expect}, got {got}.  "
            f"The download is corrupted or the upstream archive changed; "
            f"nothing was placed into the cache.")


def place(src: str | pathlib.Path, data_dir: str | pathlib.Path,
          name: str, filename: str,
          expect: str | None = None) -> pathlib.Path:
    """Verify ``src`` (when ``expect`` is given) and move it into the
    cache layout the registry reads: ``<data_dir>/<name>/<filename>``
    plus the ``.sha256`` sidecar ``idx.read`` checks.  Atomic: verify
    first, ``rename`` into place, sidecar last.  Refuses to overwrite
    an existing cache file (delete it yourself if you mean it)."""
    src = pathlib.Path(src)
    if expect is not None:
        verify_file(src, expect)
    dest = pathlib.Path(data_dir).expanduser() / name / filename
    if dest.exists():
        raise FetchError(
            f"{dest} already exists — refusing to overwrite a cache "
            f"file (it may be a mirror stand-in or an earlier real "
            f"download; remove it and its .sha256 sidecar first)")
    dest.parent.mkdir(parents=True, exist_ok=True)
    tmp = dest.with_name(dest.name + ".part")
    shutil.move(str(src), tmp)
    tmp.rename(dest)
    idx.write_checksum(dest)
    return dest


def _download(url: str, dest: pathlib.Path, timeout: float) -> None:
    with urllib.request.urlopen(url, timeout=timeout) as r, \
            open(dest, "wb") as f:
        shutil.copyfileobj(r, f)


def fetch(name: str, data_dir: str | pathlib.Path, *,
          urls: dict[str, str] | None = None,
          timeout: float = 60.0) -> list[pathlib.Path]:
    """Download every archive of dataset ``name`` (``mnist`` /
    ``fashionmnist``), verify each against its pinned sha256, and place
    the verified files into ``<data_dir>/<name>/``.  ``urls`` overrides
    the source per filename (``file://`` works — how the offline tests
    exercise this path).  Resumable: a cache file whose sha256 matches
    the pin is skipped; one that does not (an offline-mirror stand-in
    written under the same name, or a corrupted earlier download) fails
    loudly — never silently accepted as the real archive."""
    if name not in ARCHIVES:
        raise ValueError(
            f"no pinned archives for {name!r}; choose from "
            f"{tuple(ARCHIVES)} (femnist/LEAF has no single official "
            f"archive — generate it with the LEAF toolchain)")
    placed = []
    root = pathlib.Path(data_dir).expanduser() / name
    with tempfile.TemporaryDirectory() as td:
        for filename, digest in ARCHIVES[name].items():
            existing = root / filename
            if existing.exists():
                # resumable only if the existing file IS the pinned
                # archive — a synthetic mirror stand-in under the same
                # name must not masquerade as verified real data
                try:
                    verify_file(existing, digest)
                except FetchError as e:
                    raise FetchError(
                        f"{existing} exists but is not the pinned "
                        f"archive (an offline-mirror stand-in or a "
                        f"corrupted download?) — remove it and its "
                        f".sha256 sidecar, then re-run fetch.  {e}"
                    ) from e
                continue
            candidates = ([urls[filename]] if urls and filename in urls
                          else [b + filename for b in _BASES[name]])
            tmp = pathlib.Path(td) / filename
            last_err: Exception | None = None
            for url in candidates:
                try:
                    _download(url, tmp, timeout)
                    last_err = None
                    break
                except OSError as e:          # URLError subclasses OSError
                    last_err = e
            if last_err is not None:
                raise FetchError(
                    f"could not download {filename} from any of "
                    f"{candidates}: {last_err}") from last_err
            placed.append(place(tmp, data_dir, name, filename,
                                expect=digest))
    return placed
