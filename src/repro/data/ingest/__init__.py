"""Dataset ingestion: byte-exact readers, encodings, registry, partitioner.

The layer between raw bytes on disk and the federated runtime:

* :mod:`repro.data.ingest.idx` — the MNIST-family IDX codec, both
  directions, gzip-aware, with sha256 cache sidecars.
* :mod:`repro.data.ingest.leaf` — LEAF-style per-writer JSON shards
  (FEMNIST's natural non-IID distribution format).
* :mod:`repro.data.ingest.encode` — jit-able feature encodings
  (booleanize / thermometer / quantile) shared by TM and MLP paths.
* :mod:`repro.data.ingest.registry` — the ``DatasetSpec`` registry:
  one ``load(name, data_dir)`` for every real and synthetic flavour;
  the single source of truth for dataset names.
* :mod:`repro.data.ingest.mirror` — the offline mirror that writes
  genuine IDX/LEAF files from the synthetic generators, so the whole
  parse→encode→partition path runs with no network.
* :mod:`repro.data.ingest.natural` — writer-identity partitioning of
  LEAF pools onto rectangular ``ClientData``.

See ``docs/datasets.md`` for formats, cache layout, and how to drop in
real data.
"""
from repro.data.ingest.encode import (                    # noqa: F401
    ENCODINGS, Booleanize, Pipeline, Quantile, Thermometer)
from repro.data.ingest.natural import (                   # noqa: F401
    partition_pool, partition_writers)
from repro.data.ingest.registry import (                  # noqa: F401
    REAL_DATASETS, SYNTH_DATASETS, DatasetSpec, Pool, load, names)
