"""Feature encodings: unit-scale pixels → TM-ready bits, jit-able.

Every encoder is a frozen dataclass whose ``__call__`` is a pure
function of a float array in [0, 1] (the registry normalizes raw pixel
scales *before* the pipeline, so nothing here branches on data values
and every transform jits).  Encoders compose via :class:`Pipeline`, and
both the TM path (bits are the literals) and the MLP baselines (bits as
float inputs) consume the same output, so TM-vs-MLP comparisons always
see identical features.

* :class:`Booleanize` — one bit per pixel at the paper's threshold
  (``x >= t``; the "independent booleanization function" of §5).
* :class:`Thermometer` — ``levels`` bits per pixel at evenly spaced
  thresholds ``k/(levels+1)``; bit k is monotone in x and the bit count
  per pixel equals the number of thresholds passed (pinned by tests).
* :class:`Quantile` — thermometer with per-feature thresholds fitted at
  the empirical quantiles of a reference pool (:meth:`Quantile.fit`),
  so every bit fires on ~the same fraction of the data even under
  skewed pixel distributions.

Bit layout is feature-major: pixel f's ``levels`` bits are contiguous
(``f·levels + k``), identical for Thermometer and Quantile, so encoders
with equal level counts are drop-in interchangeable for a fixed model
shape.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

ENCODINGS = ("bool", "thermometer", "quantile")


@dataclasses.dataclass(frozen=True)
class Booleanize:
    threshold: float = 0.5

    def out_features(self, n_in: int) -> int:
        return n_in

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return (x >= self.threshold).astype(jnp.uint8)


@dataclasses.dataclass(frozen=True)
class Thermometer:
    levels: int = 4

    @property
    def thresholds(self) -> jnp.ndarray:
        return (jnp.arange(self.levels, dtype=jnp.float32) + 1.0) \
            / (self.levels + 1.0)

    def out_features(self, n_in: int) -> int:
        return n_in * self.levels

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        bits = (x[..., :, None] >= self.thresholds).astype(jnp.uint8)
        return bits.reshape(*x.shape[:-1], x.shape[-1] * self.levels)


@dataclasses.dataclass(frozen=True)
class Quantile:
    """Per-feature thermometer at fitted quantile thresholds.

    ``thresholds`` is (n_features, levels); build with :meth:`fit` on
    the global pool, then apply anywhere (the transform itself is pure
    and jit-able — fitting is the only data-dependent step and happens
    once, on the host, at load time)."""

    thresholds: jnp.ndarray

    @classmethod
    def fit(cls, pool: jnp.ndarray, levels: int = 4) -> "Quantile":
        qs = (jnp.arange(levels, dtype=jnp.float32) + 1.0) / (levels + 1.0)
        th = jnp.quantile(jnp.asarray(pool, jnp.float32), qs, axis=0)
        return cls(thresholds=th.T)          # (F, levels)

    @property
    def levels(self) -> int:
        return int(self.thresholds.shape[1])

    def out_features(self, n_in: int) -> int:
        return n_in * self.levels

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        bits = (x[..., :, None] > self.thresholds).astype(jnp.uint8)
        return bits.reshape(*x.shape[:-1],
                            x.shape[-1] * self.levels)


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """Left-to-right composition of encoders (all pure → still jit-able)."""

    steps: tuple

    def out_features(self, n_in: int) -> int:
        for step in self.steps:
            n_in = step.out_features(n_in)
        return n_in

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        for step in self.steps:
            x = step(x)
        return x


def build(spec: str, pool: jnp.ndarray | None = None):
    """Parse an encoding spec string into an encoder.

    Accepted forms: ``bool`` / ``bool:<threshold>``,
    ``thermometer:<levels>`` (default 4), ``quantile:<levels>`` (default
    4; needs ``pool``, the unit-scale global pool to fit thresholds on).
    """
    name, _, arg = spec.partition(":")
    if name == "bool":
        return Booleanize(threshold=float(arg) if arg else 0.5)
    if name == "thermometer":
        return Thermometer(levels=int(arg) if arg else 4)
    if name == "quantile":
        if pool is None:
            raise ValueError("quantile encoding needs the pool to fit on")
        return Quantile.fit(pool, levels=int(arg) if arg else 4)
    raise ValueError(
        f"unknown encoding {spec!r}; choose from "
        f"bool[:threshold] | thermometer[:levels] | quantile[:levels]")
