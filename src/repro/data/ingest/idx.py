"""Byte-exact IDX codec — the MNIST-family on-disk format, both ways.

The IDX format (Y. LeCun's spec, as served for MNIST / FashionMNIST /
EMNIST) is::

    magic      4 bytes   00 00 <dtype code> <ndim>
    dims       ndim × u4 big-endian
    data       prod(dims) elements, big-endian, row-major

dtype codes: 0x08 u1, 0x09 i1, 0x0B i2, 0x0C i4, 0x0D f4, 0x0E f8.

``decode(encode(a)) == a`` bit-for-bit for every supported dtype — the
ingest test suite pins this property over random shapes.  ``read`` /
``write`` add the file layer: gzip transparent on read (sniffed from the
two-byte gzip magic, so a ``.gz``-less gzipped file still parses) and
driven by the ``.gz`` suffix on write.

A cache file can carry a ``<name>.sha256`` sidecar holding the hex
digest of the stored bytes (post-gzip).  :func:`verify_bytes` rejects
corruption before a single byte is parsed (the readers check the buffer
they just read — one pass over the file); the offline mirror writes a
sidecar next to everything it generates.
"""
from __future__ import annotations

import gzip
import hashlib
import pathlib
import struct

import numpy as np

# dtype code ↔ numpy dtype (big-endian on the wire)
DTYPE_OF_CODE = {0x08: np.dtype(np.uint8), 0x09: np.dtype(np.int8),
                 0x0B: np.dtype(np.int16), 0x0C: np.dtype(np.int32),
                 0x0D: np.dtype(np.float32), 0x0E: np.dtype(np.float64)}
CODE_OF_DTYPE = {v: k for k, v in DTYPE_OF_CODE.items()}

_GZIP_MAGIC = b"\x1f\x8b"


class IDXFormatError(ValueError):
    """Malformed IDX bytes: bad magic, dtype code, or truncated payload."""


class ChecksumError(ValueError):
    """A cache file does not match its recorded sha256 sidecar."""


# ---------------------------------------------------------------------------
# bytes codec
# ---------------------------------------------------------------------------

def encode(arr: np.ndarray) -> bytes:
    """Serialize ``arr`` to IDX bytes (big-endian payload)."""
    arr = np.asarray(arr)
    code = CODE_OF_DTYPE.get(arr.dtype)
    if code is None:
        raise IDXFormatError(
            f"dtype {arr.dtype} has no IDX code; supported: "
            f"{sorted(str(d) for d in CODE_OF_DTYPE)}")
    if arr.ndim < 1 or arr.ndim > 255:
        raise IDXFormatError(f"IDX needs 1..255 dims, got {arr.ndim}")
    head = struct.pack(">BBBB", 0, 0, code, arr.ndim)
    dims = struct.pack(f">{arr.ndim}I", *arr.shape)
    body = np.ascontiguousarray(arr, dtype=arr.dtype.newbyteorder(">"))
    return head + dims + body.tobytes()


def decode(buf: bytes) -> np.ndarray:
    """Parse IDX bytes back to a (native-byte-order) numpy array.

    Strict: the buffer must hold *exactly* ``prod(dims)`` elements —
    truncation and trailing garbage are both rejected, so a cache hit is
    byte-exactly the file the writer produced.
    """
    if len(buf) < 4:
        raise IDXFormatError("IDX header truncated")
    z0, z1, code, ndim = struct.unpack_from(">BBBB", buf, 0)
    if z0 != 0 or z1 != 0:
        raise IDXFormatError(f"bad IDX magic {buf[:4]!r}")
    dtype = DTYPE_OF_CODE.get(code)
    if dtype is None:
        raise IDXFormatError(f"unknown IDX dtype code 0x{code:02x}")
    if len(buf) < 4 + 4 * ndim:
        raise IDXFormatError("IDX dims truncated")
    dims = struct.unpack_from(f">{ndim}I", buf, 4)
    off = 4 + 4 * ndim
    count = int(np.prod(dims, dtype=np.int64)) if ndim else 0
    expect = off + count * dtype.itemsize
    if len(buf) != expect:
        raise IDXFormatError(
            f"IDX payload is {len(buf) - off} bytes, dims {dims} need "
            f"{expect - off}")
    data = np.frombuffer(buf, dtype=dtype.newbyteorder(">"), count=count,
                         offset=off)
    return data.astype(dtype).reshape(dims)


# ---------------------------------------------------------------------------
# file layer (gzip-aware) + checksum sidecars
# ---------------------------------------------------------------------------

def write(path: str | pathlib.Path, arr: np.ndarray,
          checksum: bool = True) -> pathlib.Path:
    """Write ``arr`` as an IDX file (gzipped when the name ends ``.gz``),
    plus a ``.sha256`` sidecar unless ``checksum=False``."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    raw = encode(arr)
    if path.suffix == ".gz":
        # fixed mtime so identical arrays produce identical file bytes
        raw = gzip.compress(raw, mtime=0)
    path.write_bytes(raw)
    if checksum:
        write_checksum(path)
    return path


def read(path: str | pathlib.Path, verify: bool = True) -> np.ndarray:
    """Read an IDX file; gzip is sniffed from the stored magic.  With
    ``verify`` (default) a ``.sha256`` sidecar, if present, is checked
    against the stored bytes first (on the single buffer already read —
    no second pass over the file)."""
    path = pathlib.Path(path)
    buf = path.read_bytes()
    if verify:
        verify_bytes(path, buf)
    if buf[:2] == _GZIP_MAGIC:
        buf = gzip.decompress(buf)
    return decode(buf)


def sha256_file(path: str | pathlib.Path) -> str:
    return hashlib.sha256(pathlib.Path(path).read_bytes()).hexdigest()


def checksum_path(path: str | pathlib.Path) -> pathlib.Path:
    path = pathlib.Path(path)
    return path.with_name(path.name + ".sha256")


def write_checksum(path: str | pathlib.Path) -> pathlib.Path:
    """Record ``sha256(stored bytes)`` in the file's sidecar."""
    side = checksum_path(path)
    side.write_text(sha256_file(path) + "\n")
    return side


def verify_bytes(path: str | pathlib.Path, buf: bytes) -> None:
    """Check ``buf`` (the stored bytes of ``path``, already in memory)
    against the sidecar digest, if one exists."""
    side = checksum_path(path)
    if not side.exists():
        return
    want = side.read_text().strip()
    got = hashlib.sha256(buf).hexdigest()
    if got != want:
        raise ChecksumError(
            f"checksum mismatch for {path}: sidecar {want[:12]}…, "
            f"file {got[:12]}… — if the file is corrupt, delete it and "
            f"re-fetch; if you deliberately replaced it (e.g. real data "
            f"over a mirror file), delete the stale {side.name!r} "
            f"sidecar")
