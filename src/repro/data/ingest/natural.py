"""Natural (writer-identity) partitioning for LEAF-style pools.

FEMNIST's canonical non-IID split assigns each *writer* to a client —
the heterogeneity is real handwriting style plus genuinely unequal
sample counts, not a simulated Dirichlet draw.  This module maps a
writer-tagged :class:`~repro.data.ingest.registry.Pool` onto the
rectangular :class:`~repro.data.partition.ClientData` the federated
runtime vmaps over:

* writers are grouped onto ``n_clients`` clients in contiguous
  writer-id blocks (one writer per client when counts match; several
  writers per client when there are more writers than clients);
* the rectangular per-client budget (``n_train + n_test + n_conf``
  rows, the paper's fixed-cost setup) is met by deterministic
  subsampling when a client holds more, and by wraparound padding when
  it holds fewer — with the held-out rows (test + conf) reserved
  *before* the training rows, so a padded client never evaluates on
  samples it trained on (train/eval stay disjoint whenever the client
  has at least two samples; test and conf may share rows only when the
  client cannot fill both);
* ``ClientData.sizes`` records each client's *real* pre-budget sample
  count — the heterogeneous deployment sizes that drive the runtime
  scheduler's ``weighted`` sampling;
* ``ClientData.mixtures`` is the client's empirical label histogram
  (over its full writer data, not the subsampled budget), so
  mixture-based diagnostics read the true skew.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import partition as _partition
from repro.data.partition import ClientData

# fold_in tag for the per-client budget draw: disjoint from every other
# stream so adding clients never perturbs earlier ones
_TAG_BUDGET = 0xFE31


def partition_pool(pool, *, n_clients: int, n_train: int, n_test: int,
                   n_conf: int, key: jax.Array,
                   experiment: int = 5) -> ClientData:
    """The one Pool → ClientData dispatch every entry point shares:
    writer-tagged pools take the natural writer split (``experiment``
    does not apply), the rest take the paper's Dirichlet split."""
    if pool.writers is not None:
        return partition_writers(pool, n_clients=n_clients,
                                 n_train=n_train, n_test=n_test,
                                 n_conf=n_conf, key=key)
    return _partition.dirichlet_clients(
        pool.x, pool.y, pool.n_classes, n_clients=n_clients,
        experiment=experiment, key=key, n_train=n_train, n_test=n_test,
        n_conf=n_conf)


def partition_writers(pool, *, n_clients: int, n_train: int, n_test: int,
                      n_conf: int, key: jax.Array) -> ClientData:
    """Writer-natural :class:`ClientData` from a writer-tagged pool."""
    if pool.writers is None:
        raise ValueError(
            f"pool {pool.name!r} carries no writer identities — use the "
            f"Dirichlet partitioner (repro.data.partition) instead")
    writers = np.asarray(pool.writers)
    writer_ids = np.unique(writers)
    if len(writer_ids) < n_clients:
        raise ValueError(
            f"{len(writer_ids)} writers cannot fill {n_clients} clients; "
            f"lower --clients, or — for a mirror-written cache — clear "
            f"the dataset's cache directory and rerun with --writers ≥ "
            f"the client count so the mirror regenerates larger")
    x = np.asarray(pool.x)
    y = np.asarray(pool.y)
    groups = np.array_split(writer_ids, n_clients)

    eval_need = n_test + n_conf
    xs, ys, sizes, mixtures = [], [], [], []
    for i, group in enumerate(groups):
        rows = np.nonzero(np.isin(writers, group))[0]
        sizes.append(len(rows))
        counts = np.bincount(y[rows], minlength=pool.n_classes)
        mixtures.append(counts / counts.sum())
        order = rows[np.asarray(jax.random.permutation(
            jax.random.fold_in(jax.random.fold_in(key, _TAG_BUDGET), i),
            len(rows)))]
        # held-out rows first: padding must never leak a training row
        # into test/conf, so the pools are disjoint (except the
        # degenerate single-sample client, where there is no choice)
        if len(order) > eval_need:
            eval_pool, train_pool = order[:eval_need], order[eval_need:]
        elif len(order) > 1:
            eval_pool, train_pool = order[:-1], order[-1:]
        else:
            eval_pool = train_pool = order
        picked = np.concatenate([
            train_pool[np.arange(n_train) % len(train_pool)],
            eval_pool[np.arange(n_test) % len(eval_pool)],
            eval_pool[(n_test + np.arange(n_conf)) % len(eval_pool)]])
        xs.append(x[picked])
        ys.append(y[picked])

    xs = jnp.asarray(np.stack(xs))
    ys = jnp.asarray(np.stack(ys), jnp.int32)
    return ClientData(
        x_train=xs[:, :n_train], y_train=ys[:, :n_train],
        x_test=xs[:, n_train:n_train + n_test],
        y_test=ys[:, n_train:n_train + n_test],
        x_conf=xs[:, n_train + n_test:], y_conf=ys[:, n_train + n_test:],
        mixtures=jnp.asarray(np.stack(mixtures), jnp.float32),
        sizes=jnp.asarray(np.asarray(sizes), jnp.int32),
    )
