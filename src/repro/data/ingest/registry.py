"""`DatasetSpec` registry: one ``load(name, data_dir)`` for every flavour.

The single source of truth for dataset names — ``synthetic.DATASETS``
and the ``fed_train`` / ``benchmarks`` argparse choices all derive from
this module, so an unknown name fails in exactly one place, with the
registry's listing.

``load`` returns a :class:`Pool` — the encoded global sample pool the
partitioners consume: :func:`repro.data.partition.dirichlet_clients`
for Dirichlet flavours, :func:`repro.data.ingest.natural.partition_writers`
when the pool carries writer identities (LEAF kinds).

Resolution order, per spec kind:

* ``data_dir`` given — files under ``<data_dir>/<name>/`` are parsed
  (checksum-verified when ``.sha256`` sidecars exist).  Missing files
  are first written by the offline mirror
  (:mod:`repro.data.ingest.mirror`), then parsed through the *same*
  byte-level readers — the pool is always a pure function of the file
  bytes, so mirror-written and pre-existing (e.g. real, downloaded)
  files are indistinguishable downstream.  Real MNIST / FashionMNIST
  IDX pairs and real LEAF FEMNIST shards dropped into the cache are
  used transparently; see ``docs/datasets.md`` for the layout.
* ``data_dir=None`` — synthetic flavours fall back to the in-memory
  generator; real flavours raise, since they are only reachable
  through files.  For the IDX flavours the fallback is *bit-identical*
  to the file path (the mirror stores the same bits as 0/255
  grayscale).  ``synthfemnist`` differs by construction: its in-memory
  fallback is the legacy Dirichlet-pool generator with no writer
  identities (callers take the Dirichlet split), while the LEAF mirror
  generates a per-writer pool that partitions naturally — pass a
  ``data_dir`` whenever you want writer-natural behaviour.

Raw pixel scales are normalized to [0, 1] *here* (u8 grayscale → /255,
LEAF floats as-is, synthetic bits as-is), so the encoding pipeline
(:mod:`repro.data.ingest.encode`) stays value-branch-free and jit-able.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.ingest import encode, idx, leaf, mirror

SYNTH_DATASETS = ("synthmnist", "synthfashion", "synthfemnist")
REAL_DATASETS = ("mnist", "fashionmnist", "femnist")

T10K_IMAGES = "t10k-images-idx3-ubyte.gz"
T10K_LABELS = "t10k-labels-idx1-ubyte.gz"


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str              # "idx" (MNIST-family) | "leaf" (writer shards)
    n_classes: int
    flavour: str           # synthetic generator backing the offline mirror
    native_side: int | None = None   # fixed image side (real formats); None
    #                                  → the caller's ``side`` (synth mirrors)

    def side_for(self, side: int | None) -> int:
        return self.native_side or side or 12


SPECS = {
    "synthmnist": DatasetSpec("synthmnist", "idx", 10, "synthmnist"),
    "synthfashion": DatasetSpec("synthfashion", "idx", 10, "synthfashion"),
    "synthfemnist": DatasetSpec("synthfemnist", "leaf", 62, "synthfemnist"),
    "mnist": DatasetSpec("mnist", "idx", 10, "synthmnist",
                         native_side=28),
    "fashionmnist": DatasetSpec("fashionmnist", "idx", 10, "synthfashion",
                                native_side=28),
    "femnist": DatasetSpec("femnist", "leaf", 62, "synthfemnist",
                           native_side=28),
}


class Pool(NamedTuple):
    """Encoded global pool + metadata, ready for a partitioner."""

    x: jnp.ndarray                 # (N, F) uint8 bits (post-encoding)
    y: jnp.ndarray                 # (N,) int32 labels
    writers: jnp.ndarray | None    # (N,) int32 writer ids, or None
    n_classes: int
    n_features: int                # F — *after* encoding (levels included)
    name: str


class StreamPool(NamedTuple):
    """A LEAF dataset as a *writer table*, nothing materialized: what
    :func:`load_stream` returns and
    :class:`repro.fl.store.StreamingClientData` consumes.  Holds the
    shard root, the index-derived writer names + per-writer sample
    counts, and the fitted encoder — per-cohort rows are parsed and
    encoded on demand (``leaf.read_writers``), never the pool."""

    root: pathlib.Path             # shard directory (index.json present)
    users: tuple                   # (W,) writer names, index order
    writer_sizes: tuple            # (W,) per-writer sample counts
    n_classes: int
    n_features: int                # F — *after* encoding (levels included)
    encoder: object                # fitted encode pipeline (elementwise)
    name: str
    verify: bool = True


def names() -> tuple:
    """Every registered dataset name (argparse ``choices`` derive here)."""
    return tuple(SPECS)


def get(name: str) -> DatasetSpec:
    spec = SPECS.get(name)
    if spec is None:
        raise ValueError(
            f"unknown dataset {name!r}; choose from {names()}")
    return spec


def _find(root: pathlib.Path, gz_name: str) -> pathlib.Path | None:
    """Accept the .gz cache name or an uncompressed drop-in — but never
    both: a plain real file silently shadowed by a stale mirror ``.gz``
    (or vice versa) is exactly the wrong-numbers failure the checksums
    exist to prevent, so ambiguity fails loudly."""
    gz, plain = root / gz_name, root / gz_name[:-len(".gz")]
    if gz.exists() and plain.exists():
        raise FileExistsError(
            f"both {gz.name!r} and {plain.name!r} exist under {root} — "
            f"remove the one you don't mean (a mirror-written .gz next "
            f"to a real drop-in, usually), plus any stale .sha256 "
            f"sidecar")
    for cand in (gz, plain):
        if cand.exists():
            return cand
    return None


def _pair(root: pathlib.Path, img_name: str, lab_name: str, what: str):
    """Resolve an images/labels IDX pair; a partial pair fails loudly
    (never mix mirror-written halves with possibly-real drop-ins, and
    never silently shrink the pool)."""
    img, lab = _find(root, img_name), _find(root, lab_name)
    if (img is None) != (lab is None):
        raise FileNotFoundError(
            f"partial {what} IDX pair under {root}: found "
            f"{(img or lab).name!r} without its counterpart — drop in "
            f"the full pair, or remove it")
    return img, lab


def _load_idx_pool(spec: DatasetSpec, root: pathlib.Path, n_samples: int,
                   side: int, seed: int, verify: bool):
    images_path, labels_path = _pair(root, mirror.IMAGES_FILE,
                                     mirror.LABELS_FILE, "train")
    if images_path is None:
        if any(root.glob("t10k-*")):
            # a real held-out pair with no train pair: never silently
            # mix a synthetic mirror train pool into real test data
            raise FileNotFoundError(
                f"{root} holds t10k files but no train pair — drop in "
                f"the real train pair too (the offline mirror refuses "
                f"to write synthetic train data next to real files)")
        mirror.write_idx_mirror(root, spec.flavour, n_samples, side, seed)
        images_path = _find(root, mirror.IMAGES_FILE)
        labels_path = _find(root, mirror.LABELS_FILE)
    images = idx.read(images_path, verify=verify)
    labels = idx.read(labels_path, verify=verify)
    # a real drop-in usually brings the held-out pair too — fold it into
    # the global pool (the partitioners draw per-client splits from it)
    t_img, t_lab = _pair(root, T10K_IMAGES, T10K_LABELS, "t10k")
    if t_img is not None:
        images = np.concatenate(
            [images, idx.read(t_img, verify=verify)], axis=0)
        labels = np.concatenate(
            [labels, idx.read(t_lab, verify=verify)], axis=0)
    if images.ndim != 3 or images.shape[0] != labels.shape[0]:
        raise idx.IDXFormatError(
            f"{root}: images {images.shape} vs labels {labels.shape}")
    unit = images.reshape(images.shape[0], -1).astype(np.float32) / 255.0
    return unit, labels.astype(np.int32), None


def _load_leaf_pool(spec: DatasetSpec, root: pathlib.Path, n_samples: int,
                    side: int, seed: int, n_writers: int, verify: bool):
    if not sorted(root.glob(leaf.SHARD_PATTERN)):
        mirror.write_leaf_mirror(root, spec.flavour, n_samples, side, seed,
                                 n_writers=n_writers)
    pool = leaf.read_shards(root, verify=verify)
    return pool.x, pool.y, pool.writers


def load(name: str, data_dir: str | pathlib.Path | None = None, *,
         encoding: str = "bool", n_samples: int = 6000,
         side: int | None = None, seed: int = 0, n_writers: int = 25,
         verify: bool = True) -> Pool:
    """Load one dataset flavour as an encoded global :class:`Pool`.

    ``n_samples`` / ``side`` / ``n_writers`` / ``seed`` parameterize the
    offline mirror (and the in-memory synthetic fallback); when cache
    files already exist they fully determine the pool and these are
    ignored.  ``encoding`` is an :func:`repro.data.ingest.encode.build`
    spec string.
    """
    spec = get(name)
    if data_dir is None:
        if name not in SYNTH_DATASETS:
            raise ValueError(
                f"dataset {name!r} is file-backed: pass a data_dir (the "
                f"offline mirror will populate it; drop real IDX/LEAF "
                f"files there for absolute paper numbers)")
        from repro.data import synthetic
        x, y, _ = synthetic.make_dataset(name, n_samples,
                                         jax.random.PRNGKey(seed),
                                         side=spec.side_for(side))
        unit, labels, writers = np.asarray(x, np.float32), \
            np.asarray(y, np.int32), None
    else:
        root = pathlib.Path(data_dir) / name
        eff_side = spec.side_for(side)
        if spec.kind == "idx":
            unit, labels, writers = _load_idx_pool(
                spec, root, n_samples, eff_side, seed, verify)
        else:
            unit, labels, writers = _load_leaf_pool(
                spec, root, n_samples, eff_side, seed, n_writers, verify)

    enc = encode.build(encoding, pool=unit)
    bits = enc(jnp.asarray(unit, jnp.float32))
    return Pool(x=bits, y=jnp.asarray(labels, jnp.int32),
                writers=None if writers is None
                else jnp.asarray(writers, jnp.int32),
                n_classes=spec.n_classes,
                n_features=int(bits.shape[1]), name=name)


def load_stream(name: str, data_dir: str | pathlib.Path, *,
                encoding: str = "bool", n_samples: int = 6000,
                side: int | None = None, seed: int = 0,
                n_writers: int = 25, verify: bool = True) -> StreamPool:
    """Load a LEAF flavour as a :class:`StreamPool` — the writer table
    only, for populations too large to materialize.

    Same cache resolution as :func:`load` (mirror-writes missing
    shards, real drop-ins win), but no shard payload beyond the index
    is touched here: ``leaf.ensure_index`` builds the index if missing
    (the one full parse, once), and the encoder is fitted pool-free —
    ``quantile`` encodings need the pool's empirical quantiles, so they
    raise exactly where :func:`repro.data.ingest.encode.build` says so.
    """
    spec = get(name)
    if spec.kind != "leaf":
        raise ValueError(
            f"dataset {name!r} is {spec.kind!r}-backed; streaming "
            f"ingestion needs per-writer LEAF shards — choose a leaf "
            f"flavour ({[n for n, s in SPECS.items() if s.kind == 'leaf']})")
    if data_dir is None:
        raise ValueError(
            f"streaming {name!r} is file-backed by construction: pass "
            f"a data_dir (the offline mirror will populate it)")
    root = pathlib.Path(data_dir) / name
    if not sorted(root.glob(leaf.SHARD_PATTERN)):
        mirror.write_leaf_mirror(root, spec.flavour, n_samples,
                                 spec.side_for(side), seed,
                                 n_writers=n_writers)
    index = leaf.ensure_index(root, verify=verify)
    users, sizes = [], []
    for entry in index["shards"]:
        users.extend(entry["users"])
        sizes.extend(entry["num_samples"])
    enc = encode.build(encoding)       # pool-free: quantile raises here
    n_features = int(
        enc(jnp.zeros((1, index["num_features"]), jnp.float32)).shape[1])
    return StreamPool(root=root, users=tuple(users),
                      writer_sizes=tuple(int(s) for s in sizes),
                      n_classes=spec.n_classes, n_features=n_features,
                      encoder=enc, name=name, verify=verify)
