"""LEAF-style FEMNIST reader/writer — per-writer JSON shards.

The LEAF benchmark suite (Caldas et al.) distributes FEMNIST as JSON
shards, each holding a block of writers::

    {"users":       ["f0000_14", ...],
     "num_samples": [104, ...],
     "user_data":   {"f0000_14": {"x": [[784 floats in [0,1]], ...],
                                  "y": [int, ...]}, ...}}

The *writer* is the natural client: each user's samples come from one
hand, so partitioning by user reproduces the canonical natural non-IID
split (writer = client identity) without any Dirichlet simulation.

:func:`read_shards` concatenates every ``*.json`` shard under a
directory (sorted by name, users in shard order) into one flat pool plus
a per-sample writer id — exactly what the registry hands to the natural
partitioner.  :func:`write_shards` is the inverse used by the offline
mirror; pixel values are written as numbers JSON round-trips exactly
(Python ``repr`` floats), so mirror-written shards parse back
bit-identical.

Streaming: :func:`write_shards` additionally records an ``index.json``
(writer names + sample counts per shard, in the same sorted-name order
:func:`read_shards` walks, plus the feature width) so
:func:`read_writers` can parse **only** the shards a sampled cohort's
writers live in — the ingestion half of the engine's O(K) working set.
:func:`ensure_index` rebuilds a missing index from the shards (one full
parse, once — e.g. for real LEAF drop-ins that ship without one);
``read_shards`` itself never consults the index, so a stale index can
never corrupt the materialized pool.
"""
from __future__ import annotations

import json
import pathlib
from typing import NamedTuple, Sequence

import numpy as np

from repro.data.ingest import idx

SHARD_PATTERN = "all_data_*.json"
INDEX_NAME = "index.json"
INDEX_VERSION = 1


class LeafPool(NamedTuple):
    x: np.ndarray        # (N, F) float32 — unit-scale features, flat
    y: np.ndarray        # (N,)  int32
    writers: np.ndarray  # (N,)  int32 — index into ``users``
    users: tuple         # (W,)  writer names, shard order


class LeafFormatError(ValueError):
    """Malformed LEAF shard: missing keys or inconsistent sample counts."""


def _parse_shard(path: pathlib.Path, verify: bool = True) -> dict:
    """One shard file → its dict (checksum-verified on the single read).

    Module-level on purpose: this is the one seam every shard byte
    passes through, so tests can shim it to count / forbid parses (the
    streaming-ingestion "never materializes the pool" pin)."""
    raw = path.read_bytes()
    if verify:
        idx.verify_bytes(path, raw)     # single read, no second pass
    return json.loads(raw)


def _user_arrays(path: pathlib.Path, shard: dict,
                 name: str, u: int) -> tuple[np.ndarray, np.ndarray]:
    """Validate + extract one user's (x, y) block from a parsed shard —
    shared by the pool reader and the per-writer streaming reader, so
    both reject malformed data identically."""
    user_data = shard["user_data"]
    entry = user_data.get(name)
    if entry is None:
        raise LeafFormatError(
            f"{path}: user {name!r} listed but missing from "
            f"user_data")
    x = np.asarray(entry["x"], dtype=np.float32)
    y = np.asarray(entry["y"], dtype=np.int32)
    if x.ndim != 2 or x.shape[0] != y.shape[0]:
        raise LeafFormatError(
            f"{path}: user {name!r} has x {x.shape} vs y "
            f"{y.shape}")
    num_samples = shard.get("num_samples")
    if num_samples is not None and num_samples[u] != y.shape[0]:
        raise LeafFormatError(
            f"{path}: user {name!r} declares {num_samples[u]} "
            f"samples but holds {y.shape[0]}")
    return x, y


def write_shards(root: str | pathlib.Path, users: Sequence[str],
                 xs: Sequence[np.ndarray], ys: Sequence[np.ndarray],
                 writers_per_shard: int = 10,
                 checksum: bool = True) -> list[pathlib.Path]:
    """Write per-writer data as LEAF JSON shards under ``root``.

    ``xs[i]`` is writer i's (n_i, F) feature block, ``ys[i]`` the labels.
    Returns the shard paths (``all_data_<k>.json`` + ``.sha256``
    sidecars)."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    paths = []
    for k in range(0, len(users), writers_per_shard):
        block = slice(k, k + writers_per_shard)
        names = list(users[block])
        shard = {
            "users": names,
            "num_samples": [int(len(ys[i]))
                            for i in range(*block.indices(len(users)))],
            "user_data": {
                name: {"x": np.asarray(xs[i]).astype(float).tolist(),
                       "y": np.asarray(ys[i]).astype(int).tolist()}
                for name, i in zip(names,
                                   range(*block.indices(len(users))))},
        }
        path = root / f"all_data_{k // writers_per_shard}.json"
        path.write_text(json.dumps(shard))
        if checksum:
            idx.write_checksum(path)
        paths.append(path)
    # the streaming index rides along: per-shard writer names + counts,
    # listed in the sorted-name order read_shards walks, so a writer's
    # global id is derivable without parsing any shard payload
    entries = {p.name: e for p, e in zip(paths, (
        {"file": p.name,
         "users": list(users[k:k + writers_per_shard]),
         "num_samples": [int(len(ys[i])) for i in
                         range(k, min(k + writers_per_shard, len(users)))]}
        for k, p in zip(range(0, len(users), writers_per_shard), paths)))}
    index = {"version": INDEX_VERSION,
             "num_features": int(np.asarray(xs[0]).shape[1]) if xs else 0,
             "shards": [entries[name] for name in sorted(entries)]}
    index_path = root / INDEX_NAME
    index_path.write_text(json.dumps(index))
    if checksum:
        idx.write_checksum(index_path)
    return paths


def read_shards(root: str | pathlib.Path, verify: bool = True) -> LeafPool:
    """Parse every LEAF shard under ``root`` into one flat writer-tagged
    pool.  Shards are read in sorted name order and users in shard
    order, so the writer ids are stable across runs."""
    root = pathlib.Path(root)
    shards = sorted(root.glob(SHARD_PATTERN))
    if not shards:
        raise FileNotFoundError(
            f"no LEAF shards ({SHARD_PATTERN}) under {root}")
    xs, ys, writers, users = [], [], [], []
    for path in shards:
        shard = _parse_shard(path, verify)
        try:
            shard_users = shard["users"]
            shard["user_data"]
        except KeyError as e:
            raise LeafFormatError(f"{path}: missing key {e}") from e
        num_samples = shard.get("num_samples")
        if num_samples is not None and len(num_samples) != len(shard_users):
            raise LeafFormatError(
                f"{path}: num_samples lists {len(num_samples)} entries "
                f"for {len(shard_users)} users")
        for u, name in enumerate(shard_users):
            x, y = _user_arrays(path, shard, name, u)
            wid = len(users)
            users.append(name)
            xs.append(x)
            ys.append(y)
            writers.append(np.full((x.shape[0],), wid, np.int32))
    return LeafPool(x=np.concatenate(xs, axis=0),
                    y=np.concatenate(ys, axis=0),
                    writers=np.concatenate(writers, axis=0),
                    users=tuple(users))


# ---------------------------------------------------------------------------
# streaming: shard index + per-writer reads (no pool materialization)
# ---------------------------------------------------------------------------

def read_index(root: str | pathlib.Path, verify: bool = True) -> dict:
    """Parse ``index.json`` (checksum-verified) and validate it against
    the shard files actually present — a stale index (shards added /
    removed / renamed since it was written) fails loudly rather than
    mis-routing writer ids."""
    root = pathlib.Path(root)
    path = root / INDEX_NAME
    raw = path.read_bytes()
    if verify:
        idx.verify_bytes(path, raw)
    index = json.loads(raw)
    if index.get("version") != INDEX_VERSION:
        raise LeafFormatError(
            f"{path}: index version {index.get('version')!r}, "
            f"expected {INDEX_VERSION}")
    listed = [e["file"] for e in index.get("shards", ())]
    present = [p.name for p in sorted(root.glob(SHARD_PATTERN))]
    if listed != present:
        raise LeafFormatError(
            f"{path} is stale: it lists shards {listed} but the "
            f"directory holds {present} — delete the index (and its "
            f".sha256 sidecar) to rebuild it")
    return index


def ensure_index(root: str | pathlib.Path, verify: bool = True) -> dict:
    """``read_index``, building the index first if missing (one full
    parse over the shards — the only time streaming ever touches them
    all; real LEAF drop-ins ship without an index)."""
    root = pathlib.Path(root)
    if not (root / INDEX_NAME).exists():
        shards = sorted(root.glob(SHARD_PATTERN))
        if not shards:
            raise FileNotFoundError(
                f"no LEAF shards ({SHARD_PATTERN}) under {root}")
        entries, num_features = [], 0
        for path in shards:
            shard = _parse_shard(path, verify)
            try:
                names = list(shard["users"])
                user_data = shard["user_data"]
            except KeyError as e:
                raise LeafFormatError(f"{path}: missing key {e}") from e
            counts = []
            for u, name in enumerate(names):
                x, y = _user_arrays(path, shard, name, u)
                counts.append(int(y.shape[0]))
                num_features = int(x.shape[1])
            del user_data
            entries.append({"file": path.name, "users": names,
                            "num_samples": counts})
        index = {"version": INDEX_VERSION, "num_features": num_features,
                 "shards": entries}
        path = root / INDEX_NAME
        path.write_text(json.dumps(index))
        idx.write_checksum(path)
    return read_index(root, verify)


def read_writers(root: str | pathlib.Path, wids,
                 verify: bool = True) -> dict[int, tuple]:
    """Per-writer ``{wid: (x, y)}`` for just the requested global writer
    ids — only the shards those writers live in are parsed.  Writer ids
    are the :func:`read_shards` enumeration (sorted shard names, users
    in shard order), so a streamed writer block is bit-identical to the
    corresponding pool slice."""
    root = pathlib.Path(root)
    index = read_index(root, verify)
    # global wid → (shard file, user name, position-in-shard)
    table, wid = [], 0
    for entry in index["shards"]:
        for u, name in enumerate(entry["users"]):
            table.append((entry["file"], name, u))
            wid += 1
    wanted = sorted({int(w) for w in np.asarray(wids).reshape(-1)})
    if wanted and (wanted[0] < 0 or wanted[-1] >= len(table)):
        raise ValueError(
            f"writer ids out of range [0, {len(table)}): {wanted[:8]}")
    by_shard: dict[str, list[int]] = {}
    for w in wanted:
        by_shard.setdefault(table[w][0], []).append(w)
    out: dict[int, tuple] = {}
    for fname, ws in by_shard.items():
        path = root / fname
        shard = _parse_shard(path, verify)
        for w in ws:
            _, name, u = table[w]
            out[w] = _user_arrays(path, shard, name, u)
    return out
