"""LEAF-style FEMNIST reader/writer — per-writer JSON shards.

The LEAF benchmark suite (Caldas et al.) distributes FEMNIST as JSON
shards, each holding a block of writers::

    {"users":       ["f0000_14", ...],
     "num_samples": [104, ...],
     "user_data":   {"f0000_14": {"x": [[784 floats in [0,1]], ...],
                                  "y": [int, ...]}, ...}}

The *writer* is the natural client: each user's samples come from one
hand, so partitioning by user reproduces the canonical natural non-IID
split (writer = client identity) without any Dirichlet simulation.

:func:`read_shards` concatenates every ``*.json`` shard under a
directory (sorted by name, users in shard order) into one flat pool plus
a per-sample writer id — exactly what the registry hands to the natural
partitioner.  :func:`write_shards` is the inverse used by the offline
mirror; pixel values are written as numbers JSON round-trips exactly
(Python ``repr`` floats), so mirror-written shards parse back
bit-identical.
"""
from __future__ import annotations

import json
import pathlib
from typing import NamedTuple, Sequence

import numpy as np

from repro.data.ingest import idx

SHARD_PATTERN = "all_data_*.json"


class LeafPool(NamedTuple):
    x: np.ndarray        # (N, F) float32 — unit-scale features, flat
    y: np.ndarray        # (N,)  int32
    writers: np.ndarray  # (N,)  int32 — index into ``users``
    users: tuple         # (W,)  writer names, shard order


class LeafFormatError(ValueError):
    """Malformed LEAF shard: missing keys or inconsistent sample counts."""


def write_shards(root: str | pathlib.Path, users: Sequence[str],
                 xs: Sequence[np.ndarray], ys: Sequence[np.ndarray],
                 writers_per_shard: int = 10,
                 checksum: bool = True) -> list[pathlib.Path]:
    """Write per-writer data as LEAF JSON shards under ``root``.

    ``xs[i]`` is writer i's (n_i, F) feature block, ``ys[i]`` the labels.
    Returns the shard paths (``all_data_<k>.json`` + ``.sha256``
    sidecars)."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    paths = []
    for k in range(0, len(users), writers_per_shard):
        block = slice(k, k + writers_per_shard)
        names = list(users[block])
        shard = {
            "users": names,
            "num_samples": [int(len(ys[i]))
                            for i in range(*block.indices(len(users)))],
            "user_data": {
                name: {"x": np.asarray(xs[i]).astype(float).tolist(),
                       "y": np.asarray(ys[i]).astype(int).tolist()}
                for name, i in zip(names,
                                   range(*block.indices(len(users))))},
        }
        path = root / f"all_data_{k // writers_per_shard}.json"
        path.write_text(json.dumps(shard))
        if checksum:
            idx.write_checksum(path)
        paths.append(path)
    return paths


def read_shards(root: str | pathlib.Path, verify: bool = True) -> LeafPool:
    """Parse every LEAF shard under ``root`` into one flat writer-tagged
    pool.  Shards are read in sorted name order and users in shard
    order, so the writer ids are stable across runs."""
    root = pathlib.Path(root)
    shards = sorted(root.glob(SHARD_PATTERN))
    if not shards:
        raise FileNotFoundError(
            f"no LEAF shards ({SHARD_PATTERN}) under {root}")
    xs, ys, writers, users = [], [], [], []
    for path in shards:
        raw = path.read_bytes()
        if verify:
            idx.verify_bytes(path, raw)     # single read, no second pass
        shard = json.loads(raw)
        try:
            shard_users = shard["users"]
            user_data = shard["user_data"]
        except KeyError as e:
            raise LeafFormatError(f"{path}: missing key {e}") from e
        num_samples = shard.get("num_samples")
        if num_samples is not None and len(num_samples) != len(shard_users):
            raise LeafFormatError(
                f"{path}: num_samples lists {len(num_samples)} entries "
                f"for {len(shard_users)} users")
        for u, name in enumerate(shard_users):
            entry = user_data.get(name)
            if entry is None:
                raise LeafFormatError(
                    f"{path}: user {name!r} listed but missing from "
                    f"user_data")
            x = np.asarray(entry["x"], dtype=np.float32)
            y = np.asarray(entry["y"], dtype=np.int32)
            if x.ndim != 2 or x.shape[0] != y.shape[0]:
                raise LeafFormatError(
                    f"{path}: user {name!r} has x {x.shape} vs y "
                    f"{y.shape}")
            if num_samples is not None and num_samples[u] != y.shape[0]:
                raise LeafFormatError(
                    f"{path}: user {name!r} declares {num_samples[u]} "
                    f"samples but holds {y.shape[0]}")
            wid = len(users)
            users.append(name)
            xs.append(x)
            ys.append(y)
            writers.append(np.full((x.shape[0],), wid, np.int32))
    return LeafPool(x=np.concatenate(xs, axis=0),
                    y=np.concatenate(ys, axis=0),
                    writers=np.concatenate(writers, axis=0),
                    users=tuple(users))
