"""Deterministic batching for both workload kinds.

* :class:`TokenBatcher` — LM training batches (tokens/labels) from the
  modality-appropriate stub stream, seeded per step (what the train
  driver and smoke tests consume; swaps for a real tokenized corpus by
  replacing `_draw`).
* :class:`FederatedSampler` — per-round client minibatch order for the
  TPFL federation (shuffled without replacement per local epoch).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import stubs
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class TokenBatcher:
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    def _draw(self, step: int) -> jnp.ndarray:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return stubs.tokens_for(self.cfg, key, self.batch, self.seq_len + 1)

    def __call__(self, step: int) -> dict[str, jnp.ndarray]:
        toks = self._draw(step)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass(frozen=True)
class FederatedSampler:
    """Per-(client, round, epoch) minibatch order, shuffled without
    replacement.

    Determinism contract (the ingest pipeline and the runtime's
    reproducibility guarantees rely on it, and a tier-1 test pins it):
    the order is a pure function of ``(seed, client, rnd, epoch)`` —
    same tuple, same permutation, on any process, in any call order —
    because each draw keys a fresh ``fold_in`` chain off the seed and
    holds no mutable state.  Distinct tuples give independent streams,
    so adding clients/rounds/epochs never perturbs existing orders.
    """

    n_samples: int
    batch: int
    seed: int = 0

    def epoch_order(self, client: int, rnd: int, epoch: int) -> jnp.ndarray:
        key = jax.random.fold_in(
            jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(self.seed), client),
                rnd), epoch)
        return jax.random.permutation(key, self.n_samples)

    def batches(self, client: int, rnd: int, epoch: int) -> jnp.ndarray:
        order = self.epoch_order(client, rnd, epoch)
        n = (self.n_samples // self.batch) * self.batch
        return order[:n].reshape(-1, self.batch)
