"""Documentation checks — so the docs tree can't rot silently.

Two checks over the repo's markdown (``README.md``, ``docs/**``, and
every ``README.md`` under ``src/``):

* **links** — every relative markdown link ``[text](path)`` must point
  at a file or directory that exists (anchors and absolute URLs are
  skipped).  Catches renames/moves that orphan the docs.
* **examples** — every ``python -m <module>`` (or ``python
  tools/<script>``) appearing in a fenced ```` ```bash ```` block is
  executed in ``--help`` form with ``PYTHONPATH=src``: the module must
  import and its argparse surface must answer.  Catches deleted
  modules, renamed entry points, and import-time breakage without
  paying for a full run.

CI runs both on every push (the ``docs`` job); ``tests/test_docs.py``
runs the cheap link check inside tier-1.

    python tools/check_docs.py [--links-only]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```bash\n(.*?)```", re.DOTALL)
_PY_M = re.compile(r"python\s+-m\s+([\w.]+)")
_PY_SCRIPT = re.compile(r"python\s+((?:tools|benchmarks|examples)/[\w/]+\.py)")


def doc_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    files += sorted((ROOT / "src").glob("**/README.md"))
    return [f for f in files if f.is_file()]


def check_links(files: list[Path]) -> list[str]:
    """Dead relative links, as ``file -> target`` strings (empty = ok)."""
    dead = []
    for f in files:
        for m in _LINK.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (f.parent / path).resolve().exists():
                dead.append(f"{f.relative_to(ROOT)} -> {target}")
    return dead


def example_commands(files: list[Path]) -> list[list[str]]:
    """Every distinct CLI named in a bash fence, as a ``--help`` argv."""
    seen, argvs = set(), []
    for f in files:
        for block in _FENCE.finditer(f.read_text()):
            text = block.group(1).replace("\\\n", " ")
            for mod in _PY_M.findall(text):
                if mod not in seen:
                    seen.add(mod)
                    argvs.append([sys.executable, "-m", mod, "--help"])
            for script in _PY_SCRIPT.findall(text):
                if script not in seen:
                    seen.add(script)
                    argvs.append([sys.executable, str(ROOT / script),
                                  "--help"])
    return argvs


def check_examples(files: list[Path]) -> list[str]:
    """Run each example CLI in ``--help`` form; return failures."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    failures = []
    for argv in example_commands(files):
        shown = " ".join(argv[1:])
        try:
            res = subprocess.run(argv, cwd=ROOT, env=env,
                                 capture_output=True, text=True,
                                 timeout=300)
        except subprocess.TimeoutExpired:
            failures.append(f"{shown}: timed out")
            continue
        if res.returncode != 0:
            tail = (res.stderr or res.stdout).strip().splitlines()[-5:]
            failures.append(f"{shown}: exit {res.returncode}\n  "
                            + "\n  ".join(tail))
        else:
            print(f"ok: {shown}", flush=True)
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--links-only", action="store_true",
                    help="skip executing the fenced CLI examples")
    args = ap.parse_args(argv)

    files = doc_files()
    print(f"checking {len(files)} markdown files", flush=True)
    problems = [f"dead link: {d}" for d in check_links(files)]
    if not args.links_only:
        problems += [f"broken example: {b}"
                     for b in check_examples(files)]
    for p in problems:
        print(p, file=sys.stderr, flush=True)
    print(f"{len(problems)} problem(s)", flush=True)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
