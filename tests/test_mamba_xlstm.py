"""Recurrent mixers: chunked-scan vs exact sequential oracle; decode ≡ apply."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba, xlstm
from repro.models.config import LayerSpec, MambaConfig, ModelConfig


def _cfg(d=32, heads=4):
    return ModelConfig(
        name="t", n_layers=1, d_model=d, n_heads=heads, n_kv_heads=heads,
        d_ff=0, vocab=97, mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        segments=((1, (LayerSpec(mixer="mamba", ffn="none"),)),))


def _f32(p):
    return jax.tree.map(lambda a: a.astype(jnp.float32)
                        if a.dtype == jnp.bfloat16 else a, p)


def mamba_sequential_oracle(params, x, cfg):
    """Step-by-step recurrence (no chunking, no associative scan)."""
    B, T, _ = x.shape
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = mamba._conv_causal(xin, params["conv_w"], params["conv_b"])
    decay, dBx, Cs = mamba._ssm_inputs(params, xc, cfg)
    d_inner = xin.shape[-1]
    h = jnp.zeros((B, d_inner, cfg.mamba.d_state), jnp.float32)
    ys = []
    for t in range(T):
        h = decay[:, t] * h + dBx[:, t]
        ys.append(jnp.einsum("bds,bs->bd", h, Cs[:, t]))
    y = jnp.stack(ys, axis=1) + params["D"] * xc.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    return y @ params["out_proj"]


@pytest.mark.parametrize("T,chunk", [(16, 4), (17, 8), (32, 32)])
@pytest.mark.parametrize("seed", [0, 1])
def test_mamba_chunked_matches_sequential(T, chunk, seed):
    cfg = _cfg()
    p = _f32(mamba.mamba_init(jax.random.PRNGKey(seed), cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 5), (2, T, 32))
    ref = mamba_sequential_oracle(p, x, cfg)
    out = mamba.mamba_apply(p, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_mamba_decode_matches_apply():
    cfg = _cfg()
    p = _f32(mamba.mamba_init(jax.random.PRNGKey(0), cfg))
    T = 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 32))
    full = mamba.mamba_apply(p, x, cfg, chunk=4)
    cache = _f32(mamba.mamba_init_cache(cfg, 2))
    outs = []
    for t in range(T):
        y, cache = mamba.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


def test_mamba_state_is_constant_memory():
    cfg = _cfg()
    cache = mamba.mamba_init_cache(cfg, 3)
    assert cache.h.shape == (3, 64, 4)          # independent of seq len
    assert cache.conv.shape == (3, 3, 64)


@pytest.mark.parametrize("chunk", [4, 16])
def test_mlstm_chunk_invariance_and_decode(chunk):
    cfg = _cfg(d=32, heads=4)
    p = _f32(xlstm.mlstm_init(jax.random.PRNGKey(0), cfg))
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 32)) * 0.5
    full = xlstm.mlstm_apply(p, x, cfg, chunk=chunk)
    base = xlstm.mlstm_apply(p, x, cfg, chunk=T)
    np.testing.assert_allclose(np.asarray(full), np.asarray(base),
                               rtol=2e-4, atol=2e-5)

    cache = _f32(xlstm.mlstm_init_cache(cfg, 2))
    outs = []
    for t in range(T):
        y, cache = xlstm.mlstm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 16])
def test_slstm_chunk_invariance_and_decode(chunk):
    cfg = _cfg(d=32, heads=4)
    p = _f32(xlstm.slstm_init(jax.random.PRNGKey(0), cfg))
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 32)) * 0.5
    full = xlstm.slstm_apply(p, x, cfg, chunk=chunk)
    base = xlstm.slstm_apply(p, x, cfg, chunk=T)
    np.testing.assert_allclose(np.asarray(full), np.asarray(base),
                               rtol=2e-4, atol=2e-5)

    cache = _f32(xlstm.slstm_init_cache(cfg, 2))
    outs = []
    for t in range(T):
        y, cache = xlstm.slstm_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("T,chunk", [(12, 4), (33, 8), (24, 24)])
@pytest.mark.parametrize("seed", [0, 1])
def test_mlstm_chunkwise_matches_sequential(T, chunk, seed):
    """The chunkwise-parallel mLSTM (§Perf optimization) is exactly the
    stabilized recurrence, restructured — values and grads must agree."""
    cfg = _cfg(d=32, heads=4)
    p = _f32(xlstm.mlstm_init(jax.random.PRNGKey(seed), cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 5), (2, T, 32)) * 0.5
    a = xlstm.mlstm_apply(p, x, cfg, chunk=chunk, impl="scan")
    b = xlstm.mlstm_apply(p, x, cfg, chunk=chunk, impl="chunkwise")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-4,
                               atol=1e-5)

    def loss(impl):
        return lambda pp: (xlstm.mlstm_apply(pp, x, cfg, chunk=chunk,
                                             impl=impl) ** 2).sum()

    ga = jax.grad(loss("scan"))(p)
    gb = jax.grad(loss("chunkwise"))(p)
    for kk in ga:
        scale = np.abs(np.asarray(ga[kk])).max() + 1e-9
        err = np.abs(np.asarray(ga[kk] - gb[kk])).max()
        assert err / scale < 1e-3, kk


def test_mlstm_no_nan_long_sequence():
    """Exponential gating must stay stabilized over long ranges."""
    cfg = _cfg(d=32, heads=4)
    p = _f32(xlstm.mlstm_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 32)) * 2.0
    out = xlstm.mlstm_apply(p, x, cfg, chunk=32)
    assert not bool(jnp.isnan(out).any())
