"""Telemetry plane tests (repro.fl.obs): serialization safety, derived
gauges, span accounting, the run-dir artifact pair, the summarizer, and
the end-to-end CLI wiring.

The bit-parity neutrality contract itself (obs-on == obs-off across
both backends and both aggregation modes) lives in
``tests/test_fl_conformance.py`` next to the rest of the parity matrix;
this file covers the obs layer's own behaviour.
"""
import io
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.core import tm
from repro.data import partition, synthetic
from repro.fl import masked_collectives, obs
from repro.fl.obs import events as ev
from repro.fl.obs.summarize import main as obs_cli_main
from repro.fl.obs.tracer import NullTracer, PhaseTracer
from repro.fl.runtime import (Engine, RuntimeConfig, Scheduler,
                              SchedulerConfig, TPFLStrategy, checkpointing)

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=20, n_features=100,
                     n_states=63, s=5.0, T=20)
N_CLIENTS = 8


@pytest.fixture(scope="module")
def data():
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1500,
                                        jax.random.PRNGKey(0), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=N_CLIENTS, experiment=5,
        key=jax.random.PRNGKey(1), n_train=40, n_test=20, n_conf=20)


class _FakeReport:
    """Duck-typed RoundReport for event-derivation unit tests, loaded
    with numpy types that plain ``json`` refuses to serialize."""

    def __init__(self, n=8, j=2, n_slots=4, round_idx=0, assignment=None):
        rng = np.random.default_rng(round_idx)
        self.round_idx = np.int64(round_idx)
        self.per_client_accuracy = rng.uniform(0.3, 1.0, n).astype(
            np.float32)
        self.mean_accuracy = np.float32(self.per_client_accuracy.mean())
        self.assignment = (np.asarray(assignment) if assignment is not None
                           else rng.integers(-1, n_slots, (n, j)))
        counts = np.zeros(n_slots, np.int64)
        flat = self.assignment[self.assignment >= 0]
        np.add.at(counts, flat, 1)
        self.cluster_counts = counts
        self.upload_bytes = np.int64(12345)
        self.download_bytes_broadcast = np.int64(678)
        self.download_bytes_per_client = np.int64(90)
        self.aggregated_uploads = np.int64(n)
        self.buffered_uploads = np.int64(0)
        self.evicted_uploads = np.int64(0)
        self.participation = None


# ---------------------------------------------------------------------------
# serialization: numpy/int64-safe JSONL round-trip
# ---------------------------------------------------------------------------

def test_to_jsonable_coerces_numpy_and_nonfinite():
    raw = {
        "i64": np.int64(2 ** 40), "f32": np.float32(0.5),
        "bool": np.bool_(True), "arr": np.arange(3, dtype=np.int64),
        "nested": [np.float64("nan"), np.float64("inf"), 1.5],
        "path": pathlib.Path("/tmp/x"), "none": None, "s": "ok",
        np.int64(7): "numpy key",
    }
    out = ev.to_jsonable(raw)
    # everything is now plain-JSON: a dumps/loads round-trip is lossless
    assert json.loads(json.dumps(out)) == out
    assert out["i64"] == 2 ** 40 and isinstance(out["i64"], int)
    assert out["bool"] is True
    assert out["arr"] == [0, 1, 2]
    assert out["nested"] == [None, None, 1.5]   # NaN/inf have no JSON
    assert out["path"] == "/tmp/x"
    assert out["7"] == "numpy key"


def test_round_event_jsonl_roundtrip_with_numpy_payload(tmp_path):
    """The satellite contract: an event built from a numpy-laden report
    (int64 counters, float32 accuracies) appends as valid JSONL and
    reads back equal to its jsonable form."""
    path = tmp_path / "events.jsonl"
    written = []
    prev = None
    for r in range(3):
        rep = _FakeReport(round_idx=r)
        event = ev.round_event(rep, spans={"round": np.float64(0.25)},
                               prev_assignment=prev)
        written.append(ev.append_event(path, event))
        prev = rep.assignment
    back = ev.read_events(path)
    assert back == written
    assert [e["round"] for e in back] == [0, 1, 2]
    assert all(e["schema"] == ev.SCHEMA_VERSION for e in back)
    assert back[0]["cluster"]["churn_vs_prev"] is None      # no prev yet
    assert back[1]["cluster"]["churn_vs_prev"] is not None
    assert back[0]["bytes"]["upload"] == 12345


# ---------------------------------------------------------------------------
# derived gauges
# ---------------------------------------------------------------------------

def test_accuracy_deciles_and_worst_decile_mean():
    acc = np.arange(1, 21, dtype=np.float64) / 20.0       # 0.05 .. 1.0
    dec = ev.accuracy_deciles(acc)
    assert len(dec) == 11
    assert dec[0] == pytest.approx(0.05)                  # worst client
    assert dec[-1] == pytest.approx(1.0)                  # best client
    assert dec == sorted(dec)
    # worst decile of 20 clients = the 2 worst
    assert ev.worst_decile_mean(acc) == pytest.approx((0.05 + 0.10) / 2)
    # a single client is its own worst decile
    assert ev.worst_decile_mean([0.7]) == pytest.approx(0.7)


def test_cluster_gauges_churn_occupancy_retention():
    a0 = np.array([[0, 1], [0, -1], [2, -1], [1, 0]])
    rep = _FakeReport(n=4, j=2, n_slots=4, assignment=a0)
    rep.per_client_accuracy = np.array([1.0, 0.5, 0.25, 0.75])
    g = ev._cluster_gauges(rep, prev_assignment=None)
    assert g["occupancy"] == [3, 2, 1, 0]                 # per-slot clients
    assert g["slot_accuracy"][0] == pytest.approx((1.0 + 0.5 + 0.75) / 3)
    assert g["slot_accuracy"][3] is None                  # empty slot
    assert g["empty_slot_retention_rate"] == pytest.approx(1 / 4)
    assert g["churn_vs_prev"] is None
    # one of four clients changes a slot → churn 0.25
    a1 = a0.copy()
    a1[2, 0] = 3
    rep1 = _FakeReport(n=4, j=2, n_slots=4, assignment=a1)
    g1 = ev._cluster_gauges(rep1, prev_assignment=a0)
    assert g1["churn_vs_prev"] == pytest.approx(0.25)


def test_participation_summary_counts_are_consistent():
    sched = Scheduler(SchedulerConfig(participation=0.5, dropout=0.25,
                                      straggler=0.5, max_staleness=3),
                      n_clients=32)
    part = sched.sample(0, jax.random.PRNGKey(0))
    s = part.summary()
    active = np.asarray(part.active)
    assert s["sampled"] == active.shape[0]
    assert s["dropped"] == int((~active).sum())
    assert s["arrived_on_time"] + s["stragglers"] == int(active.sum())
    assert sum(s["staleness_hist"]) == int(active.sum())
    json.dumps(s)                                         # plain types


def test_collective_payload_bytes_formulae():
    # gather ships every upload row: 4 bytes * uploads * dim
    assert masked_collectives.collective_payload_bytes(
        "gather", n_uploads=16, dim=100, n_clusters=10) == 4 * 16 * 100
    # psum ships the (sum, count) accumulators: 4 * clusters * (dim+1)
    assert masked_collectives.collective_payload_bytes(
        "psum", n_uploads=16, dim=100, n_clusters=10) == 4 * 10 * 101
    with pytest.raises(ValueError):
        masked_collectives.collective_payload_bytes("allgather", 1, 1, 1)


# ---------------------------------------------------------------------------
# tracer: span accounting
# ---------------------------------------------------------------------------

def test_phase_tracer_accumulates_discards_and_drains():
    tr = PhaseTracer()
    with tr.span("a"):
        pass
    with tr.span("a"):                                    # re-entry adds
        pass
    with tr.span("b"):
        pass
    with tr.span("vacuous"):
        pass
    tr.discard("vacuous")
    spans = tr.take()
    assert set(spans) == {"a", "b"}
    assert all(v >= 0.0 for v in spans.values())
    assert tr.take() == {}                                # drained


def test_null_tracer_is_inert():
    tr = NullTracer()
    assert tr.enabled is False
    with tr.span("x"):
        pass
    tr.fence(np.zeros(3), None)
    tr.discard("x")
    assert tr.take() == {}
    assert obs.NULL.manifest is None
    obs.NULL.on_round(object())                           # no-op, no raise
    obs.NULL.close()


# ---------------------------------------------------------------------------
# recorder + engine integration
# ---------------------------------------------------------------------------

def test_recorder_run_dir_holds_manifest_and_events(tmp_path, data):
    run_dir = tmp_path / "run"
    cfg = RuntimeConfig(rounds=2)
    rec = obs.RunRecorder(run_dir=run_dir)
    rec.start(obs.build_manifest(config=cfg, seed=0,
                                 extra={"strategy": "tpfl"}))
    engine = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data, cfg,
                    telemetry=rec)
    engine.run(jax.random.PRNGKey(0))
    rec.close()

    manifest = obs.read_manifest(run_dir)
    assert manifest["seed"] == 0
    assert manifest["strategy"] == "tpfl"
    assert manifest["config"]["aggregation"] == "sync"
    assert manifest["config"]["scheduler"]["participation"] == 1.0
    assert manifest["jax_version"] == jax.__version__

    events = ev.read_events(run_dir / "events.jsonl")
    assert len(events) == 2 == len(rec.history)
    assert events == rec.history
    for e in events:
        assert e["accuracy"]["deciles"][0] <= e["accuracy"]["mean"]
        assert e["scheduler"]["sampled"] == N_CLIENTS
        assert e["phases"]["client_step"] > 0.0


def test_phase_spans_sum_to_round_total(data):
    """Acceptance criterion: the per-phase wall times approximately
    account for the whole round — fences bill device work to the stage
    that launched it, so the stage sum can't be a sliver of the total."""
    rec = obs.RunRecorder()                               # in-memory
    engine = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data,
                    RuntimeConfig(rounds=3), telemetry=rec)
    engine.run(jax.random.PRNGKey(0))
    for e in rec.history:
        phases = e["phases"]
        total = phases["round"]
        stages = sum(v for k, v in phases.items() if k != "round")
        assert stages <= total * 1.05                     # no double-billing
        assert stages >= total * 0.5                      # ...and no gaps


def test_async_round_records_buffer_phases(data):
    rec = obs.RunRecorder()
    cfg = RuntimeConfig(rounds=2, aggregation="async", async_min_uploads=2,
                        scheduler=SchedulerConfig(straggler=0.5,
                                                  max_staleness=2))
    Engine(TPFLStrategy(TM_CFG, local_epochs=1), data, cfg,
           telemetry=rec).run(jax.random.PRNGKey(0))
    for e in rec.history:
        assert "aggregate" in e["phases"]
        asy = e["async"]
        assert asy["aggregated"] >= 0 and asy["buffered"] >= 0


def test_checkpoint_carries_manifest_ride_along(tmp_path, data):
    cfg = RuntimeConfig(rounds=2, checkpoint_dir=str(tmp_path / "ck"),
                        checkpoint_every=1)
    rec = obs.RunRecorder()
    rec.start(obs.build_manifest(config=cfg, seed=0))
    engine = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data, cfg,
                    telemetry=rec)
    engine.run(jax.random.PRNGKey(0))
    ck_manifest = json.loads(
        (tmp_path / "ck" / checkpointing.MANIFEST_NAME).read_text())
    assert ck_manifest["seed"] == 0
    assert ck_manifest["config"]["rounds"] == 2
    # restore ignores the provenance file and still works
    like = engine.init(jax.random.PRNGKey(0))
    restored = checkpointing.restore(
        checkpointing.latest(tmp_path / "ck"), like)
    assert restored is not None


# ---------------------------------------------------------------------------
# summarizer + CLI
# ---------------------------------------------------------------------------

def _telemetry_run(tmp_path, data, rounds=2):
    run_dir = tmp_path / "run"
    cfg = RuntimeConfig(rounds=rounds)
    rec = obs.RunRecorder(run_dir=run_dir)
    rec.start(obs.build_manifest(config=cfg, seed=0,
                                 extra={"strategy": "tpfl",
                                        "dataset": "synthmnist"}))
    Engine(TPFLStrategy(TM_CFG, local_epochs=1), data, cfg,
           telemetry=rec).run(jax.random.PRNGKey(0))
    rec.close()
    return run_dir


def test_summarize_renders_run_dir(tmp_path, data):
    run_dir = _telemetry_run(tmp_path, data)
    buf = io.StringIO()
    out = obs.summarize(run_dir, out=buf)
    assert len(out["events"]) == 2
    text = buf.getvalue()
    assert "strategy=tpfl" in text
    assert "client_step" in text                          # phase table
    assert "worst-decile mean" in text                    # decile table
    assert "round total" in text


def test_summarize_refuses_non_run_dir(tmp_path):
    with pytest.raises(SystemExit, match="events.jsonl"):
        obs.summarize(tmp_path)


def test_obs_cli_main_smoke(tmp_path, data, capsys):
    run_dir = _telemetry_run(tmp_path, data)
    assert obs_cli_main(["summarize", str(run_dir)]) == 0
    assert "per-phase wall time" in capsys.readouterr().out


def test_fed_train_telemetry_dir_end_to_end(tmp_path):
    from repro.launch import fed_train
    run_dir = tmp_path / "run"
    out = fed_train.main(["--strategy", "tpfl", "--clients", "6",
                          "--rounds", "2", "--local-epochs", "1",
                          "--telemetry-dir", str(run_dir)])
    assert len(out["acc_per_round"]) == 2
    assert len(out["final_accuracy_deciles"]) == 11
    manifest = obs.read_manifest(run_dir)
    assert manifest["strategy"] == "tpfl"
    assert manifest["rounds"] == 2
    events = ev.read_events(run_dir / "events.jsonl")
    assert len(events) == 2
    # the events' metered bytes match the CLI's own totals
    assert sum(e["bytes"]["upload"] for e in events) == out["upload_bytes"]
    buf = io.StringIO()
    obs.summarize(run_dir, out=buf)
    assert "rounds: 2" in buf.getvalue()
