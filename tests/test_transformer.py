"""Full-model consistency: decode-with-cache ≡ parallel forward, segment
scanning ≡ layer semantics, loss plumbing, reduced-config contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import config as mcfg
from repro.models import transformer
from repro.models.config import LayerSpec, ModelConfig


def _f32(tree):
    return jax.tree.map(lambda a: a.astype(jnp.float32)
                        if a.dtype == jnp.bfloat16 else a, tree)


@pytest.mark.parametrize("arch", ["yi_6b", "deepseek_v3_671b",
                                  "jamba_1_5_large_398b", "xlstm_350m"])
def test_decode_matches_forward(arch):
    """Greedy per-token decode must reproduce the parallel forward logits."""
    cfg = mcfg.reduced(registry.get(arch))
    params = _f32(transformer.init(jax.random.PRNGKey(0), cfg))
    T = 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab)
    full, _ = transformer.forward(params, cfg, tokens=toks, remat=False)

    caches = _f32(transformer.init_cache(cfg, 2, T))
    outs = []
    for t in range(T):
        lg, caches = transformer.decode_step(params, cfg, toks[:, t:t + 1],
                                             caches)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-2, atol=5e-2)
    # and the argmax (what serving actually uses) matches almost always
    agree = (dec.argmax(-1) == full.argmax(-1)).mean()
    assert float(agree) > 0.9


def test_segment_scan_equals_unrolled():
    """(2, [spec]) scanned segments ≡ the same 2 layers listed explicitly."""
    base = dict(name="t", d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab=101)
    spec = LayerSpec(mixer="attn", ffn="dense")
    cfg_scan = ModelConfig(n_layers=2, segments=((2, (spec,)),), **base)
    cfg_unroll = ModelConfig(n_layers=2, segments=((1, (spec, spec)),),
                             **base)
    p = _f32(transformer.init(jax.random.PRNGKey(0), cfg_scan))
    # rebuild the unrolled params from the stacked ones
    stacked = p["segments"][0][0]
    p_unroll = dict(p)
    p_unroll["segments"] = [tuple(
        jax.tree.map(lambda a, i=i: a[i:i + 1], stacked) for i in range(2))]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 101)
    a, _ = transformer.forward(p, cfg_scan, tokens=toks, remat=False)
    b, _ = transformer.forward(p_unroll, cfg_unroll, tokens=toks,
                               remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


def test_tied_embeddings_path():
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=4, d_ff=64, vocab=53,
                      segments=((1, (LayerSpec(),)),), tie_embeddings=True)
    p = transformer.init(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in p
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 53)
    logits, _ = transformer.forward(p, cfg, tokens=toks)
    assert logits.shape == (1, 6, cfg.padded_vocab)   # vocab pads to 128
    assert int(logits.argmax(-1).max()) < 53          # pads masked to −inf


def test_lm_loss_uniform_at_init_scale():
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=4, d_ff=64, vocab=64,
                      segments=((1, (LayerSpec(),)),))
    p = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    loss, parts = transformer.lm_loss(p, cfg, toks, toks)
    # near-uniform logits at init → CE ≈ ln(vocab)
    assert abs(float(parts["ce"]) - float(jnp.log(64.0))) < 1.0


def test_ce_from_logits_valid_mask_broadcasts():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 512))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 512)
    plain = transformer._ce_from_logits(logits, labels)
    masked = transformer._ce_from_logits(logits, labels,
                                         jnp.ones((1, 16)))
    np.testing.assert_allclose(float(plain), float(masked), rtol=1e-6)


def test_mtp_loss_positive_and_masks_tail():
    from repro.configs import registry
    cfg = mcfg.reduced(registry.get("deepseek_v3_671b"))
    p = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    l1 = transformer.mtp_loss(p, cfg, toks, labels, depth=1, weight=0.3)
    # ≈ 0.3 · ln(V) at init (uniform logits)
    assert 0.2 * float(jnp.log(cfg.vocab)) < float(l1) \
        < 0.45 * float(jnp.log(cfg.vocab))


def test_remat_does_not_change_values():
    cfg = mcfg.reduced(registry.get("yi_6b"))
    p = transformer.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    a, _ = transformer.forward(p, cfg, tokens=toks, remat=True)
    b, _ = transformer.forward(p, cfg, tokens=toks, remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_reduced_preserves_family_structure():
    for arch in registry.ARCHS:
        full = registry.get(arch)
        red = mcfg.reduced(full)
        full_mixers = {s.mixer for s in full.layer_list()}
        red_mixers = {s.mixer for s in red.layer_list()}
        assert red_mixers <= full_mixers
        assert (red.moe is None) == (full.moe is None)
        assert red.attn_kind == full.attn_kind


def test_param_count_matches_manual():
    cfg = ModelConfig(name="t", n_layers=1, d_model=8, n_heads=2,
                      n_kv_heads=2, d_ff=16, vocab=11,
                      segments=((1, (LayerSpec(),)),))
    assert cfg.padded_vocab == 128          # vocab pads to a 128 multiple
    n = cfg.param_count()
    dh = 4
    expect = (128 * 8           # embed (padded vocab)
              + 8 * 128         # lm_head (padded vocab)
              + 8                # final norm
              + 8 + 8            # block norms
              + 8 * 2 * dh * 2 + 8 * 2 * dh * 2   # wq wk wv wo (2 heads)
              + 3 * 8 * 16)      # mlp
    assert n == expect


def test_active_params_moe():
    cfg = registry.get("deepseek_v3_671b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert active < total * 0.12        # 37B active of 671B ≈ 5.5%
    # sanity: published numbers ±25%
    assert 5.0e11 < total < 8.5e11, total
    assert 2.7e10 < active < 5.5e10, active
