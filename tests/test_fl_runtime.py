"""Federated runtime system tests: codec, scheduler, engine, resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federation, tm
from repro.data import partition, synthetic
from repro.fl import masked_collectives
from repro.fl.runtime import (CodecConfig, Engine, FedAvgStrategy,
                              IFCAStrategy, RuntimeConfig, Scheduler,
                              SchedulerConfig, TPFLStrategy, checkpointing,
                              codec)

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=20, n_features=100,
                     n_states=63, s=5.0, T=20)


def _data(n_clients=8, experiment=5, seed=0):
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1500,
                                        jax.random.PRNGKey(seed), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=n_clients, experiment=experiment,
        key=jax.random.PRNGKey(seed + 1), n_train=40, n_test=20, n_conf=20)


def _tpfl_engine(data, rt_cfg, local_epochs=1):
    strat = TPFLStrategy(TM_CFG, local_epochs=local_epochs)
    return Engine(strat, data, rt_cfg)


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", codec.CODECS)
@pytest.mark.parametrize("sparse", [False, True])
def test_codec_roundtrip_within_quantization_tolerance(name, sparse):
    rng = np.random.default_rng(0)
    vec = rng.normal(scale=30.0, size=64).astype(np.float32)
    ref = rng.normal(scale=30.0, size=64).astype(np.float32)
    cfg = CodecConfig(name, sparse=sparse)
    buf = codec.encode(vec, cfg, ref=ref)
    out = codec.decode(buf, 64, cfg, ref=ref)
    tol = codec.roundtrip_tolerance(vec - ref if sparse else vec, cfg)
    assert np.abs(out - vec).max() <= tol + 1e-6
    if name == "float32" and not sparse:
        assert (out == vec).all()           # legacy wire format: bit-exact


def test_codec_dense_frame_sizes_exact():
    m = 33
    vec = np.linspace(-5, 5, m).astype(np.float32)
    assert len(codec.encode(vec, CodecConfig("float32"))) == 4 * m
    assert len(codec.encode(vec, CodecConfig("int8"))) == 4 + m
    assert len(codec.encode(vec, CodecConfig("int4"))) == 4 + (m + 1) // 2


def test_codec_sparse_delta_smaller_when_delta_sparse():
    m = 256
    ref = np.arange(m, dtype=np.float32)
    vec = ref.copy()
    vec[[3, 100]] += 7.0                    # two entries changed
    cfg = CodecConfig("int8", sparse=True)
    buf = codec.encode(vec, cfg, ref=ref)
    assert len(buf) < len(codec.encode(vec, CodecConfig("int8")))
    out = codec.decode(buf, m, cfg, ref=ref)
    assert np.abs(out - vec).max() <= codec.roundtrip_tolerance(vec - ref,
                                                                cfg) + 1e-6


def test_metered_bytes_equal_encoded_buffer_length():
    """The engine's upload meter is Σ (4-byte slot id + len(frame))."""
    data = _data(n_clients=4)
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, codec=CodecConfig("int8")))
    _, reports = eng.run(jax.random.PRNGKey(0))
    frame = 4 + (4 + TM_CFG.n_clauses)      # id + (scale + m int8 bytes)
    assert reports[0].upload_bytes == 4 * frame


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_full_participation_is_identity():
    s = Scheduler(SchedulerConfig(), n_clients=6)
    part = s.sample(0, jax.random.PRNGKey(0))
    assert part.idx.tolist() == list(range(6))
    assert bool(part.active.all()) and int(part.staleness.sum()) == 0


def test_scheduler_uniform_samples_k_distinct():
    s = Scheduler(SchedulerConfig(participation=0.25), n_clients=16)
    assert s.k == 4
    part = s.sample(3, jax.random.PRNGKey(1))
    ids = part.idx.tolist()
    assert len(set(ids)) == 4 and all(0 <= i < 16 for i in ids)


def test_scheduler_round_robin_covers_population():
    s = Scheduler(SchedulerConfig(participation=0.25,
                                  sampling="round_robin"), n_clients=8)
    seen = set()
    for r in range(4):
        seen.update(s.sample(r, jax.random.PRNGKey(r)).idx.tolist())
    assert seen == set(range(8))


def test_scheduler_straggler_staleness_bounded():
    s = Scheduler(SchedulerConfig(straggler=1.0, max_staleness=3),
                  n_clients=12)
    part = s.sample(0, jax.random.PRNGKey(2))
    st = part.staleness.tolist()
    assert all(1 <= v <= 3 for v in st)


# ---------------------------------------------------------------------------
# engine: dropout isolation (the paper's non-IID core claim)
# ---------------------------------------------------------------------------

def test_dropped_sole_member_leaves_its_cluster_untouched():
    """dropout = 1.0: every upload is lost, so every cluster — including
    any whose only member was sampled — keeps its previous weights and no
    wrongful aggregation happens."""
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, scheduler=SchedulerConfig(dropout=1.0)))
    state = eng.init(jax.random.PRNGKey(0))
    seeded = state._replace(server=state.server._replace(
        slots=jnp.arange(TM_CFG.n_classes * TM_CFG.n_clauses,
                         dtype=jnp.float32).reshape(TM_CFG.n_classes, -1)))
    new_state, rep = eng.run_round(seeded, jax.random.PRNGKey(1))
    assert (new_state.server.slots == seeded.server.slots).all()
    assert int(rep.cluster_counts.sum()) == 0
    assert int(rep.upload_bytes) == 0
    # the dropped clients' local state is also untouched (crashed mid-round)
    assert (new_state.client_state.weights
            == seeded.client_state.weights).all()


def test_partial_participation_leaves_nonparticipants_unchanged():
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, scheduler=SchedulerConfig(participation=0.25)))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, rep = eng.run_round(state, jax.random.PRNGKey(1))
    part = set(rep.participation.idx.tolist())
    assert len(part) == 2
    for i in range(8):
        same = bool((new_state.client_state.ta_state[i]
                     == state.client_state.ta_state[i]).all())
        if i not in part:
            assert same
            assert int(rep.assignment[i, 0]) == -1


# ---------------------------------------------------------------------------
# engine: legacy reproduction + scenarios
# ---------------------------------------------------------------------------

def test_sync_full_participation_reproduces_legacy_run_round():
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=2, local_epochs=1)
    key = jax.random.PRNGKey(0)

    k_init, k_rounds = jax.random.split(key)
    st = federation.init_state(TM_CFG, fed, k_init)
    legacy = []
    for r in range(fed.rounds):
        st, m = federation.run_round(
            st, data, jax.random.fold_in(k_rounds, r), TM_CFG, fed)
        legacy.append(m)

    st2, hist = federation.run(data, TM_CFG, fed, key)
    for a, b in zip(legacy, hist):
        assert float(a.mean_accuracy) == float(b.mean_accuracy)
        assert (a.assignment == b.assignment).all()
        assert (a.cluster_counts == b.cluster_counts).all()
        assert a.upload_bytes == b.upload_bytes
        assert a.download_bytes_broadcast == b.download_bytes_broadcast
        assert a.download_bytes_per_client == b.download_bytes_per_client
    assert (st.client_params.weights == st2.client_params.weights).all()
    assert jnp.allclose(st.cluster_weights, st2.cluster_weights)


def test_async_buffered_aggregation_applies_mature_uploads():
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=3, aggregation="async", async_min_uploads=2,
        scheduler=SchedulerConfig(participation=0.5, straggler=0.5,
                                  max_staleness=2)))
    _, reports = eng.run(jax.random.PRNGKey(0))
    total_agg = sum(r.aggregated_uploads for r in reports)
    assert total_agg > 0
    assert all(r.evicted_uploads == 0 for r in reports)
    # stale uploads either matured (aggregated) or still sit in the buffer
    sent = sum(int(r.participation.active.sum()) for r in reports)
    assert total_agg + reports[-1].buffered_uploads == sent
    # the async path must not wreck the models (e.g. by broadcasting
    # never-aggregated zero slots over freshly trained clients)
    assert float(reports[-1].mean_accuracy) > 0.4


@pytest.mark.parametrize("buffer", ["device", "host"])
def test_async_below_threshold_broadcasts_nothing(buffer):
    """Rounds where the buffer stays below B must leave both the server
    and the clients' locally trained weights untouched."""
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, aggregation="async", async_min_uploads=10 ** 6,
        async_buffer=buffer))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, rep = eng.run_round(state, jax.random.PRNGKey(1))
    assert rep.aggregated_uploads == 0
    assert (new_state.server.slots == state.server.slots).all()
    assert (rep.assignment == -1).all()          # nothing applied
    assert rep.download_bytes_per_client == 0    # nothing billed either
    # clients keep their local training: accuracy ≈ isolated-TM level
    assert float(rep.mean_accuracy) > 0.5


@pytest.mark.parametrize("buffer", ["device", "host"])
def test_async_overflow_evicts_oldest_insertion_first(buffer):
    """4 uploads into a capacity-2 buffer: the two oldest are evicted,
    the two newest survive."""
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, aggregation="async", async_min_uploads=10 ** 6,
        buffer_capacity=2, async_buffer=buffer,
        scheduler=SchedulerConfig(participation=0.5)))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, rep = eng.run_round(state, jax.random.PRNGKey(1))
    assert rep.evicted_uploads == 2
    assert rep.buffered_uploads == 2
    assert new_state.buf_seq.tolist() == [2, 3]      # newest insertions


@pytest.mark.parametrize("buffer", ["device", "host"])
def test_async_zero_staleness_weight_never_populates_a_slot(buffer):
    """discount=0 + every upload stale → zero aggregate weight: the
    server must keep its previous rows rather than zeroing them."""
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, aggregation="async", async_min_uploads=1,
        staleness_discount=0.0, async_buffer=buffer,
        scheduler=SchedulerConfig(straggler=1.0, max_staleness=1)))
    state = eng.init(jax.random.PRNGKey(0))
    seeded = state._replace(server=state.server._replace(
        slots=jnp.full_like(state.server.slots, 7.0)))
    # round 0 buffers everything (staleness 1); round 1 matures them
    mid, rep0 = eng.run_round(seeded, jax.random.PRNGKey(1))
    new_state, rep1 = eng.run_round(mid, jax.random.PRNGKey(2))
    assert rep0.aggregated_uploads == 0
    assert rep1.aggregated_uploads == 0          # weight-0 ≠ contribution
    assert (new_state.server.slots == seeded.server.slots).all()
    assert (rep1.assignment == -1).all()         # nothing broadcast


@pytest.mark.parametrize("buffer", ["device", "host"])
def test_async_maturing_exactly_at_min_uploads_aggregates(buffer):
    """The maturity gate is ≥, not >: a round whose matured count lands
    exactly on ``async_min_uploads`` aggregates all of them and drains
    the buffer."""
    data = _data()   # 8 clients, full participation, j = 1 → 8 uploads
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, aggregation="async", async_min_uploads=8,
        async_buffer=buffer))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, rep = eng.run_round(state, jax.random.PRNGKey(1))
    assert rep.aggregated_uploads == 8
    assert rep.buffered_uploads == 0
    assert not bool(np.asarray(new_state.buf_valid).any())
    # one fewer upload must NOT aggregate
    eng9 = _tpfl_engine(data, RuntimeConfig(
        rounds=1, aggregation="async", async_min_uploads=9,
        async_buffer=buffer))
    _, rep9 = eng9.run_round(eng9.init(jax.random.PRNGKey(0)),
                             jax.random.PRNGKey(1))
    assert rep9.aggregated_uploads == 0
    assert rep9.buffered_uploads == 8


def test_async_entries_can_outlive_max_staleness_ungated():
    """An upload whose maturity round has long passed (buffer age >
    max_staleness because the B-threshold never fired) must stay valid
    with its original discount weight — age in the buffer is not
    staleness, and nothing silently expires."""
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=4, aggregation="async", async_min_uploads=10 ** 6,
        buffer_capacity=64,
        scheduler=SchedulerConfig(participation=0.25, straggler=1.0,
                                  max_staleness=2)))
    state = eng.init(jax.random.PRNGKey(0))
    for r in range(4):
        state, rep = eng.run_round(state, jax.random.fold_in(
            jax.random.PRNGKey(0), r))
        assert rep.aggregated_uploads == 0
    valid = np.asarray(state.buf_valid)
    ready = np.asarray(state.buf_ready)[valid]
    weight = np.asarray(state.buf_weight)[valid]
    assert valid.sum() == 4 * 2                 # K=2 per round, none lost
    # round-0 entries matured at ready ≤ 2 — two rounds “overdue” by now
    assert int(ready.min()) <= 2 < int(state.round_idx)
    assert (weight >= 0.5 ** 2 - 1e-7).all()    # discount from staleness,
    assert (weight <= 1.0).all()                # never from buffer age


def test_client_step_consumes_codec_roundtripped_broadcast():
    """ROADMAP fix: local training must start from the broadcast rows a
    client would actually hold after a lossy downlink, not the
    aggregator's full-precision state.  Spy on the server matrix the
    engine hands the executor's train stage."""
    from repro.fl.runtime import codec as codec_mod
    data = _data(n_clients=4)
    wire = CodecConfig("int8")
    eng = Engine(FedAvgStrategy(n_features=100, n_classes=10, n_hidden=16,
                                local_epochs=1),
                 data, RuntimeConfig(rounds=1, codec=wire))
    state = eng.init(jax.random.PRNGKey(0))
    seen = {}
    orig = eng.executor.train

    def spy(strategy, cs, server, d, keys):
        seen["server"] = np.asarray(server)
        return orig(strategy, cs, server, d, keys)

    eng.executor.train = spy
    eng.run_round(state, jax.random.PRNGKey(1))

    full = np.asarray(state.server.slots, np.float32)
    dense = CodecConfig("int8")
    expect = np.stack([
        codec_mod.decode(codec_mod.encode(full[s], dense), full.shape[1],
                         dense) for s in range(full.shape[0])])
    assert (seen["server"] == expect).all()
    assert (expect != full).any()        # int8 really did lose precision


def test_wire_tx_server_is_identity_for_dense_float32():
    data = _data(n_clients=4)
    eng = _tpfl_engine(data, RuntimeConfig(rounds=1))
    server = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    assert eng._wire_tx_server(server) is server


def test_engine_run_rounds_override_completes_remainder():
    data = _data()
    eng = _tpfl_engine(data, RuntimeConfig(rounds=3))
    key = jax.random.PRNGKey(5)
    state, reports = eng.run(key, rounds=1)
    assert len(reports) == 1 and int(state.round_idx) == 1
    state, reports = eng.run(key, state=state, rounds=2)
    assert len(reports) == 2 and int(state.round_idx) == 3


def test_checkpoint_resume_is_bit_identical(tmp_path):
    data = _data()
    key = jax.random.PRNGKey(5)
    full = _tpfl_engine(data, RuntimeConfig(rounds=4))
    state_full, reports_full = full.run(key)

    half = _tpfl_engine(data, RuntimeConfig(
        rounds=2, checkpoint_dir=str(tmp_path), checkpoint_every=2))
    half.run(key)
    ck = checkpointing.latest(tmp_path)
    assert ck is not None and "round_000002" in ck.name
    resumed = checkpointing.restore(
        ck, half.init(jax.random.PRNGKey(0)))
    state_res, reports_res = half.run(key, state=resumed)

    assert int(state_res.round_idx) == 4
    for a, b in zip(reports_full[2:], reports_res):
        assert float(a.mean_accuracy) == float(b.mean_accuracy)
        assert (a.assignment == b.assignment).all()
    assert (state_full.client_state.weights
            == state_res.client_state.weights).all()


def test_lossy_downlink_is_applied_to_clients():
    """Clients must receive the codec-roundtripped broadcast, not the
    aggregator's full-precision rows."""
    data = _data(n_clients=4)
    eng = _tpfl_engine(data, RuntimeConfig(
        rounds=1, codec=CodecConfig("int4")))
    state = eng.init(jax.random.PRNGKey(0))
    new_state, rep = eng.run_round(state, jax.random.PRNGKey(1))
    dense = CodecConfig("int4")
    checked = 0
    for i in range(4):
        s = int(rep.assignment[i, 0])
        if s < 0:
            continue
        row = np.asarray(new_state.server.slots[s], np.float32)
        rx = codec.decode(codec.encode(row, dense), TM_CFG.n_clauses,
                          dense)
        expect = np.round(rx).astype(np.int32)
        got = np.asarray(new_state.client_state.weights[i, s])
        assert (got == expect).all()
        checked += 1
    assert checked > 0


def test_conf_threshold_cuts_metered_upload_bytes():
    """Slot −1 ('nothing shared') sends no frame: §7 selective sharing
    shows up in the byte-exact meter, not just in cluster counts."""
    data = _data(n_clients=4)
    gated = Engine(TPFLStrategy(TM_CFG, local_epochs=1,
                                conf_threshold=1e9),
                   data, RuntimeConfig(rounds=1))
    _, reports = gated.run(jax.random.PRNGKey(0))
    assert reports[0].upload_bytes == 0
    assert reports[0].download_bytes_per_client == 0


def test_federation_run_rounds_follow_fed_cfg():
    """fed_cfg.rounds is authoritative even when a runtime_cfg is passed
    for scenario knobs (its default rounds must not leak in)."""
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1)
    _, hist = federation.run(data, TM_CFG, fed, jax.random.PRNGKey(0),
                             runtime_cfg=RuntimeConfig(
                                 codec=CodecConfig("int8")))
    assert len(hist) == 1


def test_weighted_clustered_mean_matches_unweighted_at_one():
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(key, (12, 7))
    assign = jax.random.randint(key, (12,), 0, 4)
    a = masked_collectives.clustered_mean(vals, assign, 4)
    b = masked_collectives.clustered_weighted_mean(
        vals, assign, jnp.ones(12), 4)
    assert jnp.allclose(a, b, atol=1e-5)


def test_engine_runs_dl_baseline_strategies():
    data = _data(n_clients=4)
    for strat in (FedAvgStrategy(n_features=100, n_classes=10, n_hidden=16,
                                 local_epochs=1),
                  IFCAStrategy(n_features=100, n_classes=10, n_hidden=16,
                               k=3, local_epochs=1)):
        eng = Engine(strat, data, RuntimeConfig(rounds=2))
        _, reports = eng.run(jax.random.PRNGKey(0))
        assert 0.0 <= float(reports[-1].mean_accuracy) <= 1.0
        assert reports[-1].upload_bytes > 0
        # FedAvg slots all 0; IFCA slots within [0, k)
        assert int(reports[-1].assignment.max()) < strat.n_slots
