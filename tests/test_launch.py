"""Launch-layer machinery on the single-device host mesh: input_specs →
lower → compile for a reduced arch (the same path dryrun.py exercises at
512 devices), plus the federated-round builders and checkpointing."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import federation, tm
from repro.checkpoint import ckpt
from repro.launch import fed_train, hlo_analysis, mesh as mesh_mod, steps
from repro.models import config as mcfg
from repro.sharding import compat


@pytest.fixture(scope="module")
def host_mesh():
    return mesh_mod.make_host_mesh()


def _reduced(arch="yi_6b"):
    return mcfg.reduced(registry.get(arch))


@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_lower_compile_reduced_on_host_mesh(host_mesh, shape_name):
    cfg = _reduced()
    shape = dataclasses.replace(steps.SHAPES[shape_name],
                                seq_len=64, global_batch=2)
    ins = steps.input_specs(cfg, shape, host_mesh)
    with compat.set_mesh(host_mesh):
        if shape.kind == "train":
            lowered = jax.jit(steps.make_train_step(cfg)).lower(
                ins["params"], ins["opt_state"], ins["batch"])
        else:
            lowered = jax.jit(steps.make_serve_step(
                cfg, window=ins["window"])).lower(
                ins["params"], ins["token"], ins["caches"])
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # jax ≤0.4.x wraps it in a list
        ca = ca[0]
    assert ca["flops"] > 0
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    assert all(v >= 0 for v in coll.values())


def test_trip_count_weighting_scales_with_scan_length():
    """Collectives inside a scanned body must count once per iteration."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    m = Mesh(np.array(jax.devices()[:1]), ("model",))

    def f(x):
        def body(c, _):
            s = jax.lax.with_sharding_constraint(c, P("model"))
            return s * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y.sum()

    with compat.set_mesh(m):
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32,
                                 sharding=NamedSharding(m, P()))
        ).compile().as_text()
    w = hlo_analysis.collective_bytes(txt, weighted=True)
    u = hlo_analysis.collective_bytes(txt, weighted=False)
    # single device → no collectives expected, but weighting must not crash
    assert sum(w.values()) >= sum(u.values())


def test_fed_round_builders_run_small():
    tm_cfg = tm.TMConfig(n_classes=4, n_clauses=8, n_features=36,
                         n_states=31, s=3.0, T=10)
    fed_cfg = federation.FedConfig(n_clients=4, rounds=1, local_epochs=1)
    from repro.data import partition, synthetic
    x, y, dcfg = synthetic.make_dataset("synthmnist", 400,
                                        jax.random.PRNGKey(0), side=6)
    x = x[:, :36]
    data = partition.partition(x, y, 4, n_clients=4, experiment=5,
                               key=jax.random.PRNGKey(1), n_train=20,
                               n_test=10, n_conf=10)
    # labels in [0, 10) from the synth dataset; clamp to 4 classes
    data = data._replace(y_train=data.y_train % 4, y_test=data.y_test % 4,
                         y_conf=data.y_conf % 4)
    state = federation.init_state(tm_cfg, fed_cfg, jax.random.PRNGKey(2))

    tpfl = jax.jit(fed_train.make_tpfl_round(tm_cfg, fed_cfg))
    p2, cw, metrics = tpfl(state.client_params, state.cluster_weights,
                           data, jax.random.PRNGKey(3))
    assert metrics["assignment"].shape == (4,)
    assert float(metrics["mean_accuracy"]) >= 0.0

    favg = jax.jit(fed_train.make_fedavg_tm_round(tm_cfg, fed_cfg))
    p3, m2 = favg(state.client_params, data, jax.random.PRNGKey(4))
    # fedavg result: every client identical
    assert (p3.ta_state[0] == p3.ta_state[1]).all()


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": [jnp.ones(4), {"c": jnp.zeros((2,), jnp.int32)}]}
    path = tmp_path / "ck.msgpack"
    ckpt.save(path, tree)
    like = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    out = ckpt.restore(path, like)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.dtype == b.dtype
        assert (a == b).all()


def test_abstract_fed_inputs_shapes(host_mesh):
    tm_cfg = tm.TMConfig(n_classes=4, n_clauses=8, n_features=36)
    fed_cfg = federation.FedConfig(n_clients=4)
    params, cw, data, key = fed_train.abstract_fed_inputs(
        tm_cfg, fed_cfg, host_mesh, n_train=8, n_test=4, n_conf=4)
    assert params.ta_state.shape == (4, 4, 8, 72)
    assert data.x_train.shape == (4, 8, 36)
