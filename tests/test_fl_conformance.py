"""Federation conformance suite — the permanent contract that the
shard-mapped engine == the in-process engine == the legacy
``federation.run`` loop, bit for bit, for every (strategy, codec,
participation) cell; plus the property-level contracts underneath it
(codec roundtrips and byte metering, scheduler sampling distributions).

The suite runs on whatever devices are visible.  To exercise a real
multi-device ``clients`` mesh (every shard_map boundary, padding path,
and collective actually partitioned) spawn virtual CPU devices *before*
jax initializes — this is CI's second matrix job:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m pytest -q tests/test_fl_conformance.py
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, federation, tm
from repro.data import partition, synthetic
from repro.fl import masked_collectives
from repro.fl.runtime import (CodecConfig, Engine, FedAvgStrategy,
                              FedTMStrategy, FLISStrategy, IFCAStrategy,
                              RuntimeConfig, Scheduler, SchedulerConfig,
                              TPFLStrategy, codec)
from repro.sharding import compat

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=20, n_features=100,
                     n_states=63, s=5.0, T=20)
N_CLIENTS = 8
ROUNDS = 2

FLIS_KW = dict(n_features=100, n_classes=10, n_hidden=16, local_epochs=1,
               max_slots=4, probe_size=16)

STRATEGIES = {
    "tpfl": lambda: TPFLStrategy(TM_CFG, local_epochs=1),
    "fedavg": lambda: FedAvgStrategy(n_features=100, n_classes=10,
                                     n_hidden=16, local_epochs=1),
    "fedprox": lambda: FedAvgStrategy(n_features=100, n_classes=10,
                                      n_hidden=16, local_epochs=1,
                                      prox_mu=0.1),
    "ifca": lambda: IFCAStrategy(n_features=100, n_classes=10, n_hidden=16,
                                 k=3, local_epochs=1),
    # server-state API v2: FLIS assigns slots *server-side* per round
    # (dynamic clustering through the assign hook), FedTM is the one-slot
    # full-weight TM strategy — both must hold the same backend parity
    "flis_dc": lambda: FLISStrategy(linkage="dc", **FLIS_KW),
    "flis_hc": lambda: FLISStrategy(linkage="hc", **FLIS_KW),
    "fedtm": lambda: FedTMStrategy(TM_CFG, local_epochs=1),
}
WIRES = {
    "float32": CodecConfig("float32"),
    "int8": CodecConfig("int8"),
    "int4_sparse": CodecConfig("int4", sparse=True),
}
PARTICIPATION = {
    "full": SchedulerConfig(),
    "partial": SchedulerConfig(participation=0.5, dropout=0.25),
}


@pytest.fixture(scope="module")
def data():
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1500,
                                        jax.random.PRNGKey(0), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=N_CLIENTS, experiment=5,
        key=jax.random.PRNGKey(1), n_train=40, n_test=20, n_conf=20)


def _run(strategy, data, sched, wire, backend, collective="gather",
         rounds=ROUNDS):
    cfg = RuntimeConfig(rounds=rounds, scheduler=sched, codec=wire,
                        backend=backend, mesh_collective=collective)
    engine = Engine(strategy, data, cfg)
    return engine.run(jax.random.PRNGKey(0))


def _assert_bitwise_equal_runs(sa, ra, sb, rb):
    """Every observable of the two runs is bit-identical: reports and
    final population/server state."""
    for a, b in zip(ra, rb):
        assert float(a.mean_accuracy) == float(b.mean_accuracy)
        assert (np.asarray(a.per_client_accuracy)
                == np.asarray(b.per_client_accuracy)).all()
        assert (np.asarray(a.assignment) == np.asarray(b.assignment)).all()
        assert (np.asarray(a.cluster_counts)
                == np.asarray(b.cluster_counts)).all()
        assert a.upload_bytes == b.upload_bytes
        assert a.download_bytes_broadcast == b.download_bytes_broadcast
        assert a.download_bytes_per_client == b.download_bytes_per_client
        assert a.aggregated_uploads == b.aggregated_uploads
    # the whole strategy-owned server pytree: slot matrix + aux (FLIS's
    # probe set and membership table ride along)
    for la, lb in zip(jax.tree.leaves(sa.server),
                      jax.tree.leaves(sb.server)):
        assert (np.asarray(la) == np.asarray(lb)).all()
    for la, lb in zip(jax.tree.leaves(sa.client_state),
                      jax.tree.leaves(sb.client_state)):
        assert (np.asarray(la) == np.asarray(lb)).all()


# ---------------------------------------------------------------------------
# the bit-parity matrix: shard-mapped == in-process
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("part_name", sorted(PARTICIPATION))
@pytest.mark.parametrize("wire_name", sorted(WIRES))
@pytest.mark.parametrize("strat_name", sorted(STRATEGIES))
def test_shardmap_round_is_bit_identical_to_inprocess(
        strat_name, wire_name, part_name, data):
    sched = PARTICIPATION[part_name]
    wire = WIRES[wire_name]
    sa, ra = _run(STRATEGIES[strat_name](), data, sched, wire, "inprocess")
    sb, rb = _run(STRATEGIES[strat_name](), data, sched, wire, "shardmap")
    _assert_bitwise_equal_runs(sa, ra, sb, rb)


def test_three_way_parity_with_legacy_federation_run(data):
    """The original contract, now three-way: legacy loop == in-process
    engine == shard-mapped engine for the default TPFL configuration."""
    fed = federation.FedConfig(n_clients=N_CLIENTS, rounds=ROUNDS,
                               local_epochs=1)
    key = jax.random.PRNGKey(0)
    k_init, k_rounds = jax.random.split(key)
    st = federation.init_state(TM_CFG, fed, k_init)
    legacy = []
    for r in range(fed.rounds):
        st, m = federation.run_round(
            st, data, jax.random.fold_in(k_rounds, r), TM_CFG, fed)
        legacy.append(m)

    for backend in ("inprocess", "shardmap"):
        end, hist = federation.run(
            data, TM_CFG, fed, key,
            runtime_cfg=RuntimeConfig(backend=backend))
        for a, b in zip(legacy, hist):
            assert float(a.mean_accuracy) == float(b.mean_accuracy)
            assert (np.asarray(a.assignment)
                    == np.asarray(b.assignment)).all()
            assert (np.asarray(a.cluster_counts)
                    == np.asarray(b.cluster_counts)).all()
            assert a.upload_bytes == b.upload_bytes
            assert a.download_bytes_broadcast == b.download_bytes_broadcast
            assert a.download_bytes_per_client == b.download_bytes_per_client
        assert (np.asarray(st.client_params.weights)
                == np.asarray(end.client_params.weights)).all()
        assert (np.asarray(st.cluster_weights)
                == np.asarray(end.cluster_weights)).all()


def test_psum_collective_matches_within_float_tolerance(data):
    """The communication-optimal psum lowering reduces in shard order, so
    it is allclose- (not bit-) equal; discrete observables still match."""
    sa, ra = _run(TPFLStrategy(TM_CFG, local_epochs=1), data,
                  SchedulerConfig(), WIRES["float32"], "inprocess")
    sb, rb = _run(TPFLStrategy(TM_CFG, local_epochs=1), data,
                  SchedulerConfig(), WIRES["float32"], "shardmap",
                  collective="psum")
    for a, b in zip(ra, rb):
        assert (np.asarray(a.assignment) == np.asarray(b.assignment)).all()
        assert (np.asarray(a.cluster_counts)
                == np.asarray(b.cluster_counts)).all()
        assert a.upload_bytes == b.upload_bytes
    assert np.allclose(np.asarray(sa.server.slots),
                       np.asarray(sb.server.slots), atol=1e-4)


# ---------------------------------------------------------------------------
# tm_backend parity: fused Pallas kernels == reference jnp path
# ---------------------------------------------------------------------------

TM_PALLAS_CASES = {
    "tpfl": lambda: TPFLStrategy(TM_CFG, local_epochs=1),
    # the §7 confidence gate exercises the masked-row upload path under
    # the fused kernels too
    "tpfl_thresh": lambda: TPFLStrategy(TM_CFG, local_epochs=1,
                                        top_classes=2, conf_threshold=0.0),
    "fedtm": lambda: FedTMStrategy(TM_CFG, local_epochs=1),
}


@pytest.mark.parametrize("backend", ("inprocess", "shardmap"))
@pytest.mark.parametrize("case", sorted(TM_PALLAS_CASES))
def test_pallas_tm_backend_is_bit_identical_to_ref(case, backend, data):
    """RuntimeConfig(tm_backend="pallas") swaps the TM strategies onto
    the fused client-batched Pallas kernels (interpret mode on CPU,
    Mosaic on TPU).  Every engine observable — accuracies, assignment,
    counts, metered bytes, final client/server state — must equal the
    reference path bit for bit, on both executors."""

    def run(tb):
        cfg = RuntimeConfig(rounds=ROUNDS, backend=backend, tm_backend=tb)
        return Engine(TM_PALLAS_CASES[case](), data, cfg).run(
            jax.random.PRNGKey(0))

    sa, ra = run("ref")
    sb, rb = run("pallas")
    _assert_bitwise_equal_runs(sa, ra, sb, rb)


# ---------------------------------------------------------------------------
# conf_threshold byte metering: masked uploads ship nothing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tm_backend", ("ref", "pallas"))
def test_conf_threshold_zeroes_masked_rows_and_bytes(tm_backend, data):
    """A slot masked to −1 by the confidence gate must carry a *zero*
    payload row (it used to ship class 0's weights) and must not be
    metered: upload_bytes is exactly one (4 + 4·d)-byte frame per
    surviving slot of the round's assignment."""
    import dataclasses as _dc
    cfg = TM_CFG if tm_backend == "ref" \
        else _dc.replace(TM_CFG, use_kernel=True)

    # direct client_step: an all-masking threshold zeroes every row
    strat = TPFLStrategy(cfg, local_epochs=1, top_classes=2,
                         conf_threshold=1e9)
    cs, server = strat.init(jax.random.PRNGKey(0), N_CLIENTS)
    d0 = jax.tree.map(lambda a: a[0], data)
    p0 = jax.tree.map(lambda a: a[0], cs)
    if tm_backend == "pallas":
        _, up = strat.fused_client_step(
            jax.tree.map(lambda a: a[:1], cs), server.slots,
            jax.tree.map(lambda a: a[:1], data),
            jax.random.split(jax.random.PRNGKey(1), 1))
    else:
        _, up = strat.client_step(p0, server.slots, d0,
                                  jax.random.PRNGKey(1))
    assert (np.asarray(up.slots) == -1).all()
    assert (np.asarray(up.vecs) == 0).all()

    # engine metering: a mid-range gate masks some-but-not-all slots,
    # and every metered byte maps onto a surviving assignment entry.
    # The gate compares raw confidence margins, so a fixed constant can
    # land outside the data's range — derive the threshold from a probe
    # training pass instead (median of the clients' top-2 margins masks
    # roughly half the slots).
    probe = TPFLStrategy(cfg, local_epochs=1, top_classes=2)
    trained, _ = jax.vmap(probe.client_step, in_axes=(0, None, 0, 0))(
        cs, server.slots, data,
        jax.random.split(jax.random.PRNGKey(2), N_CLIENTS))
    conf = jax.vmap(lambda p, x: tm.confidence_scores(p, x, cfg))(
        trained, data.x_conf)
    mid = float(jnp.median(jax.lax.top_k(conf, 2)[0]))
    strat = TPFLStrategy(cfg, local_epochs=1, top_classes=2,
                         conf_threshold=mid)
    eng = Engine(strat, data, RuntimeConfig(rounds=ROUNDS))
    _, reports = eng.run(jax.random.PRNGKey(0))
    frame = 4 + 4 * strat.vec_dim
    saw_masked = saw_shared = False
    for rep in reports:
        shared = int((np.asarray(rep.assignment) >= 0).sum())
        assert rep.upload_bytes == shared * frame
        saw_shared |= shared > 0
        saw_masked |= shared < N_CLIENTS * strat.j_slots
    assert saw_shared and saw_masked, "threshold gate never exercised"

    # the all-masking gate meters zero bytes end to end
    strat = TPFLStrategy(cfg, local_epochs=1, conf_threshold=1e9)
    _, reports = Engine(strat, data, RuntimeConfig(rounds=1)).run(
        jax.random.PRNGKey(0))
    assert reports[0].upload_bytes == 0
    assert (np.asarray(reports[0].assignment) == -1).all()


def test_sharded_weighted_mean_matches_host_form():
    """The staleness-discounted sharded mean (one psum) agrees with the
    host ``clustered_weighted_mean`` it lowers."""
    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("clients",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n, d, c = 4 * n_dev, 7, 3
    key = jax.random.PRNGKey(0)
    vals = jax.random.normal(key, (n, d))
    slots = jax.random.randint(jax.random.fold_in(key, 1), (n,), -1, c)
    stale = jax.random.randint(jax.random.fold_in(key, 2), (n,), 0, 3)
    weights = 0.5 ** stale.astype(jnp.float32)

    host = masked_collectives.clustered_weighted_mean(vals, slots, weights, c)
    means, total = jax.jit(shard_map(
        lambda v, s, w: masked_collectives.clustered_weighted_mean_sharded(
            v, s, w, c, "clients"),
        mesh=mesh, in_specs=(P("clients"), P("clients"), P("clients")),
        out_specs=(P(), P()), check_rep=False))(vals, slots, weights)
    assert np.allclose(np.asarray(host), np.asarray(means), atol=1e-5)
    onehot = jax.nn.one_hot(slots, c) * weights[:, None]
    assert np.allclose(np.asarray(total), np.asarray(onehot.sum(0)),
                       atol=1e-5)


def test_fed_train_mesh_cli_checkpoint_resume_bit_identical(tmp_path):
    """`fed_train --mesh clients:D` end to end: an uninterrupted mesh run
    and a checkpoint/resume cycle produce bit-identical final metrics."""
    from repro.launch import fed_train
    base = ["--clients", "8", "--rounds", "4", "--local-epochs", "1",
            "--clauses", "16", "--mesh", f"clients:{len(jax.devices())}"]
    full = fed_train.main(base)

    ck = ["--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    interrupted = fed_train.main(base[:3] + ["2"] + base[4:] + ck)
    resumed = fed_train.main(base + ck + ["--resume"])      # rounds 2-3
    # per-round accuracies of interrupted+resumed == the uninterrupted
    # run, float-for-float, and the resumed segment's byte totals equal
    # the uninterrupted run's second half (uniform rounds)
    assert (interrupted["acc_per_round"] + resumed["acc_per_round"]
            == full["acc_per_round"])
    assert resumed["upload_bytes"] * 2 == full["upload_bytes"]
    assert (resumed["download_bytes_per_client"] * 2
            == full["download_bytes_per_client"])


# ---------------------------------------------------------------------------
# the async bit-parity matrix: device buffer == host reference == shard-mapped
# ---------------------------------------------------------------------------

ASYNC_SCHED = SchedulerConfig(participation=0.75, dropout=0.25,
                              straggler=0.5, max_staleness=2)


def _run_async(strategy, data, backend, async_buffer="device",
               collective="gather", capacity=5, rounds=3):
    """Small capacity + stragglers: every async code path fires within
    three rounds — buffering, maturity gating, aggregation, overflow
    eviction."""
    cfg = RuntimeConfig(rounds=rounds, scheduler=ASYNC_SCHED,
                        aggregation="async", async_min_uploads=2,
                        buffer_capacity=capacity, async_buffer=async_buffer,
                        backend=backend, mesh_collective=collective)
    return Engine(strategy, data, cfg).run(jax.random.PRNGKey(0))


def _assert_async_reports_equal(ra, rb):
    for a, b in zip(ra, rb):
        assert a.aggregated_uploads == b.aggregated_uploads
        assert a.buffered_uploads == b.buffered_uploads
        assert a.evicted_uploads == b.evicted_uploads


@pytest.mark.parametrize("strat_name", ["tpfl", "ifca"])
def test_async_device_buffer_bit_identical_to_host_reference(
        strat_name, data):
    """The tentpole contract: the compiled device-buffer path (insert
    scan, masked maturity gate, weighted mean) reproduces the original
    host numpy loop bit for bit — same accuracy, assignment, byte
    totals, buffer occupancy, and final state including every buffer
    lane."""
    sa, ra = _run_async(STRATEGIES[strat_name](), data, "inprocess",
                        async_buffer="host")
    sb, rb = _run_async(STRATEGIES[strat_name](), data, "inprocess",
                        async_buffer="device")
    _assert_bitwise_equal_runs(sa, ra, sb, rb)
    _assert_async_reports_equal(ra, rb)
    assert sum(r.evicted_uploads for r in ra) > 0   # overflow exercised
    for lane in ("buf_vecs", "buf_slots", "buf_ready", "buf_weight",
                 "buf_valid", "buf_seq"):
        assert (np.asarray(getattr(sa, lane))
                == np.asarray(getattr(sb, lane))).all(), lane


@pytest.mark.parametrize("strat_name", ["tpfl", "ifca"])
def test_async_shardmap_gather_bit_identical_to_inprocess(strat_name, data):
    """backend="shardmap" + aggregation="async" (the configuration that
    used to raise): the shard-mapped buffered round — uploads gathered
    in canonical order, replicated insert replay, host-form mean —
    matches the in-process device path bit for bit."""
    sa, ra = _run_async(STRATEGIES[strat_name](), data, "inprocess")
    sb, rb = _run_async(STRATEGIES[strat_name](), data, "shardmap")
    _assert_bitwise_equal_runs(sa, ra, sb, rb)
    _assert_async_reports_equal(ra, rb)
    for lane in ("buf_vecs", "buf_slots", "buf_ready", "buf_weight",
                 "buf_valid", "buf_seq"):
        assert (np.asarray(getattr(sa, lane))
                == np.asarray(getattr(sb, lane))).all(), lane


def test_async_shardmap_psum_matches_within_float_tolerance(data):
    """The C·m psum lowering of the buffered mean
    (``buffered_weighted_mean_sharded``) reduces in shard order:
    discrete observables stay exact, the server is allclose."""
    sa, ra = _run_async(TPFLStrategy(TM_CFG, local_epochs=1), data,
                        "inprocess")
    sb, rb = _run_async(TPFLStrategy(TM_CFG, local_epochs=1), data,
                        "shardmap", collective="psum")
    _assert_async_reports_equal(ra, rb)
    for a, b in zip(ra, rb):
        assert (np.asarray(a.assignment) == np.asarray(b.assignment)).all()
        assert a.upload_bytes == b.upload_bytes
    assert np.allclose(np.asarray(sa.server.slots),
                       np.asarray(sb.server.slots), atol=1e-4)
    assert (np.asarray(sa.buf_valid) == np.asarray(sb.buf_valid)).all()


def test_buffered_weighted_mean_sharded_matches_host_form():
    """The replicated-buffer psum variant slices shard blocks out of the
    same lanes the host form reduces — means must agree allclose for
    any capacity, including one that does not divide the mesh."""
    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("clients",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    cap, d, c = 4 * n_dev + 3, 6, 4          # deliberately non-divisible
    key = jax.random.PRNGKey(3)
    vals = jax.random.normal(key, (cap, d))
    slots = jax.random.randint(jax.random.fold_in(key, 1), (cap,), -1, c)
    weights = jax.random.uniform(jax.random.fold_in(key, 2), (cap,))

    host = masked_collectives.clustered_weighted_mean(vals, slots, weights, c)
    means, total = jax.jit(shard_map(
        lambda v, s, w: masked_collectives.buffered_weighted_mean_sharded(
            v, s, w, c, "clients", n_dev),
        mesh=mesh, in_specs=(P(), P(), P()),
        out_specs=(P(), P()), check_rep=False))(vals, slots, weights)
    assert np.allclose(np.asarray(host), np.asarray(means), atol=1e-5)
    onehot = jax.nn.one_hot(slots, c) * weights[:, None]
    assert np.allclose(np.asarray(total), np.asarray(onehot.sum(0)),
                       atol=1e-5)


def test_fed_train_mesh_async_checkpoint_resume_bit_identical(tmp_path):
    """`fed_train --mode async --mesh clients:D` with a checkpoint cycle:
    the buffer lanes are part of the state pytree, so an interrupted
    async mesh run resumes bit-identically (pending buffered uploads
    mature in the resumed half exactly as in the uninterrupted run)."""
    from repro.launch import fed_train
    base = ["--clients", "8", "--rounds", "4", "--local-epochs", "1",
            "--clauses", "16", "--mode", "async", "--straggler", "0.5",
            "--async-min-uploads", "2", "--buffer-capacity", "5",
            "--mesh", f"clients:{len(jax.devices())}"]
    full = fed_train.main(base)

    ck = ["--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    interrupted = fed_train.main(base[:3] + ["2"] + base[4:] + ck)
    resumed = fed_train.main(base + ck + ["--resume"])      # rounds 2-3
    assert (interrupted["acc_per_round"] + resumed["acc_per_round"]
            == full["acc_per_round"])


def test_shardmap_plus_host_buffer_is_rejected():
    """The numpy reference loop cannot run on the mesh — the config
    catches the combination instead of silently degrading."""
    with pytest.raises(ValueError, match="host-buffered"):
        RuntimeConfig(backend="shardmap", aggregation="async",
                      async_buffer="host")


# ---------------------------------------------------------------------------
# server-state API v2: engine FLIS/FedTM == core/baselines reference loops
# ---------------------------------------------------------------------------

BCFG = baselines.BaselineConfig(n_clients=N_CLIENTS, rounds=ROUNDS,
                                local_epochs=1, n_hidden=16,
                                flis_probe=16, flis_max_slots=4)


@pytest.mark.parametrize("linkage", ["dc", "hc"])
def test_engine_flis_matches_reference_loop(linkage, data):
    """The new-strategy parity contract: the engine's FLIS — clients
    train and upload, the server recomputes cluster membership per
    round through the ``assign`` hook (jit-able DC label propagation /
    HC agglomerative merges) — reproduces the straight-line host
    reference loop in ``core/baselines.py`` exactly: same per-round
    assignment, same accuracy, float for float."""
    strat = FLISStrategy(linkage=linkage, **FLIS_KW)
    _, reports = Engine(strat, data, RuntimeConfig(rounds=ROUNDS)).run(
        jax.random.PRNGKey(2))
    ref = baselines.run_flis(data, BCFG, jax.random.PRNGKey(2), 100, 10,
                             linkage=linkage)
    for r in range(ROUNDS):
        assert float(reports[r].mean_accuracy) == ref.accuracy[r]
        assert (np.asarray(reports[r].assignment)[:, 0]
                == ref.assignments[r]).all()
    # the reported cluster counts are the reference labelling's counts
    counts = np.bincount(ref.assignments[-1], minlength=4)
    assert (np.asarray(reports[-1].cluster_counts) == counts).all()


def test_engine_fedtm_matches_reference_loop(data):
    """Engine FedTM (one slot, full-weight TM averaging through the
    wire codec) == the ``core/baselines.py`` reference loop: integer
    weight sums are exact in float32, so the rounded global mean — and
    hence every accuracy — is bit-identical."""
    _, reports = Engine(FedTMStrategy(TM_CFG, local_epochs=1), data,
                        RuntimeConfig(rounds=ROUNDS)).run(
        jax.random.PRNGKey(3))
    ref = baselines.run_fedtm(data, TM_CFG, BCFG, jax.random.PRNGKey(3))
    for r in range(ROUNDS):
        assert float(reports[r].mean_accuracy) == ref.accuracy[r]


def test_flis_dynamic_assignment_is_serverside(data):
    """Clients tag uploads with the row they last applied (0 before any
    broadcast); the round report's assignment is the server-side
    clustering — proof the ids were recomputed between uplink and
    aggregation, not taken from the clients."""
    strat = FLISStrategy(linkage="dc", **FLIS_KW)
    engine = Engine(strat, data, RuntimeConfig(rounds=1))
    state = engine.init(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), N_CLIENTS)
    _, _, proposed = engine.executor.train(
        strat, state.client_state, state.server.slots, data, keys)
    assert (np.asarray(proposed) == 0).all()      # fresh init: no row yet
    _, rep = engine.run_round(state, jax.random.PRNGKey(1))
    assert len(set(np.asarray(rep.assignment)[:, 0].tolist())) > 1


def test_flis_prev_slot_follows_applied_assignment(data):
    """The FLIS client-state ride-along: after each round, every
    client's ``prev_slot`` is the server row it last *applied* —
    advanced to the round's assignment where one was made, kept
    otherwise — and the next round's uplink tags carry exactly those
    ids to the server."""
    strat = FLISStrategy(linkage="dc", **FLIS_KW)
    engine = Engine(strat, data, RuntimeConfig(
        rounds=3, scheduler=SchedulerConfig(participation=0.5,
                                            sampling="round_robin")))
    key = jax.random.PRNGKey(0)
    k_init, k_rounds = jax.random.split(key)
    state = engine.init(k_init)
    for r in range(3):
        prev = state
        rk = jax.random.fold_in(k_rounds, r)
        part = engine.scheduler.sample(r, rk)
        state, rep = engine.run_round(state, rk)
        # the uplink tags this round are the prev_slot lanes entering it
        idx = np.asarray(part.idx)
        keys = jax.random.split(rk, N_CLIENTS)[part.idx]
        sub_cs = jax.tree.map(lambda a: a[part.idx], prev.client_state)
        sub_data = jax.tree.map(lambda a: a[part.idx], data)
        _, _, slots = engine.executor.train(
            strat, sub_cs, engine._wire_tx_server(prev.server.slots),
            sub_data, keys)
        assert (np.asarray(slots)[:, 0]
                == np.asarray(prev.client_state.prev_slot)[idx]).all()
        # prev_slot advances to the applied assignment, else is kept
        assign = np.asarray(rep.assignment)[:, 0]
        want = np.where(assign >= 0, assign,
                        np.asarray(prev.client_state.prev_slot))
        assert (np.asarray(state.client_state.prev_slot) == want).all()


def test_flis_sparse_uplink_encodes_against_prev_slot_reference(data):
    """Byte-metering pin for the ride-along: FLIS sparse-delta uplinks
    encode against the tracked reference of the row each client last
    applied (its ``prev_slot`` tag) — replayed from scratch per round,
    the metered totals must match exactly."""
    wire = CodecConfig("int8", sparse=True)
    strat = FLISStrategy(linkage="dc", **FLIS_KW)
    engine = Engine(strat, data, RuntimeConfig(rounds=3, codec=wire))
    key = jax.random.PRNGKey(0)
    k_init, k_rounds = jax.random.split(key)
    state = engine.init(k_init)
    for r in range(3):
        prev = state
        rk = jax.random.fold_in(k_rounds, r)
        part = engine.scheduler.sample(r, rk)
        state, rep = engine.run_round(state, rk)

        idx = np.asarray(part.idx)
        keys = jax.random.split(rk, N_CLIENTS)[part.idx]
        sub_cs = jax.tree.map(lambda a: a[part.idx], prev.client_state)
        sub_data = jax.tree.map(lambda a: a[part.idx], data)
        _, vecs, slots = engine.executor.train(
            strat, sub_cs, engine._wire_tx_server(prev.server.slots),
            sub_data, keys)
        np_vecs, np_slots = np.asarray(vecs), np.asarray(slots)
        expect = 0
        for c in range(idx.shape[0]):
            s = int(np_slots[c, 0])
            ref = np.asarray(prev.ref_vecs)[int(idx[c]), s]
            expect += 4 + len(codec.encode(np_vecs[c, 0], wire, ref=ref))
        assert rep.upload_bytes == expect
    # after a synced round the reference is no longer the zero row, so
    # the tag genuinely selects a nearer reference than slot-0 zeros
    assert (np.asarray(state.ref_round) >= 0).any()


def test_flis_requires_sync_aggregation(data):
    """Dynamic per-round assignment has no meaning against a cross-round
    upload buffer — the engine rejects the combination at init."""
    with pytest.raises(ValueError, match="sync"):
        Engine(FLISStrategy(**FLIS_KW), data,
               RuntimeConfig(aggregation="async"))


def test_stringly_downloads_typo_is_rejected(data):
    """`downloads` is a validated vocabulary now: a typo used to fall
    through silently to assigned-slot broadcast/billing."""
    bad = TPFLStrategy(TM_CFG, local_epochs=1)
    object.__setattr__(bad, "downloads", "al_slots")   # the typo
    with pytest.raises(ValueError, match="downloads"):
        Engine(bad, data, RuntimeConfig())


# ---------------------------------------------------------------------------
# empty-slot retention (Alg. 2 invariant) under the v2 server_update hook
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["inprocess", "shardmap"])
def test_empty_slot_masked_mean_keeps_prev_row_bitwise(backend):
    """Property test: the per-slot masked mean with zero contributors
    keeps the previous server row bit-for-bit, through the raw-mean +
    ``server_update`` split, on both executors.  Randomized slot
    patterns with guaranteed-empty slots (fixed seed)."""
    from repro.fl.runtime.executors import (InProcessExecutor,
                                            ShardMapExecutor)
    from repro.fl.runtime.strategy import ServerState, default_server_update

    class _Spec:
        n_slots, vec_dim, j_slots = 6, 5, 1

    executor = (InProcessExecutor() if backend == "inprocess"
                else ShardMapExecutor())
    rng = np.random.default_rng(17)
    for _ in range(10):
        k = int(rng.integers(2, 9))
        empty = set(rng.choice(6, size=int(rng.integers(1, 4)),
                               replace=False).tolist())
        pool = [s for s in range(6) if s not in empty] + [-1]
        slots = jnp.asarray(rng.choice(pool, size=(k, 1)), jnp.int32)
        dec = jnp.asarray(rng.normal(size=(k, 1, 5)), jnp.float32)
        arrive = jnp.asarray(rng.random(k) < 0.8)
        prev = jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)
        agg, counts = executor.masked_mean(_Spec, dec, slots, arrive)
        server = default_server_update(ServerState(prev), agg, counts)
        np_counts = np.asarray(counts)
        for s in range(6):
            if np_counts[s] == 0:
                assert (np.asarray(server.slots[s])
                        == np.asarray(prev[s])).all(), (backend, s)
        assert set(np.asarray(
            jnp.nonzero(counts)[0]).tolist()).isdisjoint(empty)


@pytest.mark.parametrize("backend", ["inprocess", "shardmap"])
def test_flis_server_update_retains_unfed_rows(backend, data):
    """Engine-level, under the custom ``server_update`` hook: FLIS rows
    whose (dynamic) cluster received no contributors this round keep
    their previous value bit-for-bit, and the aux membership table
    matches the round's counts."""
    strat = FLISStrategy(linkage="dc", **FLIS_KW)
    engine = Engine(strat, data, RuntimeConfig(rounds=1, backend=backend))
    state = engine.init(jax.random.PRNGKey(0))
    seeded = state._replace(server=state.server._replace(
        slots=jnp.arange(4 * strat.vec_dim,
                         dtype=jnp.float32).reshape(4, -1)))
    new_state, rep = engine.run_round(seeded, jax.random.PRNGKey(1))
    counts = np.asarray(rep.cluster_counts)
    for s in range(4):
        if counts[s] == 0:
            assert (np.asarray(new_state.server.slots[s])
                    == np.asarray(seeded.server.slots[s])).all()
        else:
            assert not (np.asarray(new_state.server.slots[s])
                        == np.asarray(seeded.server.slots[s])).all()
    assert (np.asarray(new_state.server.aux.members) == counts).all()


def test_server_state_checkpoint_rides_and_drift_is_loud(tmp_path, data):
    """The strategy-owned server pytree (slots + FLIS aux) rides
    checkpoints bit-for-bit; restoring under a different server-state
    layout (other strategy / max_slots) fails loudly instead of
    silently coercing."""
    from repro.fl.runtime import checkpointing
    strat = FLISStrategy(linkage="dc", **FLIS_KW)
    engine = Engine(strat, data, RuntimeConfig(rounds=1))
    state, _ = engine.run(jax.random.PRNGKey(0))
    path = checkpointing.save(tmp_path, state)
    restored = checkpointing.restore(
        path, engine.init(jax.random.PRNGKey(0)))
    for la, lb in zip(jax.tree.leaves(state.server),
                      jax.tree.leaves(restored.server)):
        assert (np.asarray(la) == np.asarray(lb)).all()

    other = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data,
                   RuntimeConfig(rounds=1))
    with pytest.raises(ValueError, match="layout"):
        checkpointing.restore(path, other.init(jax.random.PRNGKey(0)))


def test_fed_train_flis_mesh_cli_runs_end_to_end():
    """The acceptance CLI: `fed_train --strategy flis_dc --max-slots 8
    --backend shardmap` runs a real shard-mapped federation and meters
    nonzero bytes."""
    from repro.launch import fed_train
    out = fed_train.main(["--strategy", "flis_dc", "--max-slots", "8",
                          "--backend", "shardmap", "--clients", "8",
                          "--rounds", "2", "--local-epochs", "1"])
    assert len(out["acc_per_round"]) == 2
    assert out["upload_bytes"] > 0


# ---------------------------------------------------------------------------
# wire-codec property tests (randomized shapes/values, fixed seed)
# ---------------------------------------------------------------------------

def test_codec_float32_roundtrip_bit_exact_random_shapes():
    rng = np.random.default_rng(7)
    cfg = CodecConfig("float32")
    for _ in range(40):
        m = int(rng.integers(1, 512))
        vec = (rng.normal(scale=10.0 ** rng.integers(-3, 4), size=m)
               .astype(np.float32))
        buf = codec.encode(vec, cfg)
        assert len(buf) == 4 * m            # metered bytes == len(buffer)
        assert (codec.decode(buf, m, cfg) == vec).all()


@pytest.mark.parametrize("name", ["int8", "int4"])
def test_codec_quantized_error_bounded_by_half_step(name):
    rng = np.random.default_rng(11)
    cfg = CodecConfig(name)
    for _ in range(40):
        m = int(rng.integers(1, 512))
        vec = (rng.normal(scale=10.0 ** rng.integers(-2, 3), size=m)
               .astype(np.float32))
        buf = codec.encode(vec, cfg)
        expect = 4 + (m if name == "int8" else (m + 1) // 2)
        assert len(buf) == expect           # metered bytes == len(buffer)
        out = codec.decode(buf, m, cfg)
        assert np.abs(out - vec).max() <= codec.roundtrip_tolerance(vec, cfg)


@pytest.mark.parametrize("name", codec.CODECS)
def test_codec_sparse_delta_decode_encode_idempotent(name):
    """A vector that already survived the wire re-encodes to itself —
    decode∘encode is a projection (bit-exact fixed point)."""
    rng = np.random.default_rng(13)
    cfg = CodecConfig(name, sparse=True)
    for _ in range(25):
        m = int(rng.integers(1, 300))
        ref = rng.normal(scale=10.0, size=m).astype(np.float32)
        mask = rng.random(m) < 0.3
        vec = (ref + mask * rng.normal(scale=2.0, size=m)
               ).astype(np.float32)
        once = codec.decode(codec.encode(vec, cfg, ref=ref), m, cfg,
                            ref=ref)
        twice = codec.decode(codec.encode(once, cfg, ref=ref), m, cfg,
                             ref=ref)
        assert (twice == once).all()


def test_engine_metered_bytes_equal_reencoded_buffer_lengths(data):
    """The engine's upload meter is Σ (4-byte slot id + len(frame)) of
    the actual frames — recompute it from the wire-visible uploads.
    Sparse frames encode against the *per-client tracked reference*
    (all-zeros on a fresh engine: no client has ever synced)."""
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    for wire in (CodecConfig("float32"), CodecConfig("int8"),
                 CodecConfig("int8", sparse=True)):
        engine = Engine(strat, data, RuntimeConfig(rounds=1, codec=wire))
        state = engine.init(jax.random.PRNGKey(0))
        part = engine.scheduler.sample(0, jax.random.PRNGKey(1))
        keys = jax.random.split(jax.random.PRNGKey(1), N_CLIENTS)
        _, vecs, slots = engine.executor.train(
            strat, state.client_state, state.server.slots, data, keys)
        _, up_bytes = engine._wire_uplink(state, vecs, slots, part)
        expect = 0
        np_vecs, np_slots = np.asarray(vecs), np.asarray(slots)
        for c in range(N_CLIENTS):
            for j in range(np_slots.shape[1]):
                s = int(np_slots[c, j])
                if s < 0:
                    continue
                ref = np.asarray(state.ref_vecs)[c, s] if wire.sparse \
                    else None
                expect += 4 + len(codec.encode(np_vecs[c, j], wire,
                                               ref=ref))
        assert up_bytes == expect


# ---------------------------------------------------------------------------
# sparse-delta per-client broadcast-reference tracking
# ---------------------------------------------------------------------------

def test_sparse_refs_track_what_each_client_received(data):
    """After one full-participation sparse round, each client's
    reference holds exactly the broadcast rows it applied (its assigned
    slot), zeros elsewhere, and ``ref_round`` records the sync."""
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    engine = Engine(strat, data, RuntimeConfig(
        rounds=1, codec=CodecConfig("float32", sparse=True)))
    state, reports = engine.run(jax.random.PRNGKey(0))
    refs = np.asarray(state.ref_vecs)
    server = np.asarray(state.server.slots)
    assign = np.asarray(reports[0].assignment)
    for c in range(N_CLIENTS):
        got = {int(s) for s in assign[c] if s >= 0}
        for s in range(strat.n_slots):
            if s in got:
                assert (refs[c, s] == server[s]).all()
            else:
                assert (refs[c, s] == 0).all()
    assert (np.asarray(state.ref_round) == 0).all()


def test_sparse_uplink_encodes_against_tracked_reference(data):
    """The metering honesty contract under partial participation: every
    round's upload bytes equal a from-scratch re-encoding against the
    references each client held *entering* the round — stale or zero
    for clients that missed recent broadcasts — and clients the
    round-robin window has not reached yet remain unsynced (``ref_round
    == −1``, zero reference)."""
    wire = CodecConfig("int8", sparse=True)
    strat = IFCAStrategy(n_features=100, n_classes=10, n_hidden=16,
                         k=3, local_epochs=1)    # server init ≠ 0: a
    # tracked zero reference is distinguishable from the server row
    engine = Engine(strat, data, RuntimeConfig(
        rounds=2, codec=wire,
        scheduler=SchedulerConfig(participation=0.5,
                                  sampling="round_robin")))
    key = jax.random.PRNGKey(0)
    k_init, k_rounds = jax.random.split(key)
    state = engine.init(k_init)
    for r in range(2):
        prev = state
        rk = jax.random.fold_in(k_rounds, r)
        part = engine.scheduler.sample(r, rk)
        state, rep = engine.run_round(state, rk)

        # replay the round's wire from prev.ref_vecs, independently
        idx = np.asarray(part.idx)
        keys = jax.random.split(rk, N_CLIENTS)[part.idx]
        sub_cs = jax.tree.map(lambda a: a[part.idx], prev.client_state)
        sub_data = jax.tree.map(lambda a: a[part.idx], data)
        _, vecs, slots = engine.executor.train(
            strat, sub_cs, engine._wire_tx_server(prev.server.slots),
            sub_data, keys)
        np_vecs, np_slots = np.asarray(vecs), np.asarray(slots)
        expect = 0
        for c in range(idx.shape[0]):
            for j in range(np_slots.shape[1]):
                s = int(np_slots[c, j])
                if s < 0:
                    continue
                ref = np.asarray(prev.ref_vecs)[int(idx[c]), s]
                expect += 4 + len(codec.encode(np_vecs[c, j], wire,
                                               ref=ref))
        assert rep.upload_bytes == expect

        synced = np.zeros(N_CLIENTS, bool)
        for rr in range(r + 1):
            synced[np.asarray(
                engine.scheduler.sample(
                    rr, jax.random.fold_in(k_rounds, rr)).idx)] = True
        ref_round = np.asarray(state.ref_round)
        assert (ref_round[~synced] == -1).all()
        assert (np.asarray(state.ref_vecs)[~synced] == 0).all()
        assert (ref_round[synced] >= 0).all()
    # disjoint round-robin windows: everyone synced after 2 half-rounds
    assert (np.asarray(state.ref_round) >= 0).all()


def test_sparse_refs_ride_checkpoints(tmp_path, data):
    """The reference lanes are part of the state pytree: a sparse run
    checkpointed and restored resumes with bit-identical references and
    byte totals."""
    from repro.fl.runtime import checkpointing
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    cfg = RuntimeConfig(
        rounds=2, codec=CodecConfig("int8", sparse=True),
        scheduler=SchedulerConfig(participation=0.5, dropout=0.25))
    key = jax.random.PRNGKey(0)

    full_state, full_reports = Engine(strat, data, cfg).run(key)

    engine = Engine(strat, data, cfg)
    half, _ = engine.run(key, rounds=1)
    path = checkpointing.save(tmp_path, half)
    restored = checkpointing.restore(path, engine.init(jax.random.PRNGKey(0)))
    assert (np.asarray(restored.ref_vecs)
            == np.asarray(half.ref_vecs)).all()
    assert (np.asarray(restored.ref_round)
            == np.asarray(half.ref_round)).all()
    resumed, resumed_reports = engine.run(key, state=restored, rounds=1)
    assert resumed_reports[0].upload_bytes == full_reports[1].upload_bytes
    assert (np.asarray(resumed.ref_vecs)
            == np.asarray(full_state.ref_vecs)).all()
    assert (np.asarray(resumed.ref_round)
            == np.asarray(full_state.ref_round)).all()


# ---------------------------------------------------------------------------
# scheduler distribution tests
# ---------------------------------------------------------------------------

def _chi_square(counts: np.ndarray, expected: np.ndarray) -> float:
    return float(((counts - expected) ** 2 / expected).sum())


def test_uniform_sampling_frequencies_match_expectation():
    n, rounds = 16, 300
    s = Scheduler(SchedulerConfig(participation=0.25), n_clients=n)
    counts = np.zeros(n)
    for r in range(rounds):
        counts[np.asarray(s.sample(r, jax.random.PRNGKey(r)).idx)] += 1
    expected = np.full(n, rounds * s.k / n)
    # df = 15; the 99.99% quantile is ≈ 44 — generous but not vacuous
    assert _chi_square(counts, expected) < 60.0


def test_weighted_sampling_driven_by_partition_sizes(data):
    """The fix under test: weighted sampling uses the real per-client
    dataset sizes recorded by ``partition`` (previously plumbed through
    ``Engine(client_weights=...)`` but never connected)."""
    assert data.sizes is not None and int(data.sizes.min()) >= 1
    assert len(set(np.asarray(data.sizes).tolist())) > 1  # heterogeneous

    engine = Engine(
        TPFLStrategy(TM_CFG, local_epochs=1), data,
        RuntimeConfig(scheduler=SchedulerConfig(
            participation=1 / N_CLIENTS, sampling="weighted")))
    sizes = np.asarray(data.sizes, np.float64)
    assert np.allclose(np.asarray(engine.scheduler.p), sizes / sizes.sum(),
                       atol=1e-6)

    rounds = 600
    counts = np.zeros(N_CLIENTS)
    for r in range(rounds):
        part = engine.scheduler.sample(r, jax.random.PRNGKey(1000 + r))
        counts[np.asarray(part.idx)] += 1    # K = 1 → frequencies ∝ p
    expected = rounds * sizes / sizes.sum()
    assert _chi_square(counts, np.maximum(expected, 1.0)) < 50.0


def test_round_robin_covers_population_in_ceil_n_over_k_rounds():
    for n, k_frac in ((8, 0.5), (10, 0.4), (12, 0.25)):
        cfg = SchedulerConfig(participation=k_frac, sampling="round_robin")
        s = Scheduler(cfg, n_clients=n)
        need = -(-n // s.k)                  # ⌈N/K⌉
        seen = set()
        for r in range(need):
            seen.update(np.asarray(
                s.sample(r, jax.random.PRNGKey(r)).idx).tolist())
        assert seen == set(range(n))


def test_staleness_never_exceeds_max_staleness():
    s = Scheduler(SchedulerConfig(straggler=0.7, max_staleness=3),
                  n_clients=32)
    for r in range(50):
        st = np.asarray(s.sample(r, jax.random.PRNGKey(r)).staleness)
        assert ((st >= 0) & (st <= 3)).all()


def test_async_buffer_never_holds_an_upload_older_than_max_staleness(data):
    """Engine-level: every buffered upload matures within max_staleness
    rounds of the round that sent it."""
    max_staleness = 2
    engine = Engine(
        TPFLStrategy(TM_CFG, local_epochs=1), data,
        RuntimeConfig(rounds=3, aggregation="async",
                      async_min_uploads=10 ** 6,
                      scheduler=SchedulerConfig(straggler=1.0,
                                                max_staleness=max_staleness)))
    state = engine.init(jax.random.PRNGKey(0))
    for r in range(3):
        state, _ = engine.run_round(state, jax.random.PRNGKey(r))
        ready = np.asarray(state.buf_ready)[np.asarray(state.buf_valid)]
        assert (ready <= r + max_staleness).all()


# ---------------------------------------------------------------------------
# telemetry neutrality: obs-on == obs-off, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("aggregation", ["sync", "async"])
@pytest.mark.parametrize("backend", ["inprocess", "shardmap"])
def test_telemetry_is_bit_neutral(backend, aggregation, data, tmp_path):
    """The obs plane only reads: a fully instrumented run (RunRecorder
    writing a run dir, spans + fences live) produces bit-identical
    RoundReports and final state to the un-instrumented run, on both
    backends and both aggregation modes."""
    from repro.fl.obs import RunRecorder, build_manifest, read_events

    cfg = RuntimeConfig(
        rounds=3, aggregation=aggregation, async_min_uploads=2,
        backend=backend,
        scheduler=SchedulerConfig(participation=0.75, dropout=0.25,
                                  straggler=0.5, max_staleness=2))
    s_off, r_off = Engine(TPFLStrategy(TM_CFG, local_epochs=1),
                          data, cfg).run(jax.random.PRNGKey(0))

    run_dir = tmp_path / f"{backend}-{aggregation}"
    rec = RunRecorder(run_dir=run_dir)
    rec.start(build_manifest(config=cfg, seed=0))
    try:
        s_on, r_on = Engine(TPFLStrategy(TM_CFG, local_epochs=1),
                            data, cfg, telemetry=rec
                            ).run(jax.random.PRNGKey(0))
    finally:
        rec.close()

    _assert_bitwise_equal_runs(s_off, r_off, s_on, r_on)
    # ...and the instrumented run really materialized its run dir
    assert (run_dir / "manifest.json").is_file()
    events = read_events(run_dir / "events.jsonl")
    assert [e["round"] for e in events] == [0, 1, 2]
    assert all(e["phases"] for e in events)


# ---------------------------------------------------------------------------
# million-client client store: mmap engine == resident engine, bit for bit
# ---------------------------------------------------------------------------

# hook coverage on purpose: tpfl/fedtm carry the O(K) ``init_cohort``
# fast path, ifca/flis_dc take the hookless full-init fallback — both
# must hold the same parity
MMAP_STRATEGIES = ("tpfl", "ifca", "flis_dc", "fedtm")


def _run_mmap(strat_name, data, sched, wire, backend, store_dir,
              rounds=ROUNDS):
    cfg = RuntimeConfig(rounds=rounds, scheduler=sched, codec=wire,
                        backend=backend, client_store="mmap",
                        store_dir=str(store_dir))
    engine = Engine(STRATEGIES[strat_name](), data, cfg)
    state, reports = engine.run(jax.random.PRNGKey(0))
    return engine, state, reports


def _assert_mmap_run_equals_resident(sa, ra, engine_m, sm, rm):
    """Every non-store observable of the mmap run equals the resident
    run bit for bit; the population itself is compared through the
    store (the mmap state intentionally carries no O(N) lanes)."""
    for a, b in zip(ra, rm):
        assert float(a.mean_accuracy) == float(b.mean_accuracy)
        assert (np.asarray(a.per_client_accuracy)
                == np.asarray(b.per_client_accuracy)).all()
        assert (np.asarray(a.assignment) == np.asarray(b.assignment)).all()
        assert (np.asarray(a.cluster_counts)
                == np.asarray(b.cluster_counts)).all()
        assert a.upload_bytes == b.upload_bytes
        assert a.download_bytes_broadcast == b.download_bytes_broadcast
        assert a.download_bytes_per_client == b.download_bytes_per_client
        assert a.aggregated_uploads == b.aggregated_uploads
        # host-I/O gauges: the resident engine never touches a store,
        # the mmap engine spills its cohort every round
        assert a.store_read_bytes == 0 and a.store_written_bytes == 0
        assert b.store_written_bytes > 0
    for la, lb in zip(jax.tree.leaves(sa.server),
                      jax.tree.leaves(sm.server)):
        assert (np.asarray(la) == np.asarray(lb)).all()
    # O(K) contract: the mmap state holds zero-row placeholders, the
    # population lives in the store — gather it whole for comparison
    assert jax.tree.leaves(sm.client_state)[0].shape[0] == 0
    pop = engine_m.store.gather(np.arange(engine_m.n))
    for la, lb in zip(jax.tree.leaves(sa.client_state),
                      jax.tree.leaves(pop["cs"])):
        assert (np.asarray(la) == np.asarray(lb)).all()
    if "ref_vecs" in pop:       # sparse-delta reference lanes ride rows
        assert (np.asarray(sa.ref_vecs)
                == np.asarray(pop["ref_vecs"])).all()
        assert (np.asarray(sa.ref_round)
                == np.asarray(pop["ref_round"])).all()


@pytest.mark.parametrize("part_name", sorted(PARTICIPATION))
@pytest.mark.parametrize("wire_name", sorted(WIRES))
@pytest.mark.parametrize("strat_name", MMAP_STRATEGIES)
def test_mmap_store_engine_bit_identical_to_resident(
        strat_name, wire_name, part_name, data, tmp_path):
    """The tentpole contract: ``client_store="mmap"`` — K sampled rows
    gathered from the host store into the compiled round, spilled back
    after upload — reproduces the resident engine bit for bit: every
    report field, the server pytree, the full population (including
    rows the scheduler never touched, regenerated by the fault-in
    init), and the sparse-delta reference lanes now living in the
    store."""
    sched, wire = PARTICIPATION[part_name], WIRES[wire_name]
    sa, ra = _run(STRATEGIES[strat_name](), data, sched, wire, "inprocess")
    em, sm, rm = _run_mmap(strat_name, data, sched, wire, "inprocess",
                           tmp_path / "store")
    _assert_mmap_run_equals_resident(sa, ra, em, sm, rm)


@pytest.mark.parametrize("wire_name", ["float32", "int4_sparse"])
@pytest.mark.parametrize("strat_name", ["tpfl", "fedtm"])
def test_mmap_store_engine_on_shardmap_matches_resident(
        strat_name, wire_name, data, tmp_path):
    """The store sits *outside* the mesh program: a shard-mapped mmap
    run equals the in-process resident run bit for bit (gather feeds
    the same compiled round the resident engine runs)."""
    sched = PARTICIPATION["partial"]
    sa, ra = _run(STRATEGIES[strat_name](), data, sched,
                  WIRES[wire_name], "inprocess")
    em, sm, rm = _run_mmap(strat_name, data, sched, WIRES[wire_name],
                           "shardmap", tmp_path / "store")
    _assert_mmap_run_equals_resident(sa, ra, em, sm, rm)


def test_mmap_store_engine_async_matches_resident(data, tmp_path):
    """Async aggregation over the store: the device buffer lanes are
    replicated state (they ride the checkpoint, not the store), so the
    buffered mmap run must equal the resident one bit for bit —
    including every buffer lane."""
    kw = dict(rounds=3, scheduler=ASYNC_SCHED, aggregation="async",
              async_min_uploads=2, buffer_capacity=5)
    sa, ra = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data,
                    RuntimeConfig(**kw)).run(jax.random.PRNGKey(0))
    em = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data,
                RuntimeConfig(**kw, client_store="mmap",
                              store_dir=str(tmp_path / "store")))
    sm, rm = em.run(jax.random.PRNGKey(0))
    _assert_mmap_run_equals_resident(sa, ra, em, sm, rm)
    _assert_async_reports_equal(ra, rm)
    for lane in ("buf_vecs", "buf_slots", "buf_ready", "buf_weight",
                 "buf_valid", "buf_seq"):
        assert (np.asarray(getattr(sa, lane))
                == np.asarray(getattr(sm, lane))).all(), lane


def test_mmap_checkpoint_resume_bit_identical(tmp_path, data):
    """An interrupted mmap run (replicated-state checkpoint + flushed
    store dir) resumes bit-identically to both the uninterrupted mmap
    run and the resident engine — sparse references included, and the
    store manifest rides the checkpoint directory."""
    from repro.fl.runtime import checkpointing

    def cfg(**kw):
        return RuntimeConfig(
            rounds=2, codec=CodecConfig("int8", sparse=True),
            scheduler=SchedulerConfig(participation=0.5, dropout=0.25),
            **kw)

    key = jax.random.PRNGKey(0)
    strat = lambda: TPFLStrategy(TM_CFG, local_epochs=1)  # noqa: E731
    s_res, r_res = Engine(strat(), data, cfg()).run(key)
    em_full = Engine(strat(), data, cfg(
        client_store="mmap", store_dir=str(tmp_path / "store_full")))
    s_full, r_full = em_full.run(key)

    # interrupted half: engine-driven checkpoint at round 1 (flushes
    # the store and writes store_manifest.json alongside)
    store_b = tmp_path / "store_half"
    ck = tmp_path / "ckpt"
    e1 = Engine(strat(), data, cfg(
        client_store="mmap", store_dir=str(store_b),
        checkpoint_dir=str(ck), checkpoint_every=1))
    e1.run(key, rounds=1)
    assert (ck / checkpointing.STORE_MANIFEST_NAME).is_file()

    # resume: fresh engine over the same store dir — the `like` state
    # deliberately uses a different key (the fed_train idiom); run()
    # re-keys the store's fault-in init from the run key
    e2 = Engine(strat(), data, cfg(
        client_store="mmap", store_dir=str(store_b)))
    restored = checkpointing.restore(
        checkpointing.latest(ck), e2.init(jax.random.PRNGKey(7)))
    s_resumed, r_resumed = e2.run(key, state=restored, rounds=1)

    for rep, full_rep, res_rep in zip(r_resumed, r_full[1:], r_res[1:]):
        assert float(rep.mean_accuracy) == float(full_rep.mean_accuracy)
        assert float(rep.mean_accuracy) == float(res_rep.mean_accuracy)
        assert rep.upload_bytes == full_rep.upload_bytes == \
            res_rep.upload_bytes
    _assert_mmap_run_equals_resident(s_res, r_res[1:], e2, s_resumed,
                                     r_resumed)


def test_mmap_sampled_eval_reports_cohort_accuracy(data, tmp_path):
    """``store_eval="sampled"`` (the million-client regime: scoring all
    N every round is exactly the O(N) scan the store exists to avoid)
    reports K-shaped accuracy for the round's cohort, equal to the
    resident engine's population-shaped report sliced at the sampled
    ids."""
    sched = SchedulerConfig(participation=0.5)
    sa, ra = _run(TPFLStrategy(TM_CFG, local_epochs=1), data, sched,
                  WIRES["float32"], "inprocess")
    engine = Engine(TPFLStrategy(TM_CFG, local_epochs=1), data,
                    RuntimeConfig(rounds=ROUNDS, scheduler=sched,
                                  client_store="mmap",
                                  store_dir=str(tmp_path / "store"),
                                  store_eval="sampled"))
    key = jax.random.PRNGKey(0)
    k_init, k_rounds = jax.random.split(key)
    state = engine.init(k_init)
    for r in range(ROUNDS):
        rk = jax.random.fold_in(k_rounds, r)
        idx = np.asarray(engine.scheduler.sample(r, rk).idx)
        state, rep = engine.run_round(state, rk)
        assert np.asarray(rep.per_client_accuracy).shape == idx.shape
        assert (np.asarray(rep.per_client_accuracy)
                == np.asarray(ra[r].per_client_accuracy)[idx]).all()
        assert (np.asarray(rep.assignment)
                == np.asarray(ra[r].assignment)[idx]).all()


def test_mmap_weighted_sampling_size_table_matches_resident(data):
    """Satellite fix pin: the scheduler accepts the store's host-side
    ``int64`` size table as weights — same key, same sampled ids as the
    resident engine's device-array sizes, so resident and streamed runs
    draw identical cohorts."""
    cfg = SchedulerConfig(participation=0.25, sampling="weighted")
    dev = Scheduler(cfg, N_CLIENTS, weights=jnp.asarray(data.sizes))
    host = Scheduler(cfg, N_CLIENTS,
                     weights=np.asarray(data.sizes, np.int64))
    assert (np.asarray(dev.p) == np.asarray(host.p)).all()
    for r in range(20):
        key = jax.random.PRNGKey(100 + r)
        assert (np.asarray(dev.sample(r, key).idx)
                == np.asarray(host.sample(r, key).idx)).all()


def test_streaming_population_requires_mmap_store(data):
    """A streaming population has no resident tensors to fall back to —
    the engine rejects ``client_store="resident"`` at construction
    instead of failing deep in the first gather."""

    class _FakeStream:
        n_clients = 64
        sizes = np.full(64, 10, np.int64)

        def gather_clients(self, ids):            # pragma: no cover
            raise AssertionError("not reached")

    with pytest.raises(ValueError, match="mmap"):
        Engine(TPFLStrategy(TM_CFG, local_epochs=1), _FakeStream(),
               RuntimeConfig())
