"""TPFL federation (Algorithms 1 & 2) system tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import clustering, federation, tm
from repro.data import partition, synthetic

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=20, n_features=100,
                     n_states=63, s=5.0, T=20)


def _data(n_clients=8, experiment=5, seed=0):
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1500,
                                        jax.random.PRNGKey(seed), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=n_clients, experiment=experiment,
        key=jax.random.PRNGKey(seed + 1), n_train=40, n_test=20, n_conf=20)


def test_cluster_aggregate_mean_and_counts():
    uploads = jnp.array([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])
    assign = jnp.array([0, 0, 2])
    res = clustering.aggregate(uploads, assign, n_clusters=3)
    assert jnp.allclose(res.cluster_weights[0], jnp.array([2.0, 3.0]))
    assert jnp.allclose(res.cluster_weights[2], jnp.array([5.0, 6.0]))
    assert res.counts.tolist() == [2, 0, 1]


def test_cluster_aggregate_permutation_invariant():
    key = jax.random.PRNGKey(0)
    uploads = jax.random.normal(key, (12, 7))
    assign = jax.random.randint(key, (12,), 0, 4)
    perm = jax.random.permutation(jax.random.PRNGKey(1), 12)
    a = clustering.aggregate(uploads, assign, 4)
    b = clustering.aggregate(uploads[perm], assign[perm], 4)
    assert jnp.allclose(a.cluster_weights, b.cluster_weights, atol=1e-5)
    assert (a.counts == b.counts).all()


def test_empty_cluster_keeps_previous_weights():
    prev = jnp.full((3, 2), 7.0)
    uploads = jnp.array([[1.0, 1.0]])
    res = clustering.aggregate(uploads, jnp.array([0]), 3, prev=prev)
    assert jnp.allclose(res.cluster_weights[1], 7.0)
    assert jnp.allclose(res.cluster_weights[0], 1.0)


def test_tpfl_round_mechanics():
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1)
    state, hist = federation.run(data, TM_CFG, fed, jax.random.PRNGKey(0))
    h = hist[0]
    # cluster ids live in [0, C); counts sum to n_clients
    assert int(h.assignment.min()) >= 0
    assert int(h.assignment.max()) < TM_CFG.n_classes
    assert int(h.cluster_counts.sum()) == 8
    # at most C clusters (paper: #clusters ≤ #classes)
    assert int((h.cluster_counts > 0).sum()) <= TM_CFG.n_classes


def test_tpfl_comm_accounting_exact():
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=2, local_epochs=1)
    _, hist = federation.run(data, TM_CFG, fed, jax.random.PRNGKey(0))
    m, bpw = TM_CFG.n_clauses, fed.bytes_per_weight
    for h in hist:
        assert h.upload_bytes == 8 * (m * bpw + 4)
        nonempty = int((h.cluster_counts > 0).sum())
        assert h.download_bytes_broadcast == nonempty * m * bpw
        assert h.download_bytes_per_client == 8 * m * bpw


def test_tpfl_upload_is_one_class_slice_only():
    """The paper's headline saving: upload = m weights, not C·m."""
    fed = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1)
    full_model = TM_CFG.n_classes * TM_CFG.n_clauses * fed.bytes_per_weight
    upload = TM_CFG.n_clauses * fed.bytes_per_weight + 4
    assert upload < full_model / (TM_CFG.n_classes - 1)


def test_multiclass_sharing_more_upload_more_clusters():
    """§7 future-work extension: top_classes=2 doubles upload and lets a
    client join two clusters; accuracy stays in a sane band."""
    data = _data()
    fed1 = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1)
    fed2 = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1,
                                top_classes=2)
    _, h1 = federation.run(data, TM_CFG, fed1, jax.random.PRNGKey(0))
    _, h2 = federation.run(data, TM_CFG, fed2, jax.random.PRNGKey(0))
    assert h2[0].upload_bytes == 2 * h1[0].upload_bytes
    assert h2[0].assignment.shape == (8, 2)
    assert int(h2[0].cluster_counts.sum()) == 16     # 2 memberships each
    assert abs(float(h2[0].mean_accuracy)
               - float(h1[0].mean_accuracy)) < 0.3


def test_confidence_threshold_skips_unconfident_shares():
    """§7: with an absurdly high threshold nothing is shared — cluster
    counts are zero and weights pass through Phase D unchanged."""
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1,
                               conf_threshold=1e9)
    _, hist = federation.run(data, TM_CFG, fed, jax.random.PRNGKey(0))
    assert int(hist[0].cluster_counts.sum()) == 0


@pytest.mark.slow
def test_tpfl_accuracy_improves_under_noniid():
    data = _data(n_clients=10, experiment=5, seed=3)
    fed = federation.FedConfig(n_clients=10, rounds=3, local_epochs=2)
    _, hist = federation.run(data, TM_CFG, fed, jax.random.PRNGKey(4))
    accs = [float(h.mean_accuracy) for h in hist]
    assert accs[-1] > 0.7
    assert accs[-1] >= accs[0] - 0.05   # no collapse across rounds


def test_phase_d_overwrites_only_cmax_class():
    data = _data()
    fed = federation.FedConfig(n_clients=8, rounds=1, local_epochs=1)
    k = jax.random.PRNGKey(0)
    state = federation.init_state(TM_CFG, fed, k)
    params, c_max, uploads = federation._phase_a(state, data, k, TM_CFG, fed)
    res = clustering.aggregate(uploads.reshape(-1, TM_CFG.n_clauses),
                               c_max.reshape(-1), TM_CFG.n_classes,
                               prev=state.cluster_weights)
    newp = federation._phase_d(params, c_max, res.cluster_weights)
    for i in range(4):
        c = int(c_max[i, 0])
        others = [cc for cc in range(TM_CFG.n_classes) if cc != c]
        # non-c_max classes untouched
        assert (newp.weights[i, others] == params.weights[i, others]).all()
        assert jnp.allclose(newp.weights[i, c],
                            jnp.round(res.cluster_weights[c]))
