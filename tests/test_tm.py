"""Tsetlin Machine unit + property(seed-swept) tests."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import tm


def _cfg(**kw):
    base = dict(n_classes=4, n_clauses=20, n_features=16, n_states=63,
                s=3.0, T=15)
    base.update(kw)
    return tm.TMConfig(**base)


def _blocky_data(n, key, n_classes=4, n_features=16):
    """class c ⇔ bits [4c, 4c+4) set (plus noise)."""
    ky, kn = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = (jax.random.uniform(kn, (n, n_features)) < 0.05).astype(jnp.int32)
    idx = jnp.arange(n_features)[None, :]
    on = (idx >= 4 * y[:, None]) & (idx < 4 * y[:, None] + 4)
    return jnp.where(on, 1, x), y


def test_init_shapes_and_bounds():
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    assert p.ta_state.shape == (4, 20, 32)
    assert p.weights.shape == (4, 20)
    assert int(p.ta_state.min()) >= 1
    assert int(p.ta_state.max()) <= 2 * cfg.n_states


def test_literals():
    x = jnp.array([[1, 0, 1]])
    lits = tm.literals(x)
    assert (lits == jnp.array([[1, 0, 1, 0, 1, 0]])).all()


def test_clause_outputs_are_boolean_and_empty_clause_convention():
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(1))
    # force one clause fully excluded (empty)
    ta = p.ta_state.at[0, 0].set(1)
    p = p._replace(ta_state=ta)
    x, _ = _blocky_data(8, jax.random.PRNGKey(2))
    learn = tm.clause_outputs(p, tm.literals(x), cfg, predict=False)
    pred = tm.clause_outputs(p, tm.literals(x), cfg, predict=True)
    assert set(jnp.unique(learn).tolist()) <= {0, 1}
    assert (learn[:, 0, 0] == 1).all()     # empty fires while learning
    assert (pred[:, 0, 0] == 0).all()      # and not during inference


@pytest.mark.parametrize("seed", range(3))
def test_learning_improves_accuracy(seed):
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(seed))
    x, y = _blocky_data(200, jax.random.PRNGKey(seed + 10))
    xt, yt = _blocky_data(100, jax.random.PRNGKey(seed + 20))
    before = float(tm.accuracy(p, xt, yt, cfg))
    p = tm.train(p, x, y, jax.random.PRNGKey(seed + 30), cfg, epochs=5)
    after = float(tm.accuracy(p, xt, yt, cfg))
    assert after > max(before, 0.8), (before, after)


@pytest.mark.parametrize("seed", range(3))
def test_ta_states_stay_bounded_after_training(seed):
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(seed))
    x, y = _blocky_data(100, jax.random.PRNGKey(seed))
    p = tm.train(p, x, y, jax.random.PRNGKey(seed), cfg, epochs=2)
    assert int(p.ta_state.min()) >= 1
    assert int(p.ta_state.max()) <= 2 * cfg.n_states
    assert int(p.weights.min()) >= 0


def test_votes_clipped_at_threshold():
    cfg = _cfg(T=5)
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    # saturate weights to force large raw votes
    p = p._replace(weights=jnp.full_like(p.weights, 1000),
                   ta_state=jnp.full_like(p.ta_state, 1))  # all excluded
    x, _ = _blocky_data(4, jax.random.PRNGKey(1))
    _, votes = tm.forward(p, x, cfg)
    assert int(jnp.abs(votes).max()) <= cfg.T


def test_confidence_tracks_data_skew():
    """A client trained only on class 0 should be most confident in 0."""
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    x, y = _blocky_data(300, jax.random.PRNGKey(1))
    keep = y == 0
    x0 = jnp.where(keep[:, None], x, x[0][None])   # mostly class-0 samples
    y0 = jnp.zeros_like(y)
    p = tm.train(p, x0, y0, jax.random.PRNGKey(2), cfg, epochs=3)
    xc, _ = _blocky_data(80, jax.random.PRNGKey(3))
    conf = tm.confidence_scores(p, xc, cfg)
    assert int(jnp.argmax(conf)) == 0


def test_kernel_path_equals_jnp_path():
    """cfg.use_kernel=True must be bit-identical (same uniforms)."""
    cfg_a = _cfg()
    cfg_b = _cfg(use_kernel=True)
    p = tm.init_params(cfg_a, jax.random.PRNGKey(0))
    x, y = _blocky_data(50, jax.random.PRNGKey(1))
    pa = tm.train(p, x, y, jax.random.PRNGKey(2), cfg_a, epochs=1)
    pb = tm.train(p, x, y, jax.random.PRNGKey(2), cfg_b, epochs=1)
    assert (pa.ta_state == pb.ta_state).all()
    assert (pa.weights == pb.weights).all()


@pytest.mark.parametrize("epochs", [1, 2])
@pytest.mark.parametrize("seed", range(2))
def test_kernel_train_bit_identical_at_unaligned_shapes(epochs, seed):
    """Full jit'd train through the fused epoch kernel at tile-unaligned
    shapes (L = 130, C·m = 99 — neither a multiple of 128): params must
    equal the reference scan bit for bit, not just single-op parity."""
    cfg = tm.TMConfig(n_classes=3, n_clauses=33, n_features=65,
                      n_states=63, s=3.0, T=15)
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    key = jax.random.PRNGKey(seed)
    kp, kx, ky, kt = jax.random.split(key, 4)
    p = tm.init_params(cfg, kp)
    x = (jax.random.uniform(kx, (23, cfg.n_features)) < 0.4).astype(jnp.int32)
    y = jax.random.randint(ky, (23,), 0, cfg.n_classes)
    pa = tm.train(p, x, y, kt, cfg, epochs=epochs)
    pb = tm.train(p, x, y, kt, kcfg, epochs=epochs)
    assert (pa.ta_state == pb.ta_state).all()
    assert (pa.weights == pb.weights).all()


def test_batched_entry_points_bit_identical_to_vmap(seed=0):
    """The client-batched kernel entry points (one launch for a stacked
    cohort) must match the vmapped per-client reference bit for bit."""
    cfg = tm.TMConfig(n_classes=3, n_clauses=33, n_features=65,
                      n_states=63, s=3.0, T=15)
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    N, S = 4, 17
    key = jax.random.PRNGKey(seed)
    kp, kx, ky, kt, ke = jax.random.split(key, 5)
    params = jax.vmap(lambda k: tm.init_params(cfg, k))(
        jax.random.split(kp, N))
    xs = (jax.random.uniform(kx, (N, S, cfg.n_features)) < 0.4).astype(
        jnp.int32)
    ys = jax.random.randint(ky, (N, S), 0, cfg.n_classes)
    keys = jax.random.split(kt, N)
    pa = tm.train_batched(params, xs, ys, keys, cfg, epochs=2)
    pb = tm.train_batched(params, xs, ys, keys, kcfg, epochs=2)
    assert (pa.ta_state == pb.ta_state).all()
    assert (pa.weights == pb.weights).all()
    xe = (jax.random.uniform(ke, (N, 9, cfg.n_features)) < 0.4).astype(
        jnp.int32)
    ye = jax.random.randint(jax.random.fold_in(ke, 1), (N, 9), 0,
                            cfg.n_classes)
    assert (tm.accuracy_batched(pa, xe, ye, cfg)
            == tm.accuracy_batched(pb, xe, ye, kcfg)).all()
    for weighted in (False, True):
        assert (tm.confidence_scores_batched(pa, xe, cfg, weighted=weighted)
                == tm.confidence_scores_batched(pb, xe, kcfg,
                                                weighted=weighted)).all()


def test_predict_kernel_clips_votes_before_argmax():
    """Regression: the kernel predict path used to argmax *unclipped*
    fused votes.  Craft vote saturation — class 0 fires weight 2, class
    1 fires weight 3, T = 1 — so clipped votes tie at +T (argmax → 0)
    while unclipped votes would pick class 1."""
    cfg = tm.TMConfig(n_classes=2, n_clauses=4, n_features=2,
                      n_states=63, s=3.0, T=1)
    kcfg = dataclasses.replace(cfg, use_kernel=True)
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    ta = jnp.ones_like(p.ta_state)          # everything excluded (empty)
    ta = ta.at[0, 0, 0].set(cfg.n_states + 1)   # class 0, clause 0: x0
    ta = ta.at[1, 0, 0].set(cfg.n_states + 1)   # class 1, clause 0: x0
    w = jnp.ones_like(p.weights).at[0, 0].set(2).at[1, 0].set(3)
    p = tm.TMParams(ta_state=ta, weights=w)
    x = jnp.array([[1, 0]], jnp.int32)          # both clauses fire
    r = tm.predict(p, x, cfg)
    k = tm.predict(p, x, kcfg)
    assert int(r[0]) == 0                       # ±T tie → first argmax
    assert (r == k).all()
    # and the batched kernel evaluate path clips identically
    y = jnp.zeros((1, 1), jnp.int32)
    stack = jax.tree.map(lambda a: a[None], p)
    assert float(tm.accuracy_batched(stack, x[None], y, kcfg)[0]) == 1.0
