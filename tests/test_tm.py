"""Tsetlin Machine unit + property(seed-swept) tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import tm


def _cfg(**kw):
    base = dict(n_classes=4, n_clauses=20, n_features=16, n_states=63,
                s=3.0, T=15)
    base.update(kw)
    return tm.TMConfig(**base)


def _blocky_data(n, key, n_classes=4, n_features=16):
    """class c ⇔ bits [4c, 4c+4) set (plus noise)."""
    ky, kn = jax.random.split(key)
    y = jax.random.randint(ky, (n,), 0, n_classes)
    x = (jax.random.uniform(kn, (n, n_features)) < 0.05).astype(jnp.int32)
    idx = jnp.arange(n_features)[None, :]
    on = (idx >= 4 * y[:, None]) & (idx < 4 * y[:, None] + 4)
    return jnp.where(on, 1, x), y


def test_init_shapes_and_bounds():
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    assert p.ta_state.shape == (4, 20, 32)
    assert p.weights.shape == (4, 20)
    assert int(p.ta_state.min()) >= 1
    assert int(p.ta_state.max()) <= 2 * cfg.n_states


def test_literals():
    x = jnp.array([[1, 0, 1]])
    lits = tm.literals(x)
    assert (lits == jnp.array([[1, 0, 1, 0, 1, 0]])).all()


def test_clause_outputs_are_boolean_and_empty_clause_convention():
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(1))
    # force one clause fully excluded (empty)
    ta = p.ta_state.at[0, 0].set(1)
    p = p._replace(ta_state=ta)
    x, _ = _blocky_data(8, jax.random.PRNGKey(2))
    learn = tm.clause_outputs(p, tm.literals(x), cfg, predict=False)
    pred = tm.clause_outputs(p, tm.literals(x), cfg, predict=True)
    assert set(jnp.unique(learn).tolist()) <= {0, 1}
    assert (learn[:, 0, 0] == 1).all()     # empty fires while learning
    assert (pred[:, 0, 0] == 0).all()      # and not during inference


@pytest.mark.parametrize("seed", range(3))
def test_learning_improves_accuracy(seed):
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(seed))
    x, y = _blocky_data(200, jax.random.PRNGKey(seed + 10))
    xt, yt = _blocky_data(100, jax.random.PRNGKey(seed + 20))
    before = float(tm.accuracy(p, xt, yt, cfg))
    p = tm.train(p, x, y, jax.random.PRNGKey(seed + 30), cfg, epochs=5)
    after = float(tm.accuracy(p, xt, yt, cfg))
    assert after > max(before, 0.8), (before, after)


@pytest.mark.parametrize("seed", range(3))
def test_ta_states_stay_bounded_after_training(seed):
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(seed))
    x, y = _blocky_data(100, jax.random.PRNGKey(seed))
    p = tm.train(p, x, y, jax.random.PRNGKey(seed), cfg, epochs=2)
    assert int(p.ta_state.min()) >= 1
    assert int(p.ta_state.max()) <= 2 * cfg.n_states
    assert int(p.weights.min()) >= 0


def test_votes_clipped_at_threshold():
    cfg = _cfg(T=5)
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    # saturate weights to force large raw votes
    p = p._replace(weights=jnp.full_like(p.weights, 1000),
                   ta_state=jnp.full_like(p.ta_state, 1))  # all excluded
    x, _ = _blocky_data(4, jax.random.PRNGKey(1))
    _, votes = tm.forward(p, x, cfg)
    assert int(jnp.abs(votes).max()) <= cfg.T


def test_confidence_tracks_data_skew():
    """A client trained only on class 0 should be most confident in 0."""
    cfg = _cfg()
    p = tm.init_params(cfg, jax.random.PRNGKey(0))
    x, y = _blocky_data(300, jax.random.PRNGKey(1))
    keep = y == 0
    x0 = jnp.where(keep[:, None], x, x[0][None])   # mostly class-0 samples
    y0 = jnp.zeros_like(y)
    p = tm.train(p, x0, y0, jax.random.PRNGKey(2), cfg, epochs=3)
    xc, _ = _blocky_data(80, jax.random.PRNGKey(3))
    conf = tm.confidence_scores(p, xc, cfg)
    assert int(jnp.argmax(conf)) == 0


def test_kernel_path_equals_jnp_path():
    """cfg.use_kernel=True must be bit-identical (same uniforms)."""
    cfg_a = _cfg()
    cfg_b = _cfg(use_kernel=True)
    p = tm.init_params(cfg_a, jax.random.PRNGKey(0))
    x, y = _blocky_data(50, jax.random.PRNGKey(1))
    pa = tm.train(p, x, y, jax.random.PRNGKey(2), cfg_a, epochs=1)
    pb = tm.train(p, x, y, jax.random.PRNGKey(2), cfg_b, epochs=1)
    assert (pa.ta_state == pb.ta_state).all()
    assert (pa.weights == pb.weights).all()
