"""AdamW, sharding rules, confidence helpers, HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import confidence
from repro.launch import hlo_analysis
from repro.optim import adamw
from repro.sharding import rules


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = adamw.update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2
    assert int(state.step) == 100


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(3)}
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = adamw.init(params, cfg)
    g = {"w": jnp.array([100.0, 0.0, 0.0])}
    p2, _ = adamw.update(params, g, state, cfg)
    # clipped to unit norm → first-step Adam update magnitude ≈ lr
    assert float(jnp.abs(p2["w"]).max()) < 1.5


def test_adamw_preserves_tree_structure():
    params = {"a": {"b": jnp.ones((2, 3))}, "c": [jnp.ones(4)]}
    state = adamw.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    p2, s2 = adamw.update(params, g, state)
    assert jax.tree.structure(p2) == jax.tree.structure(params)
    assert jax.tree.structure(s2.m) == jax.tree.structure(params)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def _fake_mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.array(jax.devices() * (shape[0] * shape[1]))[
        :shape[0] * shape[1]].reshape(shape)
    return Mesh(devs, axes)


def test_param_spec_patterns():
    mesh = _fake_mesh()
    assert rules.param_spec("embed", (64, 32), mesh) == P("model", "data")
    # lm_head keeps d replicated on purpose (see rules.py §Perf note)
    assert rules.param_spec("lm_head", (32, 64), mesh) == P(None, "model")
    s = rules.param_spec("segments/0/mixer/wq", (4, 32, 64), mesh)
    assert s == P(None, "data", "model")
    s = rules.param_spec("segments/0/mixer/wo", (4, 64, 32), mesh)
    assert s == P(None, "model", "data")
    # MoE expert bank, expert-parallel
    s = rules.param_spec("segments/0/ffn/gate", (4, 8, 32, 16), mesh, "ep")
    assert s == P(None, "model", "data", None)
    # norm scales replicated
    s = rules.param_spec("segments/0/norm1", (4, 32), mesh)
    assert s == P(None, None)


def test_param_spec_divisibility_guard():
    mesh = _fake_mesh()
    # vocab 49155 not divisible by 2 → replicated on that dim
    assert rules.param_spec("embed", (49155, 32), mesh) == P(None, "data")
    # lm_head: d replicated by design; non-divisible vocab also replicated
    assert rules.param_spec("lm_head", (32, 49155), mesh) == P(None, None)


def test_batch_spec_fallback_for_tiny_batch():
    mesh = _fake_mesh()
    assert rules.batch_spec(mesh, 8) == P("data", None)
    assert rules.batch_spec(mesh, 1) == P(None, None)   # long_500k case


# ---------------------------------------------------------------------------
# Confidence (NN analogue)
# ---------------------------------------------------------------------------

def test_logit_margin_confidence_prefers_dominant_class():
    logits = jnp.array([[5.0, 1.0, 0.0],
                        [4.0, 2.0, 0.0],
                        [0.0, 0.5, 0.2]])
    conf = confidence.logit_margin_confidence(logits)
    assert int(jnp.argmax(conf)) == 0
    assert int(confidence.cluster_assignment(conf)) == 0


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[16]{0} all-reduce(%y), to_apply=%add
  %p = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
  %cp = f32[2,2]{1,0} collective-permute(%z)
  %notacoll = f32[999]{0} add(%a, %b)
"""
    out = hlo_analysis.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 16 * 4
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["collective-permute"] == 4 * 4


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"all-reduce": int(50e9 * 3)}
    rf = hlo_analysis.roofline(cost, coll, peak_flops=197e12, hbm_bw=819e9,
                               ici_bw=50e9, model_flops=197e12 * 256,
                               chips=256)
    assert abs(rf["compute_s"] - 1.0) < 1e-9
    assert abs(rf["memory_s"] - 2.0) < 1e-9
    assert abs(rf["collective_s"] - 3.0) < 1e-9
    assert rf["bottleneck"] == "collective"
    assert abs(rf["useful_flops_ratio"] - 1.0) < 1e-9
