"""Real-transport runtime tests: wire framing, message packing,
compression v2 (error feedback + varint/RLE index coding), the
loopback==in-process conformance pin, fault injection/retry, and the
multi-process socket smoke (slow-marked)."""
import dataclasses
import io

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tm
from repro.data import partition, synthetic
from repro.fl.runtime import (CodecConfig, Engine, RuntimeConfig,
                              Scheduler, SchedulerConfig, TPFLStrategy,
                              checkpointing, codec)
from repro.fl.runtime.executors import InProcessExecutor, applied_slots
from repro.fl.runtime.scheduler import arrival_participation
from repro.fl.runtime.strategy import (build_baseline_strategy,
                                       resolve_server_update)
from repro.fl import masked_collectives
from repro.fl.transport import (BadMagicError, DisconnectError, FaultPlan,
                                FrameTooLargeError, MsgKind, RetryPolicy,
                                TransportEngine, TruncatedFrameError,
                                WireError, framing)
from repro.fl.transport import messages as msgs

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=20, n_features=100,
                     n_states=63, s=5.0, T=20)


def _data(n_clients=6, seed=0):
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1500,
                                        jax.random.PRNGKey(seed), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=n_clients, experiment=5,
        key=jax.random.PRNGKey(seed + 1), n_train=40, n_test=20, n_conf=20)


def _flis(max_slots=4):
    return build_baseline_strategy(
        "flis_dc", n_features=100, n_classes=10, n_hidden=16,
        local_epochs=1, max_slots=max_slots, probe_size=32)


def _stream_reader(buf: bytes):
    bio = io.BytesIO(buf)
    return lambda n: bio.read(n)


# ---------------------------------------------------------------------------
# framing: length-prefixed wire robustness
# ---------------------------------------------------------------------------

def test_frame_roundtrip_property():
    rng = np.random.default_rng(0)
    for _ in range(50):
        kind = int(rng.integers(0, 256))
        payload = rng.bytes(int(rng.integers(0, 512)))
        frame = framing.pack_frame(kind, payload)
        k, p, consumed = framing.decode_frame(frame)
        assert (k, p, consumed) == (kind, payload, len(frame))
        k2, p2 = framing.read_frame(_stream_reader(frame))
        assert (k2, p2) == (kind, payload)


def test_bad_magic_is_loud():
    frame = bytearray(framing.pack_frame(2, b"hello"))
    frame[0] ^= 0xFF
    with pytest.raises(BadMagicError):
        framing.read_frame(_stream_reader(bytes(frame)))


def test_truncated_frame_mid_payload_is_loud():
    frame = framing.pack_frame(2, b"hello world")
    with pytest.raises(TruncatedFrameError):
        framing.read_frame(_stream_reader(frame[:-3]))


def test_disconnect_at_frame_boundary():
    """EOF between frames is a disconnect, not a truncation."""
    with pytest.raises(DisconnectError):
        framing.read_frame(_stream_reader(b""))


def test_oversized_length_prefix_is_loud():
    hdr = framing.HEADER.pack(framing.MAGIC, 1, framing.MAX_FRAME + 1)
    with pytest.raises(FrameTooLargeError):
        framing.read_frame(_stream_reader(hdr))


def test_corrupted_header_property():
    """Flipping any header byte either raises a typed WireError or
    changes what the stream decodes to — corruption is never silently
    absorbed."""
    payload = b"x" * 40
    frame = framing.pack_frame(3, payload)
    second = framing.pack_frame(4, b"tail")
    rng = np.random.default_rng(1)
    for _ in range(60):
        pos = int(rng.integers(0, framing.HEADER.size))
        flip = int(rng.integers(1, 256))
        buf = bytearray(frame + second)
        buf[pos] ^= flip
        reader = _stream_reader(bytes(buf))
        try:
            out = [framing.read_frame(reader), framing.read_frame(reader)]
        except WireError:
            continue                        # loud typed failure: good
        assert out != [(3, payload), (4, b"tail")]


# ---------------------------------------------------------------------------
# round-protocol messages
# ---------------------------------------------------------------------------

def test_message_roundtrip_property():
    rng = np.random.default_rng(2)
    for _ in range(20):
        clients = tuple(
            msgs.WorkClient(gidx=int(rng.integers(0, 1000)),
                            key=(int(rng.integers(0, 2**32)),
                                 int(rng.integers(0, 2**32))),
                            active=bool(rng.integers(0, 2)),
                            staleness=int(rng.integers(0, 4)))
            for _ in range(int(rng.integers(0, 5))))
        rows = tuple(rng.bytes(int(rng.integers(0, 64)))
                     for _ in range(int(rng.integers(1, 4))))
        w = msgs.Work(round_idx=int(rng.integers(0, 100)), dim=16,
                      rows=rows, clients=clients)
        assert msgs.Work.unpack(w.pack()) == w

        entries = tuple(
            msgs.UploadEntry(
                gidx=int(rng.integers(0, 1000)),
                src_round=int(rng.integers(0, 100)),
                staleness=int(rng.integers(0, 4)),
                frames=tuple((int(rng.integers(0, 3)),
                              int(rng.integers(0, 8)),
                              rng.bytes(int(rng.integers(0, 32))))
                             for _ in range(int(rng.integers(0, 3)))))
            for _ in range(int(rng.integers(0, 4))))
        u = msgs.Upload(round_idx=3, entries=entries)
        assert msgs.Upload.unpack(u.pack()) == u

        dl = msgs.Downlink(
            round_idx=7, dim=16, rows=rows,
            clients=tuple(
                msgs.DownClient(gidx=i, arrive=bool(i % 2),
                                applied=(int(rng.integers(-1, 4)),))
                for i in range(3)))
        assert msgs.Downlink.unpack(dl.pack()) == dl

        acc = rng.random(5).astype(np.float32)
        ev = msgs.Eval.unpack(msgs.Eval(round_idx=1, acc=acc).pack())
        assert np.array_equal(ev.acc, acc)


def test_message_trailing_and_truncated_bytes_are_loud():
    buf = msgs.Work(round_idx=0, dim=4, rows=(b"abcd",),
                    clients=()).pack()
    with pytest.raises(WireError):
        msgs.Work.unpack(buf + b"\x00")     # trailing garbage
    with pytest.raises(WireError):
        msgs.Work.unpack(buf[:-2])          # truncated payload


# ---------------------------------------------------------------------------
# compression v2: varint+RLE index coding and error feedback
# ---------------------------------------------------------------------------

def test_vrle_roundtrip_matches_u2_decode():
    rng = np.random.default_rng(3)
    for _ in range(20):
        m = int(rng.integers(8, 300))
        ref = rng.normal(scale=10.0, size=m).astype(np.float32)
        vec = ref.copy()
        nz = rng.choice(m, size=int(rng.integers(0, max(2, m // 10))),
                        replace=False)
        vec[nz] += rng.normal(scale=5.0, size=nz.size).astype(np.float32)
        u2 = CodecConfig("int8", sparse=True)
        v2 = CodecConfig("int8", sparse=True, index_coding="vrle")
        out_u2 = codec.decode(codec.encode(vec, u2, ref=ref), m, u2,
                              ref=ref)
        out_v2 = codec.decode(codec.encode(vec, v2, ref=ref), m, v2,
                              ref=ref)
        assert np.array_equal(out_u2, out_v2)


def test_vrle_addresses_vectors_beyond_u2_range():
    """Varint indices lift the legacy <u2 65535-entry ceiling."""
    m = 70_000
    ref = np.zeros(m, np.float32)
    vec = ref.copy()
    idx = np.array([5, 6, 7, 66_000, 69_999])
    vec[idx] = 42.0
    cfg = CodecConfig("int8", sparse=True, index_coding="vrle")
    buf = codec.encode(vec, cfg, ref=ref)
    assert len(buf) < 100                   # 5 entries, not 70k
    out = codec.decode(buf, m, cfg, ref=ref)
    tol = codec.roundtrip_tolerance(vec - ref, cfg)
    assert np.abs(out - vec).max() <= tol + 1e-6
    assert set(np.nonzero(out)[0]) == set(idx.tolist())


def test_vrle_smaller_for_clustered_indices():
    m = 4096
    ref = np.zeros(m, np.float32)
    vec = ref.copy()
    vec[100:400] = np.linspace(1, 5, 300, dtype=np.float32)  # one run
    u2 = CodecConfig("int8", sparse=True)
    v2 = CodecConfig("int8", sparse=True, index_coding="vrle")
    assert len(codec.encode(vec, v2, ref=ref)) < \
        len(codec.encode(vec, u2, ref=ref))


def test_error_feedback_cancels_quantization_bias():
    """Over repeated rounds the EF stream's *accumulated* decode error
    stays bounded near one quantization step, while the plain lossy
    stream's bias adds up linearly."""
    cfg = CodecConfig("int4", error_feedback=True)
    rng = np.random.default_rng(4)
    vec = rng.normal(scale=10.0, size=64).astype(np.float32)
    residual = np.zeros_like(vec)
    ef_sum = np.zeros_like(vec)
    plain_sum = np.zeros_like(vec)
    rounds = 32
    for _ in range(rounds):
        frame, residual = codec.ef_encode(vec, cfg, residual)
        ef_sum += codec.decode(frame, 64, cfg)
        plain_sum += codec.decode(codec.encode(vec, cfg), 64, cfg)
    target = rounds * vec
    step = codec.roundtrip_tolerance(vec, cfg)
    assert np.abs(ef_sum - target).max() <= 2 * step + 1e-4
    assert np.abs(ef_sum - target).max() < np.abs(plain_sum - target).max()


def test_codec_config_v2_validation():
    with pytest.raises(ValueError, match="requires sparse=True"):
        CodecConfig("int8", index_coding="vrle")
    with pytest.raises(ValueError, match="lossy codec"):
        CodecConfig("float32", error_feedback=True)
    with pytest.raises(ValueError, match="unknown index_coding"):
        CodecConfig("int8", sparse=True, index_coding="rle9")


# ---------------------------------------------------------------------------
# RuntimeConfig transport validation
# ---------------------------------------------------------------------------

def test_runtime_config_transport_validation():
    with pytest.raises(ValueError, match="unknown transport"):
        RuntimeConfig(transport="sockets")          # the typo, loudly
    with pytest.raises(ValueError, match="workers >= 1"):
        RuntimeConfig(transport="loopback")
    with pytest.raises(ValueError, match="transport knob"):
        RuntimeConfig(transport="inprocess", workers=2)
    with pytest.raises(ValueError, match="sparse"):
        RuntimeConfig(transport="socket", workers=2, aggregation="async",
                      codec=CodecConfig("int8", sparse=True))


def test_arrival_participation_validation_and_summary():
    with pytest.raises(ValueError, match="same length"):
        arrival_participation([1, 2], [0])
    with pytest.raises(ValueError, match="cannot arrive before"):
        arrival_participation([1], [-1])
    s = arrival_participation([3, 5, 9], [0, 2, 0]).summary()
    assert s["sampled"] == 3 and s["stragglers"] == 1
    assert s["staleness_hist"] == [2, 0, 1]


# ---------------------------------------------------------------------------
# loopback == in-process: the conformance pin
# ---------------------------------------------------------------------------

def _assert_runs_equal(strategy, data, cfg, key, rounds=2):
    """Reports (every pre-transport field), codec-metered byte totals,
    and final state must be bit-identical between the in-process engine
    and the loopback transport; the wire gauges are transport-only
    extras (framed bytes that actually crossed the wire — zero by
    definition in-process)."""
    eng = Engine(strategy, data, dataclasses.replace(cfg, rounds=rounds))
    st_a, reps_a = eng.run(key)
    tr = TransportEngine(strategy, data,
                         dataclasses.replace(cfg, rounds=rounds,
                                             transport="loopback",
                                             workers=2))
    st_b, reps_b = tr.run(key)
    for ra, rb in zip(reps_a, reps_b):
        assert ra.round_idx == rb.round_idx
        assert np.array_equal(np.asarray(ra.per_client_accuracy),
                              np.asarray(rb.per_client_accuracy))
        assert np.array_equal(np.asarray(ra.assignment),
                              np.asarray(rb.assignment))
        assert np.array_equal(np.asarray(ra.cluster_counts),
                              np.asarray(rb.cluster_counts))
        assert ra.upload_bytes == rb.upload_bytes
        assert ra.download_bytes_broadcast == rb.download_bytes_broadcast
        assert ra.download_bytes_per_client == rb.download_bytes_per_client
        assert ra.aggregated_uploads == rb.aggregated_uploads
        assert ra.wire_tx_bytes == 0 and ra.wire_rx_bytes == 0
        assert rb.wire_tx_bytes > 0 and rb.wire_rx_bytes > 0
    leaves_a, leaves_b = jax.tree.leaves(st_a), jax.tree.leaves(st_b)
    assert len(leaves_a) == len(leaves_b)
    for a, b in zip(leaves_a, leaves_b):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    return reps_b


def test_loopback_equals_inprocess_identity_wire():
    data = _data()
    _assert_runs_equal(TPFLStrategy(TM_CFG, local_epochs=1), data,
                       RuntimeConfig(), jax.random.PRNGKey(42))


def test_loopback_equals_inprocess_int8_error_feedback():
    """Lossy wire + EF residual memory: worker-held residuals advance
    identically to the engine's ``ef_residual`` lane (re-assembled into
    the loopback final state)."""
    data = _data()
    _assert_runs_equal(
        TPFLStrategy(TM_CFG, local_epochs=1), data,
        RuntimeConfig(codec=CodecConfig("int8", error_feedback=True)),
        jax.random.PRNGKey(42), rounds=3)


def test_loopback_equals_inprocess_partial_participation():
    """K-of-N sampling + dropout + stragglers: the sync barrier over
    real frames (straggler frames are sent and metered, then discarded
    by the barrier) matches the injected-schedule engine."""
    data = _data(n_clients=8)
    _assert_runs_equal(
        TPFLStrategy(TM_CFG, local_epochs=1), data,
        RuntimeConfig(scheduler=SchedulerConfig(
            participation=0.75, dropout=0.2, straggler=0.3)),
        jax.random.PRNGKey(7))


def test_loopback_equals_inprocess_flis_assign_over_wire():
    """Server-side dynamic assignment runs on the decoded frames the
    wire actually delivered."""
    data = _data()
    _assert_runs_equal(_flis(), data,
                       RuntimeConfig(codec=CodecConfig("int8")),
                       jax.random.PRNGKey(3))


def test_loopback_async_records_observed_staleness():
    """Async over the transport is arrival-driven: workers hold
    straggling uploads and flush them rounds later, and the server
    records the real arrival lags."""
    data = _data()
    cfg = RuntimeConfig(rounds=4, aggregation="async",
                        transport="loopback", workers=2,
                        scheduler=SchedulerConfig(straggler=0.5,
                                                  max_staleness=2))
    _, reps = TransportEngine(TPFLStrategy(TM_CFG, local_epochs=1),
                              data, cfg).run(jax.random.PRNGKey(0))
    obs = [r.observed_staleness for r in reps]
    assert all(o is not None for o in obs)
    # something straggled: some round saw an upload with lag >= 1
    assert any(len(o["staleness_hist"]) > 1 for o in obs)
    assert all(r.wire_tx_bytes > 0 for r in reps)


# ---------------------------------------------------------------------------
# async × dynamic assignment: buffered FLIS vs a host reference loop
# ---------------------------------------------------------------------------

def test_async_buffered_flis_matches_host_reference_loop():
    """The engine's assign-at-aggregation-time path (async + server-side
    hooks) pinned bit-for-bit against an independent reference loop:
    explicit numpy buffer, maturity gate, ``strategy.assign`` over the
    matured entries, weighted clustered mean, ``server_update``."""
    data = _data()
    strategy = _flis()
    cfg = RuntimeConfig(rounds=4, aggregation="async", async_min_uploads=2,
                        buffer_capacity=32,
                        scheduler=SchedulerConfig(straggler=0.4,
                                                  max_staleness=2))
    key = jax.random.PRNGKey(11)
    eng = Engine(strategy, data, cfg)
    st_eng, reps_eng = eng.run(key)

    # -- reference loop ----------------------------------------------------
    ex = InProcessExecutor()
    srv_update = resolve_server_update(strategy)
    n = int(data.x_train.shape[0])
    cap, d = cfg.buffer_capacity, strategy.vec_dim
    k_init, k_rounds = jax.random.split(key)
    cs, server = strategy.init(k_init, n, data)
    sched = Scheduler(cfg.scheduler, n)
    bvecs = np.zeros((cap, d), np.float32)
    bslots = np.full((cap,), -1, np.int32)
    bready = np.zeros((cap,), np.int32)
    bweight = np.zeros((cap,), np.float32)
    bvalid = np.zeros((cap,), bool)
    bseq = np.zeros((cap,), np.int32)
    next_seq = 0
    counts_per_round = []
    for r in range(cfg.rounds):
        round_key = jax.random.fold_in(k_rounds, r)
        part = sched.sample(r, round_key)
        keys = jax.random.split(round_key, n)[part.idx]
        sub_cs = jax.tree.map(lambda a: a[part.idx], cs)
        sub_data = jax.tree.map(lambda a: a[part.idx], data)
        new_sub, vecs, slots = ex.train(strategy, sub_cs, server.slots,
                                        sub_data, keys)
        np_vecs, np_slots = np.asarray(vecs), np.asarray(slots)
        active = np.asarray(part.active)
        stale = np.asarray(part.staleness)
        for c in range(np_vecs.shape[0]):
            if not active[c]:
                continue
            for j in range(np_vecs.shape[1]):
                if np_slots[c, j] < 0:
                    continue
                i = int(np.nonzero(~bvalid)[0][0])   # capacity is ample
                bvecs[i] = np_vecs[c, j]
                bslots[i] = np_slots[c, j]
                bready[i] = r + int(stale[c])
                bweight[i] = cfg.staleness_discount ** int(stale[c])
                bvalid[i] = True
                bseq[i] = next_seq
                next_seq += 1
        mature = bvalid & (bready <= r)
        contrib = mature & (bweight > 0)
        if int(mature.sum()) >= cfg.async_min_uploads:
            s = jnp.asarray(np.where(contrib, bslots, -1), jnp.int32)
            new_s = strategy.assign(server, jnp.asarray(bvecs)[:, None, :],
                                    s[:, None], jnp.asarray(contrib))
            s = jnp.where(jnp.asarray(contrib), new_s[:, 0],
                          -1).astype(jnp.int32)
            mean = masked_collectives.clustered_weighted_mean(
                jnp.asarray(bvecs), s,
                jnp.asarray(np.where(contrib, bweight, 0.0), jnp.float32),
                strategy.n_slots)
            counts = jax.nn.one_hot(s, strategy.n_slots,
                                    dtype=jnp.float32).sum(0)
            server = srv_update(server, mean, counts)
            bvalid &= ~mature
        else:
            counts = jnp.zeros((strategy.n_slots,), jnp.float32)
        counts_per_round.append(counts)
        recv = jnp.asarray(active)
        applied = applied_slots(slots, counts, recv)
        merged = ex.apply_merge(strategy, new_sub, applied, server.slots,
                                sub_cs, recv)
        cs = merged      # full uniform participation: identity scatter

    for rep, counts in zip(reps_eng, counts_per_round):
        assert np.array_equal(np.asarray(rep.cluster_counts),
                              np.asarray(counts))
    assert np.array_equal(np.asarray(st_eng.server.slots),
                          np.asarray(server.slots))
    for a, b in zip(jax.tree.leaves(st_eng.client_state),
                    jax.tree.leaves(cs)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# error-feedback residual state rides checkpoints
# ---------------------------------------------------------------------------

def test_ef_residual_checkpoint_resume_bit_identical(tmp_path):
    data = _data()
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    cfg = RuntimeConfig(rounds=4,
                        codec=CodecConfig("int8", error_feedback=True))
    key = jax.random.PRNGKey(5)
    full = Engine(strat, data, cfg)
    st_full, reps_full = full.run(key)
    assert float(jnp.abs(st_full.ef_residual).sum()) > 0

    half = Engine(strat, data, dataclasses.replace(
        cfg, rounds=2, checkpoint_dir=str(tmp_path), checkpoint_every=2))
    half.run(key)
    resumed = checkpointing.restore(
        checkpointing.latest(tmp_path), half.init(jax.random.PRNGKey(0)))
    assert resumed.ef_residual.shape == st_full.ef_residual.shape
    st_res, reps_res = half.run(key, state=resumed, rounds=2)

    for a, b in zip(reps_full[2:], reps_res):
        assert np.array_equal(np.asarray(a.per_client_accuracy),
                              np.asarray(b.per_client_accuracy))
        assert a.upload_bytes == b.upload_bytes
    assert np.array_equal(np.asarray(st_full.ef_residual),
                          np.asarray(st_res.ef_residual))
    assert np.array_equal(np.asarray(st_full.server.slots),
                          np.asarray(st_res.server.slots))


# ---------------------------------------------------------------------------
# fault injection and retry
# ---------------------------------------------------------------------------

def test_injected_disconnect_is_retried_and_run_unperturbed():
    """A disconnect on the server's recv path is retried with backoff;
    the queued frame is intact, so the run's results are unchanged."""
    data = _data()
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    cfg = RuntimeConfig(rounds=2, transport="loopback", workers=2)
    key = jax.random.PRNGKey(0)
    _, clean = TransportEngine(strat, data, cfg).run(key)
    faulty = TransportEngine(
        strat, data, cfg,
        faults=FaultPlan(disconnect=((0, 0), (1, 2))),
        retry=RetryPolicy(attempts=3, backoff=0.001))
    _, reps = faulty.run(key)
    for ra, rb in zip(clean, reps):
        assert np.array_equal(np.asarray(ra.per_client_accuracy),
                              np.asarray(rb.per_client_accuracy))
        assert ra.upload_bytes == rb.upload_bytes


def test_retry_exhaustion_raises_disconnect():
    data = _data()
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    eng = TransportEngine(
        strat, data, RuntimeConfig(rounds=1, transport="loopback",
                                   workers=2),
        faults=FaultPlan(disconnect=((0, 0), (0, 1), (0, 2))),
        retry=RetryPolicy(attempts=2, backoff=0.001))
    with pytest.raises(DisconnectError):
        eng.run(jax.random.PRNGKey(0))


def test_fault_delay_shows_up_as_observed_staleness():
    """An injected per-client delivery delay (async) surfaces as real
    arrival lag in the round's observed-staleness section."""
    data = _data()
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    cfg = RuntimeConfig(rounds=3, aggregation="async",
                        transport="loopback", workers=2)
    delayed = TransportEngine(strat, data, cfg,
                              faults=FaultPlan(delay=((0, 2, 2),)))
    _, reps = delayed.run(jax.random.PRNGKey(0))
    # client 2's round-0 upload arrives in round 2 with lag 2
    hist = reps[2].observed_staleness["staleness_hist"]
    assert len(hist) >= 3 and hist[2] >= 1


def test_fault_drop_removes_upload_from_barrier():
    data = _data()
    strat = TPFLStrategy(TM_CFG, local_epochs=1)
    cfg = RuntimeConfig(rounds=1, transport="loopback", workers=2)
    key = jax.random.PRNGKey(0)
    _, clean = TransportEngine(strat, data, cfg).run(key)
    dropped = TransportEngine(strat, data, cfg,
                              faults=FaultPlan(drop=((0, 3),)))
    _, reps = dropped.run(key)
    assert reps[0].aggregated_uploads < clean[0].aggregated_uploads
    assert reps[0].upload_bytes < clean[0].upload_bytes


def test_fault_plan_and_retry_validation():
    with pytest.raises(ValueError):
        RetryPolicy(attempts=0)
    plan = FaultPlan(delay=((0, 1, 2), (0, 1, 1)))
    assert plan.delay_for(0, 1) == 3        # matching extras sum
    assert plan.delay_for(1, 1) == 0


# ---------------------------------------------------------------------------
# socket transport: real multi-process smoke
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_socket_transport_matches_inprocess(tmp_path):
    """End-to-end over real subprocesses + TCP: the fed_train driver's
    socket run reproduces the in-process metrics exactly (identity
    wire)."""
    from repro.launch import fed_train
    base = ["--clients", "6", "--rounds", "2", "--clauses", "16",
            "--local-epochs", "1"]
    ref = fed_train.main(base)
    out = fed_train.main(base + ["--transport", "socket",
                                 "--workers", "2"])
    assert out["acc_per_round"] == ref["acc_per_round"]
    assert out["upload_bytes"] == ref["upload_bytes"]
    assert out["download_bytes_per_client"] == ref["download_bytes_per_client"]
