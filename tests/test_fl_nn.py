"""TPFL-for-NN generalization (repro.fl): confidence clustering over
neural clients + masked-collective aggregation semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import masked_collectives, nn_federation
from repro.core import mlp
from repro.data import partition, synthetic


@pytest.fixture(scope="module")
def data():
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1200,
                                        jax.random.PRNGKey(0), side=10)
    return partition.partition(x, y, dcfg.n_classes, n_clients=6,
                               experiment=5, key=jax.random.PRNGKey(1),
                               n_train=40, n_test=20, n_conf=20)


def test_masked_mean_equals_cluster_mean():
    vals = jnp.arange(12.0).reshape(6, 2)
    assign = jnp.array([0, 1, 0, 2, 1, 0])
    out = masked_collectives.clustered_mean(vals, assign, 3)
    expect0 = vals[jnp.array([0, 2, 5])].mean(0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect0),
                               rtol=1e-5)
    # every member receives its own cluster's mean
    per_client = out[assign]
    np.testing.assert_allclose(np.asarray(per_client[1]),
                               np.asarray(vals[jnp.array([1, 4])].mean(0)),
                               rtol=1e-5)


def test_nn_tpfl_round_runs_and_personalizes(data):
    cfg = nn_federation.NNFedConfig(n_clients=6, rounds=2, local_epochs=2,
                                    n_hidden=32, lr=0.1)
    hist = nn_federation.run(data, cfg, jax.random.PRNGKey(0),
                             n_features=100, n_classes=10)
    assert len(hist.accuracy) == 2
    assert hist.accuracy[-1] > 0.3
    assert hist.assignments.shape == (2, 6)
    assert int(hist.assignments.max()) < 10


def test_nn_tpfl_comm_less_than_fedavg(data):
    """Selective head-row upload < full-model upload (DESIGN.md caveat:
    the saving is marginal for NNs — but must be strictly positive)."""
    cfg = nn_federation.NNFedConfig(n_clients=6, rounds=1, local_epochs=1,
                                    n_hidden=32, lr=0.1)
    hist = nn_federation.run(data, cfg, jax.random.PRNGKey(0),
                             n_features=100, n_classes=10)
    full = mlp.n_bytes(mlp.init(jax.random.PRNGKey(0), 100, 32, 10))
    assert hist.upload_bytes_per_client_round < full
