"""Documentation link/example integrity (the cheap half of the CI
``docs`` job — ``tools/check_docs.py`` additionally executes every
fenced CLI example in ``--help`` form on each push)."""
import importlib.util
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))
import check_docs  # noqa: E402


def test_docs_tree_exists_and_is_indexed():
    files = [p.relative_to(check_docs.ROOT).as_posix()
             for p in check_docs.doc_files()]
    assert "docs/architecture.md" in files
    assert "docs/async-runtime.md" in files
    assert "README.md" in files
    assert "src/repro/fl/runtime/README.md" in files
    # both READMEs link into the docs tree
    top = (check_docs.ROOT / "README.md").read_text()
    rt = (check_docs.ROOT / "src/repro/fl/runtime/README.md").read_text()
    for readme in (top, rt):
        assert "architecture.md" in readme
        assert "async-runtime.md" in readme


def test_no_dead_relative_links():
    dead = check_docs.check_links(check_docs.doc_files())
    assert dead == []


def test_fenced_cli_examples_name_importable_modules():
    """Every ``python -m X`` in the docs must resolve to a module that
    actually exists under PYTHONPATH=src (execution is the CI docs
    job's business — this pins against renames slipping through)."""
    sys.path.insert(0, str(check_docs.ROOT / "src"))
    try:
        argvs = check_docs.example_commands(check_docs.doc_files())
        mods = [a[2] for a in argvs if a[1] == "-m"]
        assert "repro.launch.fed_train" in mods      # the quickstarts
        assert "repro.launch.fed_dryrun" in mods
        for mod in mods:
            assert importlib.util.find_spec(mod) is not None, mod
    finally:
        sys.path.remove(str(check_docs.ROOT / "src"))
