"""Per-assigned-architecture smoke tests (reduced family variants):
one forward + one train step + one decode step on CPU, asserting output
shapes and no NaNs.  (Deliverable f.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.launch import steps
from repro.models import config as mcfg
from repro.models import stubs, transformer
from repro.optim import adamw


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _reduced(arch):
    return mcfg.reduced(registry.get(arch))


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_full_config_is_exact(arch):
    """The full config matches the assignment numbers (no allocation)."""
    cfg = registry.get(arch)
    assert len(cfg.layer_list()) == cfg.n_layers
    spec = {
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "granite_20b": (52, 6144, 48, 1, 24576, 49152),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "deepseek_v3_671b": (61, 7168, 128, 128, 18432, 129280),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "chameleon_34b": (48, 8192, 64, 8, 22016, 65536),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab) == spec


def test_moe_configs():
    j = registry.get("jamba_1_5_large_398b").moe
    assert (j.n_experts, j.top_k) == (16, 2)
    d = registry.get("deepseek_v3_671b").moe
    assert (d.n_experts, d.top_k, d.n_shared) == (256, 8, 1)
    g = registry.get("granite_moe_3b_a800m").moe
    assert (g.n_experts, g.top_k) == (40, 8)


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_reduced_smoke_forward_and_decode(arch, key):
    cfg = _reduced(arch)
    assert cfg.d_model <= 512 and len(cfg.layer_list()) <= 2
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = transformer.init(key, cfg)
    toks = stubs.tokens_for(cfg, jax.random.PRNGKey(1), 2, 16)
    logits, aux = jax.jit(
        lambda p, t: transformer.forward(p, cfg, tokens=t))(params, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())

    caches = transformer.init_cache(cfg, 2, 32)
    lg, caches2 = jax.jit(
        lambda p, t, c: transformer.decode_step(p, cfg, t, c))(
        params, toks[:, :1], caches)
    assert lg.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_reduced_smoke_train_step(arch, key):
    cfg = _reduced(arch)
    params = transformer.init(key, cfg)
    opt = adamw.init(params)
    step = jax.jit(steps.make_train_step(cfg))
    toks = stubs.tokens_for(cfg, jax.random.PRNGKey(2), 2, 16)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    params2, opt2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert loss == loss and loss > 0        # finite, positive CE
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2))
    assert max(delta) > 0
    assert int(opt2.step) == 1


@pytest.mark.parametrize("arch", ["yi_6b", "xlstm_350m",
                                  "granite_moe_3b_a800m"])
def test_reduced_loss_decreases(arch, key):
    """A few steps on repeated data must reduce the loss."""
    cfg = _reduced(arch)
    params = transformer.init(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    toks = stubs.tokens_for(cfg, jax.random.PRNGKey(3), 2, 16)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
