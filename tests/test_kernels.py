"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Shape sweep covers unaligned sizes (padding paths), paper-scale machines,
and both int/bool-ish dtype inputs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import clause_eval, draws, ref, ta_update

SHAPES_CLAUSE = [
    # (CM, L, B)
    (8, 32, 4),
    (300, 1568, 16),      # paper scale: 300 clauses × 784 features
    (130, 200, 7),        # unaligned everything
    (1, 128, 1),
]


@pytest.mark.parametrize("cm,L,B", SHAPES_CLAUSE)
@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("predict", [False, True])
def test_clause_outputs_kernel_vs_ref(cm, L, B, seed, predict):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    include = jax.random.bernoulli(k1, 0.1, (cm, L)).astype(jnp.int32)
    lits = jax.random.bernoulli(k2, 0.5, (B, L)).astype(jnp.int32)
    r = ref.clause_outputs_ref(include, lits, predict=predict)
    k = clause_eval.clause_outputs_pallas(include, lits, predict=predict)
    assert r.shape == k.shape
    assert (r == k).all()


@pytest.mark.parametrize("C,m,L,B", [(4, 16, 32, 8), (10, 300, 1568, 4),
                                     (3, 33, 130, 5)])
@pytest.mark.parametrize("seed", [0, 1])
def test_fused_votes_kernel_vs_ref(C, m, L, B, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    include = jax.random.bernoulli(ks[0], 0.1, (C, m, L)).astype(jnp.int32)
    lits = jax.random.bernoulli(ks[1], 0.5, (B, L)).astype(jnp.int32)
    wpol = jax.random.randint(ks[2], (C, m), -7, 8)
    r = ref.fused_votes_ref(include, lits, wpol, predict=True)
    k = clause_eval.fused_votes_pallas(include, lits, wpol, predict=True)
    assert (r == k).all()


@pytest.mark.parametrize("m,L", [(20, 32), (300, 1568), (7, 130), (256, 512)])
@pytest.mark.parametrize("seed", range(3))
def test_ta_update_kernel_vs_ref(m, L, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 7)
    ta = jax.random.randint(ks[0], (m, L), 1, 255)
    lit = jax.random.bernoulli(ks[1], 0.5, (1, L)).astype(jnp.int32)
    fired = jax.random.bernoulli(ks[2], 0.5, (m, 1)).astype(jnp.int32)
    t1 = jax.random.bernoulli(ks[3], 0.5, (m, 1)).astype(jnp.int32)
    t2 = (1 - t1) * jax.random.bernoulli(ks[4], 0.5, (m, 1)).astype(jnp.int32)
    u1 = jax.random.uniform(ks[5], (m, L))
    u2 = jax.random.uniform(ks[6], (m, L))
    args = (ta, lit, fired, t1, t2, u1, u2)
    r = ref.ta_update_ref(*args, p_inc=0.9, p_dec=0.1, n_states=127)
    k = ta_update.ta_update_pallas(*args, p_inc=0.9, p_dec=0.1, n_states=127)
    assert (r == k).all()
    assert int(k.min()) >= 1 and int(k.max()) <= 254


def test_ta_update_kernel_extreme_probs():
    m, L = 16, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 7)
    ta = jax.random.randint(ks[0], (m, L), 1, 255)
    lit = jnp.ones((1, L), jnp.int32)
    fired = jnp.ones((m, 1), jnp.int32)
    t1 = jnp.ones((m, 1), jnp.int32)
    t2 = jnp.zeros((m, 1), jnp.int32)
    u1 = jax.random.uniform(ks[5], (m, L))
    u2 = jax.random.uniform(ks[6], (m, L))
    # p_inc = 1.0 (boost_true_positive): every (fired, lit) TA moves up
    out = ta_update.ta_update_pallas(ta, lit, fired, t1, t2, u1, u2,
                                     p_inc=1.0, p_dec=0.0, n_states=127)
    expect = jnp.clip(ta + 1, 1, 254)
    assert (out == expect).all()


@pytest.mark.parametrize("C,m,L,B,N", [(4, 16, 32, 8, 3), (3, 33, 130, 5, 4)])
@pytest.mark.parametrize("seed", [0, 1])
def test_fused_votes_batched_kernel_vs_ref(C, m, L, B, N, seed):
    """The client-batched votes kernel row-for-row equals the per-client
    fused-votes reference (including unaligned shapes — no padding)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    include = jax.random.bernoulli(ks[0], 0.1, (N, C, m, L)).astype(jnp.int32)
    lits = jax.random.bernoulli(ks[1], 0.5, (N, B, L)).astype(jnp.int32)
    wpol = jax.random.randint(ks[2], (N, C, m), -7, 8)
    k = clause_eval.fused_votes_batched_pallas(include, lits, wpol,
                                               predict=True)
    for i in range(N):
        r = ref.fused_votes_ref(include[i], lits[i], wpol[i], predict=True)
        assert (r == k[i]).all()


@pytest.mark.parametrize("p", [0.2, 1.0 / 3.0, 0.8, 2.0 / 3.0, 1e-7, 1.0])
def test_int_threshold_matches_uniform_compare(p):
    """The fused epoch kernel consumes pre-compared coin flips via the
    int-domain trick (bits >> 9 < ceil(f32(p)·2²³)); pin it against the
    f32 uniform compare the reference trainer performs, including
    non-representable thresholds like 1/3 and s=3's p_inc=2/3."""
    k = jax.random.PRNGKey(0)
    a = jax.random.uniform(k, (8192,)) < p
    b = (jax.random.bits(k, (8192,), jnp.uint32) >> 9) < draws.int_threshold(p)
    assert (a == b).all()


@pytest.mark.parametrize("bt,ct,lt", [(8, 128, 128), (16, 256, 256)])
def test_clause_kernel_tile_invariance(bt, ct, lt):
    """Result must not depend on BlockSpec tiling choices."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    include = jax.random.bernoulli(k1, 0.15, (200, 300)).astype(jnp.int32)
    lits = jax.random.bernoulli(k2, 0.5, (24, 300)).astype(jnp.int32)
    base = ref.clause_outputs_ref(include, lits)
    out = clause_eval.clause_outputs_pallas(include, lits, bt=bt, ct=ct,
                                            lt=lt)
    assert (base == out).all()
