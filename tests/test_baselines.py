"""Baseline methods: mechanics + comm accounting (Table 5 machinery)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, mlp, tm
from repro.data import partition, synthetic

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=16, n_features=100,
                     n_states=63, s=5.0, T=16)
BCFG = baselines.BaselineConfig(n_clients=6, rounds=2, local_epochs=1,
                                ifca_k=3, batch=16)


@pytest.fixture(scope="module")
def data():
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1200,
                                        jax.random.PRNGKey(0), side=10)
    return partition.partition(x, y, dcfg.n_classes, n_clients=6,
                               experiment=5, key=jax.random.PRNGKey(1),
                               n_train=40, n_test=20, n_conf=20)


def test_mlp_learns(data):
    p = mlp.init(jax.random.PRNGKey(0), 100, 64, 10)
    before = float(mlp.accuracy(p, data.x_train[0], data.y_train[0]))
    p = mlp.local_train(p, data.x_train[0], data.y_train[0],
                        jax.random.PRNGKey(1), epochs=20, batch=16, lr=0.1)
    after = float(mlp.accuracy(p, data.x_train[0], data.y_train[0]))
    assert after > max(before, 0.8)


def test_fedprox_proximal_term_pulls_towards_ref():
    p = mlp.init(jax.random.PRNGKey(0), 100, 16, 10)
    ref = jax.tree.map(jnp.zeros_like, p)
    x = jnp.zeros((8, 100))
    y = jnp.zeros((8,), jnp.int32)
    base = mlp.loss_fn(p, x, y)
    prox = mlp.loss_fn(p, x, y, prox_mu=0.1, prox_ref=ref)
    assert float(prox) > float(base)


@pytest.mark.parametrize("fn_name", ["fedavg", "fedprox", "ifca", "flis"])
def test_dl_baselines_run_and_meter_comm(fn_name, data):
    fn = baselines.BASELINES[fn_name]
    hist = fn(data, BCFG, jax.random.PRNGKey(2), 100, 10)
    assert len(hist.accuracy) == BCFG.rounds
    assert all(0.0 <= a <= 1.0 for a in hist.accuracy)
    assert hist.upload_mb > 0
    pbytes = mlp.n_bytes(mlp.init(jax.random.PRNGKey(0), 100,
                                  BCFG.n_hidden, 10))
    expect_up = BCFG.rounds * BCFG.n_clients * pbytes / 1e6
    assert abs(hist.upload_mb - expect_up) < 1e-9
    if fn_name == "ifca":
        assert abs(hist.download_mb - expect_up * BCFG.ifca_k) < 1e-9


def test_fedtm_runs_and_comm_is_all_classes(data):
    hist = baselines.run_fedtm(data, TM_CFG, BCFG, jax.random.PRNGKey(3))
    assert len(hist.accuracy) == BCFG.rounds
    expect = BCFG.rounds * BCFG.n_clients * TM_CFG.n_classes \
        * TM_CFG.n_clauses * 4 / 1e6
    assert abs(hist.upload_mb - expect) < 1e-9


def test_tpfl_uploads_factor_c_less_than_fedtm():
    """TPFL uploads one class's vector; FedTM uploads all C — the paper's
    communication claim, checked as an exact formula."""
    from repro.core import federation
    fed = federation.FedConfig(n_clients=6, rounds=2, local_epochs=1)
    tpfl_up = fed.rounds * fed.n_clients * (TM_CFG.n_clauses * 4 + 4)
    fedtm_up = fed.rounds * fed.n_clients * TM_CFG.n_classes \
        * TM_CFG.n_clauses * 4
    ratio = fedtm_up / tpfl_up
    assert ratio > TM_CFG.n_classes * 0.9


def test_similarity_clusters_connected_components():
    sim = np.array([[1.0, 0.95, 0.0],
                    [0.95, 1.0, 0.0],
                    [0.0, 0.0, 1.0]])
    lab = baselines._similarity_clusters(sim, 0.9)
    assert lab[0] == lab[1] != lab[2]
