"""Dataset-ingestion subsystem tests: IDX codec properties, LEAF
roundtrips, encoding invariants, registry/mirror identity, natural
partitioning, and the golden ClientData digest.

Everything here runs offline against a tmp ``--data-dir`` (the CI
``data-offline`` job runs exactly this file with no network).
"""
import gzip
import hashlib
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import partition
from repro.data.ingest import encode, idx, leaf, natural, registry

# ---------------------------------------------------------------------------
# IDX codec: write→read roundtrip property tests
# ---------------------------------------------------------------------------

_DTYPES = (np.uint8, np.int8, np.int16, np.int32, np.float32, np.float64)


def _random_array(rng, dtype):
    ndim = int(rng.integers(1, 4))
    shape = tuple(int(rng.integers(1, 7)) for _ in range(ndim))
    a = rng.normal(scale=50.0, size=shape)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        a = np.clip(np.rint(a), info.min, info.max)
    return a.astype(dtype)


def test_idx_bytes_roundtrip_bit_exact_random_shapes():
    """decode(encode(a)) == a — every dtype code, random shapes; and the
    metered size is exactly len(buffer) (header + dims + payload)."""
    rng = np.random.default_rng(0)
    for _ in range(60):
        a = _random_array(rng, _DTYPES[int(rng.integers(len(_DTYPES)))])
        buf = idx.encode(a)
        assert len(buf) == 4 + 4 * a.ndim + a.size * a.dtype.itemsize
        out = idx.decode(buf)
        assert out.dtype == a.dtype and out.shape == a.shape
        assert (out == a).all() or \
            (np.isnan(out) == np.isnan(a)).all()  # float NaN payloads


@pytest.mark.parametrize("gz", [False, True])
def test_idx_file_roundtrip_gzip_on_off(tmp_path, gz):
    rng = np.random.default_rng(1)
    for i in range(8):
        a = _random_array(rng, _DTYPES[i % len(_DTYPES)])
        path = tmp_path / (f"a{i}.idx.gz" if gz else f"a{i}.idx")
        idx.write(path, a)
        out = idx.read(path)
        assert out.dtype == a.dtype and (out == a).all()
        # sidecar written and verified on read
        assert idx.checksum_path(path).exists()


def test_idx_gzip_sniffed_without_suffix(tmp_path):
    """A gzipped file without the .gz suffix still parses (magic sniff)."""
    a = np.arange(24, dtype=np.int16).reshape(4, 6)
    plain = tmp_path / "plain"
    plain.write_bytes(gzip.compress(idx.encode(a)))
    assert (idx.read(plain) == a).all()


def test_idx_corrupted_checksum_rejected(tmp_path):
    path = idx.write(tmp_path / "x.gz", np.arange(100, dtype=np.uint8))
    raw = bytearray(path.read_bytes())
    raw[-1] ^= 0xFF
    path.write_bytes(bytes(raw))
    with pytest.raises(idx.ChecksumError, match="checksum mismatch"):
        idx.read(path)
    # verification is the gate: skipping it surfaces the gzip/IDX error
    with pytest.raises(Exception):
        idx.read(path, verify=False)


def test_idx_malformed_rejected():
    a = np.arange(6, dtype=np.uint8)
    buf = idx.encode(a)
    with pytest.raises(idx.IDXFormatError, match="magic"):
        idx.decode(b"\x01" + buf[1:])
    with pytest.raises(idx.IDXFormatError, match="dtype code"):
        idx.decode(buf[:2] + b"\x42" + buf[3:])
    with pytest.raises(idx.IDXFormatError):
        idx.decode(buf[:-1])                     # truncated payload
    with pytest.raises(idx.IDXFormatError):
        idx.decode(buf + b"\x00")                # trailing garbage
    with pytest.raises(idx.IDXFormatError):
        idx.encode(np.arange(4, dtype=np.uint16))  # no IDX code


# ---------------------------------------------------------------------------
# LEAF shards
# ---------------------------------------------------------------------------

def test_leaf_write_read_roundtrip(tmp_path):
    rng = np.random.default_rng(3)
    users = [f"w{i}" for i in range(7)]
    xs = [rng.random((int(rng.integers(2, 9)), 16)).astype(np.float32)
          for _ in users]
    ys = [rng.integers(0, 62, size=len(x)).astype(np.int32) for x in xs]
    paths = leaf.write_shards(tmp_path, users, xs, ys, writers_per_shard=3)
    assert len(paths) == 3                       # 7 writers / 3 per shard
    pool = leaf.read_shards(tmp_path)
    assert pool.users == tuple(users)
    for i in range(len(users)):
        rows = pool.writers == i
        assert (pool.y[rows] == ys[i]).all()
        assert np.allclose(pool.x[rows], xs[i])  # repr-float JSON roundtrip
        assert (pool.x[rows] == xs[i].astype(np.float32)).all()


# ---------------------------------------------------------------------------
# encodings: thermometer invariants, quantile, jit-ability
# ---------------------------------------------------------------------------

def test_thermometer_monotone_and_level_counts():
    """Bit k is monotone in x; bits-per-pixel == thresholds passed; the
    layout is feature-major with exactly ``levels`` bits per feature."""
    levels = 5
    enc = encode.Thermometer(levels=levels)
    x = jnp.linspace(0.0, 1.0, 13)[:, None]      # (13, 1) increasing
    bits = np.asarray(enc(x))
    assert bits.shape == (13, levels)
    assert enc.out_features(7) == 7 * levels
    # monotone: a larger pixel never clears a bit a smaller one set
    assert (np.diff(bits.astype(np.int32), axis=0) >= 0).all()
    # per-pixel popcount equals the number of thresholds passed
    th = np.asarray(enc.thresholds)
    expect = (np.asarray(x) >= th[None, :]).sum(axis=1)
    assert (bits.sum(axis=1) == expect).all()
    # thermometer property: bits are a prefix (1s then 0s) per pixel
    assert (np.sort(bits, axis=1)[:, ::-1] == bits).all()


def test_quantile_fits_pool_and_balances_bits():
    rng = np.random.default_rng(5)
    pool = jnp.asarray(rng.random((400, 6)) ** 3)   # skewed pixels
    enc = encode.Quantile.fit(pool, levels=4)
    bits = np.asarray(enc(pool))
    assert bits.shape == (400, 24)
    # each fitted threshold splits the pool near its quantile
    rates = bits.reshape(400, 6, 4).mean(axis=0)
    expect = 1.0 - (np.arange(1, 5) / 5.0)
    assert np.abs(rates - expect[None, :]).max() < 0.05


def test_encodings_are_jit_able_and_composable():
    x = jnp.asarray(np.random.default_rng(6).random((5, 9)), jnp.float32)
    for enc in (encode.Booleanize(0.4), encode.Thermometer(3),
                encode.Quantile.fit(x, 2),
                encode.Pipeline((encode.Thermometer(2),))):
        eager = np.asarray(enc(x))
        jitted = np.asarray(jax.jit(enc.__call__)(x))
        assert (eager == jitted).all()
        assert eager.shape[1] == enc.out_features(9)
        assert eager.dtype == np.uint8


def test_encoding_spec_parser():
    assert encode.build("bool").threshold == 0.5
    assert encode.build("bool:0.3").threshold == 0.3
    assert encode.build("thermometer:7").levels == 7
    q = encode.build("quantile:3", pool=jnp.ones((10, 4)))
    assert q.levels == 3
    with pytest.raises(ValueError, match="unknown encoding"):
        encode.build("onehot")
    with pytest.raises(ValueError, match="needs the pool"):
        encode.build("quantile:3")


# ---------------------------------------------------------------------------
# registry + offline mirror
# ---------------------------------------------------------------------------

def test_registry_unknown_name_lists_choices():
    with pytest.raises(ValueError, match="synthmnist"):
        registry.load("mnist2", None)
    assert set(registry.SYNTH_DATASETS) <= set(registry.names())
    assert set(registry.REAL_DATASETS) <= set(registry.names())


def test_real_flavour_requires_data_dir():
    with pytest.raises(ValueError, match="file-backed"):
        registry.load("mnist", None)


def test_synth_names_are_the_single_source_of_truth():
    from repro.data import synthetic
    assert synthetic.DATASETS is registry.SYNTH_DATASETS


def test_mirror_written_and_preexisting_files_load_identically(tmp_path):
    """First load writes the mirror and parses it; second load parses
    the now pre-existing files — pools must be bit-identical (the pool
    is a pure function of the file bytes).  The in-memory synthetic
    fallback agrees too (the mirror stores the same bits as 0/255)."""
    kw = dict(side=10, n_samples=300, seed=4)
    first = registry.load("synthmnist", tmp_path, **kw)
    second = registry.load("synthmnist", tmp_path, **kw)
    memory = registry.load("synthmnist", None, **kw)
    for a, b in ((first, second), (first, memory)):
        assert (np.asarray(a.x) == np.asarray(b.x)).all()
        assert (np.asarray(a.y) == np.asarray(b.y)).all()
    assert first.n_features == 100 and first.writers is None


def test_leaf_mirror_identity_and_writer_tags(tmp_path):
    kw = dict(side=8, n_samples=300, seed=5, n_writers=9)
    first = registry.load("synthfemnist", tmp_path, **kw)
    second = registry.load("synthfemnist", tmp_path, **kw)
    assert (np.asarray(first.x) == np.asarray(second.x)).all()
    assert (np.asarray(first.writers) == np.asarray(second.writers)).all()
    assert first.n_classes == 62
    sizes = np.bincount(np.asarray(first.writers))
    assert len(sizes) == 9 and len(set(sizes.tolist())) > 1  # heterogeneous


def test_partial_idx_pair_is_rejected_not_overwritten(tmp_path):
    """A lone (possibly real) images file must never be silently paired
    with mirror-written synthetic labels — or worse, overwritten."""
    root = tmp_path / "mnist"
    target = root / "train-images-idx3-ubyte.gz"
    idx.write(target, np.zeros((3, 28, 28), np.uint8))
    before = target.read_bytes()
    with pytest.raises(FileNotFoundError, match="partial train IDX pair"):
        registry.load("mnist", tmp_path, n_samples=50, seed=0)
    assert target.read_bytes() == before        # untouched


def test_leaf_malformed_shards_rejected(tmp_path):
    users = ["wa", "wb"]
    xs = [np.zeros((2, 4), np.float32), np.ones((3, 4), np.float32)]
    ys = [np.zeros(2, np.int32), np.ones(3, np.int32)]
    leaf.write_shards(tmp_path, users, xs, ys)
    import json
    path = tmp_path / "all_data_0.json"
    shard = json.loads(path.read_text())

    missing = dict(shard, user_data={"wa": shard["user_data"]["wa"]})
    path.write_text(json.dumps(missing))
    idx.write_checksum(path)
    with pytest.raises(leaf.LeafFormatError, match="missing from"):
        leaf.read_shards(tmp_path)

    lying = dict(shard, num_samples=[2, 99])
    path.write_text(json.dumps(lying))
    idx.write_checksum(path)
    with pytest.raises(leaf.LeafFormatError, match="declares"):
        leaf.read_shards(tmp_path)


def test_ambiguous_gz_and_plain_pair_is_rejected(tmp_path):
    """A mirror .gz next to a plain real drop-in must fail loudly, not
    silently shadow one of them."""
    registry.load("synthmnist", tmp_path, side=8, n_samples=100, seed=0)
    root = tmp_path / "synthmnist"
    idx.write(root / "train-images-idx3-ubyte",
              np.zeros((2, 8, 8), np.uint8))
    with pytest.raises(FileExistsError, match="remove the one"):
        registry.load("synthmnist", tmp_path, side=8, n_samples=100,
                      seed=0)


def test_t10k_without_train_pair_refuses_mirror(tmp_path):
    """A real held-out pair with no train pair must not be silently
    completed with synthetic mirror train data."""
    root = tmp_path / "mnist"
    idx.write(root / "t10k-images-idx3-ubyte.gz",
              np.zeros((2, 28, 28), np.uint8))
    idx.write(root / "t10k-labels-idx1-ubyte.gz",
              np.zeros((2,), np.uint8))
    with pytest.raises(FileNotFoundError, match="refuses"):
        registry.load("mnist", tmp_path, n_samples=50, seed=0)
    assert not (root / "train-images-idx3-ubyte.gz").exists()


def test_partial_t10k_pair_is_rejected(tmp_path):
    registry.load("synthmnist", tmp_path, side=8, n_samples=100, seed=0)
    idx.write(tmp_path / "synthmnist" / "t10k-images-idx3-ubyte.gz",
              np.zeros((2, 8, 8), np.uint8))
    with pytest.raises(FileNotFoundError, match="partial t10k"):
        registry.load("synthmnist", tmp_path, side=8, n_samples=100,
                      seed=0)


def test_corrupted_cache_is_rejected_at_load(tmp_path):
    registry.load("synthmnist", tmp_path, side=8, n_samples=100, seed=0)
    target = tmp_path / "synthmnist" / "train-images-idx3-ubyte.gz"
    raw = bytearray(target.read_bytes())
    raw[-1] ^= 0xFF
    target.write_bytes(bytes(raw))
    with pytest.raises(idx.ChecksumError):
        registry.load("synthmnist", tmp_path, side=8, n_samples=100, seed=0)


# ---------------------------------------------------------------------------
# natural (writer-identity) partitioning
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def femnist_pool(tmp_path_factory):
    root = tmp_path_factory.mktemp("leafcache")
    return registry.load("synthfemnist", root, side=8, n_samples=600,
                         seed=6, n_writers=12)


def test_natural_partition_contract(femnist_pool):
    cd = natural.partition_writers(femnist_pool, n_clients=5, n_train=24,
                                   n_test=8, n_conf=8,
                                   key=jax.random.PRNGKey(0))
    assert cd.x_train.shape == (5, 24, femnist_pool.n_features)
    assert cd.x_conf.shape == (5, 8, femnist_pool.n_features)
    # real heterogeneous deployment sizes, summing to the pool
    sizes = np.asarray(cd.sizes)
    assert sizes.sum() == femnist_pool.x.shape[0]
    assert len(set(sizes.tolist())) > 1
    # mixtures are the true label histograms (rows sum to 1)
    assert np.allclose(np.asarray(cd.mixtures).sum(axis=1), 1.0, atol=1e-5)
    # deterministic
    cd2 = natural.partition_writers(femnist_pool, n_clients=5, n_train=24,
                                    n_test=8, n_conf=8,
                                    key=jax.random.PRNGKey(0))
    assert (np.asarray(cd.y_train) == np.asarray(cd2.y_train)).all()


def test_natural_partition_samples_stay_within_writer_group(femnist_pool):
    """Every row a client holds belongs to one of its writers — the
    non-IID structure is real, not resampled across clients."""
    n_clients = 4
    cd = natural.partition_writers(femnist_pool, n_clients=n_clients,
                                   n_train=16, n_test=8, n_conf=8,
                                   key=jax.random.PRNGKey(1))
    writers = np.asarray(femnist_pool.writers)
    x = np.asarray(femnist_pool.x)
    groups = np.array_split(np.unique(writers), n_clients)
    for i in range(n_clients):
        rows = x[np.isin(writers, groups[i])]
        for split in (cd.x_train, cd.x_test, cd.x_conf):
            for sample in np.asarray(split[i]):
                assert (rows == sample[None, :]).all(axis=1).any()


def test_natural_partition_padding_never_leaks_train_into_eval():
    """A client whose writers hold fewer rows than the budget is padded
    by wraparound — but only within the training split: no test/conf
    row may also appear in x_train (eval integrity under padding)."""
    rng = np.random.default_rng(9)
    n_writers, f = 6, 12
    # continuous unique-ish rows so byte equality == same pool row
    xs = [rng.random((int(n), f)).astype(np.float32)
          for n in (3, 5, 4, 30, 3, 6)]      # mostly tiny writers
    ys = [rng.integers(0, 5, size=len(x)).astype(np.int32) for x in xs]
    pool = registry.Pool(
        x=jnp.asarray(np.concatenate(xs)),
        y=jnp.asarray(np.concatenate(ys)),
        writers=jnp.asarray(np.concatenate(
            [np.full(len(x), w, np.int32) for w, x in enumerate(xs)])),
        n_classes=5, n_features=f, name="tiny")
    cd = natural.partition_writers(pool, n_clients=n_writers, n_train=16,
                                   n_test=8, n_conf=8,
                                   key=jax.random.PRNGKey(2))
    for i in range(n_writers):
        train = {np.asarray(s).tobytes()
                 for s in np.asarray(cd.x_train[i])}
        for split in (cd.x_test, cd.x_conf):
            for s in np.asarray(split[i]):
                assert s.tobytes() not in train, f"client {i} leaked"


def test_natural_partition_needs_enough_writers(femnist_pool):
    with pytest.raises(ValueError, match="writers"):
        natural.partition_writers(femnist_pool, n_clients=13, n_train=4,
                                  n_test=2, n_conf=2,
                                  key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="no writer identities"):
        natural.partition_writers(
            registry.load("synthmnist", None, side=8, n_samples=50),
            n_clients=2, n_train=4, n_test=2, n_conf=2,
            key=jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# golden digest: the full parse→encode→partition chain, pinned
# ---------------------------------------------------------------------------

def _digest(tree) -> str:
    h = hashlib.sha256()
    for arr in jax.tree_util.tree_leaves(tree):
        a = np.asarray(arr)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# sha256 over every ClientData leaf (dtype + shape + bytes) of the
# load("synthmnist")→dirichlet_clients chain below, as produced by the
# CPU threefry PRNG.  If a jax upgrade legitimately changes a sampler,
# regenerate with: PYTHONPATH=src python -c "from tests.test_ingest
# import _golden; print(_golden(None))"  (pass a tmp dir to pin the
# file path too).
GOLDEN_SYNTHMNIST_CLIENTDATA = (
    "9f3fdb2f746df9cb5c6e55b2ec968db4ae5387e14ec04438a29a56a2a7d8a0ee")


def _golden(data_dir) -> str:
    pool = registry.load("synthmnist", data_dir, side=10, n_samples=400,
                         seed=0)
    cd = partition.dirichlet_clients(
        pool.x, pool.y, pool.n_classes, n_clients=4, experiment=5,
        key=jax.random.PRNGKey(1), n_train=20, n_test=10, n_conf=10)
    return _digest(cd)


def test_golden_synthmnist_clientdata_digest(tmp_path):
    """load("synthmnist") → ClientData is bit-identical to the committed
    digest — through the file path (mirror write → IDX parse → encode →
    Dirichlet partition) *and* the in-memory fallback."""
    assert _golden(tmp_path) == GOLDEN_SYNTHMNIST_CLIENTDATA
    assert _golden(None) == GOLDEN_SYNTHMNIST_CLIENTDATA


# ---------------------------------------------------------------------------
# streaming ingestion: per-writer shards on demand, no pool
# ---------------------------------------------------------------------------

# sha256 over every gathered-ClientData leaf of the streaming chain
# below (load_stream → StreamingClientData.gather_clients over the full
# population) — the SAME digest the materialized chain (load →
# partition_writers) produces, pinning that on-demand shard reads
# reproduce the committed pool-backed partition bit for bit.
# Regenerate (e.g. after a legitimate sampler change) with:
#   PYTHONPATH=src python -c "from tests.test_ingest import \
#     _golden_stream; print(_golden_stream())"
GOLDEN_SYNTHFEMNIST_STREAM = (
    "5e1e8fa7b1225f2fcdc90fa00ebe01aa35968fa7cfe2fbad9509e5c2c9ee8d73")

_STREAM_KW = dict(side=8, n_samples=600, seed=6, n_writers=12)
_BUDGET = dict(n_clients=5, n_train=24, n_test=8, n_conf=8)


@pytest.fixture(scope="module")
def femnist_stream(tmp_path_factory):
    """Mirror root shared by the materialized pool (the reference) and
    the streaming writer table over the same shard files."""
    root = tmp_path_factory.mktemp("leafstream")
    pool = registry.load("synthfemnist", root, **_STREAM_KW)
    spool = registry.load_stream("synthfemnist", root, **_STREAM_KW)
    return pool, spool


def _golden_stream(root=None) -> str:
    import tempfile

    from repro.fl.store import StreamingClientData
    root = root or tempfile.mkdtemp(prefix="leafstream_golden_")
    spool = registry.load_stream("synthfemnist", root, **_STREAM_KW)
    sdata = StreamingClientData(spool, key=jax.random.PRNGKey(0),
                                **_BUDGET)
    return _digest(sdata.gather_clients(np.arange(_BUDGET["n_clients"])))


def test_streaming_gather_matches_materialized_partition(femnist_stream):
    """``StreamingClientData.gather_clients`` == ``partition_writers``
    field for field: the on-demand per-writer shard loads reproduce the
    pool-backed natural partition bit for bit — full population, and
    any subset equals the full gather sliced at its ids."""
    from repro.fl.store import StreamingClientData
    pool, spool = femnist_stream
    cd = natural.partition_writers(pool, key=jax.random.PRNGKey(0),
                                   **_BUDGET)
    sdata = StreamingClientData(spool, key=jax.random.PRNGKey(0),
                                **_BUDGET)
    full = sdata.gather_clients(np.arange(5))
    for la, lb in zip(jax.tree_util.tree_leaves(cd),
                      jax.tree_util.tree_leaves(full)):
        assert (np.asarray(la) == np.asarray(lb)).all()
    assert _digest(full) == _digest(cd)
    # the O(N) scheduler table is the partition's real size table
    assert (np.asarray(sdata.sizes) == np.asarray(cd.sizes)).all()
    sub = sdata.gather_clients(np.asarray([3, 1]))
    for la, lb in zip(jax.tree_util.tree_leaves(sub),
                      jax.tree_util.tree_leaves(full)):
        assert (np.asarray(la) == np.asarray(lb)[[3, 1]]).all()


def test_streaming_golden_digest(femnist_stream):
    """The streaming chain is bit-identical to the committed digest —
    mirror write → shard index → on-demand parse → encode → budgeted
    split, pinned against drift exactly like the synthmnist golden."""
    _, spool = femnist_stream
    from repro.fl.store import StreamingClientData
    sdata = StreamingClientData(spool, key=jax.random.PRNGKey(0),
                                **_BUDGET)
    got = _digest(sdata.gather_clients(np.arange(5)))
    assert got == GOLDEN_SYNTHFEMNIST_STREAM


def test_streaming_parses_only_needed_shards_and_never_the_pool(
        femnist_stream, monkeypatch):
    """The O(K) ingestion contract: gathering one client parses only
    the shard(s) holding its writers (counting shim on the shard
    parser), and full-pool materialization (``leaf.read_shards``) is
    never triggered."""
    from repro.fl.store import StreamingClientData
    _, spool = femnist_stream
    index = leaf.ensure_index(spool.root)      # index already built
    assert len(index["shards"]) == 2           # 12 writers, 10 per shard

    calls = []
    real_parse = leaf._parse_shard
    monkeypatch.setattr(
        leaf, "_parse_shard",
        lambda path, verify=True: (calls.append(pathlib.Path(path).name),
                                   real_parse(path, verify))[1])
    monkeypatch.setattr(
        leaf, "read_shards",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError(
            "streaming gather materialized the full pool")))

    sdata = StreamingClientData(spool, key=jax.random.PRNGKey(0),
                                **_BUDGET)
    sdata.gather_clients(np.asarray([0]))      # writers 0-2: shard 0 only
    assert calls == ["all_data_0.json"]

    calls.clear()
    sdata.gather_clients(np.asarray([4]))      # writers 10-11: shard 1
    assert calls == ["all_data_1.json"]


def test_streaming_index_staleness_is_loud_and_rebuildable(
        femnist_stream, tmp_path):
    """A shard set that drifted under an existing index fails loudly —
    a stale index would silently mis-route writer ids to the wrong
    shards — and deleting the index rebuilds it over the current
    shard set."""
    import shutil
    _, spool = femnist_stream
    root = tmp_path / "drift"
    shutil.copytree(spool.root, root)
    before = leaf.ensure_index(root)
    src = root / "all_data_1.json"
    dup = root / "all_data_2.json"
    shutil.copy(src, dup)
    shutil.copy(idx.checksum_path(src), idx.checksum_path(dup))
    with pytest.raises(leaf.LeafFormatError, match="stale"):
        leaf.read_index(root)
    (root / leaf.INDEX_NAME).unlink()
    idx.checksum_path(root / leaf.INDEX_NAME).unlink()
    after = leaf.ensure_index(root)
    assert len(after["shards"]) == len(before["shards"]) + 1


# ---------------------------------------------------------------------------
# end to end: fed_train on the offline FEMNIST mirror
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fed_train_femnist_offline_mirror_end_to_end(tmp_path):
    """Acceptance: `fed_train --dataset femnist --data-dir <cache>` runs
    on the offline mirror with writer-natural partitioning, and a rerun
    against the now pre-existing LEAF files is bit-identical."""
    from repro.launch import fed_train
    argv = ["--dataset", "femnist", "--data-dir", str(tmp_path),
            "--rounds", "2", "--clients", "4", "--clauses", "8",
            "--local-epochs", "1", "--sampling", "weighted",
            "--participation", "0.5"]
    first = fed_train.main(argv)
    second = fed_train.main(argv)        # parses pre-existing files
    assert first == second
    assert len(first["acc_per_round"]) == 2
    assert first["upload_bytes"] > 0


def test_fed_train_synthfemnist_mirror_is_writer_natural(tmp_path):
    """The LEAF flavours route through the natural partitioner: the
    partition sizes driving weighted sampling are the real
    heterogeneous per-writer counts."""
    from repro.data.ingest import registry as datasets
    from repro.data.ingest import natural as nat
    pool = datasets.load("synthfemnist", tmp_path, side=8, n_samples=400,
                         seed=0, n_writers=10)
    cd = nat.partition_writers(pool, n_clients=5, n_train=8, n_test=4,
                               n_conf=4, key=jax.random.PRNGKey(1))
    sizes = np.asarray(cd.sizes)
    assert len(set(sizes.tolist())) > 1


# ---------------------------------------------------------------------------
# fetch-and-verify (offline: mirror files + file:// URLs, no network)
# ---------------------------------------------------------------------------

def test_fetch_place_verifies_then_lands_in_registry_cache(tmp_path):
    """verify→place drops a file into exactly the layout the registry
    reads, with the .sha256 sidecar idx.read checks — exercised against
    an offline-mirror-written archive standing in for a real download."""
    from repro.data.ingest import fetch, mirror
    from repro.data.ingest import registry as datasets
    staging = tmp_path / "staging"
    mirror.write_idx_mirror(staging, "synthmnist", 60, 8, 0)
    for f in staging.glob("*.sha256"):
        f.unlink()                      # a raw download has no sidecar
    cache = tmp_path / "cache"
    for name in (mirror.IMAGES_FILE, mirror.LABELS_FILE):
        src = staging / name
        digest = fetch.sha256_path(src)
        dest = fetch.place(src, cache, "synthmnist", name, expect=digest)
        assert dest.exists() and idx.checksum_path(dest).exists()
    pool = datasets.load("synthmnist", cache, side=8, n_samples=60, seed=0)
    assert int(pool.x.shape[0]) == 60


def test_fetch_wrong_digest_places_nothing(tmp_path):
    from repro.data.ingest import fetch, mirror
    staging = tmp_path / "staging"
    mirror.write_idx_mirror(staging, "synthmnist", 40, 8, 0)
    src = staging / mirror.IMAGES_FILE
    cache = tmp_path / "cache"
    with pytest.raises(fetch.FetchError, match="sha256 mismatch"):
        fetch.place(src, cache, "synthmnist", mirror.IMAGES_FILE,
                    expect="0" * 64)
    assert not (cache / "synthmnist").exists()
    assert src.exists()                 # the suspect file stays put


def test_fetch_refuses_to_overwrite_cache_files(tmp_path):
    from repro.data.ingest import fetch, mirror
    mirror.write_idx_mirror(tmp_path / "mnist", "synthmnist", 40, 8, 0)
    staging = tmp_path / "staging"
    mirror.write_idx_mirror(staging, "synthmnist", 40, 8, 1)
    src = staging / mirror.IMAGES_FILE
    with pytest.raises(fetch.FetchError, match="refusing to overwrite"):
        fetch.place(src, tmp_path, "mnist", mirror.IMAGES_FILE,
                    expect=fetch.sha256_path(src))


def test_fetch_downloads_via_file_urls_offline(tmp_path, monkeypatch):
    """The full fetch path — download, pinned-digest verify, place —
    without a socket: file:// URL overrides point at mirror-written
    archives whose digests are pinned for the test."""
    from repro.data.ingest import fetch, mirror
    staging = tmp_path / "staging"
    mirror.write_idx_mirror(staging, "synthmnist", 40, 8, 0)
    urls, digests = {}, {}
    for f in sorted(staging.glob("*.gz")):
        urls[f.name] = f.as_uri()
        digests[f.name] = fetch.sha256_path(f)
    monkeypatch.setitem(fetch.ARCHIVES, "mnist", digests)
    cache = tmp_path / "cache"
    placed = fetch.fetch("mnist", cache, urls=urls)
    assert sorted(p.name for p in placed) == sorted(digests)
    for p in placed:
        assert p.parent == cache / "mnist"
        fetch.verify_file(p, digests[p.name])
    # resumable: a second call is a no-op, not an overwrite error
    assert fetch.fetch("mnist", cache, urls=urls) == []


def test_fetch_unknown_dataset_lists_choices(tmp_path):
    from repro.data.ingest import fetch
    with pytest.raises(ValueError, match="femnist"):
        fetch.fetch("femnist", tmp_path)


def test_fetch_rejects_mirror_standins_masquerading_as_real(tmp_path):
    """Resume must re-verify: an offline-mirror stand-in sitting under
    the real archive's cache name is never silently accepted as the
    pinned real archive."""
    from repro.data.ingest import fetch, mirror
    mirror.write_idx_mirror(tmp_path / "mnist", "synthmnist", 40, 8, 0)
    with pytest.raises(fetch.FetchError, match="stand-in"):
        fetch.fetch("mnist", tmp_path, urls={})
