"""MoE dispatch correctness: capacity and ragged impls vs the dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe
from repro.models.config import LayerSpec, MoEConfig, ModelConfig


def _cfg(E=4, k=2, shared=0):
    return ModelConfig(
        name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab=97,
        moe=MoEConfig(n_experts=E, top_k=k, d_expert=16, n_shared=shared),
        segments=((1, (LayerSpec(ffn="moe"),)),))


@pytest.mark.parametrize("E,k,shared", [(4, 2, 0), (8, 2, 1), (4, 1, 0)])
@pytest.mark.parametrize("seed", [0, 1])
def test_capacity_impl_matches_dense_when_no_drops(E, k, shared, seed):
    cfg = _cfg(E, k, shared)
    params = moe.moe_init(jax.random.PRNGKey(seed), cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(seed + 9), (2, 8, 32))
    # capacity_factor = E → every slot fits, zero drops
    y_cap, aux_c = moe.moe_apply(params, x, cfg, capacity_factor=float(E))
    y_ref, aux_r = moe.moe_apply_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_cap), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux_c), float(aux_r), rtol=1e-5)


@pytest.mark.parametrize("seed", [0, 1])
def test_ragged_impl_matches_dense(seed):
    cfg = _cfg(4, 2)
    params = moe.moe_init(jax.random.PRNGKey(seed), cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(seed + 3), (2, 8, 32))
    y_rag, _ = moe.moe_apply(params, x, cfg, impl="ragged")
    y_ref, _ = moe.moe_apply_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_rag), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)


def test_capacity_drops_tokens_when_overloaded():
    """With capacity_factor ≪ 1 some slots must drop (output differs)."""
    cfg = _cfg(4, 2)
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.float32), params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    y_low, _ = moe.moe_apply(params, x, cfg, capacity_factor=0.25)
    y_ref, _ = moe.moe_apply_dense_ref(params, x, cfg)
    assert not np.allclose(np.asarray(y_low), np.asarray(y_ref), atol=1e-3)


def test_router_weights_renormalized():
    cfg = _cfg(4, 2)
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    w, ids, aux = moe._route(params, x.reshape(-1, 32), cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert float(aux) >= 0.0


def test_aux_loss_balanced_router_near_one_coef():
    """Perfectly uniform routing gives aux ≈ coef (switch normalization)."""
    cfg = _cfg(4, 1)
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)
    # zero router → uniform probs → top-1 ties broken deterministically,
    # f_e concentrates; use random-but-tiny logits over many tokens instead
    params = dict(params)
    params["router"] = params["router"] * 1e-3
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 64, 32))
    _, _, aux = moe._route(params, x.reshape(-1, 32), cfg)
    coef = cfg.moe.router_aux_coef
    assert 0.5 * coef < float(aux) < 3.0 * coef
