"""Serving-plane contract tests: registry integrity (verify-then-place,
immutable versions, loud rejection of every tamper mode), atomic warm
swap under an in-flight request, and the serving-parity pin — served
predictions bit-identical to the offline predictions of each client's
resolved model, for ref and pallas TM backends, resident and mmap
stores."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.core import tm
from repro.data import partition, synthetic
from repro.data.ingest import idx
from repro.fl.runtime import (CodecConfig, Engine, RuntimeConfig,
                              SchedulerConfig, TPFLStrategy, checkpointing)
from repro.fl.serve import (ModelRegistry, RegistryError, ServeTelemetry,
                            ServingPlane)

TM_CFG = tm.TMConfig(n_classes=10, n_clauses=12, n_features=100,
                     n_states=63, s=5.0, T=20)
N_CLIENTS = 6


@pytest.fixture(scope="module")
def data():
    x, y, dcfg = synthetic.make_dataset("synthmnist", 1200,
                                        jax.random.PRNGKey(0), side=10)
    return partition.partition(
        x, y, dcfg.n_classes, n_clients=N_CLIENTS, experiment=5,
        key=jax.random.PRNGKey(1), n_train=30, n_test=15, n_conf=15)


def _strategy():
    return TPFLStrategy(TM_CFG, local_epochs=1)


def _train(data, ckpt_dir, **cfg_kw):
    """Two TPFL rounds with a checkpoint at round 2; returns the final
    engine state (the population the checkpoint holds)."""
    engine = Engine(_strategy(), data, RuntimeConfig(
        rounds=2, checkpoint_dir=str(ckpt_dir), checkpoint_every=2,
        **cfg_kw))
    state, _ = engine.run(jax.random.PRNGKey(0))
    return state


def _like(data, **cfg_kw):
    """A fresh serving-side engine + its structure template, keyed with
    the training chain's k_init."""
    engine = Engine(_strategy(), data, RuntimeConfig(**cfg_kw))
    k_init, _ = jax.random.split(jax.random.PRNGKey(0))
    return engine, engine.init(k_init)


@pytest.fixture(scope="module")
def trained(tmp_path_factory, data):
    ckpt_dir = tmp_path_factory.mktemp("ckpt")
    state = _train(data, ckpt_dir)
    return {"ckpt_dir": ckpt_dir, "state": state}


def _fresh_registry(tmp_path, trained) -> ModelRegistry:
    reg = ModelRegistry(tmp_path / "registry")
    reg.publish(checkpointing.latest(trained["ckpt_dir"]))
    return reg


# ---------------------------------------------------------------------------
# registry: verify-then-place + failure modes
# ---------------------------------------------------------------------------

def test_registry_publish_pull_roundtrip(tmp_path, data, trained):
    reg = _fresh_registry(tmp_path, trained)
    assert reg.versions() == [2]
    assert reg.latest() == 2
    assert idx.checksum_path(reg.path_for(2)).is_file()
    _, like = _like(data)
    pulled = reg.pull(2, like)
    for a, b in zip(jax.tree.leaves(pulled),
                    jax.tree.leaves(trained["state"])):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_registry_pull_rejects_corrupted_payload(tmp_path, data, trained):
    reg = _fresh_registry(tmp_path, trained)
    path = reg.path_for(2)
    raw = bytearray(path.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    path.write_bytes(bytes(raw))
    _, like = _like(data)
    with pytest.raises(idx.ChecksumError, match="mismatch"):
        reg.pull(2, like)


def test_registry_pull_rejects_flipped_sidecar(tmp_path, data, trained):
    reg = _fresh_registry(tmp_path, trained)
    side = idx.checksum_path(reg.path_for(2))
    side.write_text("0" * 64 + "\n")
    _, like = _like(data)
    with pytest.raises(idx.ChecksumError, match="mismatch"):
        reg.pull(2, like)


def test_registry_pull_requires_sidecar(tmp_path, data, trained):
    """idx.verify_bytes silently passes when no sidecar exists — the
    registry must treat a missing sidecar as corruption instead."""
    reg = _fresh_registry(tmp_path, trained)
    idx.checksum_path(reg.path_for(2)).unlink()
    _, like = _like(data)
    with pytest.raises(RegistryError, match="sidecar"):
        reg.pull(2, like)


def test_registry_pull_rejects_missing_version(tmp_path, data, trained):
    reg = _fresh_registry(tmp_path, trained)
    _, like = _like(data)
    with pytest.raises(RegistryError, match="not in the registry"):
        reg.pull(7, like)


def test_registry_versions_are_immutable(tmp_path, trained):
    reg = _fresh_registry(tmp_path, trained)
    src = checkpointing.latest(trained["ckpt_dir"])
    # identical bytes: publish is idempotent
    assert reg.publish(src) == 2
    # different bytes under the same version name: refused
    clash = tmp_path / "clash" / src.name
    clash.parent.mkdir()
    clash.write_bytes(src.read_bytes() + b"\x00")
    with pytest.raises(RegistryError, match="immutable"):
        reg.publish(clash)


def test_registry_pull_rejects_layout_drift(tmp_path, data, trained):
    """A checkpoint published under one strategy config must not decode
    into another: 12-clause state vs a 20-clause serving template."""
    reg = _fresh_registry(tmp_path, trained)
    drifted = Engine(
        TPFLStrategy(tm.TMConfig(n_classes=10, n_clauses=20,
                                 n_features=100, n_states=63,
                                 s=5.0, T=20), local_epochs=1),
        data, RuntimeConfig())
    like = drifted.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="layout"):
        reg.pull(2, like)


def test_restore_layout_error_names_leaf_and_both_layouts(tmp_path):
    """Satellite pin: the layout-drift error is actionable — it names
    the offending leaf path and both sides' dtype+shape."""
    path = tmp_path / "round_000001.msgpack"
    ckpt.save(path, {"server": {"slots": np.zeros((4, 8), np.float32)}})
    with pytest.raises(ValueError) as ei:
        ckpt.restore(path, {"server": {"slots":
                                       np.zeros((8, 8), np.float32)}})
    msg = str(ei.value)
    assert "'server/slots'" in msg
    assert "float32(4, 8)" in msg and "float32(8, 8)" in msg
    # dtype drift alone is named the same way (no silent casting)
    with pytest.raises(ValueError) as ei:
        ckpt.restore(path, {"server": {"slots":
                                       np.zeros((4, 8), np.int32)}})
    msg = str(ei.value)
    assert "'server/slots'" in msg
    assert "float32(4, 8)" in msg and "int32(4, 8)" in msg
    # and the checkpointing wrapper still labels it a layout failure
    engine_msg = pytest.raises(
        ValueError, checkpointing.restore, path,
        {"server": {"slots": np.zeros((8, 8), np.float32)}})
    assert "layout" in str(engine_msg.value)


# ---------------------------------------------------------------------------
# warm swap: atomic under an in-flight request
# ---------------------------------------------------------------------------

def _publish_successor(reg, trained, round_idx=4):
    """Forge a later-round version with visibly different slot rows."""
    state = trained["state"]
    succ = state._replace(
        round_idx=jnp.asarray(round_idx, jnp.int32),
        server=state.server._replace(slots=state.server.slots + 1.0))
    src = pathlib.Path(reg.root) / "staging"
    src.mkdir(exist_ok=True)
    path = checkpointing.save(src, succ)
    return reg.publish(path)


def test_warm_swap_is_atomic_under_inflight_request(tmp_path, data,
                                                    trained):
    """A version landing between resolve and gather must not mix into
    the in-flight batch: it is served entirely by the old version; the
    *next* request is served entirely by the new one."""
    reg = _fresh_registry(tmp_path, trained)
    engine, like = _like(data)
    ids = np.arange(N_CLIENTS)
    x = np.asarray(data.x_test)[:, 0]

    baseline = ServingPlane(engine.strategy, reg, like)
    baseline.refresh()
    want_old = baseline.predict(ids, x)

    def land_new_version(plane):
        if reg.latest() == 2:            # fire once, mid-first-request
            _publish_successor(reg, trained)
            assert plane.refresh()       # swap while request in flight

    tel = ServeTelemetry(tmp_path / "tel")
    plane = ServingPlane(engine.strategy, reg, like, telemetry=tel,
                         resolve_hook=land_new_version)
    plane.refresh()
    got = plane.predict(ids, x)
    # in-flight request: old version, bit-for-bit — never a blend
    assert plane.last_served_version == 2
    assert (got == want_old).all()
    # next request: entirely the new version
    plane.predict(ids, x)
    assert plane.last_served_version == 4
    events = [e for e in _read_events(tel.events_path)
              if e["event"] == "swap"]
    assert [(e["from_version"], e["to_version"]) for e in events] \
        == [(None, 2), (2, 4)]


def _read_events(path):
    from repro.fl.obs import events
    return events.read_events(path)


def test_refresh_never_downgrades(tmp_path, data, trained):
    reg = _fresh_registry(tmp_path, trained)
    engine, like = _like(data)
    plane = ServingPlane(engine.strategy, reg, like)
    assert plane.refresh() is True
    assert plane.refresh() is False          # same version: no swap
    _publish_successor(reg, trained)
    assert plane.refresh() is True
    assert plane.active_version == 4


def test_predict_without_active_version_is_loud(tmp_path, data, trained):
    reg = ModelRegistry(tmp_path / "empty")
    engine, like = _like(data)
    plane = ServingPlane(engine.strategy, reg, like)
    with pytest.raises(RegistryError, match="no active model"):
        plane.predict(np.arange(2), np.asarray(data.x_test)[:2, 0])


# ---------------------------------------------------------------------------
# serving parity: served == offline, ref/pallas × resident/mmap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tm_backend", ["ref", "pallas"])
@pytest.mark.parametrize("store", ["resident", "mmap"])
def test_serving_parity_bitwise(tmp_path, data, trained, store,
                                tm_backend):
    """For every client in a mixed-cluster batch, the served prediction
    equals the offline prediction of that client's resolved model —
    bit-for-bit.  The mmap cell trains at 50% participation so the
    batch mixes personalized (spilled) rows with deterministic-init
    fallbacks, and both kinds must hold parity."""
    if store == "mmap":
        cfg_kw = dict(client_store="mmap",
                      store_dir=str(tmp_path / "store"),
                      scheduler=SchedulerConfig(participation=0.5))
        _train(data, tmp_path / "ckpt", **cfg_kw)
        ckpt_dir = tmp_path / "ckpt"
        serve_kw = dict(client_store="mmap",
                        store_dir=str(tmp_path / "store"),
                        tm_backend=tm_backend)
    else:
        ckpt_dir = trained["ckpt_dir"]
        serve_kw = dict(tm_backend=tm_backend)
    reg = ModelRegistry(tmp_path / "registry")
    reg.publish(checkpointing.latest(ckpt_dir))
    engine, like = _like(data, **serve_kw)
    plane = ServingPlane(engine.strategy, reg, like, store=engine.store)
    plane.refresh()

    # mixed-cluster batch with duplicates: every client, two samples
    ids = np.concatenate([np.arange(N_CLIENTS), np.arange(N_CLIENTS)])
    x_test = np.asarray(data.x_test)
    x = np.concatenate([x_test[:, 0], x_test[:, 1]])
    got = plane.predict(ids, x)

    state = reg.pull(plane.active_version, like)
    rows, written = plane._resolve_rows(state, np.arange(N_CLIENTS))
    if store == "mmap":
        assert 0 < written.sum() < N_CLIENTS    # both kinds in the batch
    cfg = engine.strategy.tm_cfg                # use_kernel per backend
    for j, c in enumerate(ids):
        row = jax.tree.map(lambda a: a[c], rows)
        want = np.asarray(tm.predict(row, x[j:j + 1], cfg))[0]
        assert int(got[j]) == int(want)
