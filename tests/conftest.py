"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 host devices.
"""
import jax
import pytest


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield


def seeds(n):
    return [jax.random.PRNGKey(i) for i in range(n)]
