"""Property tests for the host-side client store
(:mod:`repro.fl.store`) — the O(K) working set under the mmap engine.

The store's contract mirrors the IDX ingest cache's verify-then-place
discipline (``tests/test_ingest.py``): every spilled row carries a
sha256 digest recorded with the bytes, every gather re-proves it, and a
flipped byte anywhere — row payload or manifest — fails loudly instead
of training on silently corrupt state.  On top sit the engine-facing
properties: gather∘spill is the identity (including across reopen),
never-sampled rows are untouched holes, concurrent readers agree, and
the strategies' O(K) cohort-init hooks reproduce the full init exactly.
"""
import json
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tm
from repro.data.ingest import idx
from repro.fl.runtime import FedTMStrategy, TPFLStrategy
from repro.fl.store import ClientStore
from repro.fl.store.client_store import (_DIGEST_BYTES, MANIFEST_NAME,
                                         WRITTEN_NAME)

N = 32
TEMPLATE = {"b": np.zeros((5,), np.float32),
            "w": np.zeros((3, 4), np.int32)}


def _init_fn(ids):
    """Deterministic per-client rows — the fault-in contract."""
    ids = np.asarray(ids, np.int64)
    return {
        "b": (ids[:, None] * 0.5 + np.arange(5)).astype(np.float32),
        "w": (ids[:, None, None]
              + np.arange(12).reshape(3, 4)).astype(np.int32),
    }


def _rand_rows(rng, k):
    return {"b": rng.normal(size=(k, 5)).astype(np.float32),
            "w": rng.integers(-9, 9, size=(k, 3, 4)).astype(np.int32)}


def _assert_rows_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        assert la.dtype == lb.dtype
        assert (np.asarray(la) == np.asarray(lb)).all()


def test_gather_spill_roundtrip_identity_across_reopen(tmp_path):
    """spill → gather is the identity, and stays the identity through
    flush + a fresh ClientStore over the same directory (durability)."""
    rng = np.random.default_rng(0)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    ids = np.asarray([3, 0, 17, 8])
    rows = _rand_rows(rng, ids.size)
    store.spill(ids, rows)
    _assert_rows_equal(store.gather(ids), rows)
    store.flush()

    again = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    _assert_rows_equal(again.gather(ids), rows)
    assert again.written_count() == ids.size
    # overwrite one id: last spill wins, digest re-recorded
    newer = _rand_rows(rng, 1)
    again.spill(np.asarray([17]), newer)
    _assert_rows_equal(
        jax.tree_util.tree_map(lambda a: a[np.asarray(ids) == 17],
                               again.gather(ids)), newer)


def test_unwritten_rows_fault_in_from_init_fn(tmp_path):
    """A gather mixing spilled and never-spilled ids overlays the store
    rows on the deterministic init — fault-in never touches disk (rows
    materialize only when the engine spills them back)."""
    rng = np.random.default_rng(1)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    store.spill(np.asarray([4]), _rand_rows(rng, 1))
    out = store.gather(np.asarray([2, 4, 9]))
    expect = _init_fn(np.asarray([2, 9]))
    for leaf, want in (("b", expect["b"]), ("w", expect["w"])):
        assert (np.asarray(out[leaf])[[0, 2]] == want).all()
    assert store.written_count() == 1          # fault-in is read-only

    bare = ClientStore(tmp_path, N, TEMPLATE)  # no init_fn
    with pytest.raises(ValueError, match="never spilled"):
        bare.gather(np.asarray([9]))


def test_flipped_row_byte_is_rejected_loudly(tmp_path):
    """The IDX-cache discipline on rows: one flipped byte in a spilled
    row's file region makes the next gather of that client raise
    ``ChecksumError`` — other clients stay readable."""
    rng = np.random.default_rng(2)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    ids = np.asarray([5, 11])
    store.spill(ids, _rand_rows(rng, ids.size))
    store.flush()

    leaf0 = store.manifest["leaves"][0]        # "b": 20 bytes per row
    row_nbytes = np.dtype(leaf0["dtype"]).itemsize * int(
        np.prod(leaf0["shape"]))
    path = tmp_path / (leaf0["slug"] + ".bin")
    raw = bytearray(path.read_bytes())
    raw[11 * row_nbytes] ^= 0xFF               # client 11's first byte
    path.write_bytes(raw)

    reopened = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    with pytest.raises(idx.ChecksumError, match="checksum mismatch"):
        reopened.gather(np.asarray([11]))
    reopened.gather(np.asarray([5]))           # neighbour unaffected
    # verify=False is the explicit opt-out, mirroring the ingest cache
    unchecked = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn,
                            verify=False)
    unchecked.gather(np.asarray([11]))


def test_tampered_manifest_is_rejected_at_open(tmp_path):
    """The manifest carries a sha256 sidecar: editing it in place fails
    the open, and an honest manifest for a *different* template fails
    the layout check."""
    ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    man_path = tmp_path / MANIFEST_NAME
    man = json.loads(man_path.read_text())
    man["n_clients"] = N + 1
    man_path.write_text(json.dumps(man, indent=2, sort_keys=True))
    with pytest.raises(idx.ChecksumError):
        ClientStore(tmp_path, N, TEMPLATE)
    # re-sign the tampered manifest: now the layout mismatch is loud
    idx.write_checksum(man_path)
    with pytest.raises(ValueError, match="different engine configuration"):
        ClientStore(tmp_path, N, TEMPLATE)


def test_concurrent_gathers_are_deterministic(tmp_path):
    """Eight threads gathering overlapping id sets see identical bytes —
    reads are lock-free over the mapped files, and the io counters
    stay exact under the lock."""
    rng = np.random.default_rng(3)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    ids = np.arange(0, N, 2)
    rows = _rand_rows(rng, ids.size)
    store.spill(ids, rows)

    def snap(_):
        out = store.gather(ids)
        return [np.asarray(a).copy()
                for a in jax.tree_util.tree_leaves(out)]

    with ThreadPoolExecutor(max_workers=8) as pool:
        results = list(pool.map(snap, range(16)))
    for got in results[1:]:
        for la, lb in zip(results[0], got):
            assert (la == lb).all()
    assert store.io_read_bytes == 16 * ids.size * store.row_nbytes


def test_never_sampled_rows_stay_byte_identical(tmp_path):
    """Spilling one cohort leaves every other client's file region
    bit-for-bit untouched (still sparse holes) and unwritten in the
    bitmap — the eviction contract: dropping a never-sampled client
    costs nothing because it never materialized."""
    rng = np.random.default_rng(4)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    cohort = np.asarray([1, 7, 30])
    untouched = np.setdiff1d(np.arange(N), cohort)

    def region_bytes():
        out = []
        for spec in store.manifest["leaves"]:
            nb = np.dtype(spec["dtype"]).itemsize * int(
                np.prod(spec["shape"]))
            raw = (tmp_path / (spec["slug"] + ".bin")).read_bytes()
            out.append([raw[i * nb:(i + 1) * nb] for i in untouched])
        return out

    store.spill(cohort, _rand_rows(rng, cohort.size))
    store.flush()
    before = region_bytes()
    assert all(not any(r) for per_leaf in before for r in per_leaf)

    # more rounds of gather/spill over the same cohort
    for _ in range(3):
        bundle = store.gather(cohort)
        bundle = jax.tree_util.tree_map(
            lambda a: (a + 1).astype(a.dtype), bundle)
        store.spill(cohort, bundle)
    store.flush()
    assert region_bytes() == before
    written = np.frombuffer((tmp_path / WRITTEN_NAME).read_bytes(),
                            np.uint8)
    assert (written[untouched] == 0).all()
    assert store.written_count() == cohort.size


def test_io_counters_meter_exact_bytes(tmp_path):
    """``io_read_bytes`` counts only rows read back from disk (fault-in
    is free), ``io_written_bytes`` counts payload + digest + bitmap per
    spilled row — the gauges the round reports and ``BENCH_client_scale``
    publish."""
    rng = np.random.default_rng(5)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    assert store.io_read_bytes == store.io_written_bytes == 0

    store.gather(np.arange(6))                 # all fault-in: no I/O
    assert store.io_read_bytes == 0 and store.io_written_bytes == 0

    store.spill(np.arange(6), _rand_rows(rng, 6))
    assert store.io_written_bytes == 6 * (store.row_nbytes
                                          + _DIGEST_BYTES + 1)
    store.gather(np.arange(8))                 # 6 from disk, 2 fault-in
    assert store.io_read_bytes == 6 * store.row_nbytes


def test_out_of_range_ids_and_template_drift_fail_loudly(tmp_path):
    rng = np.random.default_rng(6)
    store = ClientStore(tmp_path, N, TEMPLATE, init_fn=_init_fn)
    with pytest.raises(ValueError, match="out of range"):
        store.gather(np.asarray([N]))
    with pytest.raises(ValueError, match="does not match"):
        store.spill(np.asarray([0]),
                    {"b": np.zeros((1, 5), np.float64),   # wrong dtype
                     "w": np.zeros((1, 3, 4), np.int32)})
    store.spill(np.asarray([0]), _rand_rows(rng, 1))
    store.flush()
    with pytest.raises(ValueError, match="different"):
        ClientStore(tmp_path, N + 1, TEMPLATE)  # drifted client count


@pytest.mark.parametrize("make", [
    lambda: TPFLStrategy(tm.TMConfig(n_classes=4, n_clauses=6,
                                     n_features=20, n_states=63,
                                     s=5.0, T=10), local_epochs=1),
    lambda: FedTMStrategy(tm.TMConfig(n_classes=4, n_clauses=6,
                                      n_features=20, n_states=63,
                                      s=5.0, T=10), local_epochs=1),
])
def test_cohort_init_hooks_match_full_init(make):
    """The O(K) contract behind the mmap engine's fault-in:
    ``init_cohort(key, ids, n)`` == ``init(key, n)[0][ids]`` bit for
    bit for any id subset, and ``init_server`` reproduces the full
    init's server part — so a store row regenerated on demand equals
    the row a resident engine would hold."""
    strat = make()
    key, n = jax.random.PRNGKey(42), 12
    full_cs, full_server = strat.init(key, n)
    ids = np.asarray([0, 5, 11, 5])            # repeats allowed
    cohort = strat.init_cohort(key, jnp.asarray(ids), n)
    for la, lb in zip(jax.tree_util.tree_leaves(cohort),
                      jax.tree_util.tree_leaves(
                          jax.tree_util.tree_map(lambda a: a[ids],
                                                 full_cs))):
        assert (np.asarray(la) == np.asarray(lb)).all()
    server = strat.init_server(key, n)
    for la, lb in zip(jax.tree_util.tree_leaves(server),
                      jax.tree_util.tree_leaves(full_server)):
        assert (np.asarray(la) == np.asarray(lb)).all()
