"""Data loader + LR schedule unit tests."""
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import loader
from repro.models import config as mcfg
from repro.optim import schedules


def test_token_batcher_contract():
    cfg = mcfg.reduced(registry.get("yi_6b"))
    b = loader.TokenBatcher(cfg, batch=2, seq_len=16, seed=0)
    out = b(0)
    assert out["tokens"].shape == (2, 16)
    assert out["labels"].shape == (2, 16)
    # labels are next-token shifted
    assert (out["labels"][:, :-1] == out["tokens"][:, 1:]).all()
    # deterministic per step, distinct across steps
    assert (b(0)["tokens"] == out["tokens"]).all()
    assert (b(1)["tokens"] != out["tokens"]).any()


def test_federated_sampler_permutation_without_replacement():
    s = loader.FederatedSampler(n_samples=32, batch=8, seed=0)
    b = s.batches(client=0, rnd=0, epoch=0)
    assert b.shape == (4, 8)
    assert sorted(np.asarray(b).ravel().tolist()) == list(range(32))
    # different client/round/epoch → different order
    b2 = s.batches(client=1, rnd=0, epoch=0)
    assert (np.asarray(b) != np.asarray(b2)).any()


def test_federated_sampler_is_deterministic_per_tuple():
    """The contract the ingest pipeline relies on (see the
    FederatedSampler docstring): the per-epoch order is a pure function
    of (seed, client, rnd, epoch) — identical across instances and call
    orders — and each tuple component selects an independent stream."""
    a = loader.FederatedSampler(n_samples=40, batch=10, seed=7)
    b = loader.FederatedSampler(n_samples=40, batch=10, seed=7)
    # same tuple → same order, across instances and call interleavings
    o1 = np.asarray(a.epoch_order(client=3, rnd=2, epoch=1))
    _ = a.epoch_order(client=0, rnd=0, epoch=0)     # unrelated draw
    o2 = np.asarray(b.epoch_order(client=3, rnd=2, epoch=1))
    assert (o1 == np.asarray(a.epoch_order(client=3, rnd=2, epoch=1))).all()
    assert (o1 == o2).all()
    # every tuple coordinate (and the seed) perturbs the order
    assert (o1 != np.asarray(a.epoch_order(client=4, rnd=2, epoch=1))).any()
    assert (o1 != np.asarray(a.epoch_order(client=3, rnd=3, epoch=1))).any()
    assert (o1 != np.asarray(a.epoch_order(client=3, rnd=2, epoch=2))).any()
    c = loader.FederatedSampler(n_samples=40, batch=10, seed=8)
    assert (o1 != np.asarray(c.epoch_order(client=3, rnd=2, epoch=1))).any()


def test_schedule_warmup_and_decay():
    cfg = schedules.ScheduleConfig(peak_lr=1.0, warmup_steps=10,
                                   total_steps=110, end_lr_frac=0.1)
    lr0 = float(schedules.lr_at(jnp.asarray(0), cfg))
    lr5 = float(schedules.lr_at(jnp.asarray(5), cfg))
    lr10 = float(schedules.lr_at(jnp.asarray(10), cfg))
    lr_end = float(schedules.lr_at(jnp.asarray(110), cfg))
    assert lr0 == 0.0
    assert abs(lr5 - 0.5) < 1e-6
    assert abs(lr10 - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-6
    # monotone decay after warmup
    lrs = [float(schedules.lr_at(jnp.asarray(t), cfg))
           for t in range(10, 111, 10)]
    assert all(a >= b - 1e-9 for a, b in zip(lrs, lrs[1:]))


def test_schedule_linear_and_constant():
    lin = schedules.ScheduleConfig(peak_lr=2.0, warmup_steps=0,
                                   total_steps=100, end_lr_frac=0.5,
                                   kind="linear")
    assert abs(float(schedules.lr_at(jnp.asarray(50), lin)) - 1.5) < 1e-6
    const = schedules.ScheduleConfig(peak_lr=2.0, warmup_steps=0,
                                     kind="constant")
    assert float(schedules.lr_at(jnp.asarray(9999), const)) == 2.0
